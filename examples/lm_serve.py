"""Serve a small LM with batched requests: prefill + jit-compiled decode
loop with greedy/temperature sampling and EOS masking (the production
decode path of repro.serve.engine, single-host scale).

Run:  PYTHONPATH=src python examples/lm_serve.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.registry import build_model
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = dataclasses.replace(ARCHS["gemma3-1b"].SMOKE, vocab=512)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {model.n_params() / 1e6:.2f}M params, "
          f"sliding window {cfg.sliding_window} @ 1:{cfg.global_every} global")

    engine = Engine(model, params, max_seq=128,
                    cfg=ServeConfig(max_new_tokens=16, temperature=0.8))
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (4, 12), 0, cfg.vocab, jnp.int32)
    out = engine.generate(prompts, jax.random.PRNGKey(2))
    for i, row in enumerate(out):
        toks = row.tolist()
        print(f"  request {i}: prompt={toks[:12]} -> generated={toks[12:]}")
    print("batched decode OK (4 requests x 16 tokens)")


if __name__ == "__main__":
    main()
