"""End-to-end driver (paper Sec. VII): train a clamped-ReLU CNN on the
synthetic digit set, convert it to an m-TTFS CSNN, evaluate both, then
quantize to 16/8-bit saturating datapaths and evaluate again.

Run:  PYTHONPATH=src python examples/train_csnn.py [--steps 400]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.csnn_paper import FULL
from repro.core.conversion import (ann_accuracy, fit_ann, normalize_params,
                                   quantize_params, quantized_threshold,
                                   snn_accuracy)
from repro.core.csnn import init_params
from repro.data.synthetic import synth_digits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--n-train", type=int, default=3000)
    ap.add_argument("--n-eval", type=int, default=300)
    args = ap.parse_args()

    cfg = FULL
    print("1) generating synthetic digit data (MNIST stand-in; offline container)")
    xtr, ytr = synth_digits(args.n_train, seed=0)
    xte, yte = synth_digits(args.n_eval, seed=1)

    print(f"2) training clamped-ReLU CNN for {args.steps} steps")
    params = init_params(jax.random.PRNGKey(0), cfg)
    params = fit_ann(params, cfg, xtr, ytr, steps=args.steps, log_every=100)
    acc_ann = ann_accuracy(params, cfg, xte, yte)
    print(f"   ANN accuracy: {100 * acc_ann:.1f}%")

    print("3) converting to SNN (data-based threshold balancing, V_t = 1)")
    params = normalize_params(params, jnp.asarray(xtr[:256]), cfg)
    acc_snn = snn_accuracy(params, cfg, xte, yte, capacity=400)
    print(f"   m-TTFS SNN accuracy (T={cfg.t_steps}): {100 * acc_snn:.1f}% "
          f"(gap {100 * (acc_ann - acc_snn):+.2f}pp)")

    for bits in (16, 8):
        conv = {k: v for k, v in params.items() if k.startswith("conv")}
        qp, spec = quantize_params(conv, bits, v_t=cfg.v_t)
        qp.update({k: v for k, v in params.items() if k.startswith("fc")})
        cfg_q = dataclasses.replace(cfg, v_t=quantized_threshold(cfg.v_t, spec))
        acc_q = snn_accuracy(qp, cfg_q, xte, yte, capacity=400, sat_bits=bits)
        print(f"4) int{bits} saturating datapath: {100 * acc_q:.1f}% "
              f"(scale {spec.scale:.5f}, V_t_int {cfg_q.v_t})")


if __name__ == "__main__":
    main()
