"""Train a small LM (scaled-down stablelm family) for a few hundred steps
on the synthetic token stream, with checkpoint/restart through the
fault-tolerant loop.  Demonstrates the framework's training path end to
end on one host; the same code drives the 512-chip mesh via
repro.launch.train.

Run:  PYTHONPATH=src python examples/lm_train.py [--steps 200]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.synthetic import TokenStream
from repro.models.registry import build_model
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        ARCHS["stablelm-3b"].SMOKE, n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab=2048)
    model = build_model(cfg)
    print(f"model: {cfg.name} scaled to {model.n_params() / 1e6:.1f}M params")

    ts = TokenStream(vocab=cfg.vocab, seed=0)
    data = lambda step: {k: jnp.asarray(v) for k, v in
                         ts.batch(step, batch_size=8, seq_len=128).items()}
    state, hist = run(
        model, data,
        LoopConfig(total_steps=args.steps, ckpt_every=100, log_every=20,
                   ckpt_dir=args.ckpt_dir),
        AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                    weight_decay=0.01),
        jax.random.PRNGKey(0))
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  ({h['sec']:.2f}s)")
    print(f"final step: {int(state.step)}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
