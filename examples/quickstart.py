"""Quickstart: event-driven spiking inference on one image.

Shows the paper's full pipeline on a single sample:
input -> m-TTFS multi-threshold encoding -> AEQ compaction -> event-driven
convolution (Algorithm 1) -> OR-max-pool -> spike-integrating classifier,
and verifies bit-exactness against the dense frame-based oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.csnn_paper import FULL as cfg
from repro.core.aeq import build_aeq
from repro.core.csnn import encode_input, init_params, snn_apply, snn_apply_dense
from repro.data.synthetic import synth_digits


def main():
    print(f"CSNN: {cfg.layers}, T={cfg.t_steps} time steps (m-TTFS)")
    images, labels = synth_digits(1, seed=42)
    img = jnp.asarray(images)

    spikes = encode_input(img, cfg)[0]  # (T, 28, 28, 1)
    per_step = np.asarray(spikes.sum(axis=(1, 2, 3)))
    print(f"input spikes per time step: {per_step.tolist()} "
          f"(sparsity {100 * (1 - spikes.mean()):.1f}%)")

    q = build_aeq(spikes[2, :, :, 0], capacity=784)
    print(f"AEQ at t=2: {int(q.count)} events, first 5 (interlaced order): "
          f"{np.asarray(q.coords[:5]).tolist()}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    logits, stats = snn_apply(params, spikes, cfg, capacity=784)
    logits_dense = snn_apply_dense(params, spikes, cfg)
    print(f"event-driven logits argmax: {int(jnp.argmax(logits))}; "
          f"dense-oracle match: {bool(jnp.allclose(logits, logits_dense, atol=1e-4))}")
    for li, st in enumerate(stats):
        print(f"  layer {li + 1}: input sparsity {100 * float(st.in_sparsity):.1f}%, "
              f"events/step {np.asarray(st.in_spike_counts).sum(axis=1).tolist()}")


if __name__ == "__main__":
    main()
