"""Paper Table IV/V (accuracy columns): ANN vs converted m-TTFS SNN
accuracy at float32 / 16-bit / 8-bit weights.

Dataset note: the container is offline, so (Fashion-)MNIST is replaced by
the procedural synth-digits set (recorded in EXPERIMENTS.md).  The claim
under validation is the *conversion property* — SNN accuracy within ~1%
of the source ANN, surviving 8/16-bit quantization (paper: 98.3% @8bit vs
99.2% ANN-ish references; Fashion-MNIST 88.9% @16bit).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.conversion import (ann_accuracy, quantize_params,
                                   quantized_threshold, snn_accuracy)

from .common import emit, trained_csnn


def main():
    cfg, params, (xtr, ytr, xte, yte) = trained_csnn()
    n_eval = 400  # CPU-budget-friendly; deterministic subset
    xe, ye = xte[:n_eval], yte[:n_eval]

    acc_ann = ann_accuracy(params, cfg, xe, ye)
    emit("table4/ann_float32", 0.0, f"acc={100 * acc_ann:.1f}%")

    acc_snn = snn_accuracy(params, cfg, xe, ye, capacity=400)
    emit("table4/snn_float32", 0.0,
         f"acc={100 * acc_snn:.1f}%;gap={100 * (acc_ann - acc_snn):.2f}pp")

    for bits in (16, 8):
        qp, spec = quantize_params(
            {k: v for k, v in params.items() if k.startswith("conv")}, bits,
            v_t=cfg.v_t)
        # FC head stays float (classification unit is out of scope, paper V-A)
        qp = {**qp, **{k: v for k, v in params.items() if k.startswith("fc")}}
        cfg_q = dataclasses.replace(cfg, v_t=quantized_threshold(cfg.v_t, spec))
        acc_q = snn_accuracy(qp, cfg_q, xe, ye, capacity=400, sat_bits=bits)
        emit(f"table4/snn_int{bits}", 0.0,
             f"acc={100 * acc_q:.1f}%;gap_vs_ann={100 * (acc_ann - acc_q):.2f}pp")


if __name__ == "__main__":
    main()
