"""Paper Table III: per-layer input activation sparsity vs PE utilization.

Sparsity comes from running real samples through the converted CSNN
(fraction of zero activations feeding each conv layer).  PE utilization
uses the cycle-level model of the 4-stage pipeline with the paper's
stall sources (hazards on column switches, empty queue columns, wind-up)
driven by the REAL event streams in interlaced AEQ order.

Paper reference points (first MNIST validation sample):
  sparsity 93/98/98 %, utilization 72/58/56 %.

Beyond-paper extension: the same event streams through the P-parallel
interlaced conv unit (the design ``event_par`` plans execute — up to P
same-column hazard-free events per cycle).  Cycle counts shrink by up to
P; lane utilization drops where column segments do not fill whole groups
— the parallel-design trade-off Table III quantifies.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.aeq import build_aeq
from repro.core.csnn import ConvSpec, encode_input
from repro.core.pipeline_sim import simulate_layer
from repro.core.scheduler import run_conv_layer

from .common import emit, trained_csnn


def main():
    cfg, params, (_, _, xte, yte) = trained_csnn()
    x = encode_input(jnp.asarray(xte[:1]), cfg)[0]  # (T, H, W, 1) first sample
    hw = cfg.input_hw
    layer_no = 0
    for idx, spec in enumerate(cfg.layers):
        if not isinstance(spec, ConvSpec):
            break
        layer_no += 1
        x_np = np.asarray(x, dtype=bool)
        sparsity = 1.0 - x_np.mean()
        t_steps, c_in = x_np.shape[0], x_np.shape[3]
        evs = []
        for t in range(t_steps):
            row = []
            for c in range(c_in):
                q = build_aeq(jnp.asarray(x_np[t, :, :, c]),
                              capacity=x_np.shape[1] * x_np.shape[2])
                row.append(np.asarray(q.coords)[np.asarray(q.valid)])
            evs.append(row)
        rep = simulate_layer(evs, c_out=spec.channels, fmap_hw=hw)
        emit(f"table3/layer{layer_no}", 0.0,
             f"sparsity={100 * sparsity:.1f}%;pe_util={100 * rep.pe_utilization:.1f}%;"
             f"hazard_stalls={rep.hazard_stalls};empty_cycles={rep.empty_queue_cycles}")
        for par in (4, 8):
            rp = simulate_layer(evs, c_out=spec.channels, fmap_hw=hw,
                                parallelism=par)
            emit(f"table3/layer{layer_no}_par{par}", 0.0,
                 f"lane_util={100 * rp.pe_utilization:.1f}%;"
                 f"cycles_speedup={rep.total_cycles / rp.total_cycles:.2f}x;"
                 f"hazard_stalls={rp.hazard_stalls}")
        p = params[f"conv{idx}"]
        x, _ = run_conv_layer(x, p["w"], p["b"], cfg.v_t, capacity=784,
                              pool=spec.pool)
        if spec.pool:
            hw = (-(-hw[0] // spec.pool), -(-hw[1] // spec.pool))


if __name__ == "__main__":
    main()
