"""Paper Table I: throughput/efficiency vs degree of parallelism.

The FPGA replicates whole processing units (AEQs + conv cores +
thresholding units) xP.  The TPU analogue sweeps the two replication
axes of our implementation: ``channel_block`` (output channels per
MemPot pass — intra-unit lanes) and sample batching via vmap (unit
replication).  We report wall-clock throughput [samples/s] on this CPU
host (relative scaling is the claim; absolute FPS belongs to the FPGA)
plus the cycle-model FPS at the paper's 333 MHz for the faithful
comparison with Table I.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csnn import encode_input, snn_apply
from repro.core.pipeline_sim import simulate_layer, throughput_fps

from .common import emit, timeit, trained_csnn


def cycle_model_fps(cfg, params, images) -> float:
    """Cycle-accurate FPS of the x1 FPGA configuration on our CSNN."""
    from repro.core.aeq import build_aeq
    from repro.core.csnn import ConvSpec

    spikes = np.asarray(encode_input(jnp.asarray(images[:1]), cfg))[0]  # (T,H,W,1)
    total = 0
    x = spikes
    _, stats = snn_apply(params, jnp.asarray(spikes), cfg, capacity=784)
    hw = cfg.input_hw
    conv_idx = 0
    for spec in cfg.layers:
        if not isinstance(spec, ConvSpec):
            continue
        st = stats[conv_idx]
        counts = np.asarray(st.in_spike_counts)  # (T, C_in)
        evs = [[np.zeros((int(c), 2), np.int64) for c in t_row] for t_row in counts]
        rep = simulate_layer(evs, c_out=spec.channels, fmap_hw=hw)
        total += rep.total_cycles
        if spec.pool:
            hw = (-(-hw[0] // spec.pool), -(-hw[1] // spec.pool))
        conv_idx += 1
    return 333e6 / max(total, 1)


def main():
    cfg, params, (xtr, ytr, xte, yte) = trained_csnn()
    img = jnp.asarray(xte[:8])
    spikes = encode_input(img, cfg)

    # parallelism sweep: channel_block x batch
    base_us = None
    for cb in [1, 2, 4, 8, 16]:
        fn = jax.jit(jax.vmap(lambda s, cb=cb: snn_apply(
            params, s, cfg, capacity=256, channel_block=cb, collect_stats=False)))
        us = timeit(fn, spikes)
        per_sample = us / spikes.shape[0]
        if base_us is None:
            base_us = per_sample
        emit(f"table1/channel_block_x{cb}", per_sample,
             f"speedup={base_us / per_sample:.2f};samples_per_s={1e6 / per_sample:.0f}")

    for b in [1, 2, 4, 8]:
        fn = jax.jit(jax.vmap(lambda s: snn_apply(
            params, s, cfg, capacity=256, channel_block=8, collect_stats=False)))
        sp = encode_input(jnp.asarray(xte[:b]), cfg)
        us = timeit(fn, sp)
        emit(f"table1/batch_x{b}", us / b, f"samples_per_s={1e6 * b / us:.0f}")

    fps = cycle_model_fps(cfg, params, xte)
    emit("table1/cycle_model_fps_x1", 1e6 / fps,
         f"fps={fps:.0f};paper_x1=3077")


if __name__ == "__main__":
    main()
