"""Streaming DVS admission (ISSUE 6): incremental AEQ ingestion vs the
per-frame sort path.

A serving step admits raw (t, y, x, polarity) address events for a
(T, C, H, W) input window.  The legacy binned path scatters them into
dense frames and re-compacts with ``build_aeq_batched`` — one fused
O(HW log HW) ``sort_key_val`` per admission.  The streaming path
(``aeq.append_events_batched`` + ``aeq.stream_queues``) scatters the
same events straight into the 9 interlace-column banks and finalizes
with exclusive cumulative ranks — O(HW), no sort, bit-exact queues
(coords, valid, count, column segments; truncation included — asserted
below on every timed input, and property-tested in
tests/test_streaming.py).

Rows sweep the offered event rate (events per pixel-bin-channel); the
figure of merit is ``vs_binned`` — streaming admission must be cheaper
than the sort at every rate (asserted).  A final pair of rows runs the
whole chunk step (``snn_step_chunk``) from banks vs from dense frames:
the downstream conv-unit work is identical, so the delta is the
admission cost seen end to end.  A third ``chunk_step_tuned`` row lets
the measured autotuner (``repro.tune``) pick the stream finalization —
rank compaction vs a frame rebuild + sort — per geometry, so the small
fields where the fused sort wins stop regressing the streamed row.

``--json`` (via benchmarks.run) writes the rows to BENCH_streaming.json
— the machine-readable streaming-admission trajectory tracked across
PRs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.csnn_paper import SMOKE
from repro.core.aeq import (StreamChunk, StreamState, append_events_batched,
                            build_aeq_batched, init_stream_state,
                            stream_frames, stream_queues)
from repro.core.csnn import init_params, init_state, snn_step_chunk
from repro.core.plan import plan_network

from .common import emit, timeit, write_bench_json

HW = (28, 28)        # the paper's input field
T_BINS = 5           # paper T
CHANNELS = 2         # 2-polarity DVS
BATCH = 8            # admission batch (engine slot bucket)
CAPACITY = 256       # AEQ depth, matching the table5 serving rows


def _random_events(rate: float, buffer: int, seed: int) -> StreamChunk:
    """(BATCH, buffer, 4) random event chunks at ``rate`` events per
    (pixel, bin, channel) — duplicates allowed, exactly like a sensor
    re-firing inside a bin."""
    h, w = HW
    rng = np.random.default_rng(seed)
    n = int(rate * h * w * T_BINS * CHANNELS)
    if not 0 < n <= buffer:
        raise ValueError(f"rate {rate} -> {n} events outside (0, {buffer}]")
    ev = np.full((BATCH, buffer, 4), -1, np.int32)
    for b in range(BATCH):
        ev[b, :n, 0] = rng.integers(0, T_BINS, n)
        ev[b, :n, 1] = rng.integers(0, h, n)
        ev[b, :n, 2] = rng.integers(0, w, n)
        ev[b, :n, 3] = rng.integers(0, CHANNELS, n)
    return StreamChunk(events=jnp.asarray(ev),
                       num=jnp.full((BATCH,), n, jnp.int32))


def _assert_queues_equal(qa, qb, label: str) -> None:
    for name, a, b in zip(qa._fields, qa, qb):
        if a is None and b is None:
            continue
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"{label}: queue field {name} diverged from the binned path"


def main(json_out: bool = False):
    h, w = HW
    buffer = h * w * T_BINS * CHANNELS  # deep enough for every swept rate

    # ---- admission kernels: identical input (a raw event chunk), identical
    # output (finalized AEQs for every (slot, bin, channel)); only the
    # compaction differs.  Both include their scatter — the comparison is
    # admission end to end, not sort vs cumsum in isolation.
    def admit_binned(chunk: StreamChunk):
        t, y, x, p = (chunk.events[..., k] for k in range(4))
        ok = ((jnp.arange(chunk.buffer) < chunk.num[..., None])
              & (t >= 0) & (t < T_BINS) & (y >= 0) & (y < h)
              & (x >= 0) & (x < w) & (p >= 0) & (p < CHANNELS))
        t = jnp.where(ok, t, T_BINS)
        frames = jnp.zeros((BATCH, T_BINS, CHANNELS, h, w), jnp.bool_)
        frames = frames.at[
            jnp.arange(BATCH)[:, None], t, p, y, x].max(ok, mode="drop")
        return build_aeq_batched(frames, CAPACITY)

    def admit_stream(chunk: StreamChunk):
        state = init_stream_state(HW, T_BINS, CHANNELS, lead=(BATCH,))
        state = append_events_batched(state, chunk, HW)
        return stream_queues(state, CAPACITY, HW)

    binned_fn = jax.jit(admit_binned)
    stream_fn = jax.jit(admit_stream)

    speedups = []
    for rate, tag in [(0.02, "sparse2"), (0.08, "rate8"), (0.25, "dense25")]:
        chunk = _random_events(rate, buffer, seed=int(rate * 1000))
        qb, qs = binned_fn(chunk), stream_fn(chunk)
        _assert_queues_equal(qs, qb, f"streaming/{tag}")
        us_b = timeit(binned_fn, chunk, iters=5) / BATCH
        us_s = timeit(stream_fn, chunk, iters=5) / BATCH
        n = int(chunk.num[0])
        emit(f"streaming/binned_sort_{tag}", us_b,
             f"events={n};batch={BATCH};capacity={CAPACITY}")
        speedup = us_b / us_s
        speedups.append(speedup)
        # the binned sort path is this table's dense baseline, so
        # vs_binned doubles as the vs_dense trajectory tag
        emit(f"streaming/append_{tag}", us_s,
             f"events={n};batch={BATCH};capacity={CAPACITY};"
             f"vs_binned={speedup:.2f}x;vs_dense={speedup:.2f}x")
    # geomean over the sweep, not per-rate: the win is structural (cumsum
    # vs sort) but small enough at 28x28 that a single-rate timing can
    # drown in scheduler noise on a busy CI host
    geomean = float(np.prod(speedups)) ** (1.0 / len(speedups))
    assert geomean > 1.0, (
        f"streaming admission must beat the per-frame sort path, got "
        f"geomean {geomean:.2f}x over {[f'{s:.2f}' for s in speedups]}")

    # ---- end to end: one whole chunk step from banks vs from the dense
    # frames of the SAME ingested events (SMOKE net, 2-polarity input).
    # Downstream conv-unit work is identical and the logits/state pytrees
    # are asserted bit-exact; the row delta is pure admission cost.
    from dataclasses import replace
    cfg = replace(SMOKE, input_channels=CHANNELS)
    plan = plan_network(cfg, capacity=64, channel_block=8, batch_tile=BATCH,
                        ingest=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    hw0 = cfg.input_hw
    rng = np.random.default_rng(7)
    banks = jnp.asarray(
        rng.random((BATCH, cfg.t_steps, CHANNELS, 9,
                    -(-hw0[0] // 3), -(-hw0[1] // 3))) < 0.1)
    stream = StreamState(banks=banks)
    # (B, T, C, H, W) -> the (B, T, H, W, C) layout snn_step_chunk takes
    frames = jnp.transpose(stream_frames(stream, hw0), (0, 1, 3, 4, 2))

    step_stream = jax.jit(lambda st, sp: snn_step_chunk(
        params, st, sp, cfg, plan))
    step_binned = jax.jit(lambda st, sp: snn_step_chunk(
        params, st, sp, cfg, plan))
    state0 = init_state(params, cfg, plan, BATCH)
    out_s = step_stream(state0, stream)
    out_b = step_binned(state0, frames)
    for ls, lb in zip(jax.tree_util.tree_leaves(out_s),
                      jax.tree_util.tree_leaves(out_b)):
        assert np.array_equal(np.asarray(ls), np.asarray(lb)), \
            "streamed chunk step diverged from the frame-binned step"
    us_s = timeit(step_stream, state0, stream) / BATCH
    us_b = timeit(step_binned, state0, frames) / BATCH
    emit("streaming/chunk_step_binned", us_b,
         f"batch={BATCH};T={cfg.t_steps}")
    emit("streaming/chunk_step_streamed", us_s,
         f"batch={BATCH};T={cfg.t_steps};vs_binned={us_b / us_s:.2f}x;"
         f"vs_dense={us_b / us_s:.2f}x")

    # ---- measured-tuned streamed step: the tuner times both stream
    # finalizations head to head on this geometry (rank-compaction vs a
    # scatter-to-frames + sort rebuild — at SMOKE field sizes the fused
    # sort can win, which is exactly the chunk_step_streamed gap above)
    # and pins the winner in the plan, alongside the per-layer kernel
    # variants.  Bit-exact by construction: streamed and frame-binned
    # admission under the tuned plan are asserted leaf-identical.
    plan_tuned = plan_network(cfg, capacity=64, channel_block=8,
                              batch_tile=BATCH, ingest=True,
                              tune="measured",
                              cache_path="results/plan_cache.json")
    step_tuned = jax.jit(lambda st, sp: snn_step_chunk(
        params, st, sp, cfg, plan_tuned))
    state0_t = init_state(params, cfg, plan_tuned, BATCH)
    out_ts = step_tuned(state0_t, stream)
    out_tb = step_tuned(state0_t, frames)
    for ls, lb in zip(jax.tree_util.tree_leaves(out_ts),
                      jax.tree_util.tree_leaves(out_tb)):
        assert np.array_equal(np.asarray(ls), np.asarray(lb)), \
            "tuned streamed chunk step diverged from the frame-binned step"

    def exec_sig(p):
        return (p.chunk_steps, tuple(
            (lp.capacity, lp.channel_block, lp.event_par, lp.block_e,
             lp.resolve_variant("jax"), lp.stream_finalize)
            for lp in p.layers))

    if exec_sig(plan_tuned) == exec_sig(plan):
        us_t, vs_streamed = us_s, 1.0
    else:
        us_t = timeit(step_tuned, state0_t, stream) / BATCH
        us_s_ref = us_s
        vs_streamed = us_s_ref / us_t
        for _ in range(2):  # re-measure interleaved before calling a loss
            if vs_streamed >= 1.0:
                break
            us_s_ref = min(us_s_ref, timeit(step_stream, state0, stream)
                           / BATCH)
            us_t = min(us_t, timeit(step_tuned, state0_t, stream) / BATCH)
            vs_streamed = us_s_ref / us_t
    assert vs_streamed >= 1.0, (
        f"tuned streamed step must not lose to the default streamed step, "
        f"got {vs_streamed:.2f}x")
    emit("streaming/chunk_step_tuned", us_t,
         f"finalize={plan_tuned.layers[0].resolve_stream_finalize()};"
         f"vs_streamed={vs_streamed:.2f}x;vs_binned={us_b / us_t:.2f}x;"
         f"vs_dense={us_b / us_t:.2f}x")

    if json_out:
        write_bench_json("streaming")


if __name__ == "__main__":
    main(json_out="--json" in __import__("sys").argv[1:])
