"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV rows (see each module's docstring for
the paper table it reproduces).

Optional argv filters select a subset by table name, e.g.
``python -m benchmarks.run table5`` — used by CI as a smoke invocation.
``--json`` additionally writes ``BENCH_<table>.json`` per selected table
that supports it (table5, and table6_streaming which writes
``BENCH_streaming.json``) — the machine-readable perf trajectory CI
archives as an artifact.
"""
from __future__ import annotations

import inspect
import sys
import traceback


def main(argv=None) -> None:
    from . import (table1_parallelism, table2_roofline,
                   table3_sparsity_utilization, table4_accuracy,
                   table5_throughput, table6_streaming)

    modules = (table4_accuracy, table3_sparsity_utilization,
               table1_parallelism, table5_throughput, table2_roofline,
               table6_streaming)
    args = list(sys.argv[1:] if argv is None else argv)
    flags = {a for a in args if a.startswith("--")}
    unknown = flags - {"--json"}
    if unknown:
        print(f"unknown flags {sorted(unknown)}; supported: --json",
              file=sys.stderr)
        sys.exit(2)
    wanted = [a for a in args if not a.startswith("--")]
    if wanted:
        selected = [m for m in modules
                    if any(w in m.__name__ for w in wanted)]
        if not selected:
            print(f"no benchmark matches {wanted}; have "
                  f"{[m.__name__ for m in modules]}", file=sys.stderr)
            sys.exit(2)
        modules = tuple(selected)

    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        try:
            kwargs = {}
            if ("--json" in flags
                    and "json_out" in inspect.signature(mod.main).parameters):
                kwargs["json_out"] = True
            mod.main(**kwargs)
        except Exception:
            failures += 1
            print(f"{mod.__name__},0.0,ERROR")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
