"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV rows (see each module's docstring for
the paper table it reproduces)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (table1_parallelism, table2_roofline,
                   table3_sparsity_utilization, table4_accuracy,
                   table5_throughput)

    print("name,us_per_call,derived")
    failures = 0
    for mod in (table4_accuracy, table3_sparsity_utilization,
                table1_parallelism, table5_throughput, table2_roofline):
        try:
            mod.main()
        except Exception:
            failures += 1
            print(f"{mod.__name__},0.0,ERROR")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
