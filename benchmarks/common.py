"""Shared benchmark utilities: timing, CSV + JSON emission, cached CSNN
training.

Every ``emit`` row is also recorded in-process; ``write_bench_json``
then dumps one table's rows (median throughput + the derived config
string) to ``BENCH_<table>.json`` so the perf trajectory is
machine-readable across PRs — CI runs ``benchmarks.run table5 --json``,
fails if the file is missing, and uploads it as an artifact.
"""
from __future__ import annotations

import json
import math
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = Path(__file__).resolve().parent.parent / "results"

# rows tag their dense-relative speedup as "vs_dense=<x>x" inside the
# derived string; write_bench_json folds them into one summary number
_VS_DENSE = re.compile(r"vs_dense=([0-9]+(?:\.[0-9]+)?)x")

# every emit() lands here; write_bench_json() snapshots one table's rows
_ROWS: list[dict] = []


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock microseconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return 1e6 * sorted(times)[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(float(us_per_call), 1),
                  "derived": derived})


def write_bench_json(table: str, path: str | Path | None = None) -> Path:
    """Write ``BENCH_<table>.json`` with every row emitted for ``table``.

    Rows are matched by the ``<table>/`` name prefix; the file carries
    enough environment context (jax version, backend) to compare the
    trajectory across PRs without re-deriving it from CI logs, plus a
    top-level ``geomean_vs_dense``: the geometric mean of every row's
    ``vs_dense=<x>x`` derived tag (``None`` if no row carries one) — the
    one-number perf trajectory of the event pipeline against its dense
    baseline.
    """
    rows = [r for r in _ROWS if r["name"].startswith(f"{table}/")]
    ratios = [float(m.group(1)) for r in rows
              if (m := _VS_DENSE.search(r.get("derived", "")))]
    geomean = (round(math.exp(sum(math.log(x) for x in ratios)
                              / len(ratios)), 3)
               if ratios and all(x > 0 for x in ratios) else None)
    out = Path(path) if path is not None else Path.cwd() / f"BENCH_{table}.json"
    out.write_text(json.dumps({
        "table": table,
        "geomean_vs_dense": geomean,
        "rows": rows,
        "env": {"jax": jax.__version__, "backend": jax.default_backend(),
                "device_count": jax.device_count()},
    }, indent=2) + "\n")
    print(f"# wrote {out} ({len(rows)} rows, "
          f"geomean_vs_dense={geomean})")
    return out


def trained_csnn(steps: int = 400, n_train: int = 3000, seed: int = 0):
    """Train (or load cached) paper-CSNN on synth digits; returns
    (cfg, float_params, train/test arrays)."""
    from repro.configs.csnn_paper import FULL as cfg
    from repro.core.conversion import fit_ann, normalize_params
    from repro.core.csnn import init_params
    from repro.data.synthetic import synth_digits

    cache = RESULTS / "csnn_params.npz"
    xtr, ytr = synth_digits(n_train, seed=seed)
    xte, yte = synth_digits(1000, seed=seed + 1)
    if cache.exists():
        raw = np.load(cache)
        params = {}
        for k in raw.files:
            layer, leaf = k.rsplit("/", 1)
            params.setdefault(layer, {})[leaf] = jnp.asarray(raw[k])
        return cfg, params, (xtr, ytr, xte, yte)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    params = fit_ann(params, cfg, xtr, ytr, steps=steps, log_every=0)
    params = normalize_params(params, jnp.asarray(xtr[:256]), cfg)
    cache.parent.mkdir(parents=True, exist_ok=True)
    np.savez(cache, **{f"{layer}/{leaf}": np.asarray(v)
                       for layer, d in params.items() for leaf, v in d.items()})
    return cfg, params, (xtr, ytr, xte, yte)
