"""Render the dry-run cell JSONs into the EXPERIMENTS.md roofline table
and rank hillclimb candidates.

  PYTHONPATH=src python -m benchmarks.roofline_report [--tag baseline]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(tag: str = "baseline") -> list[dict]:
    cells = []
    for f in sorted(RESULTS.glob(f"*__{tag}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def fmt_t(sec: float) -> str:
    return f"{sec * 1e3:.0f}" if sec < 99 else f"{sec:.1f}k"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.tag)
    rows = []
    print("| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | bottleneck "
          "| useful FLOPs | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in sorted(cells, key=lambda c: (c["arch"], SHAPE_ORDER.index(c["shape"]),
                                          c["mesh"])):
        key = f'| {c["arch"]} | {c["shape"]} | {c["mesh"]} '
        if c["status"] == "skipped":
            print(key + f'| — | — | — | skipped: {c["reason"][:48]} | — | — |')
            continue
        if c["status"] != "ok":
            print(key + f'| ERR | {c["error"][:60]} |')
            continue
        r = c["roofline"]
        tc, tm, tx = r["t_compute"], r["t_memory"], r["t_collective"]
        dom = max(tc, tm, tx)
        # roofline fraction: compute term / dominant term — how close the
        # step is to being limited by the MXU rather than memory/wire
        frac = tc / dom if dom else 0.0
        ratio = r["useful_flops_ratio"]
        print(key + f'| {fmt_t(tc)} | {fmt_t(tm)} | {fmt_t(tx)} | {r["bottleneck"]} '
              f'| {ratio:.2f} | {frac:.2f} |')
        rows.append((c["arch"], c["shape"], c["mesh"], tc, tm, tx, frac))

    print("\n-- hillclimb candidates (single-pod) --")
    single = [r for r in rows if r[2] == "single"]
    worst = sorted(single, key=lambda r: r[6])[:5]
    print("worst roofline fraction:")
    for r in worst:
        print(f"  {r[0]} x {r[1]}: frac {r[6]:.3f} (c {fmt_t(r[3])} m {fmt_t(r[4])} "
              f"x {fmt_t(r[5])} ms)")
    coll = sorted(single, key=lambda r: -(r[5] / max(r[3] + r[4] + r[5], 1e-12)))[:5]
    print("most collective-bound:")
    for r in coll:
        print(f"  {r[0]} x {r[1]}: t_coll {fmt_t(r[5])} ms "
              f"({100 * r[5] / (r[3] + r[4] + r[5]):.0f}% of total)")


if __name__ == "__main__":
    main()
