"""Paper Table V: throughput/latency of event-driven vs frame-based
processing, and the core scaling claim — processing time scales with the
number of spikes (queue occupancy), not with the frame size.

We sweep input sparsity, calibrate the AEQ capacity per sparsity level
(exactly how the queue BRAM would be sized), and time event-driven
inference against the dense frame-based baseline.  The figure of merit is
the slope: event-mode time follows capacity ~ spike count; dense-mode
time is flat.

Beyond-paper rows: the batched event pipeline (``snn_apply_batched``) vs
``vmap`` over the single-sample path vs the dense baseline — the batched
rows are the serving configuration and must be at least as fast per
sample as vmap (amortized queue compaction + batch-wide early exit) —
plus the memory-interlaced event-parallel pipeline (``event_par``
autotuned per layer: banked MemPot tiles, whole hazard-free columns
applied per step; bit-exact vs the sequential batched row and asserted
faster), the per-layer-planned pipeline (``plan_network`` capacities,
the padded-slot reduction recorded in the derived column), the async
micro-batching serving engine (``serve.csnn_engine``, requests submitted
one at a time and flushed on batch/deadline thresholds), — under a
bursty Poisson arrival trace — continuous batching (slot-level refill,
``t_chunk``-granular admission) vs the run-to-completion engine on the
identical trace (bit-exact logits, higher observed throughput), and the
``wide_5x5`` parametric-geometry row: the ``csnn_wide`` config's 5x5
first layer run through the identical event pipeline, bit-exact vs the
dense frame-based oracle (asserted).

``--json`` (via benchmarks.run) writes the rows to BENCH_table5.json —
the machine-readable throughput trajectory tracked across PRs.
"""
from __future__ import annotations

import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import csnn_wide
from repro.core.aeq import calibrate_capacity
from repro.core.csnn import (encode_input, init_params, snn_apply,
                             snn_apply_batched, snn_apply_dense)
from repro.core.plan import plan_network
from repro.serve.csnn_engine import CSNNEngine, CSNNServeConfig
from repro.tune import TuneConfig

from .common import emit, timeit, trained_csnn, write_bench_json


def main(json_out: bool = False):
    cfg, params, (xtr, ytr, xte, yte) = trained_csnn()
    batch = 4

    # dense frame-based baseline (SIES-style): one timing, sparsity-blind
    imgs = jnp.asarray(xte[:batch])
    spikes = encode_input(imgs, cfg)
    dense_fn = jax.jit(jax.vmap(lambda s: snn_apply_dense(params, s, cfg)))
    us_dense = timeit(dense_fn, spikes) / batch
    emit("table5/dense_frame_based", us_dense, "mode=baseline")

    # event-driven at calibrated capacity per input-density level
    rng = np.random.default_rng(0)
    synth_cap, synth_us = None, None
    for density, name in [(0.05, "sparse5"), (0.15, "synth_digits"),
                          (0.35, "dense35"), (0.7, "dense70")]:
        if name == "synth_digits":
            x = imgs
        else:
            x = jnp.asarray((rng.random((batch, 28, 28, 1)) < density)
                            .astype(np.float32))
        sp = encode_input(x, cfg)
        # calibrate the queue depth from observed spike counts (layer 1 input)
        counts = np.asarray(sp.sum(axis=(2, 3, 4)))
        cap = calibrate_capacity(counts, percentile=100.0, margin=1.1, align=32)
        cap = int(min(cap, 784))
        fn = jax.jit(jax.vmap(lambda s: snn_apply(
            params, s, cfg, capacity=cap, channel_block=8, collect_stats=False)))
        us = timeit(fn, sp)
        if name == "synth_digits":  # reused as the vmap row below
            synth_cap, synth_us = cap, us
        emit(f"table5/event_driven_{name}", us / batch,
             f"capacity={cap};vs_dense={us_dense / (us / batch):.2f}x")

    # batched event pipeline vs vmap-over-samples vs dense (serving config);
    # the vmap row reuses the synth_digits timing above — same inputs, same
    # calibrated capacity, no second compile.
    cap = synth_cap
    batched_fn = jax.jit(lambda s: snn_apply_batched(
        params, s, cfg, capacity=cap, channel_block=8, collect_stats=False))
    us_vmap = synth_us / batch
    us_batched = timeit(batched_fn, spikes) / batch
    emit("table5/vmap_per_sample", us_vmap,
         f"capacity={cap};batch={batch};vs_dense={us_dense / us_vmap:.2f}x")
    emit("table5/batched_pipeline", us_batched,
         f"capacity={cap};batch={batch};vs_vmap={us_vmap / us_batched:.2f}x;"
         f"vs_dense={us_dense / us_batched:.2f}x")

    # memory-interlaced event-parallel pipeline: event_par autotuned per
    # layer, banked MemPot tiles, sort-free compaction, one vectorized
    # column application per (t, c_in, bank).  Bit-exact vs the batched
    # row (asserted) and the headline speedup of the interlaced refactor.
    plan_il = plan_network(cfg, capacity=cap, channel_block=8,
                           batch_tile=batch, event_par=None)
    il_fn = jax.jit(lambda s: snn_apply_batched(
        params, s, cfg, plan_il, collect_stats=False))
    assert np.array_equal(np.asarray(il_fn(spikes)),
                          np.asarray(batched_fn(spikes))), \
        "interlaced pipeline must be bit-exact vs the sequential batched row"
    us_il = timeit(il_fn, spikes) / batch
    speedup = us_batched / us_il
    emit("table5/interlaced", us_il,
         f"event_par={[lp.event_par for lp in plan_il.layers]};"
         f"vs_batched={speedup:.2f}x;vs_dense={us_dense / us_il:.2f}x")
    # the speedup assertion only makes sense when the autotuner actually
    # picked a parallel width (always true on the paper net; guards the
    # degenerate all-sequential case where both rows trace identical
    # computations and the ratio is pure timer noise)
    if any(lp.event_par > 1 for lp in plan_il.layers):
        assert speedup > 1.0, (
            f"interlaced event-parallel row must beat the sequential "
            f"batched row, got {speedup:.2f}x")

    # per-layer plan: same calibrated request, capacities capped per layer
    plan = plan_network(cfg, capacity=cap, channel_block=8, batch_tile=batch)
    shared = plan_network(cfg, capacity=cap, channel_block=8, per_layer=False)
    planned_fn = jax.jit(lambda s: snn_apply_batched(
        params, s, cfg, plan, collect_stats=False))
    us_planned = timeit(planned_fn, spikes) / batch
    emit("table5/planned_per_layer", us_planned,
         f"slots={plan.total_event_slots}_vs_shared={shared.total_event_slots};"
         f"vs_batched={us_batched / us_planned:.2f}x")

    # measured-autotuned plan (repro.tune): candidate (block_e, event_par,
    # variant) tuples micro-benchmarked per layer on synthetic queues at
    # calibrated occupancy, then network-level knobs (capacity sharing,
    # t_chunk) measured whole-pipeline; winners persist in the plan cache
    # the CI tuner lane uploads.  Bit-exact vs the reference batched
    # pipeline by construction (asserted), and never slower than the best
    # analytic row (interlaced) — when the tuner lands on the exact same
    # execution it reuses that row's timing (ratio 1.00x by identity)
    # instead of re-rolling timer noise.
    plan_tuned = plan_network(cfg, capacity=cap, channel_block=8,
                              batch_tile=batch, event_par=None,
                              tune="measured",
                              tune_config=TuneConfig(batch=batch),
                              cache_path="results/plan_cache.json")
    tuned_fn = jax.jit(lambda s: snn_apply_batched(
        params, s, cfg, plan_tuned, collect_stats=False))
    assert np.array_equal(np.asarray(tuned_fn(spikes)),
                          np.asarray(batched_fn(spikes))), \
        "tuned plan must be bit-exact vs the reference batched pipeline"

    def exec_sig(p):
        # what actually determines the traced computation on this backend
        return (p.chunk_steps, tuple(
            (lp.capacity, lp.channel_block, lp.event_par, lp.block_e,
             lp.resolve_variant("jax")) for lp in p.layers))

    if exec_sig(plan_tuned) == exec_sig(plan_il):
        us_tuned, vs_il = us_il, 1.0
    else:
        us_tuned = timeit(tuned_fn, spikes) / batch
        us_il_ref = us_il
        vs_il = us_il_ref / us_tuned
        for _ in range(2):  # re-measure interleaved before calling a loss
            if vs_il >= 1.0:
                break
            us_il_ref = min(us_il_ref, timeit(il_fn, spikes) / batch)
            us_tuned = min(us_tuned, timeit(tuned_fn, spikes) / batch)
            vs_il = us_il_ref / us_tuned
    assert vs_il >= 1.0, (
        f"tuned plan must not lose to the best analytic row, got "
        f"{vs_il:.2f}x vs interlaced")
    emit("table5/tuned", us_tuned,
         f"variants={[lp.resolve_variant('jax') for lp in plan_tuned.layers]};"
         f"t_chunk={plan_tuned.chunk_steps};"
         f"slots={plan_tuned.total_event_slots};"
         f"vs_interlaced={vs_il:.2f}x;vs_batched={us_batched / us_tuned:.2f}x")

    # fused spike emission (ISSUE 10): every layer pinned "fused-handoff",
    # so spikes leave each threshold unit already compacted into the next
    # layer's padded-bank carrier — no dense intermediate, no second O(HW)
    # compaction pass per (layer, timestep).  Bit-exact vs the reference
    # batched pipeline (asserted: the carrier provably holds the same kept
    # events as build_bank_masks) and required to beat the best prior
    # event-driven row by >= 1.15x — the headline of the fusion.
    plan_fused = plan_network(cfg, capacity=cap, channel_block=8,
                              batch_tile=batch,
                              variant=["fused-handoff"] * len(plan.layers))
    fused_fn = jax.jit(lambda s: snn_apply_batched(
        params, s, cfg, plan_fused, collect_stats=False))
    bit_exact = np.array_equal(np.asarray(fused_fn(spikes)),
                               np.asarray(batched_fn(spikes)))
    assert bit_exact, \
        "fused-handoff pipeline must be bit-exact vs the batched reference"
    # the 1.15x bar is against the best event-driven row that does NOT
    # itself use the fused handoff: now that "fused-handoff" sits on the
    # tuner's candidate axis the tuned row usually IS the fused pipeline
    # (comparing against it would be fused-vs-fused, identically 1.0x),
    # in which case the honest prior best is the interlaced row.
    tuned_is_fused = any(lp.resolve_variant("jax") == "fused-handoff"
                         for lp in plan_tuned.layers)
    prior_fn, us_prior = ((il_fn, us_il) if tuned_is_fused
                          else (tuned_fn, min(us_tuned, us_il)))
    us_fused = timeit(fused_fn, spikes) / batch
    vs_prior = us_prior / us_fused
    for _ in range(2):  # re-measure interleaved before calling a miss
        if vs_prior >= 1.15:
            break
        us_prior = min(us_prior, timeit(prior_fn, spikes) / batch)
        us_fused = min(us_fused, timeit(fused_fn, spikes) / batch)
        vs_prior = us_prior / us_fused
    assert vs_prior >= 1.15, (
        f"fused-handoff must beat the best non-fused event-driven row by "
        f">= 1.15x, got {vs_prior:.2f}x")
    emit("table5/fused_handoff", us_fused,
         f"bit_exact={bit_exact};vs_prior_best={vs_prior:.2f}x;"
         f"vs_tuned={us_tuned / us_fused:.2f}x;"
         f"vs_dense={us_dense / us_fused:.2f}x")

    # beyond-paper parametric-geometry demo: the csnn_wide config swaps
    # the first conv layer to a 5x5 window (25 interlace banks) and runs
    # the identical event pipeline — planning, AEQ interlacing, banked
    # apply all derive their layout from the layer geometry.  The k=5
    # correctness claim is CI-enforced here: the event-driven pipeline
    # must stay bit-exact vs the dense frame-based oracle, so the queues
    # are sized truncation-free (capacity = H*W; the dense oracle has no
    # overflow-drop semantics to compare against).
    wcfg = csnn_wide.FULL
    wparams = init_params(jax.random.PRNGKey(2), wcfg)
    wh, ww = wcfg.input_hw
    wplan = plan_network(wcfg, capacity=wh * ww, channel_block=8,
                         batch_tile=batch, event_par=None)
    wsp = encode_input(imgs, wcfg)
    wide_fn = jax.jit(lambda s: snn_apply_batched(
        wparams, s, wcfg, wplan, collect_stats=False))
    wide_dense = jax.jit(jax.vmap(
        lambda s: snn_apply_dense(wparams, s, wcfg)))
    assert np.array_equal(np.asarray(wide_fn(wsp)),
                          np.asarray(wide_dense(wsp))), \
        "5x5 event pipeline must be bit-exact vs the dense oracle"
    us_wide = timeit(wide_fn, wsp) / batch
    us_wide_dense = timeit(wide_dense, wsp) / batch
    emit("table5/wide_5x5", us_wide,
         f"geometry={wplan.layers[0].geometry.describe()};"
         f"event_par={[lp.event_par for lp in wplan.layers]};"
         f"vs_dense={us_wide_dense / us_wide:.2f}x")

    # async serving engine: requests submitted one at a time, flushed on
    # batch/deadline thresholds; compile excluded via warmup
    engine = CSNNEngine(params, cfg, plan,
                        CSNNServeConfig(max_batch=batch, max_delay_ms=20.0))
    engine.warmup()
    reqs = list(imgs)
    engine.run_requests(reqs)  # engine-loop warmup pass
    pre = dict(engine.stats)   # stats accumulate; report the timed run only
    t0 = time.perf_counter()
    engine.run_requests(reqs)
    us_engine = 1e6 * (time.perf_counter() - t0) / batch
    emit("table5/async_engine", us_engine,
         f"batch={batch};tile={plan.batch_tile};"
         f"flushes_full={engine.stats['flushes_full'] - pre['flushes_full']};"
         f"vs_batched={us_batched / us_engine:.2f}x")

    # continuous batching under a bursty Poisson arrival trace: the same
    # request/arrival schedule replayed through the run-to-completion
    # engine and the slot-level-refill engine (median of 3 replays each).
    # The mean inter-arrival gap is set to one flush's measured service
    # time, so the offered load sits at the knee where batches are
    # genuinely partial: the run-to-completion engine sits out flush
    # deadlines and pads whole-T pipelines while slots idle; slot-level
    # refill admits every arrival at the next t_chunk boundary and packs
    # the active slots into occupancy buckets (the always-fed PE array of
    # the paper, as a serving property).
    n_req = 18
    flush_s = batch * us_engine / 1e6  # one padded whole-T flush
    gaps = np.random.default_rng(1).exponential(scale=flush_s, size=n_req)
    arrivals = np.cumsum(gaps) - gaps[0]  # first request arrives at t=0
    trace = [jnp.asarray(xte[i % batch]) for i in range(n_req)]

    def replay(eng):
        async def _drive():
            async def one(delay, img):
                await asyncio.sleep(delay)
                return await eng.submit(img)

            async with eng:
                t0 = time.perf_counter()
                res = await asyncio.gather(
                    *[one(float(d), img) for d, img in zip(arrivals, trace)])
                dt = time.perf_counter() - t0
            return np.stack(res), dt

        return asyncio.run(_drive())

    def median_replay(eng, reps=3):
        """Median makespan over ``reps`` identical replays, plus the
        per-replay stats delta (stats accumulate across replays)."""
        pre = dict(eng.stats)
        outs = [replay(eng) for _ in range(reps)]
        logits = outs[0][0]
        assert all(np.array_equal(lg, logits) for lg, _ in outs)
        per_rep = {k: (eng.stats[k] - pre[k]) / reps for k in pre
                   if isinstance(pre[k], (int, float))}
        return logits, sorted(dt for _, dt in outs)[reps // 2], per_rep

    rtc = CSNNEngine(params, cfg, plan,
                     CSNNServeConfig(max_batch=batch, max_delay_ms=20.0))
    rtc.warmup()
    logits_rtc, dt_rtc, st_rtc = median_replay(rtc)
    us_rtc = 1e6 * dt_rtc / n_req
    emit("table5/async_engine_poisson", us_rtc,
         f"n={n_req};full={st_rtc['flushes_full']:.1f};"
         f"deadline={st_rtc['flushes_deadline']:.1f};"
         f"padded={st_rtc['padded_slots']:.1f}")

    cont = CSNNEngine(params, cfg, plan,
                      CSNNServeConfig(max_batch=batch, max_delay_ms=20.0,
                                      continuous=True, t_chunk=1))
    cont.warmup()
    logits_cont, dt_cont, st_cont = median_replay(cont)
    us_cont = 1e6 * dt_cont / n_req
    assert np.array_equal(logits_cont, logits_rtc), \
        "continuous engine must be bit-exact vs run-to-completion"
    emit("table5/continuous_poisson", us_cont,
         f"n={n_req};chunks={st_cont['chunks']:.1f};"
         f"refills={st_cont['refills']:.1f};"
         f"slot_util={cont.slot_utilization:.0%};"
         f"vs_async_engine={us_rtc / us_cont:.2f}x")

    if json_out:
        write_bench_json("table5")


if __name__ == "__main__":
    main(json_out="--json" in __import__("sys").argv[1:])
