"""Paper Table V: throughput/latency of event-driven vs frame-based
processing, and the core scaling claim — processing time scales with the
number of spikes (queue occupancy), not with the frame size.

We sweep input sparsity, calibrate the AEQ capacity per sparsity level
(exactly how the queue BRAM would be sized), and time event-driven
inference against the dense frame-based baseline.  The figure of merit is
the slope: event-mode time follows capacity ~ spike count; dense-mode
time is flat.

Beyond-paper rows: the batched event pipeline (``snn_apply_batched``) vs
``vmap`` over the single-sample path vs the dense baseline — the batched
rows are the serving configuration and must be at least as fast per
sample as vmap (amortized queue compaction + batch-wide early exit) —
plus the per-layer-planned pipeline (``plan_network`` capacities, the
padded-slot reduction recorded in the derived column) and the async
micro-batching serving engine (``serve.csnn_engine``, requests submitted
one at a time and flushed on batch/deadline thresholds).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aeq import calibrate_capacity
from repro.core.csnn import (encode_input, snn_apply, snn_apply_batched,
                             snn_apply_dense)
from repro.core.plan import plan_network
from repro.serve.csnn_engine import CSNNEngine, CSNNServeConfig

from .common import emit, timeit, trained_csnn


def main():
    cfg, params, (xtr, ytr, xte, yte) = trained_csnn()
    batch = 4

    # dense frame-based baseline (SIES-style): one timing, sparsity-blind
    imgs = jnp.asarray(xte[:batch])
    spikes = encode_input(imgs, cfg)
    dense_fn = jax.jit(jax.vmap(lambda s: snn_apply_dense(params, s, cfg)))
    us_dense = timeit(dense_fn, spikes) / batch
    emit("table5/dense_frame_based", us_dense, "mode=baseline")

    # event-driven at calibrated capacity per input-density level
    rng = np.random.default_rng(0)
    synth_cap, synth_us = None, None
    for density, name in [(0.05, "sparse5"), (0.15, "synth_digits"),
                          (0.35, "dense35"), (0.7, "dense70")]:
        if name == "synth_digits":
            x = imgs
        else:
            x = jnp.asarray((rng.random((batch, 28, 28, 1)) < density)
                            .astype(np.float32))
        sp = encode_input(x, cfg)
        # calibrate the queue depth from observed spike counts (layer 1 input)
        counts = np.asarray(sp.sum(axis=(2, 3, 4)))
        cap = calibrate_capacity(counts, percentile=100.0, margin=1.1, align=32)
        cap = int(min(cap, 784))
        fn = jax.jit(jax.vmap(lambda s: snn_apply(
            params, s, cfg, capacity=cap, channel_block=8, collect_stats=False)))
        us = timeit(fn, sp)
        if name == "synth_digits":  # reused as the vmap row below
            synth_cap, synth_us = cap, us
        emit(f"table5/event_driven_{name}", us / batch,
             f"capacity={cap};vs_dense={us_dense / (us / batch):.2f}x")

    # batched event pipeline vs vmap-over-samples vs dense (serving config);
    # the vmap row reuses the synth_digits timing above — same inputs, same
    # calibrated capacity, no second compile.
    cap = synth_cap
    batched_fn = jax.jit(lambda s: snn_apply_batched(
        params, s, cfg, capacity=cap, channel_block=8, collect_stats=False))
    us_vmap = synth_us / batch
    us_batched = timeit(batched_fn, spikes) / batch
    emit("table5/vmap_per_sample", us_vmap,
         f"capacity={cap};batch={batch};vs_dense={us_dense / us_vmap:.2f}x")
    emit("table5/batched_pipeline", us_batched,
         f"capacity={cap};batch={batch};vs_vmap={us_vmap / us_batched:.2f}x;"
         f"vs_dense={us_dense / us_batched:.2f}x")

    # per-layer plan: same calibrated request, capacities capped per layer
    plan = plan_network(cfg, capacity=cap, channel_block=8, batch_tile=batch)
    shared = plan_network(cfg, capacity=cap, channel_block=8, per_layer=False)
    planned_fn = jax.jit(lambda s: snn_apply_batched(
        params, s, cfg, plan, collect_stats=False))
    us_planned = timeit(planned_fn, spikes) / batch
    emit("table5/planned_per_layer", us_planned,
         f"slots={plan.total_event_slots}_vs_shared={shared.total_event_slots};"
         f"vs_batched={us_batched / us_planned:.2f}x")

    # async serving engine: requests submitted one at a time, flushed on
    # batch/deadline thresholds; compile excluded via warmup
    engine = CSNNEngine(params, cfg, plan,
                        CSNNServeConfig(max_batch=batch, max_delay_ms=20.0))
    engine.warmup()
    reqs = list(imgs)
    engine.run_requests(reqs)  # engine-loop warmup pass
    pre = dict(engine.stats)   # stats accumulate; report the timed run only
    t0 = time.perf_counter()
    engine.run_requests(reqs)
    us_engine = 1e6 * (time.perf_counter() - t0) / batch
    emit("table5/async_engine", us_engine,
         f"batch={batch};tile={plan.batch_tile};"
         f"flushes_full={engine.stats['flushes_full'] - pre['flushes_full']};"
         f"vs_batched={us_batched / us_engine:.2f}x")


if __name__ == "__main__":
    main()
