"""Paper Table V: throughput/latency of event-driven vs frame-based
processing, and the core scaling claim — processing time scales with the
number of spikes (queue occupancy), not with the frame size.

We sweep input sparsity, calibrate the AEQ capacity per sparsity level
(exactly how the queue BRAM would be sized), and time event-driven
inference against the dense frame-based baseline.  The figure of merit is
the slope: event-mode time follows capacity ~ spike count; dense-mode
time is flat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aeq import calibrate_capacity
from repro.core.csnn import encode_input, snn_apply, snn_apply_dense

from .common import emit, timeit, trained_csnn


def main():
    cfg, params, (xtr, ytr, xte, yte) = trained_csnn()
    batch = 4

    # dense frame-based baseline (SIES-style): one timing, sparsity-blind
    imgs = jnp.asarray(xte[:batch])
    spikes = encode_input(imgs, cfg)
    dense_fn = jax.jit(jax.vmap(lambda s: snn_apply_dense(params, s, cfg)))
    us_dense = timeit(dense_fn, spikes) / batch
    emit("table5/dense_frame_based", us_dense, "mode=baseline")

    # event-driven at calibrated capacity per input-density level
    rng = np.random.default_rng(0)
    for density, name in [(0.05, "sparse5"), (0.15, "synth_digits"),
                          (0.35, "dense35"), (0.7, "dense70")]:
        if name == "synth_digits":
            x = imgs
        else:
            x = jnp.asarray((rng.random((batch, 28, 28, 1)) < density)
                            .astype(np.float32))
        sp = encode_input(x, cfg)
        # calibrate the queue depth from observed spike counts (layer 1 input)
        counts = np.asarray(sp.sum(axis=(2, 3, 4)))
        cap = calibrate_capacity(counts, percentile=100.0, margin=1.1, align=32)
        cap = int(min(cap, 784))
        fn = jax.jit(jax.vmap(lambda s: snn_apply(
            params, s, cfg, capacity=cap, channel_block=8, collect_stats=False)))
        us = timeit(fn, sp)
        emit(f"table5/event_driven_{name}", us / batch,
             f"capacity={cap};vs_dense={us_dense / (us / batch):.2f}x")


if __name__ == "__main__":
    main()
