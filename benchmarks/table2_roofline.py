"""Paper Table II analogue: resource report.

The FPGA table (LUT/FF/BRAM/DSP) has no TPU counterpart; the TPU-native
"synthesis report" is the roofline table produced by the multi-pod
dry-run (deliverable g).  This benchmark summarizes results/dryrun/*.json
as CSV — one row per (arch x shape x mesh) — and flags the dominant term.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import RESULTS, emit


def main():
    cells = sorted((RESULTS / "dryrun").glob("*__final.json"))
    if not cells:
        cells = sorted((RESULTS / "dryrun").glob("*__baseline.json"))
    if not cells:
        emit("table2/no_dryrun_results", 0.0, "run repro.launch.dryrun first")
        return
    for f in cells:
        d = json.loads(f.read_text())
        key = f"table2/{d['arch']}__{d['shape']}__{d['mesh']}"
        if d["status"] == "skipped":
            emit(key, 0.0, f"skipped={d['reason'][:60]}")
            continue
        if d["status"] != "ok":
            emit(key, 0.0, f"ERROR={d['error'][:80]}")
            continue
        r = d["roofline"]
        t_dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        emit(key, 1e6 * t_dom,
             f"bottleneck={r['bottleneck']};t_c={r['t_compute']:.3f}s;"
             f"t_m={r['t_memory']:.3f}s;t_x={r['t_collective']:.3f}s;"
             f"useful_flops={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)}")


if __name__ == "__main__":
    main()
