"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter/cache/input leaf carries logical axis names
("embed", "vocab", "heads", "experts", "batch", "cache_seq", ...).  A rule
set maps logical names to mesh axes; ``resolve`` turns a logical tuple
into a PartitionSpec, silently dropping assignments that do not divide
the dimension or that would reuse a mesh axis twice — so one rule set
serves every architecture (e.g. "heads -> model" is skipped for gemma3's
4 heads on a 16-way model axis instead of erroring).

Baseline layout (recorded as such in EXPERIMENTS.md §Perf):
  * batch/fsdp over ("pod", "data") — DP + ZeRO-3 parameter sharding
  * vocab/heads/kv_heads/mlp/experts over "model" — tensor/expert parallel
  * long-context decode (batch=1): KV-cache sequence over "data"
    (context parallelism) since the batch axis cannot shard.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def default_rules(*, phase: str = "train", long_context: bool = False) -> dict:
    fsdp = ("pod", "data")  # resolve() drops "pod" when the mesh lacks it
    rules = {
        "batch": fsdp,
        "seq": ("seq",),   # sequence parallelism when the mesh has a seq axis
        "vocab": ("model",),
        "embed": fsdp,
        "heads": ("model",),
        "kv_heads": ("model",),
        "heads_flat": ("model",),
        "head_dim": (),
        "mlp": ("model",),
        "embed2": fsdp,          # rwkv channel-mix receptance (d, d) second dim
        "expert_mlp": (),
        "experts": ("model",),
        "q_lora": (),
        "kv_lora": (),
        "layers": (),
        "cache_seq": (),
    }
    if phase == "decode":
        # serving layout: weights replicated over the data axis (they fit
        # once the model axis shards them) — no per-step weight all-gather
        rules["embed"] = ()
        rules["embed2"] = ()
    if long_context:
        # batch=1: shard the KV cache / sequence over "data" instead
        rules["batch"] = ()
        rules["cache_seq"] = ("data",)
        rules["seq"] = ("data",)
    return rules


def resolve(axes: Optional[tuple], shape: tuple, rules: dict, mesh: Mesh) -> PartitionSpec:
    """Logical axes tuple -> PartitionSpec valid for `shape` on `mesh`."""
    if axes is None:
        return PartitionSpec()
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, axes):
        cand = rules.get(name, ()) if name is not None else ()
        if isinstance(cand, str):
            cand = (cand,)
        picked = []
        prod = 1
        for ax in cand:
            if ax not in mesh.shape or ax in used:
                continue
            nxt = prod * mesh.shape[ax]
            if dim % nxt == 0:
                picked.append(ax)
                prod = nxt
        used.update(picked)
        if not picked:
            entries.append(None)
        elif len(picked) == 1:
            entries.append(picked[0])
        else:
            entries.append(tuple(picked))
    return PartitionSpec(*entries)


def tree_shardings(mesh: Mesh, axes_tree: Any, shape_tree: Any, rules: dict) -> Any:
    """Build a NamedSharding tree from (logical axes tree, abstract tree)."""
    def one(axes, arr):
        return NamedSharding(mesh, resolve(tuple(axes), arr.shape, rules, mesh))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def batch_mesh(n_devices: Optional[int] = None, *, axis: str = "batch") -> Mesh:
    """1-D mesh over (the first ``n_devices``) local devices, for sharding
    a per-sample-independent batch axis (csnn.snn_apply_sharded).  On CPU
    hosts, ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` provides
    the multi-device substrate (the CI multi-device job uses N=8)."""
    import numpy as np
    devices = jax.devices()
    if n_devices is not None:
        if n_devices > len(devices):
            raise ValueError(f"requested {n_devices} devices, "
                             f"have {len(devices)}")
        devices = devices[:n_devices]
    return Mesh(np.asarray(devices), (axis,))


# ---------------------------------------------------------------------------
# Activation sharding constraints (with_sharding_constraint plumbing)
# ---------------------------------------------------------------------------
# XLA's sharding propagation can drop to replicated through scans (observed:
# the CE loss scan compiled with fully-replicated (B, S, V) logits — 1.1 TB
# per device on gemma3 train_4k).  Launchers register the mesh + rules here;
# model code calls ``constrain`` at propagation choke points.  Without a
# registered mesh (unit tests) it is a no-op.

_CONSTRAINT_MESH: list = [None, None]  # [mesh, rules]


def set_constraint_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    _CONSTRAINT_MESH[0] = mesh
    _CONSTRAINT_MESH[1] = rules


def constrain(x, logical_axes: tuple):
    """Pin a traced activation to the rule-resolved sharding (no-op without
    a registered mesh)."""
    mesh, rules = _CONSTRAINT_MESH
    if mesh is None:
        return x
    spec = resolve(logical_axes, x.shape, rules or default_rules(), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
