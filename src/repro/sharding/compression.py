"""Gradient event-compression with error feedback.

This is the paper's core idea applied to the collective layer
(DESIGN.md Sec. 5): just as the accelerator compresses sparse binary
activations into fixed-capacity Address-Event Queues so that work scales
with the active set, gradients are compressed into fixed-capacity
(index, value) queues — top-k magnitude selection — before the data-
parallel reduction, cutting all-reduce bytes from O(N) to O(2k).

Error feedback (Stich et al.) accumulates what compression dropped and
re-injects it next step, which keeps SGD/Adam convergence (tested:
error-feedback compression at 1% density tracks dense training loss).

``sparse_psum`` runs the compressed reduction inside shard_map: each
data shard contributes its queue; queues are all-gathered (2k * n_shards
bytes, still << dense when k << N/n) and scatter-added locally.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


class CompressedGrad(NamedTuple):
    indices: jax.Array  # (k,) int32 into the flattened tensor
    values: jax.Array   # (k,)
    size: int           # original flat size


def compress_topk(flat: jax.Array, k: int) -> CompressedGrad:
    """AEQ for gradients: keep the k largest-magnitude entries."""
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    del vals
    return CompressedGrad(indices=idx.astype(jnp.int32), values=flat[idx],
                          size=flat.shape[0])


def decompress(c: CompressedGrad) -> jax.Array:
    return jnp.zeros((c.size,), c.values.dtype).at[c.indices].add(c.values)


class EFState(NamedTuple):
    """Per-leaf error-feedback residual (what compression dropped so far)."""
    residual: Any

    @staticmethod
    def init(grads: Any) -> "EFState":
        return EFState(jax.tree.map(jnp.zeros_like, grads))


def compress_with_error_feedback(grads: Any, ef: EFState, density: float):
    """tree of grads -> (tree of CompressedGrad, new EFState).

    compensated = grad + residual; transmitted = topk(compensated);
    new residual = compensated - decompress(transmitted).
    """
    def one(g, r):
        flat = g.reshape(-1).astype(jnp.float32) + r.reshape(-1).astype(jnp.float32)
        k = max(1, int(flat.shape[0] * density))
        c = compress_topk(flat, k)
        new_r = (flat - decompress(c)).reshape(g.shape).astype(r.dtype)
        return c, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_ef = EFState(jax.tree.unflatten(treedef, [p[1] for p in pairs]))
    return comp, new_ef


def sparse_psum(c: CompressedGrad, mesh: Mesh, axis: str) -> jax.Array:
    """Compressed data-parallel reduction of ONE tensor's queue.

    Inside shard_map over ``axis``: all-gather the (index, value) queues
    of every shard (wire = 2k * n vs N for a dense all-reduce) and
    scatter-add locally.  Returns the dense averaged gradient, replicated.
    """
    n = mesh.shape[axis]

    def body(idx, val):
        all_idx = jax.lax.all_gather(idx, axis)   # (n, k)
        all_val = jax.lax.all_gather(val, axis)   # (n, k)
        dense = jnp.zeros((c.size,), val.dtype)
        dense = dense.at[all_idx.reshape(-1)].add(all_val.reshape(-1))
        return dense / n

    return shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
        check_vma=False)(c.indices, c.values)


def compression_ratio(tree_sizes: Any, density: float) -> float:
    """Wire-byte ratio dense-allreduce : sparse queues (8 bytes/entry)."""
    total = sum(jax.tree.leaves(tree_sizes))
    k = sum(max(1, int(s * density)) for s in jax.tree.leaves(tree_sizes))
    return (4.0 * total) / (8.0 * k)


# ---------------------------------------------------------------------------
# int8 quantized all-reduce (DESIGN.md Sec. 5 trick iii)
# ---------------------------------------------------------------------------


class QuantizedTensor(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # () per-tensor scale


def quantize_grad(g: jax.Array, rng: jax.Array) -> QuantizedTensor:
    """Symmetric int8 quantization with stochastic rounding (unbiased:
    E[dequant(quant(g))] = g, which is what keeps SGD convergent when the
    all-reduce payload is quantized 4x)."""
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = amax / 127.0
    scaled = g.astype(jnp.float32) / scale
    noise = jax.random.uniform(rng, g.shape) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale)


def dequantize_grad(t: QuantizedTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale


def quantized_pmean(g: jax.Array, rng: jax.Array, axis: str) -> jax.Array:
    """Data-parallel mean with int8 wire payload (call inside shard_map).

    Each shard quantizes locally; int8 payloads are all-gathered
    (wire = N/4 of fp32) and dequantized+averaged locally.  Scales ride
    along (4 bytes per shard per tensor).
    """
    t = quantize_grad(g, rng)
    all_q = jax.lax.all_gather(t.q, axis)          # (n, ...)
    all_s = jax.lax.all_gather(t.scale, axis)      # (n,)
    deq = all_q.astype(jnp.float32) * all_s.reshape(-1, *([1] * g.ndim))
    return deq.mean(axis=0)
