"""Pipeline parallelism over a mesh axis (GPipe-style microbatching).

Completes the parallelism matrix (DP/FSDP + TP + EP + SP + **PP**): layer
stages are placed along a mesh axis (canonically the "pod" axis of the
2x16x16 production mesh — inter-pod links are the slowest, and PP's
point-to-point `collective_permute` is the cheapest traffic to put
there), activations flow stage-to-stage with `ppermute`, and microbatches
keep every stage busy except the (n_stages - 1)-bubble.

Implementation: the classic shard_map round-robin schedule. With S stages
and M microbatches, the loop runs S+M-1 ticks; at tick t, stage s
processes microbatch t-s. All stages execute the same program on their
own parameter shard — stage placement is just the leading (stacked)
parameter axis sharded over the pipeline mesh axis.

The bubble fraction (S-1)/(S+M-1) and per-tick wire |activation| are the
napkin numbers recorded in EXPERIMENTS.md; correctness is tested against
the unpipelined stack on a forced multi-device host.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(stage_fn: Callable, stacked_params, x: jax.Array, *,
                   mesh: Mesh, axis: str, n_microbatches: int) -> jax.Array:
    """Run ``n_stages`` stacked stages over ``x`` with microbatch pipelining.

    stage_fn(params_slice, x_mb) -> x_mb     (one stage, one microbatch)
    stacked_params: pytree with leading dim n_stages == mesh.shape[axis],
        sharded (axis, ...) — each device holds its own stage's weights.
    x: (B, ...) global batch; B % n_microbatches == 0.

    Returns stage_{S-1}(...stage_0(x)) for the whole batch.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0, (b, n_microbatches)
    mb = b // n_microbatches

    def body(params_slice, x_all):
        # params_slice: this stage's weights (leading dim 1) ; x_all: full
        # batch, replicated along the pipeline axis (it is sharded on the
        # OTHER axes by the caller's in_specs).
        params_slice = jax.tree.map(lambda t: t[0], params_slice)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_stages + n_microbatches - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        x_mbs = x_all.reshape(n_microbatches, mb, *x_all.shape[1:])
        out_mbs = jnp.zeros_like(x_mbs)
        carry = jnp.zeros((mb, *x_all.shape[1:]), x_all.dtype)

        def tick(t, state):
            carry, out_mbs = state
            # stage 0 ingests microbatch t (if still in range)
            feed_idx = jnp.clip(t, 0, n_microbatches - 1)
            inject = x_mbs[feed_idx]
            cur = jnp.where(stage == 0, inject, carry)
            valid = (t - stage >= 0) & (t - stage < n_microbatches)
            y = stage_fn(params_slice, cur)
            y = jnp.where(valid, y, carry)
            # the last stage banks its finished microbatch t - (S-1)
            done_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            bank = (stage == n_stages - 1) & (t - (n_stages - 1) >= 0)
            out_mbs = jax.lax.cond(
                bank,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None], (done_idx,) + (0,) * y.ndim),
                lambda o: o, out_mbs)
            # hand activations to the next stage
            carry = jax.lax.ppermute(y, axis, perm)
            return carry, out_mbs

        _, out_mbs = jax.lax.fori_loop(0, n_ticks, tick, (carry, out_mbs))
        # finished microbatches live on the last stage: broadcast them back
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out_mbs, jnp.zeros_like(out_mbs)),
            axis)
        return out.reshape(b, *x_all.shape[1:])

    in_specs = (P(axis), P())
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_vma=False)(stacked_params, x)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead — the napkin number for stage/microbatch sizing."""
    return (n_stages - 1) / (n_stages + n_microbatches - 1)
