"""Manual compute/communication overlap: collective matmuls via shard_map.

XLA inserts all-gathers *before* the matmuls that consume them; on a big
mesh that serializes wire time behind MXU time.  The classic fix
("collective matmul") decomposes the gather into a ring of ``ppermute``
steps, multiplying each arriving shard immediately — wire and MXU time
overlap to ~max(t_comm, t_compute) instead of their sum.

Two schedules:
* ``psum_matmul`` — Megatron row-parallel contraction with the reduction
  explicit (XLA latency-hides the async all-reduce);
* ``ring_weight_gather_matmul`` — FSDP-style: weights sharded over the
  data axis are streamed around a ring and consumed block-by-block, so
  the parameter all-gather of ZeRO-3 overlaps with the matmul itself.

Numerically validated against the unsharded product in
tests/test_distribution.py (multi-device subprocess).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import pvary, shard_map


def psum_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, axis: str):
    """Row-parallel TP matmul: y = psum(x_shard @ w_shard).

    x: (B, D) sharded (None, axis); w: (D, F) sharded (axis, None);
    returns y: (B, F) replicated over ``axis``.
    """
    def body(xs, ws):
        return jax.lax.psum(xs @ ws, axis)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(None, axis), P(axis, None)),
                     out_specs=P())(x, w)


def ring_weight_gather_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, axis: str):
    """FSDP overlap: y = x @ w with w row-sharded over the *batch* axis.

    x: (B, D) sharded (axis, None) — batch shards (ZeRO data parallelism);
    w: (D, F) sharded (axis, None) — parameter shards (ZeRO-3);
    returns y: (B, F) sharded (axis, None).

    Instead of all-gathering w before the matmul (XLA's default), the ring
    rotates weight blocks; step i multiplies the matching D/n column slice
    of the local x block with the arriving rows.  Per-step wire = |w|/n
    runs concurrently with per-step compute = B·D·F/n² (on TPU, ppermute
    is async — the schedule is the overlap).
    """
    n = mesh.shape[axis]

    def body(x_blk, w_blk):
        idx = jax.lax.axis_index(axis)
        d_blk = w_blk.shape[0]
        perm = [(j, (j + 1) % n) for j in range(n)]

        def step(i, carry):
            acc, wb = carry
            src = (idx - i) % n  # which parameter rows just arrived
            x_cols = jax.lax.dynamic_slice_in_dim(x_blk, src * d_blk, d_blk, axis=1)
            acc = acc + x_cols @ wb
            wb = jax.lax.ppermute(wb, axis, perm)
            return acc, wb

        acc0 = jnp.zeros((x_blk.shape[0], w_blk.shape[1]),
                         jnp.promote_types(x_blk.dtype, w_blk.dtype))
        acc0 = pvary(acc0, (axis,))  # mark device-varying for the carry
        acc, _ = jax.lax.fori_loop(0, n, step, (acc0, w_blk))
        return acc.astype(x_blk.dtype)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(axis, None)),
                     out_specs=P(axis, None))(x, w)
