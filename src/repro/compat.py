"""JAX version portability helpers.

The repo targets the jax >= 0.5 public API but must also run on 0.4.x
containers.  Centralizing the differences here keeps every call site on
the modern spelling.
"""
from __future__ import annotations

import jax


def shard_map(body, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``.

    jax 0.4.x exposes shard_map only under ``jax.experimental`` and calls
    the replication-checking flag ``check_rep`` (renamed ``check_vma`` in
    0.5+); semantics are identical for our uses.  ``check_vma`` defaults
    to True like ``jax.shard_map`` itself, so call sites that relied on
    the upstream default keep their trace-time replication checking on
    jax >= 0.5.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except AttributeError:  # 0.4.x deprecation stub raises on access
            pass
    from jax.experimental.shard_map import shard_map as _shard_map
    # The 0.4.x rep checker predates vma semantics (and pvary below is an
    # identity there), so the fallback always disables it.
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def pvary(x, axis_names):
    """``jax.lax.pvary`` or identity on jax versions without vma tracking."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x
