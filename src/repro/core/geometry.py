"""Parametric convolution geometry: the k x k generalization of the
paper's 3x3 memory-interlacing scheme.

The paper (Sec. V) assigns one membrane-RAM bank per kernel tap and
derives hazard freedom from a congruence-class column map: events in the
same interlace column are at least one kernel footprint apart, so a
whole column can update its banks in parallel.  Everything about that
construction is a function of the kernel window alone:

* ``n_banks = kh * kw`` RAM banks (one per tap),
* the column map ``s = (i % kh) * kw + (j % kw)`` (congruence classes of
  the event coordinate modulo the window),
* the halo ``(kh // 2, kw // 2)`` of padding a SAME conv needs around
  the membrane tile.

``ConvGeometry`` freezes those three facts plus the stride and is
threaded through the queue builders (``core/aeq.py``), the banked /
event-driven applies (``core/event_conv.py``), the Pallas kernels and
their autotuners (``kernels/event_conv``), the planner/scheduler, and
the ``repro.analysis`` proofs.  The default instance is the paper's
3x3 stride-1 geometry, and every call site defaults to it — the 3x3
pipeline is bit-identical to the pre-parametric code.

Only odd windows are supported: the interlaced layout stores membrane
cells in ``kh x kw`` macro-cells and resolves each (column, bank) pair
to a macro-cell offset in {-1, 0, +1}; that single-macro-cell halo
identity holds exactly when the window is odd (centred SAME conv).  The
event pipeline additionally requires stride 1 — a strided event conv
would drop events rather than reuse them, which the paper's architecture
never does — so strided geometries are planned (``out_hw``) but rejected
by the event-driven kernels with a clear error.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """Frozen kernel-window geometry: the single source of truth for
    bank count, column map, and halo sizing across the event pipeline."""

    kh: int = 3
    kw: int = 3
    stride: int = 1

    def __post_init__(self):
        if self.kh < 1 or self.kw < 1:
            raise ValueError(
                f"kernel window must be positive, got ({self.kh}, {self.kw})")
        if self.kh % 2 == 0 or self.kw % 2 == 0:
            raise ValueError(
                "interlaced geometry needs an odd kernel window (centred "
                f"SAME conv), got ({self.kh}, {self.kw})")
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1, got {self.stride}")

    # -- derived quantities -------------------------------------------------

    @property
    def n_banks(self) -> int:
        """One membrane-RAM bank per kernel tap: kh * kw."""
        return self.kh * self.kw

    @property
    def halo(self) -> Tuple[int, int]:
        """SAME-conv padding per side: (kh // 2, kw // 2)."""
        return (self.kh // 2, self.kw // 2)

    @property
    def window(self) -> Tuple[int, int]:
        return (self.kh, self.kw)

    def column_index_py(self, i: int, j: int) -> int:
        """Python-int column map (for host-side proofs and tables)."""
        return (i % self.kh) * self.kw + (j % self.kw)

    def column_of(self, i, j):
        """Column map over array coordinates: s = (i % kh) * kw + (j % kw).

        Works on numpy/jax arrays and Python ints alike.
        """
        return (i % self.kh) * self.kw + (j % self.kw)

    def out_hw(self, h: int, w: int) -> Tuple[int, int]:
        """SAME-padded output geometry under the stride."""
        return (-(-h // self.stride), -(-w // self.stride))

    def padded_hw(self, h: int, w: int) -> Tuple[int, int]:
        """Halo-padded membrane-tile geometry."""
        hh, hw = self.halo
        return (h + 2 * hh, w + 2 * hw)

    def require_event_compatible(self, where: str = "event pipeline"):
        """The event-driven datapath reuses every admitted event across
        the full window, which is only meaningful at stride 1."""
        if self.stride != 1:
            raise ValueError(
                f"{where} requires stride 1 (events are reused across the "
                f"whole {self.kh}x{self.kw} window); got stride="
                f"{self.stride}")

    @classmethod
    def from_kernel_shape(cls, shape) -> "ConvGeometry":
        """Geometry implied by a (kh, kw, ...) kernel array shape."""
        return cls(kh=int(shape[0]), kw=int(shape[1]))

    def describe(self) -> str:
        return (f"{self.kh}x{self.kw}/s{self.stride} "
                f"({self.n_banks} banks)")


#: The paper's geometry — every call site defaults to it, keeping the
#: pre-parametric 3x3 pipeline bit-identical.
GEOM_3X3 = ConvGeometry(3, 3, 1)
