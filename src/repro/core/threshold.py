"""Thresholding unit (paper Secs. V-C / VI-C).

After the convolution unit has accumulated all events of a time step into
the membrane potentials, the thresholding unit sweeps every neuron once:

  1. add the (scalar, per-output-channel) bias, with saturation;
  2. compare against the firing threshold V_t; a neuron spikes when it
     crosses V_t *or* its m-TTFS spike-indicator bit is already set;
  3. optionally 3x3 max-pool the binary spike map, which for binary maps
     reduces to OR-ing each non-overlapping 3x3 window (paper Fig. 1);
  4. emit the resulting address events (compaction happens in aeq.py, the
     runtime analogue of the AEQ write circuitry).

Unlike the convolution unit this stage is *dense* — every neuron must be
visited to receive its bias — which the paper implements as a stride-3
3x3-window sweep.  On TPU the whole sweep is one fused elementwise +
window-reduce pass (see kernels/threshold_pool for the Pallas version).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .quantization import saturating_add


class ThresholdResult(NamedTuple):
    v_m: jax.Array        # bias-updated membrane potentials (H, W)
    fired: jax.Array      # updated spike-indicator bits (H, W)
    spikes: jax.Array     # binary output map (H, W) or pooled (H/p, W/p)


def or_pool(spikes: jax.Array, window: int = 3) -> jax.Array:
    """Non-overlapping max-pool of a binary map == OR over each window."""
    h, w = spikes.shape
    ph, pw = -h % window, -w % window
    s = jnp.pad(spikes.astype(bool), ((0, ph), (0, pw)))
    hh, ww = s.shape
    s = s.reshape(hh // window, window, ww // window, window)
    return jnp.any(s, axis=(1, 3))


def threshold_unit(
    v_m: jax.Array,
    bias,
    v_t,
    fired: jax.Array,
    *,
    pool: Optional[int] = None,
    sat_bits: Optional[int] = None,
) -> ThresholdResult:
    """One thresholding-unit sweep over a single channel's membrane potentials.

    v_m:      (H, W) potentials (float, or int when ``sat_bits`` is set).
    bias:     scalar bias of the current output channel; added *every*
              time step (SNN-conversion semantics: the bias integrates).
    fired:    (H, W) m-TTFS spike indicator bits.
    pool:     optional OR-max-pool window (paper uses 3).
    sat_bits: if set, perform the bias add in saturating int<sat_bits>.
    """
    if sat_bits is not None:
        bias_arr = jnp.broadcast_to(jnp.asarray(bias, v_m.dtype), v_m.shape)
        v_m = saturating_add(v_m, bias_arr, sat_bits)
    else:
        v_m = v_m + jnp.asarray(bias, v_m.dtype)
    spikes = (v_m > jnp.asarray(v_t, v_m.dtype)) | fired
    fired = spikes
    out = or_pool(spikes, pool) if pool is not None else spikes
    return ThresholdResult(v_m=v_m, fired=fired, spikes=out)
