"""Integrate-and-fire neuron models (paper Eqs. 1-7).

The paper uses the time-discrete IF model with the m-TTFS neural code of
Han & Roy: once a neuron's membrane potential has crossed the firing
threshold ``v_t`` it emits a spike on *every* subsequent algorithmic time
step until the network is reset.  The "has fired" property is stored as a
spike-indicator bit alongside the membrane potential (paper Sec. VI-C).

All functions are shape-polymorphic: ``v_m`` may be any array and the
returned spike map has the same shape.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class IFState(NamedTuple):
    """State of a population of IF neurons.

    v_m:   membrane potentials (float or quantized int).
    fired: m-TTFS spike-indicator bit — True once the neuron has spiked.
    """

    v_m: jax.Array
    fired: jax.Array

    @staticmethod
    def zeros(shape, dtype=jnp.float32) -> "IFState":
        return IFState(jnp.zeros(shape, dtype), jnp.zeros(shape, jnp.bool_))


def if_reset_step(v_m: jax.Array, current: jax.Array, v_t) -> tuple[jax.Array, jax.Array]:
    """Plain IF step with reset-to-zero (paper Eqs. 1-2); rate-coding baseline.

    Returns ``(new_v_m, spikes)``.  Reset happens on the step *after* the
    threshold crossing, exactly as written in Eq. (1).
    """
    spikes = v_m > v_t
    v_m = jnp.where(spikes, jnp.zeros_like(v_m), v_m) + current
    return v_m, spikes


def mttfs_step(state: IFState, current: jax.Array, v_t) -> tuple[IFState, jax.Array]:
    """m-TTFS IF step (paper Eqs. 3-4 + Sec. VI-C spike indicator).

    The membrane potential keeps integrating (no reset); the neuron spikes
    when ``v_m > v_t`` *or* when it has fired before.  Returns
    ``(new_state, spikes)`` where ``spikes`` is boolean.
    """
    v_m = state.v_m + current
    spikes = (v_m > jnp.asarray(v_t, v_m.dtype)) | state.fired
    return IFState(v_m, spikes), spikes


def ttfs_slope_step(
    mu_m: jax.Array, v_m: jax.Array, fired: jax.Array, current: jax.Array, v_t
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Standard (slope-based) TTFS neuron of Rueckauer et al. (paper Eqs. 5-7).

    Implemented for completeness / baseline comparison: the membrane
    potential grows by the slope ``mu_m`` every step, the slope integrates
    the weighted input spikes, and each neuron fires at most once.
    Returns ``(mu_m, v_m, fired, spikes)``.
    """
    v_m = v_m + mu_m  # Eq. (6): slope drives the potential
    mu_m = mu_m + current  # Eq. (5): inputs move the slope
    spikes = (v_m > jnp.asarray(v_t, v_m.dtype)) & (~fired)  # Eq. (7): only-spike-once
    fired = fired | spikes
    return mu_m, v_m, fired, spikes
