"""ANN -> SNN conversion (paper Sec. VII; Rueckauer et al. style).

The paper trains a conventional CNN with the clamped-ReLU activation,
retrains with quantization-aware training, converts the weights with the
SNN-Toolbox (data-based activation normalization) and quantizes to
8/16 bit.  This module reproduces that flow natively in JAX:

* ``normalize_params`` — data-based threshold balancing: each layer's
  weights/biases are rescaled by lambda_{l-1}/lambda_l where lambda_l is a
  high percentile of the layer's ANN activations on a calibration batch,
  so a firing threshold of V_t = 1 is correct for every layer;
* ``quantize_params`` — symmetric per-layer weight/bias quantization to
  the requested bit width (the datapath then runs saturating integer
  arithmetic, see core/quantization.py).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .csnn import CSNNConfig, ConvSpec, _max_pool
from .quantization import QuantSpec, calibrate_scale, quantize


def layer_activations(params: dict, images: jax.Array, cfg: CSNNConfig) -> list[jax.Array]:
    """ANN forward that records each conv layer's post-ReLU activations."""
    acts, x = [], images
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x = jax.lax.conv_general_dilated(
                x, p["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jnp.clip(x + p["b"], 0.0, cfg.relu_clamp)
            acts.append(x)
            if spec.pool:
                x = _max_pool(x, spec.pool)
    return acts


def normalize_params(params: dict, images: jax.Array, cfg: CSNNConfig,
                     percentile: float = 99.9) -> dict:
    """Data-based weight normalization so that V_t = 1 holds in every layer.

    w_l <- w_l * lambda_{l-1} / lambda_l ; b_l <- b_l / lambda_l
    with lambda_l = percentile(activations_l).  With clamped ReLU at 1.0
    the lambdas are already ~1; the general rescaling is kept so that
    unclamped networks convert correctly too.
    """
    acts = layer_activations(params, images, cfg)
    lambdas = [max(float(jnp.percentile(a, percentile)), 1e-6) for a in acts]
    out, prev = dict(params), 1.0
    ai = 0
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            lam = lambdas[ai]
            p = params[f"conv{idx}"]
            out[f"conv{idx}"] = {"w": p["w"] * (prev / lam), "b": p["b"] / lam}
            prev, ai = lam, ai + 1
    return out


def quantize_params(params: dict, bits: int, v_t: float = 1.0) -> tuple[dict, "QuantSpec"]:
    """Shared-scale symmetric quantization; returns (int_params, spec).

    One fixed-point format serves every conv layer (as on the FPGA
    datapath) so a single integer firing threshold is valid everywhere.
    The threshold is folded into the calibration range with 2x headroom —
    otherwise a small weight scale could push the integer threshold past
    the saturation point and silence the network forever.
    """
    vals = jnp.concatenate([jnp.concatenate([p["w"].ravel(), p["b"].ravel()])
                            for p in params.values()]
                           + [jnp.array([2.0 * v_t], jnp.float32)])
    spec = QuantSpec(bits=bits, scale=calibrate_scale(vals, bits))
    q_params = {name: {"w": quantize(p["w"], spec), "b": quantize(p["b"], spec)}
                for name, p in params.items()}
    return q_params, spec


def quantized_threshold(v_t: float, spec: QuantSpec) -> int:
    return int(round(v_t / spec.scale))


# ---------------------------------------------------------------------------
# ANN training (paper Sec. VII: train a clamped-ReLU CNN, then convert)
# ---------------------------------------------------------------------------


def fit_ann(params: dict, cfg: CSNNConfig, images, labels, *, steps: int = 300,
            batch: int = 64, lr: float = 2e-3, seed: int = 0,
            log_every: int = 0) -> dict:
    """Minibatch Adam training of the clamped-ReLU CNN (jit-compiled)."""
    import numpy as np

    from repro.train.optimizer import AdamWConfig, adamw_update, init_state
    from .csnn import ann_apply

    ocfg = AdamWConfig(lr=lr, warmup_steps=10, total_steps=steps,
                       weight_decay=0.0, clip_norm=1.0)
    state = init_state(params, ocfg)

    def loss_fn(p, x, y):
        logits = ann_apply(p, x, cfg)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    @jax.jit
    def step_fn(st, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(st.params, x, y)
        return adamw_update(st, grads, ocfg), loss

    rng = np.random.default_rng(seed)
    n = images.shape[0]
    for step in range(steps):
        idx = rng.integers(0, n, batch)
        state, loss = step_fn(state, jnp.asarray(images[idx]), jnp.asarray(labels[idx]))
        if log_every and (step + 1) % log_every == 0:
            print(f"  ann step {step + 1}: loss {float(loss):.4f}")
    return state.params


def ann_accuracy(params: dict, cfg: CSNNConfig, images, labels, batch: int = 256) -> float:
    from .csnn import ann_apply
    import numpy as np

    correct = 0
    for i in range(0, images.shape[0], batch):
        logits = ann_apply(params, jnp.asarray(images[i:i + batch]), cfg)
        correct += int((jnp.argmax(logits, -1) == jnp.asarray(labels[i:i + batch])).sum())
    return correct / images.shape[0]


def snn_accuracy(params: dict, cfg: CSNNConfig, images, labels, *,
                 capacity: int = 256, batch: int = 32, sat_bits=None,
                 channel_block: int = 1, collect_sparsity: bool = False):
    """m-TTFS event-driven SNN accuracy (vmapped over samples)."""
    import numpy as np

    from .csnn import encode_input, snn_apply

    run = jax.jit(jax.vmap(lambda s: snn_apply(
        params, s, cfg, capacity=capacity, channel_block=channel_block,
        sat_bits=sat_bits, collect_stats=False)))
    correct, spars = 0, []
    for i in range(0, images.shape[0], batch):
        spikes = encode_input(jnp.asarray(images[i:i + batch]), cfg)
        logits = run(spikes)
        correct += int((jnp.argmax(logits, -1) == jnp.asarray(labels[i:i + batch])).sum())
    return correct / images.shape[0]
