"""Saturating fixed-point arithmetic (paper Sec. VI-B "Update calculation").

The accelerator stores membrane potentials, weights and biases at 8 or 16
bit and uses *saturation arithmetic* instead of widening the datapaths:
overflowing additions clamp to the maximum representable value,
underflowing ones to the minimum.  The paper argues this is safe for
m-TTFS coding — saturated-high potentials stay above threshold, and
saturated-low potentials stay silent.

We model the datapath exactly: values live in int8/int16 arrays, additions
are performed in int32 and clamped back.  A small symmetric quantizer maps
trained float weights onto the fixed-point grid.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

_INT_DTYPES = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}


@dataclass(frozen=True)
class QuantSpec:
    """Symmetric fixed-point format: value = int * scale."""

    bits: int
    scale: float

    @property
    def dtype(self):
        return _INT_DTYPES[self.bits]

    @property
    def max_int(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def min_int(self) -> int:
        return -(2 ** (self.bits - 1))


def quantize(x: jax.Array, spec: QuantSpec) -> jax.Array:
    """Float -> saturating fixed point integers."""
    q = jnp.round(x / spec.scale)
    return jnp.clip(q, spec.min_int, spec.max_int).astype(spec.dtype)


def dequantize(q: jax.Array, spec: QuantSpec) -> jax.Array:
    return q.astype(jnp.float32) * spec.scale


def fake_quant(x: jax.Array, spec: QuantSpec) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient (QAT)."""
    rounded = dequantize(quantize(x, spec), spec)
    return x + jax.lax.stop_gradient(rounded - x)


def saturating_add(a: jax.Array, b: jax.Array, bits: int) -> jax.Array:
    """a + b with saturation at the int<bits> range; inputs int, output int<bits>.

    Mirrors the PE adders: the sum is formed wide (int32) and clamped, so a
    single addition can never wrap around (paper: "checking a single bit").
    """
    wide = a.astype(jnp.int32) + b.astype(jnp.int32)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return jnp.clip(wide, lo, hi).astype(_INT_DTYPES[bits])


def calibrate_scale(x: jax.Array, bits: int, percentile: float = 100.0) -> float:
    """Pick the symmetric scale that covers |x| up to the given percentile."""
    amax = jnp.percentile(jnp.abs(x), percentile)
    amax = jnp.maximum(amax, 1e-8)
    return float(amax / (2 ** (bits - 1) - 1))
