"""Event-driven convolution (paper Sec. V-B, Fig. 4; Morales et al. algorithm).

To convolve a binary fmap with a 3x3 kernel, walk its Address-Event Queue:
for each event at (i, j), add the 180deg-rotated kernel into the 3x3
neighbourhood of the membrane potentials centred at (i, j).  This yields
bit-exact sliding-window convolution results while the operation count
scales with the number of events, and it needs no multipliers (the spikes
are binary).

TPU adaptation (DESIGN.md Sec. 2):

* membrane potentials carry a one-element **halo** on every side
  (H+2, W+2), which replaces the FPGA's arithmetic out-of-bounds
  detection — edge events simply write into the halo, which is cropped,
  never read back, and never thresholded;
* the per-event update is vectorized over **output channels** (the TPU
  lane dimension) instead of over the 9 kernel taps (the FPGA's 9 PEs);
* events are applied sequentially inside a `fori_loop`/`scan`, preserving
  the exact program order of the hardware — so no RAW hazards exist by
  construction;
* `apply_events_blocked` processes the queue in fixed-size blocks under a
  `lax.while_loop` and stops as soon as the valid events are exhausted:
  the block-granular analogue of the paper's self-timed execution;
* `apply_events_batched` applies one queue per batch member to a stack of
  vm tiles, with the early exit shared across the batch (the loop bound
  is the *maximum* queue occupancy — the batch drains when its fullest
  queue drains, exactly like parallel hardware queue banks on one clock).
  Skipped slots would have contributed exact zeros, so results stay
  bit-identical to the unbatched path.

Memory-interlaced event-parallel path (paper Fig. 6 cashed in, beyond the
ordering): ``bank_vm`` splits the halo-padded membrane tile into the 9
RAM banks keyed by padded position (r%3, c%3).  All events of ONE
interlace column touch every bank at a single fixed (tap, macro-shift)
pair, so one column's whole event set applies as ONE vectorized
masked-select over the bank stack — no scatter, no per-event loop, no
hazards (same-column events are >= 3 apart, hence disjoint).  Columns are
applied in the paper's s = 0..8 order, so each membrane cell sees its
contributions in exactly the sequential queue order: the banked path is
bit-exact vs `apply_events` (including the per-event saturating int
datapaths — a cell receives at most one event per column).  See
``apply_banked_columns`` / ``apply_events_banked*``; the occupancy masks
come from ``aeq.build_bank_masks``.

`ref:` the pure sliding-window oracle is `dense_conv` below (a thin
wrapper over `lax.conv_general_dilated`); the bit-exactness property is
tested with hypothesis in tests/test_event_conv.py and
tests/test_interlaced.py.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .aeq import EventQueue
from .geometry import GEOM_3X3, ConvGeometry

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def _acc(patch: jax.Array, contrib: jax.Array) -> jax.Array:
    """patch + contrib; saturating (per event) for int8/int16 datapaths,
    mirroring the FPGA PE adders and the Pallas kernel."""
    sat = _SAT_RANGE.get(patch.dtype)
    if sat is None:
        return patch + contrib
    wide = patch.astype(jnp.int32) + contrib.astype(jnp.int32)
    return jnp.clip(wide, sat[0], sat[1]).astype(patch.dtype)


def pad_vm(vm: jax.Array, geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """Add the SAME-conv halo: (H, W, ...) -> (H+2*hh, W+2*hw, ...) with
    (hh, hw) = (kh//2, kw//2) of the geometry (1 each side for 3x3)."""
    hh, hw = geometry.halo
    pad = [(hh, hh), (hw, hw)] + [(0, 0)] * (vm.ndim - 2)
    return jnp.pad(vm, pad)


def crop_vm(vm_padded: jax.Array,
            geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """Remove the halo (identity for the k=1 zero halo)."""
    hh, hw = geometry.halo
    hp, wp = vm_padded.shape[:2]
    return vm_padded[hh:hp - hh, hw:wp - hw, ...]


def rotate_kernel(kernel: jax.Array) -> jax.Array:
    """180 degree rotation over the two leading (spatial) axes (Fig. 4)."""
    return kernel[::-1, ::-1, ...]


def _event_step(vm: jax.Array, i, j, v, k_rot: jax.Array, zero: jax.Array,
                update_sizes: tuple) -> jax.Array:
    """Apply one (possibly invalid) event to one vm tile.

    Invalid slots contribute zeros at a safe (0, 0) corner: branch-free
    masking, the jit-friendly analogue of the AEQ valid bit.  The single
    source of truth for the per-event update — every event loop in this
    module (plain, blocked, batched) goes through it.
    """
    contrib = jnp.where(v, k_rot, zero)
    start = (jnp.where(v, i, 0), jnp.where(v, j, 0)) + (0,) * (vm.ndim - 2)
    patch = jax.lax.dynamic_slice(vm, start, update_sizes)
    return jax.lax.dynamic_update_slice(vm, _acc(patch, contrib), start)


def _kernel_geometry(kernel: jax.Array, where: str) -> ConvGeometry:
    """Geometry implied by the kernel's (kh, kw, ...) shape; rejects even
    windows with an actionable message naming the planned geometry."""
    try:
        return ConvGeometry.from_kernel_shape(kernel.shape)
    except ValueError as e:
        raise ValueError(
            f"{where}: kernel shape {tuple(kernel.shape)} does not define "
            f"a valid interlaced geometry ({e})") from None


def apply_events(vm_padded: jax.Array, queue: EventQueue, kernel: jax.Array) -> jax.Array:
    """Accumulate one event queue into padded membrane potentials.

    vm_padded: (H+2hh, W+2hw) or (..., C_out)   — float or int dtype,
               halo-padded for the kernel's geometry (1 per side for 3x3).
    kernel:    (kh, kw) or (kh, kw, C_out)      — matching trailing dims;
               odd window; *unrotated* (the rotation is applied here, as
               in Fig. 4).
    """
    geom = _kernel_geometry(kernel, "apply_events")
    k_rot = rotate_kernel(kernel).astype(vm_padded.dtype)
    zero = jnp.zeros_like(k_rot)
    update_sizes = geom.window + k_rot.shape[2:]

    def body(step, vm):
        return _event_step(vm, queue.coords[step, 0], queue.coords[step, 1],
                           queue.valid[step], k_rot, zero, update_sizes)

    return jax.lax.fori_loop(0, queue.capacity, body, vm_padded)


def apply_events_blocked(vm_padded: jax.Array, queue: EventQueue, kernel: jax.Array,
                         *, block: int = 64) -> jax.Array:
    """`apply_events` with block-granular early exit (self-timed analogue).

    Processes events in blocks of ``block`` under a while_loop that stops
    once ``queue.count`` events have been consumed, so the executed work
    scales with ceil(count/block) rather than with capacity.
    """
    cap = queue.capacity
    n_blocks = -(-cap // block)
    geom = _kernel_geometry(kernel, "apply_events_blocked")
    k_rot = rotate_kernel(kernel).astype(vm_padded.dtype)
    zero = jnp.zeros_like(k_rot)
    update_sizes = geom.window + k_rot.shape[2:]

    def event_body(step, vm):
        return _event_step(vm, queue.coords[step, 0], queue.coords[step, 1],
                           queue.valid[step], k_rot, zero, update_sizes)

    def cond(carry):
        b, _ = carry
        return (b < n_blocks) & (b * block < queue.count)

    def body(carry):
        b, vm = carry
        vm = jax.lax.fori_loop(b * block, jnp.minimum((b + 1) * block, cap), event_body, vm)
        return b + 1, vm

    _, vm = jax.lax.while_loop(cond, body, (jnp.asarray(0, jnp.int32), vm_padded))
    return vm


def apply_events_batched(vm_padded: jax.Array, coords: jax.Array,
                         valid: jax.Array, counts: jax.Array,
                         kernel: jax.Array, *, block: int = 64) -> jax.Array:
    """Apply one event queue per batch member, early-exiting together.

    vm_padded: (Q, H+2hh, W+2hw, ...) — one halo-padded tile per queue.
    coords:    (Q, E, 2) int32;  valid: (Q, E) bool;  counts: (Q,) int32.
    kernel:    (kh, kw) or (kh, kw, C_out) shared by every queue.

    Event step e updates all Q tiles at once (vectorized over the batch);
    blocks of ``block`` steps run under a while_loop bounded by
    ``max(counts)``, so the executed work scales with the fullest queue
    rather than with capacity.  Bit-exact vs per-queue ``apply_events``:
    the skipped tail slots are all invalid and would contribute exact
    zeros.
    """
    geom = _kernel_geometry(kernel, "apply_events_batched")
    k_rot = rotate_kernel(kernel).astype(vm_padded.dtype)
    zero = jnp.zeros_like(k_rot)
    update_sizes = geom.window + k_rot.shape[2:]

    apply_step = jax.vmap(
        lambda vm, i, j, v: _event_step(vm, i, j, v, k_rot, zero, update_sizes))

    def event_body(step, vm):
        return apply_step(vm, coords[:, step, 0], coords[:, step, 1], valid[:, step])

    cap = coords.shape[1]
    n_blocks = -(-cap // block)
    max_count = jnp.max(counts)

    def cond(carry):
        b, _ = carry
        return (b < n_blocks) & (b * block < max_count)

    def body(carry):
        b, vm = carry
        vm = jax.lax.fori_loop(b * block, jnp.minimum((b + 1) * block, cap),
                               event_body, vm)
        return b + 1, vm

    _, vm = jax.lax.while_loop(cond, body, (jnp.asarray(0, jnp.int32), vm_padded))
    return vm


# ---------------------------------------------------------------------------
# Memory-interlaced event-parallel application (banked MemPot tiles).
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _interlace_tables(kh: int = 3, kw: int = 3):
    """Static (column, bank) routing of the interlaced conv update.

    For an event of interlace column s = kw*(i%kh)+(j%kw), kernel tap
    (a, b) in [0,kh)x[0,kw) writes padded cell (i+a, j+b), which always
    lands in padded-space bank t = kw*((i%kh+a)%kh) + (j%kw+b)%kw at a
    fixed macro shift relative to the event's centre bank.  Tables (all
    n_banks x n_banks, indexed [s, t]): PERM = flat tap index a*kw+b
    feeding bank t from column s; DI/DJ = macro-cell shift of the write
    vs the centre mask — provably in {-1, 0, +1} for every odd window,
    which is what lets ``shifted_bank_masks`` get by with a single
    macro-cell pad at any k; COL_BANK[s] = padded-space bank holding
    column-s centres (i+hh, j+hw).
    """
    hh, hw = kh // 2, kw // 2
    nb = kh * kw
    perm = np.zeros((nb, nb), np.int64)
    di = np.zeros((nb, nb), np.int64)
    dj = np.zeros((nb, nb), np.int64)
    col_bank = np.zeros(nb, np.int64)
    for s in range(nb):
        si, sj = divmod(s, kw)
        col_bank[s] = ((si + hh) % kh) * kw + (sj + hw) % kw
        for t in range(nb):
            ti, tj = divmod(t, kw)
            a = (ti - si) % kh
            b = (tj - sj) % kw
            perm[s, t] = a * kw + b
            di[s, t] = (si + a) // kh - (si + hh) // kh
            dj[s, t] = (sj + b) // kw - (sj + hw) // kw
    return perm, di, dj, col_bank


_PERM, _DI, _DJ, _COL_BANK = _interlace_tables()


def bank_vm(vm_padded: jax.Array,
            geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """(..., Hp, Wp, C) halo-padded tile -> (..., n_banks, HB, WB, C)
    RAM banks.

    Bank t = kw*(r%kh) + (c%kw) of padded position (r, c); macro address
    (r//kh, c//kw).  Hp/Wp are zero-padded up to window multiples (the
    extra rows are never written — events write rows <= Hp-1 — and are
    dropped again by ``unbank_vm``).  Same banking as ``aeq.interlace``,
    with the trailing channel axis riding along.
    """
    kh, kw = geometry.kh, geometry.kw
    *lead, hp, wp, c = vm_padded.shape
    hb, wb = -(-hp // kh), -(-wp // kw)
    nl = len(lead)
    v = jnp.pad(vm_padded,
                [(0, 0)] * nl + [(0, kh * hb - hp), (0, kw * wb - wp),
                                 (0, 0)])
    v = v.reshape(*lead, hb, kh, wb, kw, c)
    v = v.transpose(*range(nl), nl + 1, nl + 3, nl, nl + 2, nl + 4)
    return v.reshape(*lead, kh * kw, hb, wb, c)


def unbank_vm(vm_banked: jax.Array, hp: int, wp: int,
              geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """Inverse of ``bank_vm``: (..., n_banks, HB, WB, C) ->
    (..., Hp, Wp, C)."""
    kh, kw = geometry.kh, geometry.kw
    *lead, _, hb, wb, c = vm_banked.shape
    nl = len(lead)
    v = vm_banked.reshape(*lead, kh, kw, hb, wb, c)
    v = v.transpose(*range(nl), nl + 2, nl, nl + 3, nl + 1, nl + 4)
    v = v.reshape(*lead, kh * hb, kw * wb, c)
    return v[..., :hp, :wp, :]


def shifted_bank_masks(masks: jax.Array,
                       geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """Pre-shift bank occupancy masks into per-(column, bank) write masks.

    masks: (..., n_banks, HB, WB) bool from ``aeq.build_bank_masks``
    (bank occupancy of the kept events' padded centres).  Returns
    (..., n_banks cols, n_banks banks, HB, WB): entry [s, t, I, J] is
    True iff bank t's cell (I, J) receives column s's tap — i.e. the
    centre mask of column s shifted by the static (DI, DJ)[s, t] macro
    offset.  Built as n_banks^2 static slices of one zero-padded array
    and a single stack (81 for 3x3), so the cost is one pass over the
    mask data; precompute it once per queue and reuse across channel
    blocks.  The single macro-cell pad suffices for every odd window
    because DI/DJ stay in {-1, 0, +1} (see ``_interlace_tables``).
    """
    perm, di_t, dj_t, col_bank = _interlace_tables(geometry.kh, geometry.kw)
    nb = geometry.n_banks
    hb, wb = masks.shape[-2:]
    nl = masks.ndim - 3
    mp = jnp.pad(masks, [(0, 0)] * (nl + 1) + [(1, 1), (1, 1)])
    per_col = []
    for s in range(nb):
        m = mp[..., col_bank[s], :, :]
        per_bank = []
        for t in range(nb):
            r0 = 1 - int(di_t[s, t])
            c0 = 1 - int(dj_t[s, t])
            per_bank.append(m[..., r0:r0 + hb, c0:c0 + wb])
        per_col.append(jnp.stack(per_bank, axis=nl))
    return jnp.stack(per_col, axis=nl)


def tap_matrix(kernel: jax.Array) -> jax.Array:
    """(kh, kw, ...) unrotated kernel -> (n_banks cols, n_banks banks,
    ...) tap values.

    Entry [s, t] is the (already 180deg-rotated) tap that column-s events
    contribute to bank t.  One static gather; hoist it out of scan/loop
    bodies so the per-column select chain stays fusable.
    """
    geom = _kernel_geometry(kernel, "tap_matrix")
    perm, _, _, _ = _interlace_tables(geom.kh, geom.kw)
    k_rot = rotate_kernel(kernel)
    flat = k_rot.reshape((geom.n_banks,) + k_rot.shape[2:])
    return flat[perm]


def _acc_masked(bank: jax.Array, tap: jax.Array, mask: jax.Array) -> jax.Array:
    """bank + tap*mask with the saturating int datapath; exact either way.

    mask is 0/1, so the contribution is exactly ``tap`` or exactly zero
    (x*1 and x+0 are identities in IEEE and integer arithmetic alike; the
    only non-identity is the sign of zero on untouched cells, which no
    downstream computation can observe — zeros compare equal and additions
    from +0-initialised potentials never produce -0).  For int dtypes the
    masked add is widened and clipped, preserving per-event saturation
    (clip is the identity on in-range untouched cells).
    """
    m = mask[..., None]
    sat = _SAT_RANGE.get(bank.dtype)
    if sat is None:
        return bank + tap * m.astype(bank.dtype)
    wide = bank.astype(jnp.int32) + tap.astype(jnp.int32) * m.astype(jnp.int32)
    return jnp.clip(wide, sat[0], sat[1]).astype(bank.dtype)


def apply_banked_columns(vm_banked: jax.Array, smasks: jax.Array,
                         taps: jax.Array) -> jax.Array:
    """Apply one queue's events to a banked tile, one column at a time.

    vm_banked: (..., n_banks, HB, WB, C) from ``bank_vm``.
    smasks:    (..., n_banks cols, n_banks banks, HB, WB) from
               ``shifted_bank_masks``.
    taps:      (n_banks cols, n_banks banks, C) from ``tap_matrix``
               (vm dtype).

    Each column step applies ALL of that column's events at once
    (disjointness makes this exact: a cell receives at most one event per
    column), and the s = 0..n_banks-1 order reproduces the sequential
    queue order per membrane cell, so the result equals ``apply_events``
    bit for bit — per-event int saturation included.  The loop nest runs
    BANK-major: each bank is pulled out once and receives its n_banks
    column contributions as a cache-resident multiply-add chain (a bank
    is 1/n_banks of the tile), which is what makes the banked unit faster
    than the per-event walk — RAM traffic is one read+write of the tile
    per queue instead of one window-patch round-trip per event.
    """
    nb = taps.shape[0]
    banks = []
    for t in range(nb):
        bank = vm_banked[..., t, :, :, :]
        for s in range(nb):
            bank = _acc_masked(bank, taps[s, t], smasks[..., s, t, :, :])
        banks.append(bank)
    return jnp.stack(banks, axis=-4)


def apply_banked_columns_fused(vm_banked: jax.Array, padded_masks: jax.Array,
                               taps: jax.Array,
                               geometry: ConvGeometry = GEOM_3X3
                               ) -> jax.Array:
    """``apply_banked_columns`` consuming the fused-handoff carrier.

    vm_banked:    (..., n_banks, HB, WB, C) from ``bank_vm``.
    padded_masks: (..., n_banks, HB+2, WB+2) bool — centre-bank occupancy
                  with one macro cell of padding per side
                  (``aeq.build_fused_handoff``).
    taps:         (n_banks cols, n_banks banks, C) from ``tap_matrix``.

    Each (column s, bank t) shifted write mask is a STATIC slice of the
    padded carrier — ``padded_masks[..., COL_BANK[s], 1-DI[s,t] :, 1-DJ
    [s,t] :]`` — which XLA fuses straight into the masked adds, so the
    n_banks^2 ``shifted_bank_masks`` stack is never materialized (the
    second O(HW) pass the fused-handoff variant eliminates).  The slices
    reproduce the shifted masks exactly and the bank-major s-order chain
    is unchanged, so this is bit-exact vs ``apply_banked_columns`` over
    ``shifted_bank_masks`` of the unpadded masks — per-event int
    saturation included (tests/test_fused_handoff.py).
    """
    _, di_t, dj_t, col_bank = _interlace_tables(geometry.kh, geometry.kw)
    nb = geometry.n_banks
    hb, wb = vm_banked.shape[-3], vm_banked.shape[-2]
    banks = []
    for t in range(nb):
        bank = vm_banked[..., t, :, :, :]
        for s in range(nb):
            r0 = 1 - int(di_t[s, t])
            c0 = 1 - int(dj_t[s, t])
            m = padded_masks[..., int(col_bank[s]), r0:r0 + hb, c0:c0 + wb]
            bank = _acc_masked(bank, taps[s, t], m)
        banks.append(bank)
    return jnp.stack(banks, axis=-4)


def apply_events_banked(vm_padded: jax.Array, masks: jax.Array,
                        kernel: jax.Array) -> jax.Array:
    """Banked-path equivalent of ``apply_events`` for one tile.

    vm_padded: (Hp, Wp) or (Hp, Wp, C); masks: (n_banks, HB, WB) bank
    occupancy of the kept events (``aeq.build_bank_masks``); kernel:
    (kh, kw) or (kh, kw, C) unrotated.  Bit-exact vs ``apply_events`` on
    the queue of the same events (tests/test_interlaced.py).
    """
    geom = _kernel_geometry(kernel, "apply_events_banked")
    squeeze = vm_padded.ndim == 2
    vm = vm_padded[..., None] if squeeze else vm_padded
    k = kernel[..., None] if squeeze else kernel
    hp, wp = vm.shape[-3:-1]
    out = unbank_vm(
        apply_banked_columns(bank_vm(vm, geom),
                             shifted_bank_masks(masks, geom),
                             tap_matrix(k).astype(vm.dtype)),
        hp, wp, geom)
    return out[..., 0] if squeeze else out


def apply_events_banked_batched(vm_padded: jax.Array, masks: jax.Array,
                                kernel: jax.Array) -> jax.Array:
    """Banked path over a stack of tiles: one queue per batch member.

    vm_padded: (Q, Hp, Wp, C); masks: (Q, n_banks, HB, WB); kernel:
    (kh, kw, C) shared by every queue.  Pure elementwise selects, so the
    batch dimension vectorizes for free — bit-exact vs per-queue
    ``apply_events`` (no shared early-exit bound is needed: empty columns
    contribute all-False masks).
    """
    geom = _kernel_geometry(kernel, "apply_events_banked_batched")
    hp, wp = vm_padded.shape[-3:-1]
    return unbank_vm(
        apply_banked_columns(bank_vm(vm_padded, geom),
                             shifted_bank_masks(masks, geom),
                             tap_matrix(kernel).astype(vm_padded.dtype)),
        hp, wp, geom)


def dense_conv(fmap: jax.Array, kernel: jax.Array) -> jax.Array:
    """Sliding-window oracle: SAME conv of a binary fmap with a k x k
    kernel.

    fmap: (H, W) bool/float; kernel: (kh, kw) or (kh, kw, C_out).
    Returns (H, W) or (H, W, C_out) in kernel dtype.  This is the
    frame-based baseline the paper compares against (SIES-style).
    """
    x = fmap.astype(kernel.dtype)[None, :, :, None]  # NHWC, C_in=1
    if kernel.ndim == 2:
        k = kernel[:, :, None, None]
    else:
        k = kernel[:, :, None, :]
    out = jax.lax.conv_general_dilated(
        x, k, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    out = out[0]
    return out[:, :, 0] if kernel.ndim == 2 else out
