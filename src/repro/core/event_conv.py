"""Event-driven convolution (paper Sec. V-B, Fig. 4; Morales et al. algorithm).

To convolve a binary fmap with a 3x3 kernel, walk its Address-Event Queue:
for each event at (i, j), add the 180deg-rotated kernel into the 3x3
neighbourhood of the membrane potentials centred at (i, j).  This yields
bit-exact sliding-window convolution results while the operation count
scales with the number of events, and it needs no multipliers (the spikes
are binary).

TPU adaptation (DESIGN.md Sec. 2):

* membrane potentials carry a one-element **halo** on every side
  (H+2, W+2), which replaces the FPGA's arithmetic out-of-bounds
  detection — edge events simply write into the halo, which is cropped,
  never read back, and never thresholded;
* the per-event update is vectorized over **output channels** (the TPU
  lane dimension) instead of over the 9 kernel taps (the FPGA's 9 PEs);
* events are applied sequentially inside a `fori_loop`/`scan`, preserving
  the exact program order of the hardware — so no RAW hazards exist by
  construction;
* `apply_events_blocked` processes the queue in fixed-size blocks under a
  `lax.while_loop` and stops as soon as the valid events are exhausted:
  the block-granular analogue of the paper's self-timed execution;
* `apply_events_batched` applies one queue per batch member to a stack of
  vm tiles, with the early exit shared across the batch (the loop bound
  is the *maximum* queue occupancy — the batch drains when its fullest
  queue drains, exactly like parallel hardware queue banks on one clock).
  Skipped slots would have contributed exact zeros, so results stay
  bit-identical to the unbatched path.

`ref:` the pure sliding-window oracle is `dense_conv` below (a thin
wrapper over `lax.conv_general_dilated`); the bit-exactness property is
tested with hypothesis in tests/test_event_conv.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .aeq import EventQueue

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def _acc(patch: jax.Array, contrib: jax.Array) -> jax.Array:
    """patch + contrib; saturating (per event) for int8/int16 datapaths,
    mirroring the FPGA PE adders and the Pallas kernel."""
    sat = _SAT_RANGE.get(patch.dtype)
    if sat is None:
        return patch + contrib
    wide = patch.astype(jnp.int32) + contrib.astype(jnp.int32)
    return jnp.clip(wide, sat[0], sat[1]).astype(patch.dtype)


def pad_vm(vm: jax.Array) -> jax.Array:
    """Add the 1-element halo: (H, W, ...) -> (H+2, W+2, ...)."""
    pad = [(1, 1), (1, 1)] + [(0, 0)] * (vm.ndim - 2)
    return jnp.pad(vm, pad)


def crop_vm(vm_padded: jax.Array) -> jax.Array:
    """Remove the halo."""
    return vm_padded[1:-1, 1:-1, ...]


def rotate_kernel(kernel: jax.Array) -> jax.Array:
    """180 degree rotation over the two leading (spatial) axes (Fig. 4)."""
    return kernel[::-1, ::-1, ...]


def _event_step(vm: jax.Array, i, j, v, k_rot: jax.Array, zero: jax.Array,
                update_sizes: tuple) -> jax.Array:
    """Apply one (possibly invalid) event to one vm tile.

    Invalid slots contribute zeros at a safe (0, 0) corner: branch-free
    masking, the jit-friendly analogue of the AEQ valid bit.  The single
    source of truth for the per-event update — every event loop in this
    module (plain, blocked, batched) goes through it.
    """
    contrib = jnp.where(v, k_rot, zero)
    start = (jnp.where(v, i, 0), jnp.where(v, j, 0)) + (0,) * (vm.ndim - 2)
    patch = jax.lax.dynamic_slice(vm, start, update_sizes)
    return jax.lax.dynamic_update_slice(vm, _acc(patch, contrib), start)


def apply_events(vm_padded: jax.Array, queue: EventQueue, kernel: jax.Array) -> jax.Array:
    """Accumulate one event queue into padded membrane potentials.

    vm_padded: (H+2, W+2) or (H+2, W+2, C_out)  — float or int dtype.
    kernel:    (3, 3) or (3, 3, C_out)          — matching trailing dims;
               *unrotated* (the rotation is applied here, as in Fig. 4).
    """
    if kernel.shape[:2] != (3, 3):
        raise ValueError(f"event conv is specialized for 3x3 kernels, got {kernel.shape}")
    k_rot = rotate_kernel(kernel).astype(vm_padded.dtype)
    zero = jnp.zeros_like(k_rot)
    update_sizes = (3, 3) + k_rot.shape[2:]

    def body(step, vm):
        return _event_step(vm, queue.coords[step, 0], queue.coords[step, 1],
                           queue.valid[step], k_rot, zero, update_sizes)

    return jax.lax.fori_loop(0, queue.capacity, body, vm_padded)


def apply_events_blocked(vm_padded: jax.Array, queue: EventQueue, kernel: jax.Array,
                         *, block: int = 64) -> jax.Array:
    """`apply_events` with block-granular early exit (self-timed analogue).

    Processes events in blocks of ``block`` under a while_loop that stops
    once ``queue.count`` events have been consumed, so the executed work
    scales with ceil(count/block) rather than with capacity.
    """
    cap = queue.capacity
    n_blocks = -(-cap // block)
    k_rot = rotate_kernel(kernel).astype(vm_padded.dtype)
    zero = jnp.zeros_like(k_rot)
    update_sizes = (3, 3) + k_rot.shape[2:]

    def event_body(step, vm):
        return _event_step(vm, queue.coords[step, 0], queue.coords[step, 1],
                           queue.valid[step], k_rot, zero, update_sizes)

    def cond(carry):
        b, _ = carry
        return (b < n_blocks) & (b * block < queue.count)

    def body(carry):
        b, vm = carry
        vm = jax.lax.fori_loop(b * block, jnp.minimum((b + 1) * block, cap), event_body, vm)
        return b + 1, vm

    _, vm = jax.lax.while_loop(cond, body, (jnp.asarray(0, jnp.int32), vm_padded))
    return vm


def apply_events_batched(vm_padded: jax.Array, coords: jax.Array,
                         valid: jax.Array, counts: jax.Array,
                         kernel: jax.Array, *, block: int = 64) -> jax.Array:
    """Apply one event queue per batch member, early-exiting together.

    vm_padded: (Q, H+2, W+2, ...) — one halo-padded tile per queue.
    coords:    (Q, E, 2) int32;  valid: (Q, E) bool;  counts: (Q,) int32.
    kernel:    (3, 3) or (3, 3, C_out) shared by every queue.

    Event step e updates all Q tiles at once (vectorized over the batch);
    blocks of ``block`` steps run under a while_loop bounded by
    ``max(counts)``, so the executed work scales with the fullest queue
    rather than with capacity.  Bit-exact vs per-queue ``apply_events``:
    the skipped tail slots are all invalid and would contribute exact
    zeros.
    """
    k_rot = rotate_kernel(kernel).astype(vm_padded.dtype)
    zero = jnp.zeros_like(k_rot)
    update_sizes = (3, 3) + k_rot.shape[2:]

    apply_step = jax.vmap(
        lambda vm, i, j, v: _event_step(vm, i, j, v, k_rot, zero, update_sizes))

    def event_body(step, vm):
        return apply_step(vm, coords[:, step, 0], coords[:, step, 1], valid[:, step])

    cap = coords.shape[1]
    n_blocks = -(-cap // block)
    max_count = jnp.max(counts)

    def cond(carry):
        b, _ = carry
        return (b < n_blocks) & (b * block < max_count)

    def body(carry):
        b, vm = carry
        vm = jax.lax.fori_loop(b * block, jnp.minimum((b + 1) * block, cap),
                               event_body, vm)
        return b + 1, vm

    _, vm = jax.lax.while_loop(cond, body, (jnp.asarray(0, jnp.int32), vm_padded))
    return vm


def dense_conv(fmap: jax.Array, kernel: jax.Array) -> jax.Array:
    """Sliding-window oracle: SAME conv of a binary fmap with a 3x3 kernel.

    fmap: (H, W) bool/float; kernel: (3, 3) or (3, 3, C_out).
    Returns (H, W) or (H, W, C_out) in kernel dtype.  This is the
    frame-based baseline the paper compares against (SIES-style).
    """
    x = fmap.astype(kernel.dtype)[None, :, :, None]  # NHWC, C_in=1
    if kernel.ndim == 2:
        k = kernel[:, :, None, None]
    else:
        k = kernel[:, :, None, :]
    out = jax.lax.conv_general_dilated(
        x, k, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    out = out[0]
    return out[:, :, 0] if kernel.ndim == 2 else out
