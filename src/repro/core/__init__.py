"""Core library: the paper's contribution as composable JAX modules.

Sommer et al., "Efficient Hardware Acceleration of Sparsely Active
Convolutional Spiking Neural Networks" (TCAD 2022), adapted FPGA -> TPU:

* aeq          — Address-Event-Queue compaction + memory interlacing (C1, C3)
* event_conv   — event-driven convolution, halo-padded, channel-vectorized (C2)
* threshold    — bias + threshold + OR-max-pool sweep (C5)
* scheduler    — Algorithm-1 channel-multiplexed execution (C4)
* neuron       — IF / m-TTFS / TTFS cells (C6)
* encoding     — multi-threshold m-TTFS input binarization (C6)
* quantization — saturating 8/16-bit datapaths (C7)
* conversion   — ANN->SNN threshold balancing + weight quantization (C9)
* csnn         — model assembly (ANN train path + SNN inference paths)
* pipeline_sim — cycle-level FPGA pipeline model for PE utilization (C8)
"""
from .aeq import (BankedEvents, BatchedEventQueue, EventQueue, StreamChunk,
                  StreamState, append_events, append_events_batched,
                  build_aeq, build_aeq_batched, build_bank_masks,
                  calibrate_capacities, calibrate_capacity, column_index,
                  deinterlace, init_stream_state, interlace,
                  interlaced_capacity, make_stream_chunk, scatter_aeq,
                  segment_pad, stream_frames, stream_queues)
from .csnn import (CSNNConfig, CSNNState, ConvSpec, FCSpec, ann_apply,
                   encode_input, init_params, init_state, snn_apply,
                   snn_apply_batched, snn_apply_dense, snn_apply_sharded,
                   snn_readout, snn_step_chunk)
from .encoding import mttfs_thresholds, multi_threshold_encode, rate_encode, spike_sparsity
from .event_conv import (apply_banked_columns, apply_events,
                         apply_events_banked, apply_events_banked_batched,
                         apply_events_batched, apply_events_blocked, bank_vm,
                         crop_vm, dense_conv, pad_vm, rotate_kernel,
                         shifted_bank_masks, tap_matrix, unbank_vm)
from .neuron import IFState, if_reset_step, mttfs_step, ttfs_slope_step
from .plan import (LayerPlan, NetworkPlan, effective_capacity, pad_capacity,
                   plan_conv_layer, plan_network, snap_t_chunk)
from .quantization import QuantSpec, calibrate_scale, dequantize, fake_quant, quantize, saturating_add
from .scheduler import (ConvCarry, LayerStats, init_conv_carry,
                        run_conv_layer, run_conv_layer_batched,
                        run_conv_layer_batched_chunk,
                        run_conv_layer_batched_chunk_streamed,
                        run_conv_layer_batched_planned, run_conv_layer_dense,
                        run_conv_layer_planned, run_fc_head,
                        run_fc_head_batched)
from .threshold import ThresholdResult, or_pool, threshold_unit
