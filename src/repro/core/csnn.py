"""CSNN model assembly: the paper's 28x28-32C3-32C3-P3-10C3-F10 network.

The execution paths share one parameter pytree:

* ``ann_apply``     — the clamped-ReLU CNN used for training (paper
  Sec. VII trains a conventional CNN and converts it);
* ``snn_apply``     — T-step m-TTFS spiking inference through the
  event-driven scheduler (Algorithm 1), the system under study;
* ``snn_apply_batched`` — the same inference for a whole sample batch
  with queue construction and kernel launches amortized across it
  (bit-exact vs ``vmap(snn_apply)``; the serving entry point).  Built as
  a thin wrapper over the step-resumable form below;
* ``init_state`` / ``snn_step_chunk`` / ``snn_readout`` — the pipeline
  cut at time-chunk boundaries: an explicit :class:`CSNNState` carry
  (per-layer MemPot stacks + fired latches + accumulated FC drive)
  advances ``plan.chunk_steps`` steps per call.  Chaining chunks is
  bit-exact vs the monolithic apply; the serving engine's continuous
  batching (slot-level refill) runs on this form;
* ``snn_apply_sharded`` — ``snn_apply_batched`` shard_mapped over the
  batch axis of a device mesh (queues are per-sample-independent, so the
  shards never communicate; bit-exact vs the unsharded batched path);
* ``snn_apply_dense`` — frame-based spiking oracle (dense baseline).

Every entry point consumes a :class:`~repro.core.plan.NetworkPlan` — the
static per-layer resource plan (queue capacities, channel/event blocks,
membrane tiles) derived once by ``plan_network``.  The loose
``capacity=``/``channel_block=`` kwargs remain as deprecation shims that
build an equivalent plan on the fly (bit-exact; tests/test_plan.py).

Parameters are plain dicts of jnp arrays; layer specs are tiny frozen
dataclasses so a config file can describe any CSNN in one line.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from .aeq import StreamState, build_fused_handoff
from .encoding import mttfs_thresholds, multi_threshold_encode
from .plan import NetworkPlan, plan_network
from .scheduler import (ConvCarry, LayerStats, init_conv_carry,
                        run_conv_layer_batched_chunk,
                        run_conv_layer_batched_chunk_streamed,
                        run_conv_layer_batched_planned, run_conv_layer_dense,
                        run_conv_layer_planned, run_fc_head,
                        run_fc_head_batched)


@dataclass(frozen=True)
class ConvSpec:
    channels: int
    kernel: int = 3
    pool: Optional[int] = None  # OR-max-pool window applied after this layer


@dataclass(frozen=True)
class FCSpec:
    features: int


@dataclass(frozen=True)
class CSNNConfig:
    """`28x28-32C3-32C3-P3-10C3-F10` == the paper's network (defaults)."""

    input_hw: tuple[int, int] = (28, 28)
    input_channels: int = 1   # e.g. 2 for 2-polarity DVS event frames
    layers: Sequence = field(default_factory=lambda: (
        ConvSpec(32), ConvSpec(32, pool=3), ConvSpec(10), FCSpec(10)))
    t_steps: int = 5          # paper: T=5 gave the best accuracy
    v_t: float = 1.0          # firing threshold after conversion
    relu_clamp: float = 1.0   # clamped-ReLU ceiling used during ANN training


def conv_out_hw(hw: tuple[int, int], spec: ConvSpec) -> tuple[int, int]:
    h, w = hw  # SAME padding keeps H, W; pooling ceil-divides
    if spec.pool:
        return (-(-h // spec.pool), -(-w // spec.pool))
    return (h, w)


def init_params(rng: jax.Array, cfg: CSNNConfig, dtype=jnp.float32) -> dict:
    params = {}
    hw, c_in = cfg.input_hw, cfg.input_channels
    for idx, spec in enumerate(cfg.layers):
        key = jax.random.fold_in(rng, idx)
        if isinstance(spec, ConvSpec):
            fan_in = spec.kernel * spec.kernel * c_in
            params[f"conv{idx}"] = {
                "w": jax.random.normal(key, (spec.kernel, spec.kernel, c_in, spec.channels),
                                       dtype) * (2.0 / fan_in) ** 0.5,
                "b": jnp.zeros((spec.channels,), dtype),
            }
            hw, c_in = conv_out_hw(hw, spec), spec.channels
        else:
            d = hw[0] * hw[1] * c_in
            params[f"fc{idx}"] = {
                "w": jax.random.normal(key, (d, spec.features), dtype) * (1.0 / d) ** 0.5,
                "b": jnp.zeros((spec.features,), dtype),
            }
    return params


def ann_apply(params: dict, images: jax.Array, cfg: CSNNConfig) -> jax.Array:
    """Clamped-ReLU CNN forward (training path).
    images: (B, H, W, cfg.input_channels) in [0,1]."""
    x = images
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x = jax.lax.conv_general_dilated(
                x, p["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = x + p["b"]
            x = jnp.clip(x, 0.0, cfg.relu_clamp)  # clamped ReLU (Rueckauer)
            if spec.pool:
                x = _max_pool(x, spec.pool)
        else:
            p = params[f"fc{idx}"]
            x = x.reshape(x.shape[0], -1) @ p["w"] + p["b"]
    return x


def _max_pool(x: jax.Array, window: int) -> jax.Array:
    pads = [(0, 0), (0, -x.shape[1] % window), (0, -x.shape[2] % window), (0, 0)]
    x = jnp.pad(x, pads, constant_values=-jnp.inf)
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1), (1, window, window, 1), "VALID")


def encode_input(images: jax.Array, cfg: CSNNConfig) -> jax.Array:
    """(B, H, W, C) floats in [0,1] -> (B, T, H, W, C) m-TTFS input spikes."""
    thresholds = mttfs_thresholds(cfg.t_steps)
    enc = lambda img: multi_threshold_encode(img, thresholds, cfg.t_steps)
    return jax.vmap(enc)(images)


def _resolve_plan(
    cfg: CSNNConfig,
    plan: Optional[NetworkPlan],
    capacity: int | Sequence[int],
    channel_block: int,
    sat_bits: Optional[int],
) -> NetworkPlan:
    """Deprecation-shim glue: build a plan from loose kwargs when the
    caller did not pass one, else validate the given plan against cfg."""
    if plan is None:
        return plan_network(cfg, capacity=capacity,
                            channel_block=channel_block, sat_bits=sat_bits)
    return plan.validate(cfg)


def snn_apply(
    params: dict,
    in_spikes: jax.Array,
    cfg: CSNNConfig,
    plan: Optional[NetworkPlan] = None,
    *,
    capacity: int | Sequence[int] = 256,
    channel_block: int = 1,
    sat_bits: Optional[int] = None,
    collect_stats: bool = True,
):
    """Event-driven m-TTFS inference for ONE sample.

    in_spikes: (T, H, W, 1) bool.  Returns (logits, [LayerStats, ...]).
    ``plan`` carries the per-layer resource sizing (build it once with
    ``plan_network``); the ``capacity``/``channel_block``/``sat_bits``
    kwargs are the deprecated shim spelling and are ignored when a plan
    is given.  vmap over samples for batching; the paper's xP parallelism
    sweep maps to batching + channel_block.
    """
    plan = _resolve_plan(cfg, plan, capacity, channel_block, sat_bits)
    x, stats, ci = in_spikes, [], 0
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x, st = run_conv_layer_planned(x, p["w"], p["b"], cfg.v_t,
                                           plan.layers[ci])
            stats.append(st)
            ci += 1
        else:
            p = params[f"fc{idx}"]
            logits = run_fc_head(x, p["w"], p["b"],
                                 capacity=plan.fc_capacity)
    return (logits, stats) if collect_stats else logits


class CSNNState(NamedTuple):
    """Explicit per-layer carry of the event pipeline over a sample batch.

    Everything ``snn_apply_batched`` used to keep implicit inside its
    per-layer scans, extracted so execution can stop and resume at any
    chunk boundary:

    * ``convs`` — one :class:`~repro.core.scheduler.ConvCarry` per conv
      layer (halo-padded MemPot stack + m-TTFS fired latches);
    * ``fc_drive`` — (B, D) accumulated spike drive into the
      classification head (exact small integers in the head's dtype, so
      chunked accumulation is bit-exact vs one whole-T sum).

    A pytree (NamedTuple of arrays): jit/donate/device_put all work.
    Every row is per-sample independent — the serving engine exploits
    this by resetting single rows as batch slots retire and refill.
    """

    convs: tuple
    fc_drive: jax.Array


def init_state(params: dict, cfg: CSNNConfig,
               plan: NetworkPlan, batch: int) -> CSNNState:
    """Fresh (t=0) :class:`CSNNState` for ``batch`` samples."""
    plan.validate(cfg)
    convs = tuple(init_conv_carry(lp, batch) for lp in plan.layers)
    last = plan.layers[-1]
    d = last.out_hw[0] * last.out_hw[1] * last.c_out
    fc_dtype = jnp.float32
    for idx, spec in enumerate(cfg.layers):
        if not isinstance(spec, ConvSpec):
            fc_dtype = params[f"fc{idx}"]["w"].dtype
    return CSNNState(convs=convs, fc_drive=jnp.zeros((batch, d), fc_dtype))


def snn_step_chunk(
    params: dict,
    state: CSNNState,
    spikes_chunk: jax.Array,
    cfg: CSNNConfig,
    plan: NetworkPlan,
    *,
    backend: str = "jax",
    collect_stats: bool = False,
):
    """Advance the batched event pipeline by one chunk of time steps.

    spikes_chunk: (B, t_chunk, H, W, C_in) bool — the next ``t_chunk``
    input time steps for every batch row (``plan.chunk_steps`` per call;
    any chunk length works, but the serving engine keeps one shape so
    nothing retraces) — OR a :class:`~repro.core.aeq.StreamState` with
    banks (B, t_chunk, C_in, n_banks, HB, WB): pre-ingested raw DVS events
    (``aeq.append_events*``), in which case the first conv layer consumes
    the input queues finalized sort-free from the banks instead of
    re-compacting dense frames (bit-exact either way;
    tests/test_streaming.py).  Each conv layer consumes the chunk from
    its carry, the head drive accumulates the final conv layer's output
    spikes, and the new :class:`CSNNState` is returned.  Chaining
    T/t_chunk calls from ``init_state`` reproduces the monolithic
    pipeline bit-exactly (per time step the computation is identical;
    only the scans are cut), which is what lets the engine admit new
    requests mid-flight without perturbing in-flight ones.

    Fused spike emission (ISSUE 10): when the NEXT conv layer is pinned
    to the ``"fused-handoff"`` variant, this loop is where the handoff
    happens — the producer's pooled output is compacted once into the
    consumer's :class:`~repro.core.aeq.FusedHandoff` carrier at the layer
    boundary and passed in place of the dense spike tensor, so the
    consumer never re-runs the dense->queue compaction pass.

    Returns ``state`` or ``(state, [chunk LayerStats, ...])`` with
    ``collect_stats``.
    """
    x, stats, ci = spikes_chunk, [], 0
    n_conv = len(plan.layers)
    new_convs = []
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            if isinstance(x, StreamState):  # streamed input, layer 0 only
                x, carry, st = run_conv_layer_batched_chunk_streamed(
                    x, p["w"], p["b"], cfg.v_t, plan.layers[ci],
                    state.convs[ci], backend=backend)
            else:
                x, carry, st = run_conv_layer_batched_chunk(
                    x, p["w"], p["b"], cfg.v_t, plan.layers[ci],
                    state.convs[ci], backend=backend)
            new_convs.append(carry)
            stats.append(st)
            ci += 1
            if (ci < n_conv and plan.layers[ci].resolve_variant(backend)
                    == "fused-handoff"):
                nxt = plan.layers[ci]
                x = build_fused_handoff(x, nxt.capacity, nxt.geometry)
    b, c = x.shape[:2]
    drive = x.reshape(b, c, -1).astype(state.fc_drive.dtype).sum(axis=1)
    state = CSNNState(convs=tuple(new_convs),
                      fc_drive=state.fc_drive + drive)
    return (state, stats) if collect_stats else state


def snn_readout(params: dict, state: CSNNState, cfg: CSNNConfig,
                plan: Optional[NetworkPlan] = None) -> jax.Array:
    """Classification-unit readout of a (fully or partially stepped) state.

    Matches ``run_fc_head_batched`` on the accumulated drive: the output
    neurons integrate weighted spikes plus ``T x bias`` and are never
    thresholded.  After all T steps the result is bit-exact vs the
    monolithic ``snn_apply_batched`` logits — ``fc_drive`` holds exact
    spike counts, so the (B, D) contraction sees identical values.
    When ``plan.fc_capacity`` is set, the drive routes through the
    event-driven sparse head (``sparse_ffn.event_readout``) instead:
    top-``fc_capacity`` AEQ compaction scattered back into the same
    dense contraction — bit-exact while the queue covers every nonzero
    drive entry (tests/test_sparse_ffn.py).
    """
    fc_capacity = plan.fc_capacity if plan is not None else None
    logits = None
    for idx, spec in enumerate(cfg.layers):
        if not isinstance(spec, ConvSpec):
            p = params[f"fc{idx}"]
            drive = state.fc_drive
            if fc_capacity is not None:
                from .sparse_ffn import event_readout
                logits = (event_readout(drive, p["w"], capacity=fc_capacity)
                          + cfg.t_steps * p["b"])
            else:
                logits = drive @ p["w"] + cfg.t_steps * p["b"]
    if logits is None:
        raise ValueError("cfg has no FC head layer")
    return logits


def _merge_chunk_stats(chunks: list) -> list:
    """Stitch per-chunk LayerStats back into whole-T stats: counts
    concatenate along the time axis; ``in_sparsity`` averages the
    (equal-length) chunk means; ``event_block`` is constant."""
    merged = []
    for per_layer in zip(*chunks):
        merged.append(LayerStats(
            in_spike_counts=jnp.concatenate(
                [s.in_spike_counts for s in per_layer], axis=1),
            out_spike_counts=jnp.concatenate(
                [s.out_spike_counts for s in per_layer], axis=1),
            in_sparsity=sum(s.in_sparsity for s in per_layer) / len(per_layer),
            event_block=per_layer[0].event_block,
            event_par=per_layer[0].event_par,
        ))
    return merged


def snn_apply_batched(
    params: dict,
    in_spikes: jax.Array,
    cfg: CSNNConfig,
    plan: Optional[NetworkPlan] = None,
    *,
    capacity: int | Sequence[int] = 256,
    channel_block: int = 1,
    sat_bits: Optional[int] = None,
    collect_stats: bool = True,
    backend: str = "jax",
):
    """Event-driven m-TTFS inference for a SAMPLE BATCH.

    in_spikes: (B, T, H, W, C_in) bool.  Returns (logits (B, n_classes),
    [LayerStats, ...]) — stats carry a leading batch dim.  Logits are
    bit-exact vs ``jax.vmap(snn_apply)`` (tests/test_batched.py); the
    difference is purely structural: per layer, ONE fused queue
    compaction over (B, T, C_in) and ONE conv-unit launch per
    (t, c_in, channel-block) step feed the whole batch, and the
    self-timed early exit is shared batch-wide.  This is the serving
    path (launch/serve.py, serve/csnn_engine.py) and the batched row of
    Table V.  ``plan`` carries the per-layer sizing; the loose kwargs are
    the deprecated shim spelling, ignored when a plan is given.

    Execution is a wrapper over the step-resumable form: ``init_state``
    then ``snn_step_chunk`` over ``plan.chunk_steps`` slices (one chunk —
    the original monolithic graph — unless the plan sets ``t_chunk``),
    then ``snn_readout``.  Bit-exact for every chunking
    (tests/test_chunked.py).
    """
    plan = _resolve_plan(cfg, plan, capacity, channel_block, sat_bits)
    t, chunk = cfg.t_steps, plan.chunk_steps
    state = init_state(params, cfg, plan, in_spikes.shape[0])
    chunk_stats = []
    for k in range(0, t, chunk):
        state, stats = snn_step_chunk(
            params, state, in_spikes[:, k:k + chunk], cfg, plan,
            backend=backend, collect_stats=True)
        chunk_stats.append(stats)
    logits = snn_readout(params, state, cfg, plan)
    if not collect_stats:
        return logits
    return logits, _merge_chunk_stats(chunk_stats)


def _conv_stack_batched(params: dict, x: jax.Array, cfg: CSNNConfig,
                        plan: NetworkPlan, backend: str):
    """The event-driven conv layers of the batched pipeline (everything up
    to the classification unit).  Split out so ``snn_apply_sharded`` can
    run it per shard — it is per-sample exact for any leading batch size —
    while the FC head matmul runs once on the gathered batch (matmul
    reduction order depends on the contraction shape, so the head must see
    the same (B, D) as the unsharded path to stay bit-exact)."""
    stats, ci = [], 0
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x, st = run_conv_layer_batched_planned(
                x, p["w"], p["b"], cfg.v_t, plan.layers[ci], backend=backend)
            stats.append(st)
            ci += 1
    return x, stats


def _fc_head_batched(params: dict, x: jax.Array, cfg: CSNNConfig,
                     fc_capacity: Optional[int] = None) -> jax.Array:
    logits = None
    for idx, spec in enumerate(cfg.layers):
        if not isinstance(spec, ConvSpec):
            p = params[f"fc{idx}"]
            # last head wins, matching snn_apply's per-layer loop exactly
            logits = run_fc_head_batched(x, p["w"], p["b"],
                                         capacity=fc_capacity)
    if logits is None:
        raise ValueError("cfg has no FC head layer")
    return logits


def snn_apply_sharded(
    params: dict,
    in_spikes: jax.Array,
    cfg: CSNNConfig,
    plan: Optional[NetworkPlan] = None,
    *,
    mesh=None,
    capacity: int | Sequence[int] = 256,
    channel_block: int = 1,
    sat_bits: Optional[int] = None,
    collect_stats: bool = False,
    backend: str = "jax",
):
    """``snn_apply_batched`` sharded over the batch axis of a device mesh.

    in_spikes: (B, T, H, W, 1) bool with B divisible by the mesh's
    ``plan.batch_axis`` size.  The event queues are per-sample-independent
    and the early-exit bound only ever *skips invalid slots*, so each
    device runs the event-driven conv stack on its B/n shard with zero
    communication; the final spike maps (tiny: T x H' x W' x C_out bools)
    are gathered and the classification head runs once on the full batch
    — the head matmul must see the same (B, D) contraction as the
    unsharded path because XLA's dot reduction order is shape-dependent.
    The gathered logits are bit-exact vs ``snn_apply_batched``
    (tests/test_sharded.py; ISSUE 3 acceptance).

    ``mesh`` defaults to a 1-D mesh over all local devices
    (``sharding.specs.batch_mesh``).  Validated on the forced-host-device
    CPU mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.sharding.specs import batch_mesh

    plan = _resolve_plan(cfg, plan, capacity, channel_block, sat_bits)
    axis = plan.batch_axis
    if mesh is None:
        mesh = batch_mesh(axis=axis)
    if axis not in mesh.shape:
        raise ValueError(f"mesh {mesh.shape} lacks the plan's batch axis "
                         f"{axis!r}")
    n_dev = mesh.shape[axis]
    b = in_spikes.shape[0]
    if b % n_dev != 0:
        raise ValueError(f"batch {b} does not divide over {n_dev} devices")

    def body(p, sp):
        return _conv_stack_batched(p, sp, cfg, plan, backend)

    n_conv = len(plan.layers)
    out_specs = (P(axis),
                 [LayerStats(P(axis), P(axis), P(axis), P(), P())] * n_conv)
    # check_vma off: per-shard constants (event_block) come back replicated
    # from device-varying inputs, which strict vma tracking rejects.
    fn = shard_map(body, mesh=mesh, in_specs=(P(), P(axis)),
                   out_specs=out_specs, check_vma=False)
    x, stats = fn(params, in_spikes)
    # Gather the (still batch-sharded) spike maps onto one device before
    # the head: a dot over a row-sharded operand would run one-row-per-
    # device matmuls, whose reduction order differs from the unsharded
    # (B, D) contraction in the last bit.
    x = jax.device_put(x, mesh.devices.flatten()[0])
    logits = _fc_head_batched(params, x, cfg, plan.fc_capacity)
    return (logits, stats) if collect_stats else logits


def snn_apply_dense(params: dict, in_spikes: jax.Array, cfg: CSNNConfig) -> jax.Array:
    """Frame-based spiking oracle (per sample); bit-exact vs snn_apply."""
    x = in_spikes
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x = run_conv_layer_dense(x, p["w"], p["b"], cfg.v_t, pool=spec.pool)
        else:
            p = params[f"fc{idx}"]
            logits = run_fc_head(x, p["w"], p["b"])
    return logits
