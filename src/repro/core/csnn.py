"""CSNN model assembly: the paper's 28x28-32C3-32C3-P3-10C3-F10 network.

Two execution paths share one parameter pytree:

* ``ann_apply``     — the clamped-ReLU CNN used for training (paper
  Sec. VII trains a conventional CNN and converts it);
* ``snn_apply``     — T-step m-TTFS spiking inference through the
  event-driven scheduler (Algorithm 1), the system under study;
* ``snn_apply_batched`` — the same inference for a whole sample batch
  with queue construction and kernel launches amortized across it
  (bit-exact vs ``vmap(snn_apply)``; the serving entry point);
* ``snn_apply_dense`` — frame-based spiking oracle (dense baseline).

Parameters are plain dicts of jnp arrays; layer specs are tiny frozen
dataclasses so a config file can describe any CSNN in one line.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .encoding import mttfs_thresholds, multi_threshold_encode
from .scheduler import (LayerStats, run_conv_layer, run_conv_layer_batched,
                        run_conv_layer_dense, run_fc_head, run_fc_head_batched)


@dataclass(frozen=True)
class ConvSpec:
    channels: int
    kernel: int = 3
    pool: Optional[int] = None  # OR-max-pool window applied after this layer


@dataclass(frozen=True)
class FCSpec:
    features: int


@dataclass(frozen=True)
class CSNNConfig:
    """`28x28-32C3-32C3-P3-10C3-F10` == the paper's network (defaults)."""

    input_hw: tuple[int, int] = (28, 28)
    layers: Sequence = field(default_factory=lambda: (
        ConvSpec(32), ConvSpec(32, pool=3), ConvSpec(10), FCSpec(10)))
    t_steps: int = 5          # paper: T=5 gave the best accuracy
    v_t: float = 1.0          # firing threshold after conversion
    relu_clamp: float = 1.0   # clamped-ReLU ceiling used during ANN training


def conv_out_hw(hw: tuple[int, int], spec: ConvSpec) -> tuple[int, int]:
    h, w = hw  # SAME padding keeps H, W; pooling ceil-divides
    if spec.pool:
        return (-(-h // spec.pool), -(-w // spec.pool))
    return (h, w)


def init_params(rng: jax.Array, cfg: CSNNConfig, dtype=jnp.float32) -> dict:
    params = {}
    hw, c_in = cfg.input_hw, 1
    for idx, spec in enumerate(cfg.layers):
        key = jax.random.fold_in(rng, idx)
        if isinstance(spec, ConvSpec):
            fan_in = spec.kernel * spec.kernel * c_in
            params[f"conv{idx}"] = {
                "w": jax.random.normal(key, (spec.kernel, spec.kernel, c_in, spec.channels),
                                       dtype) * (2.0 / fan_in) ** 0.5,
                "b": jnp.zeros((spec.channels,), dtype),
            }
            hw, c_in = conv_out_hw(hw, spec), spec.channels
        else:
            d = hw[0] * hw[1] * c_in
            params[f"fc{idx}"] = {
                "w": jax.random.normal(key, (d, spec.features), dtype) * (1.0 / d) ** 0.5,
                "b": jnp.zeros((spec.features,), dtype),
            }
    return params


def ann_apply(params: dict, images: jax.Array, cfg: CSNNConfig) -> jax.Array:
    """Clamped-ReLU CNN forward (training path). images: (B, H, W, 1) in [0,1]."""
    x = images
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x = jax.lax.conv_general_dilated(
                x, p["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = x + p["b"]
            x = jnp.clip(x, 0.0, cfg.relu_clamp)  # clamped ReLU (Rueckauer)
            if spec.pool:
                x = _max_pool(x, spec.pool)
        else:
            p = params[f"fc{idx}"]
            x = x.reshape(x.shape[0], -1) @ p["w"] + p["b"]
    return x


def _max_pool(x: jax.Array, window: int) -> jax.Array:
    pads = [(0, 0), (0, -x.shape[1] % window), (0, -x.shape[2] % window), (0, 0)]
    x = jnp.pad(x, pads, constant_values=-jnp.inf)
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1), (1, window, window, 1), "VALID")


def encode_input(images: jax.Array, cfg: CSNNConfig) -> jax.Array:
    """(B, H, W, 1) floats in [0,1] -> (B, T, H, W, 1) m-TTFS input spikes."""
    thresholds = mttfs_thresholds(cfg.t_steps)
    enc = lambda img: multi_threshold_encode(img, thresholds, cfg.t_steps)
    return jax.vmap(enc)(images)


def snn_apply(
    params: dict,
    in_spikes: jax.Array,
    cfg: CSNNConfig,
    *,
    capacity: int | Sequence[int] = 256,
    channel_block: int = 1,
    sat_bits: Optional[int] = None,
    collect_stats: bool = True,
):
    """Event-driven m-TTFS inference for ONE sample.

    in_spikes: (T, H, W, 1) bool.  Returns (logits, [LayerStats, ...]).
    ``capacity`` may be a single int or one per conv layer (calibrated).
    vmap over samples for batching; the paper's xP parallelism sweep maps
    to batching + channel_block.
    """
    conv_specs = [s for s in cfg.layers if isinstance(s, ConvSpec)]
    caps = ([capacity] * len(conv_specs) if isinstance(capacity, int) else list(capacity))
    vm_dtype = {None: jnp.float32, 8: jnp.int8, 16: jnp.int16}[sat_bits]
    x, stats, ci = in_spikes, [], 0
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x, st = run_conv_layer(
                x, p["w"], p["b"], cfg.v_t, capacity=caps[ci], pool=spec.pool,
                channel_block=channel_block, sat_bits=sat_bits, vm_dtype=vm_dtype)
            stats.append(st)
            ci += 1
        else:
            p = params[f"fc{idx}"]
            logits = run_fc_head(x, p["w"], p["b"])
    return (logits, stats) if collect_stats else logits


def snn_apply_batched(
    params: dict,
    in_spikes: jax.Array,
    cfg: CSNNConfig,
    *,
    capacity: int | Sequence[int] = 256,
    channel_block: int = 1,
    sat_bits: Optional[int] = None,
    collect_stats: bool = True,
    backend: str = "jax",
):
    """Event-driven m-TTFS inference for a SAMPLE BATCH.

    in_spikes: (B, T, H, W, 1) bool.  Returns (logits (B, n_classes),
    [LayerStats, ...]) — stats carry a leading batch dim.  Logits are
    bit-exact vs ``jax.vmap(snn_apply)`` (tests/test_batched.py); the
    difference is purely structural: per layer, ONE fused queue
    compaction over (B, T, C_in) and ONE conv-unit launch per
    (t, c_in, channel-block) step feed the whole batch, and the
    self-timed early exit is shared batch-wide.  This is the serving
    path (launch/serve.py) and the batched row of Table V.
    """
    conv_specs = [s for s in cfg.layers if isinstance(s, ConvSpec)]
    caps = ([capacity] * len(conv_specs) if isinstance(capacity, int) else list(capacity))
    vm_dtype = {None: jnp.float32, 8: jnp.int8, 16: jnp.int16}[sat_bits]
    x, stats, ci = in_spikes, [], 0
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x, st = run_conv_layer_batched(
                x, p["w"], p["b"], cfg.v_t, capacity=caps[ci], pool=spec.pool,
                channel_block=channel_block, sat_bits=sat_bits,
                vm_dtype=vm_dtype, backend=backend)
            stats.append(st)
            ci += 1
        else:
            p = params[f"fc{idx}"]
            logits = run_fc_head_batched(x, p["w"], p["b"])
    return (logits, stats) if collect_stats else logits


def snn_apply_dense(params: dict, in_spikes: jax.Array, cfg: CSNNConfig) -> jax.Array:
    """Frame-based spiking oracle (per sample); bit-exact vs snn_apply."""
    x = in_spikes
    for idx, spec in enumerate(cfg.layers):
        if isinstance(spec, ConvSpec):
            p = params[f"conv{idx}"]
            x = run_conv_layer_dense(x, p["w"], p["b"], cfg.v_t, pool=spec.pool)
        else:
            p = params[f"fc{idx}"]
            logits = run_fc_head(x, p["w"], p["b"])
    return logits
