"""Plan/execute split for the event pipeline (paper Sec. IV-V design flow).

The accelerator works because every resource is sized *per layer at design
time* — queue depths, PE tiling and interlaced membrane RAMs are static
while the spike stream is dynamic.  This module is the TPU analogue of
that design step: ``plan_network`` walks a ``CSNNConfig`` once and derives
a frozen :class:`LayerPlan` per conv layer (padded queue capacity, channel
block, event block, membrane-tile shape), plus network-wide serving knobs
(batch tile, batch mesh axis) on the :class:`NetworkPlan`.  The runtime
(``scheduler.run_conv_layer_planned`` / ``csnn.snn_apply*``) then only
executes plans; it never sizes anything.

Sizing rules (all static, all pure functions of geometry + calibration):

* **capacity** — the effective AEQ depth is ``min(pad64(requested), H·W)``:
  padded to a 64-multiple so event blocks tile evenly (the extra slots
  carry ``valid=False``), but never deeper than the feature map itself —
  a queue can hold at most H·W events, so capping there drops nothing and
  is what removes the padded-slot waste of a single shared capacity.
  When per-layer spike-count ``stats`` are given, the requested depth
  comes from ``aeq.calibrate_capacity`` per layer (BRAM sizing analogue).
* **channel_block** — snapped to a divisor of C_out (``snap_divisor``).
* **block_e** — autotuned from the capacity and the VMEM budget
  (``kernels.event_conv.ops.autotune_block_e``) unless pinned.
* **vm_tile** — the (H+2·(kh//2), W+2·(kw//2), channel_block)
  halo-padded MemPot tile held VMEM-resident per conv-unit launch
  (H+2, W+2 for the paper's 3x3 window).
* **event_par** — the memory-interlaced event-parallel width (paper
  Fig. 6 cashed in): 1 keeps the sequential one-event-at-a-time conv
  unit; > 1 selects the interlace-aware kernel variants, which apply
  same-column (hazard-free) events in parallel — the banked-select jax
  path and the ``event_conv_pallas_interlaced*`` kernels.  ``None``
  autotunes it next to ``block_e`` (``autotune_event_par``: snapped to a
  power of two, VMEM-aware, floored to 1 when queues are too shallow to
  pay for parallelism).  When > 1, ``block_e`` is additionally snapped to
  a multiple of ``event_par`` dividing the segment-padded
  :attr:`LayerPlan.queue_depth`.

Every rule only ever *lowers* the effective queue depth to the point
where nothing can be dropped (or keeps the requested truncation depth),
so planned execution is bit-exact vs the legacy shared-capacity kwargs —
the deprecation shims in scheduler.py/csnn.py rely on this; the
``event_par`` variants are bit-exact vs the sequential schedule by the
interlace disjointness argument (tests/test_interlaced.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.kernels.event_conv.ops import (autotune_block_e,
                                          autotune_event_par,
                                          snap_block_e_for_par,
                                          snap_divisor)

from .aeq import calibrate_capacity, interlaced_capacity
from .geometry import GEOM_3X3, ConvGeometry

_VM_DTYPES = {None: "float32", 8: "int8", 16: "int16"}

# Kernel variants a LayerPlan can pin (None = resolve from event_par +
# backend, the legacy rule).  "sequential" walks the queue one event at a
# time (jax loop, or the sequential Pallas kernel on the pallas backend);
# "banked-jax" holds the MemPot stack in the 9 interlace banks and applies
# whole hazard-free columns per vectorized select; "interlaced-pallas"
# feeds segment-padded queues to event_conv_pallas_interlaced*;
# "fused-handoff" consumes the producer's fused spike emission directly —
# the layer input arrives as halo-padded centre-bank occupancy masks
# (aeq.FusedHandoff, built in the upstream threshold unit) and the conv
# applies them through static per-(bank, column) slices, skipping the
# deinterlace -> dense -> recompact round trip entirely (ISSUE 10).  All
# four are bit-exact — the variant is a pure perf knob, which is what
# lets the measured autotuner (repro.tune) pick per layer.
# "fused-handoff" only runs when pinned: resolve_variant never
# auto-selects it, because it changes the *inter*-layer dataflow.
KERNEL_VARIANTS = ("sequential", "banked-jax", "interlaced-pallas",
                   "fused-handoff")

# Streaming-ingestion finalization variants (input layer only): "ranks"
# is the sort-free exclusive-cumulative-rank path (aeq.stream_queues);
# "sort" scatters the banks to dense frames and re-compacts with the
# fused sort (build_aeq_batched) — bit-exact by the streaming-equivalence
# theorem, and measurably faster at small fmaps where the O(HW log HW)
# sort beats the rank computation's constant factor (BENCH_streaming).
# None resolves by fmap size (LayerPlan.resolve_stream_finalize).
STREAM_FINALIZE = ("ranks", "sort")

# Fmap-size crossover for the None stream_finalize default: at/below this
# many fmap cells the fused-sort finalization wins (BENCH_streaming
# measured the 12x12 DVS smoke at 0.83x under "ranks" vs 1.0x+ under
# "sort"); larger fmaps amortize the rank cumsums and "ranks" wins.
_FINALIZE_SORT_MAX_HW = 256


def pad_capacity(capacity: int) -> int:
    """Queue depth padded to a multiple of 64 so the Pallas event-block
    grid divides evenly (the extra slots carry valid=False).  Depths <= 64
    are kept as-is — identical rounding in every path is part of the
    bit-exactness contract (overflow must truncate identically)."""
    return -(-capacity // 64) * 64 if capacity > 64 else capacity


def effective_capacity(requested: int, hw: int) -> int:
    """Effective AEQ depth: padded to 64-multiples, capped at the fmap
    size.  The cap never changes results — a (H, W) fmap holds at most
    H·W events, so truncation depth stays ``min(pad64(requested), hw)``
    in the legacy path and here alike."""
    return min(pad_capacity(requested), hw)


def snap_t_chunk(t_steps: int, requested: int) -> int:
    """Largest divisor of ``t_steps`` that is <= ``requested``.

    Chunked execution (``snn_step_chunk``) requires every chunk to have
    the same length — slots in a continuous-batching batch sit at
    different time offsets, so a ragged tail chunk would force a second
    compiled shape and break slot alignment.  Snapping to a divisor keeps
    one shape and exact T coverage."""
    if t_steps < 1 or requested < 1:
        raise ValueError(f"t_steps={t_steps} and requested={requested} "
                         f"must be >= 1")
    for c in range(min(requested, t_steps), 0, -1):
        if t_steps % c == 0:
            return c


@dataclass(frozen=True)
class LayerPlan:
    """Static per-layer resource plan (the design-time sizing record).

    One instance per conv layer; everything the scheduler needs to execute
    the layer without sizing decisions at trace time.
    """

    index: int                    # position in cfg.layers
    name: str                     # parameter key, e.g. "conv0"
    in_hw: tuple[int, int]        # input fmap geometry (pre-conv)
    out_hw: tuple[int, int]       # output geometry (post-pool)
    c_in: int
    c_out: int
    pool: Optional[int]           # OR-max-pool window (None = no pool)
    capacity: int                 # effective AEQ depth per (t, c_in) queue
    channel_block: int            # output channels per MemPot tile
    block_e: int                  # event-block size (divides queue_depth)
    vm_tile: tuple[int, int, int]  # halo-padded MemPot tile
                                  # (H+2*(kh//2), W+2*(kw//2), cb)
    sat_bits: Optional[int] = None  # 8/16-bit saturating datapath, None=f32
    event_par: int = 1            # same-column events applied in parallel
                                  # (1 = sequential legacy conv unit)
    ingest_capacity: Optional[int] = None  # raw-event buffer depth per
                                  # StreamChunk admission (DVS ingestion;
                                  # input layer only, None = not ingesting)
    ingest_depth: Optional[int] = None     # time bins buffered per stream
                                  # admission window (None = not ingesting)
    variant: Optional[str] = None  # pinned kernel variant (KERNEL_VARIANTS);
                                  # None = resolve from event_par + backend
    stream_finalize: Optional[str] = None  # streamed-queue finalization
                                  # ("ranks"/"sort"; input layer only,
                                  # None = resolve by fmap size —
                                  # resolve_stream_finalize)
    geometry: ConvGeometry = GEOM_3X3  # conv window + interlace layout
                                  # (kh x kw, n_banks = kh*kw membrane
                                  # banks; the paper's 3x3 by default)

    def resolve_variant(self, backend: str = "jax") -> str:
        """Effective kernel variant for this layer under ``backend``.

        A pinned :attr:`variant` wins (the measured autotuner's choice);
        otherwise the legacy rule applies: ``event_par > 1`` selects the
        interlaced machinery (Pallas kernels on the pallas backend, the
        banked-select jax path elsewhere), ``event_par == 1`` the
        sequential conv unit.
        """
        if self.variant is not None:
            return self.variant
        if self.event_par > 1:
            return ("interlaced-pallas" if backend == "pallas"
                    else "banked-jax")
        return "sequential"

    def resolve_stream_finalize(self) -> str:
        """Effective streamed-queue finalization for this (input) layer.

        An explicit :attr:`stream_finalize` (user pin or the measured
        autotuner's choice) always wins; ``None`` resolves by fmap size —
        small fmaps take the fused-sort path, larger ones the sort-free
        ranks path (the measured crossover, see ``STREAM_FINALIZE``).
        Both finalizations are bit-exact, so the default is pure perf.
        """
        if self.stream_finalize is not None:
            return self.stream_finalize
        h, w = self.in_hw
        return "sort" if h * w <= _FINALIZE_SORT_MAX_HW else "ranks"

    @property
    def vm_dtype(self):
        import jax.numpy as jnp
        return jnp.dtype(_VM_DTYPES[self.sat_bits])

    @property
    def queue_depth(self) -> int:
        """Allocated queue slots: ``capacity``, or the segment-padded
        depth (``aeq.interlaced_capacity``) when the interlaced Pallas
        layout is in play (``event_par`` > 1)."""
        return interlaced_capacity(self.capacity, self.event_par,
                                   self.geometry.n_banks)

    @property
    def event_slots(self) -> int:
        """Padded queue slots allocated per time step (all C_in queues)."""
        return self.queue_depth * self.c_in

    def __repr__(self) -> str:
        h, w = self.in_hw
        oh, ow = self.out_hw
        pool = f" pool{self.pool}" if self.pool else ""
        par = f", par={self.event_par}" if self.event_par > 1 else ""
        ing = (f", ingest={self.ingest_capacity}x{self.ingest_depth}"
               if self.ingest_capacity is not None else "")
        var = f", variant={self.variant}" if self.variant is not None else ""
        fin = (f", finalize={self.stream_finalize}"
               if self.stream_finalize is not None else "")
        geo = ("" if self.geometry == GEOM_3X3
               else f", k={self.geometry.describe()}")
        return (f"LayerPlan({self.name}: {h}x{w}x{self.c_in}{geo} -> "
                f"{oh}x{ow}x{self.c_out}{pool}, cap={self.capacity}, "
                f"cb={self.channel_block}, block_e={self.block_e}, "
                f"vm={self.vm_tile}, "
                f"{_VM_DTYPES[self.sat_bits]}{par}{var}{fin}{ing})")


@dataclass(frozen=True)
class NetworkPlan:
    """Per-layer plans plus the network-wide serving/sharding knobs."""

    layers: tuple[LayerPlan, ...]   # one per conv layer, in network order
    t_steps: int
    batch_tile: int = 8             # serving engine pads batches to this
    batch_axis: str = "batch"       # mesh axis snn_apply_sharded shards over
    t_chunk: Optional[int] = None   # time steps per snn_step_chunk call
                                    # (None = t_steps: one monolithic chunk)
    fc_capacity: Optional[int] = None  # event-driven FC readout queue depth
                                    # (sparse_ffn.event_readout opt-in;
                                    # None = dense classification head)

    @property
    def chunk_steps(self) -> int:
        """Resolved chunk length: ``t_chunk`` or the whole T window."""
        return self.t_chunk if self.t_chunk is not None else self.t_steps

    @property
    def total_event_slots(self) -> int:
        """Padded queue slots allocated over the whole T-step inference —
        the figure the per-layer capacities strictly reduce vs a single
        shared capacity (ISSUE 3 acceptance)."""
        return self.t_steps * sum(lp.event_slots for lp in self.layers)

    def layer(self, name: str) -> LayerPlan:
        for lp in self.layers:
            if lp.name == name:
                return lp
        raise KeyError(name)

    def validate(self, cfg) -> "NetworkPlan":
        """Check the plan matches ``cfg`` geometry; returns self."""
        from .csnn import ConvSpec, conv_out_hw
        conv_specs = [(i, s) for i, s in enumerate(cfg.layers)
                      if isinstance(s, ConvSpec)]
        if len(conv_specs) != len(self.layers):
            raise ValueError(
                f"plan has {len(self.layers)} conv layers, cfg has "
                f"{len(conv_specs)}")
        if self.t_steps != cfg.t_steps:
            raise ValueError(
                f"plan t_steps={self.t_steps} != cfg t_steps={cfg.t_steps}")
        if self.t_chunk is not None and (
                not 1 <= self.t_chunk <= self.t_steps
                or self.t_steps % self.t_chunk != 0):
            raise ValueError(
                f"t_chunk={self.t_chunk} must divide t_steps={self.t_steps}")
        if self.fc_capacity is not None:
            last = self.layers[-1]
            d = last.out_hw[0] * last.out_hw[1] * last.c_out
            if not 1 <= self.fc_capacity <= d:
                raise ValueError(
                    f"fc_capacity={self.fc_capacity} must be in [1, D={d}] "
                    f"(the flattened final conv output feeding the head)")
        hw, c_in = tuple(cfg.input_hw), cfg.input_channels
        for lp, (idx, spec) in zip(self.layers, conv_specs):
            if lp.in_hw != hw or lp.c_in != c_in or lp.c_out != spec.channels:
                raise ValueError(f"{lp!r} does not match cfg layer {idx} "
                                 f"(in_hw={hw}, c_in={c_in}, "
                                 f"c_out={spec.channels})")
            if lp.geometry.window != (spec.kernel, spec.kernel):
                raise ValueError(
                    f"{lp!r} geometry {lp.geometry.describe()} does not "
                    f"match cfg layer {idx} kernel {spec.kernel}x"
                    f"{spec.kernel}")
            if lp.ingest_depth is not None and not (
                    1 <= lp.ingest_depth <= self.t_steps):
                raise ValueError(
                    f"{lp!r} ingest_depth={lp.ingest_depth} must be in "
                    f"[1, t_steps={self.t_steps}]")
            if lp.variant == "fused-handoff":
                # the fused carrier is built against THIS layer's window:
                # the producer's emission places banks on the halo-padded
                # grid derived from (in_hw, geometry), and the consumer
                # slices assume vm_tile covers exactly that grid
                h, w = lp.in_hw
                hh, hw2 = lp.geometry.halo
                want = (h + 2 * hh, w + 2 * hw2, lp.channel_block)
                if tuple(lp.vm_tile) != want:
                    raise ValueError(
                        f"{lp!r} variant='fused-handoff' needs the "
                        f"halo-padded vm_tile {want} matching in_hw="
                        f"{lp.in_hw} under {lp.geometry.describe()}, got "
                        f"{tuple(lp.vm_tile)} — the handoff bank grid and "
                        f"the MemPot banks would desynchronize")
            hw, c_in = conv_out_hw(hw, spec), spec.channels
        return self

    def __repr__(self) -> str:
        lines = [f"NetworkPlan(T={self.t_steps}, t_chunk={self.chunk_steps}, "
                 f"batch_tile={self.batch_tile}, "
                 f"batch_axis={self.batch_axis!r}, "
                 f"total_event_slots={self.total_event_slots})"]
        lines += [f"  {lp!r}" for lp in self.layers]
        return "\n".join(lines)


def plan_conv_layer(
    index: int,
    name: str,
    in_hw: tuple[int, int],
    c_in: int,
    c_out: int,
    *,
    capacity: int,
    pool: Optional[int] = None,
    channel_block: int = 1,
    block_e: Optional[int] = None,
    sat_bits: Optional[int] = None,
    per_layer: bool = True,
    batch_tile: int = 1,
    vmem_budget: Optional[int] = None,
    event_par: Optional[int] = 1,
    ingest_capacity: Optional[int] = None,
    ingest_depth: Optional[int] = None,
    variant: Optional[str] = None,
    stream_finalize: Optional[str] = None,
    geometry: ConvGeometry = GEOM_3X3,
) -> LayerPlan:
    """Derive one conv layer's plan from its geometry.

    ``batch_tile`` models the batched path's residency for the block_e
    autotuner — the MemPot stack is (B, H+2, W+2, cb), B tiles resident
    at once, not one.  ``per_layer=False`` reproduces the legacy
    shared-capacity sizing (queue arrays padded to the shared depth
    regardless of fmap size) — kept as the baseline the per-layer plans
    are measured against.  ``event_par=None`` autotunes the interlaced
    event-parallel width next to ``block_e``; the default 1 keeps the
    sequential conv-unit schedule (and with it the legacy shims'
    bit-exactness-by-identity).
    """
    geometry.require_event_compatible(f"plan_conv_layer({name})")
    h, w = in_hw
    hh, hw_ = geometry.halo
    cap = (effective_capacity(capacity, h * w) if per_layer
           else pad_capacity(capacity))
    cb = snap_divisor(c_out, channel_block)
    vm_tile = (h + 2 * hh, w + 2 * hw_, cb)
    vm_bytes = {None: 4, 8: 1, 16: 2}[sat_bits]
    kwargs = {"vmem_budget": vmem_budget} if vmem_budget else {}
    if event_par is None:
        ep = autotune_event_par(cap, (max(batch_tile, 1),) + vm_tile,
                                vm_bytes=vm_bytes, geometry=geometry,
                                **kwargs)
    else:
        ep = max(1, int(event_par))
    depth = interlaced_capacity(cap, ep, geometry.n_banks)
    if block_e is None:
        be = autotune_block_e(depth, (max(batch_tile, 1),) + vm_tile,
                              vm_bytes=vm_bytes, **kwargs)
    else:
        be = block_e
    if ep > 1:
        # the interlaced grid walks event_par-aligned groups of the
        # segment-padded queue: block_e must be a multiple of event_par
        # that divides the padded depth
        be = snap_block_e_for_par(depth, be, ep)
    else:
        be = snap_divisor(depth, be)
    if pool:
        out_hw = (-(-h // pool), -(-w // pool))
    else:
        out_hw = (h, w)
    if (ingest_capacity is None) != (ingest_depth is None):
        raise ValueError("ingest_capacity and ingest_depth must be set "
                         "together (both None for non-ingesting layers)")
    if ingest_capacity is not None and (ingest_capacity < 1
                                        or ingest_depth < 1):
        raise ValueError(f"ingest_capacity={ingest_capacity} and "
                         f"ingest_depth={ingest_depth} must be >= 1")
    if variant is not None and variant not in KERNEL_VARIANTS:
        raise ValueError(f"variant={variant!r} must be one of "
                         f"{KERNEL_VARIANTS} (or None to resolve from "
                         f"event_par + backend)")
    if variant == "interlaced-pallas" and ep <= 1:
        raise ValueError(
            f"variant='interlaced-pallas' requires event_par > 1 (got "
            f"{ep}): the interlaced kernel walks event_par-aligned groups "
            f"of the segment-padded queue")
    if stream_finalize is not None and stream_finalize not in STREAM_FINALIZE:
        raise ValueError(f"stream_finalize={stream_finalize!r} must be one "
                         f"of {STREAM_FINALIZE} (or None = resolve by fmap "
                         f"size)")
    return LayerPlan(index=index, name=name, in_hw=in_hw, out_hw=out_hw,
                     c_in=c_in, c_out=c_out, pool=pool, capacity=cap,
                     channel_block=cb, block_e=be, vm_tile=vm_tile,
                     sat_bits=sat_bits, event_par=ep,
                     ingest_capacity=ingest_capacity,
                     ingest_depth=ingest_depth, variant=variant,
                     stream_finalize=stream_finalize, geometry=geometry)


def plan_network(
    cfg,
    *,
    capacity: int | Sequence[int] = 256,
    channel_block: int | Sequence[int] = 1,
    block_e: Optional[int] | Sequence[Optional[int]] = None,
    sat_bits: Optional[int] = None,
    stats: Optional[Sequence] = None,
    percentile: float = 99.9,
    margin: float = 1.25,
    batch_tile: int = 8,
    batch_axis: str = "batch",
    per_layer: bool = True,
    vmem_budget: Optional[int] = None,
    t_chunk: Optional[int] = None,
    event_par: Optional[int] | Sequence[Optional[int]] = 1,
    ingest: bool = False,
    ingest_capacity: Optional[int] = None,
    variant: Optional[str] | Sequence[Optional[str]] = None,
    stream_finalize: Optional[str] = None,
    fc_capacity: Optional[int] = None,
    tune: str = "analytic",
    tune_config=None,
    cache_path=None,
) -> NetworkPlan:
    """Derive a :class:`NetworkPlan` from a ``CSNNConfig``.

    ``capacity``/``channel_block`` may be a single value or one per conv
    layer.  When per-layer spike-count ``stats`` are given (anything
    ``aeq.calibrate_capacity`` accepts, e.g. ``LayerStats.in_spike_counts``
    from a calibration run), the requested capacity of each layer is
    calibrated from its own distribution instead — the two-tier adaptive
    capacity from the ROADMAP.  ``per_layer=False`` keeps the legacy
    shared-capacity sizing (the baseline).

    ``t_chunk`` sets how many time steps one ``snn_step_chunk`` call
    consumes (``snap_t_chunk`` snaps it to a divisor of T); ``None``
    keeps the monolithic whole-T execution.  The input channel count is
    read from ``cfg.input_channels`` (multi-channel inputs, e.g.
    2-polarity DVS encodings).  ``event_par`` selects the interlaced
    event-parallel kernel variant per layer (1 = sequential legacy
    schedule, ``None`` = autotune, or one value per conv layer).

    ``ingest=True`` sizes the streaming-DVS ingestion buffers on the
    input layer: ``ingest_depth`` is the admission window in time bins
    (the chunk length), and ``ingest_capacity`` the raw-event buffer
    depth per admitted :class:`~repro.core.aeq.StreamChunk` — by default
    one input-queue depth worth of events per (bin, channel) of the
    window, padded to a 64-multiple so jitted admission keeps one shape
    (the hardware analogue: the ingress FIFO in front of the AEQ
    builders).  Raw events beyond the buffer are refused at admission
    (host-side backpressure), never silently dropped mid-queue.

    ``variant`` pins the kernel variant per layer (one of
    :data:`KERNEL_VARIANTS`, single value or one per conv layer; ``None``
    keeps the legacy event_par/backend resolution) and
    ``stream_finalize`` the streamed-queue finalization of the ingesting
    input layer (:data:`STREAM_FINALIZE`) — both are pure perf knobs,
    bit-exact across every setting.

    ``fc_capacity`` opts the classification head into the event-driven
    sparse readout (``sparse_ffn.event_readout``): the accumulated FC
    drive is top-k-compacted to that queue depth and scattered back into
    the dense contraction's operand — bit-exact vs the dense head
    whenever the queue covers every nonzero drive entry (size it with
    ``aeq.calibrate_capacity`` over ``sparse_ffn.drive_active_counts``).

    ``tune`` selects how the perf knobs are derived: ``"analytic"`` (the
    default) keeps the closed-form VMEM model above; ``"measured"``
    micro-benchmarks candidate (block_e, event_par, t_chunk, variant)
    tuples per layer and picks measured winners (``repro.tune``),
    persisting them in the on-disk plan cache; ``"cached"`` loads a
    previously measured plan from the cache (keyed by layer geometry,
    dtype, backend, device kind and jax version; ``REPRO_PLAN_CACHE``
    overrides the location, ``cache_path`` wins over both) and only falls
    back to measuring on a miss.  ``tune_config`` is a
    :class:`repro.tune.TuneConfig`.  Tuning never changes results — every
    candidate is bit-exact — it only changes which bit-exact schedule
    runs.
    """
    if tune not in ("analytic", "measured", "cached"):
        raise ValueError(f"tune={tune!r} must be 'analytic', 'measured' or "
                         f"'cached'")
    if tune != "analytic":
        from repro.tune import tune_network
        base = dict(capacity=capacity, channel_block=channel_block,
                    block_e=block_e, sat_bits=sat_bits, stats=stats,
                    percentile=percentile, margin=margin,
                    batch_tile=batch_tile, batch_axis=batch_axis,
                    per_layer=per_layer, vmem_budget=vmem_budget,
                    t_chunk=t_chunk, event_par=event_par, ingest=ingest,
                    ingest_capacity=ingest_capacity, variant=variant,
                    stream_finalize=stream_finalize,
                    fc_capacity=fc_capacity)
        return tune_network(cfg, mode=tune, base=base, config=tune_config,
                            cache_path=cache_path)
    from .csnn import ConvSpec, conv_out_hw
    conv_specs = [(i, s) for i, s in enumerate(cfg.layers)
                  if isinstance(s, ConvSpec)]
    n = len(conv_specs)
    caps = list(capacity) if not isinstance(capacity, int) else [capacity] * n
    cbs = (list(channel_block) if not isinstance(channel_block, int)
           else [channel_block] * n)
    eps = (list(event_par) if isinstance(event_par, (list, tuple))
           else [event_par] * n)
    bes = (list(block_e) if isinstance(block_e, (list, tuple))
           else [block_e] * n)
    variants = (list(variant) if isinstance(variant, (list, tuple))
                else [variant] * n)
    if (len(caps) != n or len(cbs) != n or len(eps) != n or len(bes) != n
            or len(variants) != n):
        raise ValueError(f"need one capacity/channel_block/event_par/"
                         f"block_e/variant per conv layer ({n}), got "
                         f"{len(caps)}/{len(cbs)}/{len(eps)}/{len(bes)}/"
                         f"{len(variants)}")
    if stats is not None:
        if len(stats) != n:
            raise ValueError(f"need one stats entry per conv layer ({n}), "
                             f"got {len(stats)}")
        caps = [calibrate_capacity(np.asarray(s), percentile=percentile,
                                   margin=margin, align=8) for s in stats]

    if t_chunk is not None:
        t_chunk = snap_t_chunk(cfg.t_steps, t_chunk)
    plans, hw, c_in = [], tuple(cfg.input_hw), cfg.input_channels
    for ci, (idx, spec) in enumerate(conv_specs):
        ing_cap = ing_depth = None
        if ci == 0 and (ingest or ingest_capacity is not None):
            ing_depth = t_chunk if t_chunk is not None else cfg.t_steps
            h0, w0 = hw
            auto = (effective_capacity(caps[ci], h0 * w0)
                    * c_in * ing_depth)
            ing_cap = (ingest_capacity if ingest_capacity is not None
                       else pad_capacity(auto))
        plans.append(plan_conv_layer(
            idx, f"conv{idx}", hw, c_in, spec.channels, capacity=caps[ci],
            pool=spec.pool, channel_block=cbs[ci], block_e=bes[ci],
            sat_bits=sat_bits, per_layer=per_layer, batch_tile=batch_tile,
            vmem_budget=vmem_budget, event_par=eps[ci],
            ingest_capacity=ing_cap, ingest_depth=ing_depth,
            variant=variants[ci],
            stream_finalize=stream_finalize if ci == 0 else None,
            geometry=ConvGeometry(spec.kernel, spec.kernel)))
        hw, c_in = conv_out_hw(hw, spec), spec.channels
    return NetworkPlan(layers=tuple(plans), t_steps=cfg.t_steps,
                       batch_tile=batch_tile, batch_axis=batch_axis,
                       t_chunk=t_chunk, fc_capacity=fc_capacity)
