"""Address-Event Queue (AEQ): runtime compaction of sparse binary fmaps.

Paper Secs. V-A / VI-A.  A binary feature map is stored not as a (0,1)
matrix but as a queue of the coordinates (i, j) of its ones ("address
events").  The hardware builds these queues at runtime with dedicated
circuitry; processing a layer then walks the queue, so the cycle count
scales with the number of spikes.

TPU adaptation (DESIGN.md Sec. 2): queues become fixed-capacity event
buffers built by an O(HW log HW) sort-based stream compaction — the static
capacity plays the role of the BRAM queue depth and is calibrated offline
from observed spike counts.  The paper's *interlaced column order*
(process all events of column s=0, then s=1, ... with s = 3(i%3)+(j%3)) is
preserved: it is what makes same-column events hazard-free (their 3x3
neighbourhoods can never overlap) and we keep it so the cycle-level
pipeline simulator and the Pallas kernel see the same schedule as the RTL.

The interlace structure is a first-class layout, not just an ordering:

* every queue carries **column segment offsets/counts** (``seg_offsets``/
  ``seg_counts``, one entry per interlace column s=0..8) describing which
  contiguous queue slices are mutually hazard-free — the metadata the
  event-parallel kernels exploit;
* ``segment_pad`` re-lays a queue out so each column segment is padded to
  a multiple of ``event_par`` — then *every* aligned group of
  ``event_par`` consecutive slots is column-homogeneous by construction
  (the layout consumed by ``event_conv_pallas_interlaced``);
* ``build_bank_masks`` compacts fmaps straight into the paper's NINE
  membrane RAM banks (Fig. 6): per-column occupancy masks over the banked
  macro grid, honouring the same capacity truncation as the queue but
  needing no sort at all — the builder behind the bank-parallel jax path
  (``event_conv.apply_events_interlaced*``).

Two queue entry points share the compaction logic: ``build_aeq`` compacts
one fmap, and ``build_aeq_batched`` compacts a whole stack of fmaps (any
leading dims, e.g. (B, T, C_in, H, W)) in ONE fused batched sort — the
builder behind the batched inference pipeline (scheduler
``run_conv_layer_batched``).  Property tests live in tests/test_aeq.py and
tests/test_interlaced.py.

Streaming ingestion (ISSUE 6) skips the frame/sort path entirely for
event-camera inputs.  Raw DVS address events (t, y, x, polarity) append
incrementally into a :class:`StreamState` — per-(bin, channel) occupancy
held directly in the 9 interlace-column banks, the PR-5 hazard-free
layout, never a dense frame — via ``append_events`` /
``append_events_batched`` (idempotent scatter: duplicates dedupe,
out-of-window events drop).  ``stream_queues`` then finalizes queues
SORT-FREE with per-column cumulative ranks (the ``build_bank_masks``
technique), bit-exact vs ``build_aeq_batched`` on the binned frames —
same (s, i, j) order, same capacity truncation, same segments
(tests/test_streaming.py).  Admission therefore costs a scatter plus an
O(HW) cumsum per chunk instead of an O(HW log HW) sort per frame
(benchmarks/table6_streaming.py).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import GEOM_3X3, ConvGeometry


class EventQueue(NamedTuple):
    """Fixed-capacity queue of address events.

    coords: (capacity, 2) int32 — (i, j) per event; undefined where ~valid.
    valid:  (capacity,) bool    — which slots hold real events.
    count:  () int32            — spike demand (may exceed kept events on
                                  overflow; occupancy is valid.sum()).
    seg_offsets/seg_counts: (n_banks,) int32 — interlace column segments
        (n_banks = kh*kw of the builder's geometry, 9 for the default
        3x3): the kept events of column s occupy queue slots
        [seg_offsets[s], seg_offsets[s] + seg_counts[s]).  None for
        raster-ordered queues (``interlaced=False``), where no such
        contiguous hazard-free slices exist.
    """

    coords: jax.Array
    valid: jax.Array
    count: jax.Array
    seg_offsets: Optional[jax.Array] = None
    seg_counts: Optional[jax.Array] = None

    @property
    def capacity(self) -> int:
        return self.coords.shape[0]


class BatchedEventQueue(NamedTuple):
    """A stack of fixed-capacity queues sharing one calibrated capacity.

    coords: (..., capacity, 2) int32 — (i, j) per event; -1 where ~valid.
    valid:  (..., capacity) bool     — which slots hold real events.
    count:  (...,) int32             — spike demand per queue.
    seg_offsets/seg_counts: (..., n_banks) int32 — per-queue interlace
        column segments (see :class:`EventQueue`); None when
        raster-ordered.

    The leading dims are whatever ``build_aeq_batched`` was given, e.g.
    (T, B, C_in) in the batched scheduler.  ``queue_at`` views one member
    as a plain EventQueue.
    """

    coords: jax.Array
    valid: jax.Array
    count: jax.Array
    seg_offsets: Optional[jax.Array] = None
    seg_counts: Optional[jax.Array] = None

    @property
    def capacity(self) -> int:
        return self.coords.shape[-2]

    @property
    def num_queues(self) -> int:
        return int(np.prod(self.coords.shape[:-2], dtype=np.int64))

    def queue_at(self, index: tuple) -> EventQueue:
        return EventQueue(
            coords=self.coords[index], valid=self.valid[index],
            count=self.count[index],
            seg_offsets=None if self.seg_offsets is None
            else self.seg_offsets[index],
            seg_counts=None if self.seg_counts is None
            else self.seg_counts[index])


class BankedEvents(NamedTuple):
    """Kept events of a queue, laid out as the n_banks membrane RAM banks.

    masks: (..., n_banks, HB, WB) bool — bank_masks[..., b, I, J] is True
        iff a kept event's *halo-padded centre* (i+hh, j+hw) falls in
        padded-space bank b = kw*((i+hh)%kh) + (j+hw)%kw at macro cell
        (I, J), with (hh, hw) the geometry halo.  Events of one interlace
        column all land in a single bank, so slicing one bank == selecting
        one hazard-free column.  The banking geometry matches
        ``event_conv.bank_vm`` exactly (9 banks for the default 3x3).
    count:      (...,) int32 — spike demand (same semantics as the queue).
    seg_counts: (..., n_banks) int32 — kept events per interlace column s
        (paper order s = kw*(i%kh)+(j%kw), NOT bank order).
    """

    masks: jax.Array
    count: jax.Array
    seg_counts: jax.Array


def column_index(i: jax.Array, j: jax.Array,
                 geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """Interlacing column s in 0..n_banks-1 of a coordinate (paper
    Figs. 6/7 for 3x3; s = kw*(i%kh) + (j%kw) in general)."""
    return geometry.column_of(i, j)


def interlaced_capacity(capacity: int, event_par: int,
                        n_banks: int = 9) -> int:
    """Queue depth of the ``segment_pad`` layout: each of the ``n_banks``
    column segments is padded to a multiple of ``event_par``, so the worst
    case adds n_banks*(event_par-1) slots; rounded up to an ``event_par``
    multiple so aligned groups tile the queue evenly."""
    if event_par <= 1:
        return capacity
    base = capacity + n_banks * (event_par - 1)
    return -(-base // event_par) * event_par


def _order_keys(h: int, w: int, interlaced: bool,
                geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """(H*W,) int32 read-order key per pixel: (column s, i, j) or raster."""
    ii, jj = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()
    if interlaced:
        order_key = column_index(ii, jj, geometry) * (h * w) + ii * w + jj
    else:
        order_key = ii * w + jj
    return order_key.astype(jnp.int32)


def _kept_segments(flat: jax.Array, h: int, w: int, kept: jax.Array,
                   geometry: ConvGeometry = GEOM_3X3
                   ) -> tuple[jax.Array, jax.Array]:
    """Column segments of the first ``kept`` events in interlaced order.

    flat: (N, H*W) bool active pixels; kept: (N,) int32 events retained
    after capacity truncation.  Returns (seg_offsets, seg_counts), both
    (N, n_banks): truncation drops from the tail of the (s, i, j) order,
    so the kept count of column s is clip(kept - cum_s, 0, count_s).
    """
    nb = geometry.n_banks
    cols = column_index(jnp.arange(h * w) // w, jnp.arange(h * w) % w,
                        geometry)
    onehot = (cols[None, :, None] == jnp.arange(nb)[None, None, :])
    full = jnp.sum(flat[:, :, None] & onehot, axis=1).astype(jnp.int32)
    cum = jnp.cumsum(full, axis=-1) - full  # exclusive
    seg_counts = jnp.clip(kept[:, None] - cum, 0, full)
    seg_offsets = jnp.cumsum(seg_counts, axis=-1) - seg_counts
    return seg_offsets, seg_counts


def build_aeq_batched(fmaps: jax.Array, capacity: int, *,
                      interlaced: bool = True,
                      geometry: ConvGeometry = GEOM_3X3
                      ) -> BatchedEventQueue:
    """Compact a stack of binary fmaps (..., H, W) in one fused sort pass.

    Semantically identical to ``jax.vmap(build_aeq)`` over the flattened
    leading dims (bit-exact — tests/test_aeq.py asserts it) but compiles
    to a SINGLE batched ``sort_key_val`` over an (N, H*W) key matrix
    instead of N independent compactions, which is what lets the batched
    inference pipeline amortize queue construction across (B, T, C_in).
    All queues share one calibrated ``capacity`` (the hardware analogue:
    every BRAM queue instance is sized identically).  Interlaced queues
    additionally carry their column segment offsets/counts.
    """
    *lead, h, w = fmaps.shape
    nb = geometry.n_banks
    n = int(np.prod(lead, dtype=np.int64)) if lead else 1
    flat = fmaps.reshape(n, h * w).astype(bool)
    big = jnp.asarray(nb * h * w + 1, jnp.int32)
    keys = jnp.where(flat, _order_keys(h, w, interlaced, geometry)[None, :],
                     big)
    idx = jnp.broadcast_to(jnp.arange(h * w, dtype=jnp.int32)[None, :], keys.shape)
    sorted_keys, perm = jax.lax.sort_key_val(keys, idx, dimension=-1)
    take_n = min(capacity, h * w)
    take = perm[:, :take_n]
    valid = sorted_keys[:, :take_n] < big
    coords = jnp.stack([take // w, take % w], axis=-1)
    coords = jnp.where(valid[..., None], coords, -1)
    if take_n < capacity:
        pad = capacity - take_n
        coords = jnp.concatenate(
            [coords, jnp.full((n, pad, 2), -1, coords.dtype)], axis=1)
        valid = jnp.concatenate([valid, jnp.zeros((n, pad), bool)], axis=1)
    count = jnp.sum(flat, axis=-1).astype(jnp.int32)
    seg_off = seg_cnt = None
    if interlaced:
        kept = jnp.minimum(count, take_n)
        seg_off, seg_cnt = _kept_segments(flat, h, w, kept, geometry)
        seg_off = seg_off.reshape(*lead, nb)
        seg_cnt = seg_cnt.reshape(*lead, nb)
    return BatchedEventQueue(
        coords=coords.reshape(*lead, capacity, 2),
        valid=valid.reshape(*lead, capacity),
        count=count.reshape(tuple(lead)),
        seg_offsets=seg_off, seg_counts=seg_cnt)


def build_aeq(fmap: jax.Array, capacity: int, *, interlaced: bool = True,
              geometry: ConvGeometry = GEOM_3X3) -> EventQueue:
    """Compact a binary fmap (H, W) into an EventQueue.

    Events are ordered by (column s, i, j) when ``interlaced`` (the paper's
    hazard-free read order), else by raster (i, j).  Events beyond
    ``capacity`` are dropped — exactly what a full hardware queue would do;
    capacity is calibrated so this never happens in practice
    (``calibrate_capacity``).  One-fmap view of ``build_aeq_batched`` (the
    compaction logic is shared, so the two are bit-identical by
    construction).
    """
    bq = build_aeq_batched(fmap[None], capacity, interlaced=interlaced,
                           geometry=geometry)
    return bq.queue_at((0,))


def segment_pad(queue: BatchedEventQueue | EventQueue, event_par: int,
                geometry: ConvGeometry = GEOM_3X3
                ) -> BatchedEventQueue | EventQueue:
    """Re-lay an interlaced queue so column segments are event_par-aligned.

    Each column segment keeps its events in order but starts at a multiple
    of ``event_par`` and is padded to a multiple of ``event_par`` with
    invalid slots, so every aligned group of ``event_par`` consecutive
    slots holds events of ONE interlace column (or padding).  This is the
    layout ``event_conv_pallas_interlaced`` consumes: aligned groups are
    hazard-free by construction and the sequential column-boundary
    fallback never fires.  Replaying the padded queue sequentially is
    bit-exact vs the original (padding slots are invalid no-ops; relative
    event order is unchanged).

    The returned queue has capacity ``interlaced_capacity(cap, event_par)``
    and ``seg_offsets`` pointing into the padded layout.
    """
    if queue.seg_offsets is None:
        raise ValueError("segment_pad needs an interlaced queue carrying "
                         "column segments (build_aeq(..., interlaced=True))")
    single = isinstance(queue, EventQueue)
    if single:
        queue = BatchedEventQueue(*(x[None] for x in queue))
    coords, valid = queue.coords, queue.valid
    seg_cnt, seg_off = queue.seg_counts, queue.seg_offsets
    nb = geometry.n_banks
    lead = coords.shape[:-2]
    n = int(np.prod(lead, dtype=np.int64)) if lead else 1
    cap = coords.shape[-2]
    cap_pad = interlaced_capacity(cap, event_par, nb)
    coords = coords.reshape(n, cap, 2)
    valid = valid.reshape(n, cap)
    seg_cnt = seg_cnt.reshape(n, nb)
    seg_off = seg_off.reshape(n, nb)

    pad_cnt = -(-seg_cnt // event_par) * event_par
    pad_off = jnp.cumsum(pad_cnt, axis=-1) - pad_cnt
    col = column_index(coords[..., 0], coords[..., 1], geometry)
    col = jnp.where(valid, col, 0)
    rank = jnp.arange(cap)[None, :] - jnp.take_along_axis(seg_off, col, -1)
    newpos = jnp.take_along_axis(pad_off, col, -1) + rank
    newpos = jnp.where(valid, newpos, cap_pad)  # dropped by mode="drop"

    def scatter_one(c, v, pos):
        oc = jnp.full((cap_pad, 2), -1, c.dtype).at[pos].set(c, mode="drop")
        ov = jnp.zeros((cap_pad,), bool).at[pos].set(v, mode="drop")
        return oc, ov

    oc, ov = jax.vmap(scatter_one)(coords, valid, newpos)
    out = BatchedEventQueue(
        coords=oc.reshape(*lead, cap_pad, 2),
        valid=ov.reshape(*lead, cap_pad),
        count=queue.count,
        seg_offsets=pad_off.reshape(*lead, nb),
        seg_counts=queue.seg_counts)
    return out.queue_at((0,)) if single else out


def build_bank_masks(fmaps: jax.Array, capacity: int,
                     geometry: ConvGeometry = GEOM_3X3) -> BankedEvents:
    """Compact binary fmaps (..., H, W) straight into the n_banks RAM
    banks (9 for the default 3x3 geometry).

    Sort-free equivalent of ``build_aeq_batched`` for mask consumers: the
    kept-event set (the first ``min(capacity, H*W)`` events in the
    interlaced (s, i, j) order — identical truncation to the queue, as
    asserted in tests/test_interlaced.py) is computed with per-column
    cumulative ranks instead of a sort, then banked in padded space so the
    result plugs directly into ``event_conv.apply_events_interlaced*``.
    """
    *lead, h, w = fmaps.shape
    nb = geometry.n_banks
    hh, hw = geometry.halo
    n = int(np.prod(lead, dtype=np.int64)) if lead else 1
    flat = fmaps.reshape(n, h, w).astype(bool)
    il = interlace(flat, geometry)           # (n, nb, hb, wb) unpadded banks
    hb, wb = il.shape[-2:]
    il_flat = il.reshape(n, nb, hb * wb)
    # within a column, (I, J) raster order == (i, j) order (i = kh*I + si)
    seg_full = jnp.sum(il_flat, axis=-1).astype(jnp.int32)       # (n, nb)
    count = jnp.sum(seg_full, axis=-1)
    kept = jnp.minimum(count, min(capacity, h * w))
    seg_off = jnp.cumsum(seg_full, axis=-1) - seg_full           # exclusive
    rank_in_col = jnp.cumsum(il_flat, axis=-1) - il_flat         # exclusive
    rank = seg_off[:, :, None] + rank_in_col
    kept_il = il_flat & (rank < kept[:, None, None])
    kept_map = deinterlace(kept_il.reshape(n, nb, hb, wb), (h, w), geometry)
    seg_counts = jnp.clip(kept[:, None] - seg_off, 0, seg_full)
    # bank the halo-padded centres: event (i, j) sits at padded (i+hh, j+hw)
    padded = jnp.pad(kept_map, [(0, 0), (hh, hh), (hw, hw)])
    masks = interlace(padded, geometry)
    return BankedEvents(
        masks=masks.reshape(*lead, *masks.shape[-3:]),
        count=count.reshape(tuple(lead)).astype(jnp.int32),
        seg_counts=seg_counts.reshape(*lead, nb))


class FusedHandoff(NamedTuple):
    """Fused spike-emission carrier between adjacent conv layers.

    masks: (T, C, B, n_banks, HBp+2, WBp+2) bool — the kept events'
        halo-padded centre-bank occupancy (identical content to
        :class:`BankedEvents`.masks of the same fmaps) but (a) laid out
        scan-major for the consumer — leading T for the time scan, then C
        for the fori over input channels — and (b) carrying ONE extra
        macro cell of zero padding per side.  That pad ring is what lets
        the consumer slice every (column, bank) shifted write mask
        directly out of the carrier
        (``event_conv.apply_banked_columns_fused``) instead of
        materializing the n_banks^2 ``shifted_bank_masks`` stack:
        masks == pad(BankedEvents.masks, 1 macro cell per side) with the
        (T, B, C) lead transposed to (T, C, B).
    count: (T, B, C) int32 — spike demand per queue, in the
        :class:`BankedEvents` layout convention (feeds LayerStats
        unchanged).
    """

    masks: jax.Array
    count: jax.Array


def ranked_keep(il: jax.Array, capacity: int, hw: tuple[int, int]
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-free capacity truncation on interlaced occupancy — the
    cumulative-rank machinery shared by ``stream_queues``,
    ``build_bank_masks`` and the fused-emission builders.

    il: (..., n_banks, HB, WB) bool centre-bank occupancy of an UNPADDED
    (H, W) fmap.  Returns (kept occupancy, same shape; count (...,) int32
    spike demand; seg_counts (..., n_banks) int32 kept events per
    interlace column).  Within a column, (I, J) raster order equals the
    paper's (i, j) order, so an event's rank in the (s, i, j) read order
    is columns-before + actives-before-in-column (exclusive cumsums) and
    truncation keeps ranks < min(capacity, H*W) — identical to the
    ``build_aeq_batched`` tail drop.  When the capacity covers the whole
    fmap the rank computation is statically skipped (nothing can drop).
    """
    h, w = hw
    nb, hb, wb = il.shape[-3:]
    il_flat = il.reshape(il.shape[:-2] + (hb * wb,))
    seg_full = jnp.sum(il_flat, axis=-1).astype(jnp.int32)
    count = jnp.sum(seg_full, axis=-1)
    seg_off = jnp.cumsum(seg_full, axis=-1) - seg_full        # exclusive
    kept = jnp.minimum(count, min(capacity, h * w))
    seg_counts = jnp.clip(kept[..., None] - seg_off, 0, seg_full)
    if capacity >= h * w:
        return il, count, seg_counts
    rank_in_col = jnp.cumsum(il_flat, axis=-1) - il_flat      # exclusive
    rank = seg_off[..., None] + rank_in_col
    kept_il = il_flat & (rank < kept[..., None, None])
    return kept_il.reshape(il.shape), count, seg_counts


def place_padded_banks(kept_il: jax.Array, hw: tuple[int, int],
                       geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """Re-bank unpadded centre occupancy into the padded fused layout.

    kept_il: (..., n_banks, HB, WB) bool over the unpadded fmap (bank
    s = kw*(i%kh)+(j%kw), macro (i//kh, j//kw)).  Returns
    (..., n_banks, HBp+2, WBp+2): each column's cells land in the
    padded-space centre bank ((si+hh)%kh)*kw + (sj+hw)%kw at a static
    macro offset (1 + (si+hh)//kh, 1 + (sj+hw)//kw) — n_banks static
    placements replace the deinterlace -> pad -> interlace dense round
    trip of ``build_bank_masks``, and the result equals its masks with
    one macro cell of padding per side (tests/test_fused_handoff.py).
    """
    h, w = hw
    kh, kw = geometry.kh, geometry.kw
    hh, hw_ = geometry.halo
    nb = geometry.n_banks
    hb, wb = kept_il.shape[-2:]
    hbp, wbp = -(-(h + 2 * hh) // kh), -(-(w + 2 * hw_) // kw)
    mp = jnp.zeros(kept_il.shape[:-3] + (nb, hbp + 2, wbp + 2), jnp.bool_)
    for s in range(nb):
        si, sj = divmod(s, kw)
        tb = ((si + hh) % kh) * kw + (sj + hw_) % kw
        oi = 1 + (si + hh) // kh      # in {1, 2}: always fits (hb <= hbp)
        oj = 1 + (sj + hw_) // kw
        mp = mp.at[..., tb, oi:oi + hb, oj:oj + wb].set(kept_il[..., s, :, :])
    return mp


def build_fused_handoff(spikes: jax.Array, capacity: int,
                        geometry: ConvGeometry = GEOM_3X3) -> FusedHandoff:
    """Compact a (B, T, H, W, C) spike chunk straight into the fused
    handoff carrier — the emission half of the ``"fused-handoff"`` kernel
    variant.

    One 7-D reshape/transpose interlaces the chunk (no per-map pass), the
    shared ``ranked_keep`` machinery applies the AEQ capacity truncation,
    and ``place_padded_banks`` banks the kept centres — so the carrier
    costs one cheap pass over the spike data where the banked path pays
    interlace -> ranks -> deinterlace -> pad -> re-interlace and then an
    n_banks^2 ``shifted_bank_masks`` stack.  Mask content and counts are
    bit-identical to ``build_bank_masks`` over the same fmaps.
    """
    b, t, h, w, c = spikes.shape
    kh, kw = geometry.kh, geometry.kw
    nb = geometry.n_banks
    ph, pw = -h % kh, -w % kw
    x = jnp.pad(spikes.astype(bool), ((0, 0), (0, 0), (0, ph), (0, pw),
                                      (0, 0)))
    hb, wb = (h + ph) // kh, (w + pw) // kw
    x = x.reshape(b, t, hb, kh, wb, kw, c)
    # -> (T, C, B, kh, kw, HB, WB) -> (T, C, B, n_banks, HB, WB): same
    # bank order as ``interlace`` (s = kw*(i%kh) + j%kw)
    il = x.transpose(1, 6, 0, 3, 5, 2, 4).reshape(t, c, b, nb, hb, wb)
    kept_il, count, _ = ranked_keep(il, capacity, (h, w))
    return FusedHandoff(masks=place_padded_banks(kept_il, (h, w), geometry),
                        count=jnp.swapaxes(count, 1, 2))


def fused_handoff_from_banks(banks: jax.Array, capacity: int,
                             hw: tuple[int, int],
                             geometry: ConvGeometry = GEOM_3X3
                             ) -> FusedHandoff:
    """Fused handoff carrier straight from streamed ingestion banks.

    banks: (B, T, C, n_banks, HB, WB) bool from :class:`StreamState` —
    already the interlaced centre occupancy ``build_fused_handoff``
    computes internally, so the streamed fused path needs NO dense
    ``stream_frames`` round trip at all: rank-truncate the banks and
    place them into the padded layout.  Bit-exact vs binning the same
    events and calling ``build_fused_handoff`` (the streaming-equivalence
    theorem; tests/test_fused_handoff.py).
    """
    h, w = hw
    kh, kw = geometry.kh, geometry.kw
    nb = geometry.n_banks
    got_nb, hb, wb = banks.shape[-3:]
    if got_nb != nb:
        raise ValueError(f"stream banks must carry {nb} columns for the "
                         f"{kh}x{kw} geometry, got {got_nb}")
    if (hb, wb) != (-(-h // kh), -(-w // kw)):
        raise ValueError(f"stream banks {(hb, wb)} do not match hw={hw} "
                         f"under the {kh}x{kw} geometry")
    il = banks.transpose(1, 2, 0, 3, 4, 5)        # (T, C, B, nb, HB, WB)
    kept_il, count, _ = ranked_keep(il, capacity, (h, w))
    return FusedHandoff(masks=place_padded_banks(kept_il, (h, w), geometry),
                        count=jnp.swapaxes(count, 1, 2))


def scatter_aeq(queue: EventQueue, shape: tuple[int, int]) -> jax.Array:
    """Inverse of build_aeq: expand an EventQueue back into a binary fmap."""
    fmap = jnp.zeros(shape, jnp.bool_)
    i = jnp.where(queue.valid, queue.coords[:, 0], 0)
    j = jnp.where(queue.valid, queue.coords[:, 1], 0)
    return fmap.at[i, j].max(queue.valid)


def calibrate_capacity(spike_counts, *, percentile: float = 99.9, margin: float = 1.25,
                       align: int = 8) -> int:
    """Pick a queue capacity covering the observed spike-count distribution.

    This is the TPU analogue of sizing the FPGA queue BRAM: the dry-run /
    calibration pass records per-(layer, channel, t) spike counts and the
    capacity is the ``percentile`` count times a safety ``margin``, rounded
    up to ``align`` (vector-friendly block multiple).
    """
    counts = np.asarray(spike_counts, dtype=np.float64).ravel()
    if counts.size == 0:
        return align
    cap = float(np.percentile(counts, percentile)) * margin
    cap = int(np.ceil(max(cap, 1.0) / align) * align)
    return cap


def calibrate_capacities(per_layer_counts, *, percentile: float = 99.9,
                         margin: float = 1.25, align: int = 8) -> list[int]:
    """Per-layer ``calibrate_capacity``: one queue depth per conv layer.

    ``per_layer_counts`` is a sequence with one spike-count array per
    layer (e.g. ``[st.in_spike_counts for st in stats]`` from a
    calibration run of ``snn_apply_batched``).  This is the two-tier
    adaptive capacity from the ROADMAP: each layer's queues are sized from
    *its own* distribution instead of one network-wide worst case — feed
    the result to ``plan_network(cfg, capacity=...)`` (which additionally
    caps each depth at the layer's H·W).
    """
    return [calibrate_capacity(c, percentile=percentile, margin=margin,
                               align=align) for c in per_layer_counts]


# ---------------------------------------------------------------------------
# Memory interlacing (paper Fig. 6) — functional model.
# ---------------------------------------------------------------------------

def interlace(vm: jax.Array, geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """(..., H, W) values -> (..., n_banks, ceil(H/kh), ceil(W/kw))
    memory columns.

    Column s = kw*(i%kh) + (j%kw); within a column, the element of the
    kh x kw macro-block (I, J) = (i//kh, j//kw) lives at address (I, J).
    Any kh x kw window of the original map touches each column exactly
    once — this is the invariant the FPGA exploits for n_banks
    conflict-free ports (9 for the paper's 3x3), and the property test in
    tests/test_aeq.py asserts it.  Leading dims (batch, time, ...) pass
    through unchanged.
    """
    kh, kw = geometry.kh, geometry.kw
    *lead, h, w = vm.shape
    ph, pw = -h % kh, -w % kw
    vm = jnp.pad(vm, [(0, 0)] * len(lead) + [(0, ph), (0, pw)])
    hh, ww = vm.shape[-2:]
    nl = len(lead)
    # (..., H, W) -> (..., H/kh, kh, W/kw, kw) -> (..., kh, kw, H/kh, W/kw)
    # -> (..., kh*kw, ...)
    blocks = vm.reshape(*lead, hh // kh, kh, ww // kw, kw)
    blocks = blocks.transpose(*range(nl), nl + 1, nl + 3, nl, nl + 2)
    return blocks.reshape(*lead, kh * kw, hh // kh, ww // kw)


def deinterlace(cols: jax.Array, shape: tuple[int, int],
                geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """Inverse of ``interlace``; crops back to the original (..., H, W)."""
    kh, kw = geometry.kh, geometry.kw
    *lead, _, bh, bw = cols.shape
    nl = len(lead)
    blocks = cols.reshape(*lead, kh, kw, bh, bw)
    blocks = blocks.transpose(*range(nl), nl + 2, nl, nl + 3, nl + 1)
    return blocks.reshape(*lead, bh * kh, bw * kw)[..., : shape[0],
                                                   : shape[1]]


# ---------------------------------------------------------------------------
# Streaming DVS ingestion (ISSUE 6): incremental AEQ append.
# ---------------------------------------------------------------------------

class StreamChunk(NamedTuple):
    """A fixed-capacity buffer of raw DVS address events awaiting ingestion.

    events: (..., N, 4) int32 — one (t, y, x, polarity) row per event;
        ``t`` indexes the time bin inside the ingestion window, ``y``/``x``
        the pixel, ``polarity`` the input channel (0=OFF, 1=ON for
        2-polarity sensors).  Rows beyond ``num`` are padding and ignored;
        rows with out-of-window coordinates are dropped on append (what a
        hardware ingress FIFO does with events outside its ROI/window).
    num: (...,) int32 — valid leading rows per buffer.

    The static buffer depth N is the ingestion analogue of the AEQ
    capacity (``LayerPlan.ingest_capacity``): sized once, so jitted
    admission never retraces on event count.
    """

    events: jax.Array
    num: jax.Array

    @property
    def buffer(self) -> int:
        return self.events.shape[-2]


class StreamState(NamedTuple):
    """Incremental AEQ ingestion state for one T-bin input window.

    banks: (..., T, C, n_banks, HB, WB) bool — per-(bin, channel) pixel
        occupancy held directly in the interlace-column banks of the
        PR-5 layout (bank s = kw*(y%kh) + x%kw, macro cell (y//kh,
        x//kw); 9 banks for the default 3x3): appending an event is a
        single scatter into its hazard-free column, and no dense (H, W)
        frame is ever materialized.  Leading dims (e.g. batch) pass
        through ``append_events_batched``.

    A pytree of one bool array: jit/donate/vmap all apply, and the
    serving engine slices per-slot windows out of it directly.
    """

    banks: jax.Array

    @property
    def t_bins(self) -> int:
        return self.banks.shape[-5]

    @property
    def channels(self) -> int:
        return self.banks.shape[-4]


def init_stream_state(hw: tuple[int, int], t_bins: int, channels: int,
                      lead: tuple = (),
                      geometry: ConvGeometry = GEOM_3X3) -> StreamState:
    """Empty ingestion state for a (T, C, H, W) input window."""
    h, w = hw
    kh, kw = geometry.kh, geometry.kw
    hb, wb = -(-h // kh), -(-w // kw)
    return StreamState(
        banks=jnp.zeros((*lead, t_bins, channels, geometry.n_banks, hb, wb),
                        jnp.bool_))


def make_stream_chunk(events, buffer: Optional[int] = None) -> StreamChunk:
    """Host helper: pad an (N, 4) event list to a fixed-depth StreamChunk.

    ``buffer`` defaults to N; pad rows carry t=-1 so they can never
    scatter even if ``num`` is ignored downstream.
    """
    ev = np.asarray(events, dtype=np.int32).reshape(-1, 4)
    n = ev.shape[0]
    depth = n if buffer is None else buffer
    if n > depth:
        raise ValueError(f"{n} events exceed the chunk buffer depth {depth}")
    out = np.full((depth, 4), -1, np.int32)
    out[:n] = ev
    return StreamChunk(events=jnp.asarray(out),
                       num=jnp.asarray(n, jnp.int32))


def append_events(state: StreamState, chunk: StreamChunk,
                  hw: tuple[int, int],
                  geometry: ConvGeometry = GEOM_3X3) -> StreamState:
    """Merge one chunk of raw events into the ingestion state.

    Idempotent scatter into the column banks: duplicate events (same bin,
    pixel, polarity — a DVS pixel re-firing inside one bin) dedupe to the
    single occupancy bit the binned path would see, and events outside
    the (T, C, H, W) window (including ``num``-padding rows) are dropped.
    Append order never matters: any chunking/permutation of the same
    event set yields the same state (tests/test_streaming.py).
    """
    h, w = hw
    t_bins, channels = state.t_bins, state.channels
    t, y, x, p = (chunk.events[..., k] for k in range(4))
    ok = ((jnp.arange(chunk.buffer) < chunk.num)
          & (t >= 0) & (t < t_bins) & (y >= 0) & (y < h)
          & (x >= 0) & (x < w) & (p >= 0) & (p < channels))
    # invalid rows are pushed out of bounds so mode="drop" discards them
    # even when their other coordinates happen to be in range
    t = jnp.where(ok, t, t_bins)
    kh, kw = geometry.kh, geometry.kw
    banks = state.banks.at[t, p, column_index(y, x, geometry),
                           y // kh, x // kw].max(ok, mode="drop")
    return StreamState(banks=banks)


def append_events_batched(state: StreamState, chunk: StreamChunk,
                          hw: tuple[int, int],
                          geometry: ConvGeometry = GEOM_3X3) -> StreamState:
    """``append_events`` over matching leading dims (e.g. a slot batch):
    state banks (..., T, C, n_banks, HB, WB) + chunk events (..., N, 4)."""
    lead = state.banks.shape[:-5]
    if chunk.events.shape[:-2] != lead or chunk.num.shape != lead:
        raise ValueError(
            f"chunk leading dims {chunk.events.shape[:-2]} do not match "
            f"state leading dims {lead}")
    fn = lambda b, e, n: append_events(
        StreamState(b), StreamChunk(e, n), hw, geometry).banks
    for _ in lead:
        fn = jax.vmap(fn)
    return StreamState(banks=fn(state.banks, chunk.events, chunk.num))


def stream_frames(state: StreamState, hw: tuple[int, int],
                  geometry: ConvGeometry = GEOM_3X3) -> jax.Array:
    """Dense (..., T, C, H, W) bool view of the ingestion state — the
    exact frames the binned path would have built from the same events
    (the differential-test pivot; also feeds the banked conv path)."""
    return deinterlace(state.banks, hw, geometry)


def _queues_from_cols(il_flat: jax.Array, h: int, w: int, capacity: int,
                      interlaced: bool,
                      geometry: ConvGeometry = GEOM_3X3
                      ) -> BatchedEventQueue:
    """Sort-free queue compaction from column-bank occupancy.

    il_flat: (N, n_banks, HB*WB) bool — per-queue occupancy in interlaced
    banks, cells in raster (I, J) order.  Each kept event's queue slot is
    its *rank* in the read order, computed with exclusive cumsums instead
    of a sort: within one column, (I, J) raster order equals (i, j) order
    (i = kh*I + s//kw), so rank = columns-before + actives-before-in-
    column.  Truncation keeps ranks < min(capacity, H*W) — identical to
    the ``build_aeq_batched`` tail drop.
    """
    kh, kw = geometry.kh, geometry.kw
    nb = geometry.n_banks
    n, _, cells = il_flat.shape
    hb, wb = -(-h // kh), -(-w // kw)
    take_n = min(capacity, h * w)
    seg_full = jnp.sum(il_flat, axis=-1).astype(jnp.int32)         # (N, nb)
    count = jnp.sum(seg_full, axis=-1)                             # (N,)
    kept = jnp.minimum(count, take_n)
    rank_in_col = (jnp.cumsum(il_flat, axis=-1) - il_flat).astype(jnp.int32)
    if interlaced:
        seg_off_full = jnp.cumsum(seg_full, axis=-1) - seg_full    # exclusive
        rank = seg_off_full[:, :, None] + rank_in_col
    else:
        # raster read order: rank events by (i, j) irrespective of column
        dense = deinterlace(il_flat.reshape(n, nb, hb, wb), (h, w), geometry)
        flat = dense.reshape(n, h * w)
        rank_flat = (jnp.cumsum(flat, axis=-1) - flat).astype(jnp.int32)
        rank = interlace(rank_flat.reshape(n, h, w),
                         geometry).reshape(n, nb, cells)
    # cell (s, I, J) -> pixel (i, j); pad cells (i >= h or j >= w) are
    # never occupied, so their garbage coords are masked by ``keep``
    s = jnp.arange(nb, dtype=jnp.int32)[:, None]
    cell = jnp.arange(cells, dtype=jnp.int32)[None, :]
    ii = kh * (cell // wb) + s // kw                              # (nb, cells)
    jj = kw * (cell % wb) + s % kw
    cell_coords = jnp.stack(
        [jnp.broadcast_to(ii, (nb, cells)),
         jnp.broadcast_to(jj, (nb, cells))],
        axis=-1).reshape(nb * cells, 2)
    keep = il_flat & (rank < kept[:, None, None])
    pos = jnp.where(keep, rank, capacity).reshape(n, nb * cells)   # drop pads

    def scatter_one(p):
        return (jnp.full((capacity, 2), -1, jnp.int32)
                .at[p].set(cell_coords, mode="drop"))

    coords = jax.vmap(scatter_one)(pos)
    valid = jnp.arange(capacity, dtype=jnp.int32)[None, :] < kept[:, None]
    seg_off = seg_cnt = None
    if interlaced:
        seg_cnt = jnp.clip(kept[:, None] - seg_off_full, 0, seg_full)
        seg_off = jnp.cumsum(seg_cnt, axis=-1) - seg_cnt
    return BatchedEventQueue(coords=coords, valid=valid, count=count,
                             seg_offsets=seg_off, seg_counts=seg_cnt)


def stream_queues(state: StreamState, capacity: int, hw: tuple[int, int], *,
                  interlaced: bool = True,
                  geometry: ConvGeometry = GEOM_3X3) -> BatchedEventQueue:
    """Finalize ingested events into AEQs — sort-free, bit-exact vs the
    binned path.

    Returns a :class:`BatchedEventQueue` with leading dims
    (..., T, C) equal to
    ``build_aeq_batched(stream_frames(state, hw).astype(bool), capacity)``
    bit for bit (coords, valid, count, segments; truncation included —
    tests/test_streaming.py asserts it), but built from the column banks
    with cumulative ranks instead of a batched O(HW log HW) sort — the
    whole point of ingesting into the interlaced layout.
    """
    h, w = hw
    kh, kw = geometry.kh, geometry.kw
    nb = geometry.n_banks
    *lead_tc, got_nb, hb, wb = state.banks.shape
    if got_nb != nb:
        raise ValueError(f"StreamState banks must carry {nb} columns for "
                         f"the {kh}x{kw} geometry, got {got_nb}")
    if (hb, wb) != (-(-h // kh), -(-w // kw)):
        raise ValueError(f"StreamState banks {(hb, wb)} do not match "
                         f"hw={hw} under the {kh}x{kw} geometry")
    n = int(np.prod(lead_tc, dtype=np.int64)) if lead_tc else 1
    il_flat = state.banks.reshape(n, nb, hb * wb)
    q = _queues_from_cols(il_flat, h, w, capacity, interlaced, geometry)
    return BatchedEventQueue(
        coords=q.coords.reshape(*lead_tc, capacity, 2),
        valid=q.valid.reshape(*lead_tc, capacity),
        count=q.count.reshape(tuple(lead_tc)),
        seg_offsets=None if q.seg_offsets is None
        else q.seg_offsets.reshape(*lead_tc, nb),
        seg_counts=None if q.seg_counts is None
        else q.seg_counts.reshape(*lead_tc, nb))
