"""Address-Event Queue (AEQ): runtime compaction of sparse binary fmaps.

Paper Secs. V-A / VI-A.  A binary feature map is stored not as a (0,1)
matrix but as a queue of the coordinates (i, j) of its ones ("address
events").  The hardware builds these queues at runtime with dedicated
circuitry; processing a layer then walks the queue, so the cycle count
scales with the number of spikes.

TPU adaptation (DESIGN.md Sec. 2): queues become fixed-capacity event
buffers built by an O(HW log HW) sort-based stream compaction — the static
capacity plays the role of the BRAM queue depth and is calibrated offline
from observed spike counts.  The paper's *interlaced column order*
(process all events of column s=0, then s=1, ... with s = 3(i%3)+(j%3)) is
preserved: it is what makes same-column events hazard-free (their 3x3
neighbourhoods can never overlap) and we keep it so the cycle-level
pipeline simulator and the Pallas kernel see the same schedule as the RTL.

Two entry points share the compaction logic: ``build_aeq`` compacts one
fmap, and ``build_aeq_batched`` compacts a whole stack of fmaps (any
leading dims, e.g. (B, T, C_in, H, W)) in ONE fused batched sort — the
builder behind the batched inference pipeline (scheduler
``run_conv_layer_batched``).  Property tests live in tests/test_aeq.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EventQueue(NamedTuple):
    """Fixed-capacity queue of address events.

    coords: (capacity, 2) int32 — (i, j) per event; undefined where ~valid.
    valid:  (capacity,) bool    — which slots hold real events.
    count:  () int32            — number of valid events (= valid.sum()).
    """

    coords: jax.Array
    valid: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return self.coords.shape[0]


class BatchedEventQueue(NamedTuple):
    """A stack of fixed-capacity queues sharing one calibrated capacity.

    coords: (..., capacity, 2) int32 — (i, j) per event; -1 where ~valid.
    valid:  (..., capacity) bool     — which slots hold real events.
    count:  (...,) int32             — valid events per queue.

    The leading dims are whatever ``build_aeq_batched`` was given, e.g.
    (T, B, C_in) in the batched scheduler.  ``queue_at`` views one member
    as a plain EventQueue.
    """

    coords: jax.Array
    valid: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return self.coords.shape[-2]

    @property
    def num_queues(self) -> int:
        return int(np.prod(self.coords.shape[:-2], dtype=np.int64))

    def queue_at(self, index: tuple) -> EventQueue:
        return EventQueue(coords=self.coords[index], valid=self.valid[index],
                          count=self.count[index])


def column_index(i: jax.Array, j: jax.Array) -> jax.Array:
    """Interlacing column s in 0..8 of a coordinate (paper Figs. 6/7)."""
    return (i % 3) * 3 + (j % 3)


def _order_keys(h: int, w: int, interlaced: bool) -> jax.Array:
    """(H*W,) int32 read-order key per pixel: (column s, i, j) or raster."""
    ii, jj = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()
    if interlaced:
        order_key = column_index(ii, jj) * (h * w) + ii * w + jj
    else:
        order_key = ii * w + jj
    return order_key.astype(jnp.int32)


def build_aeq(fmap: jax.Array, capacity: int, *, interlaced: bool = True) -> EventQueue:
    """Compact a binary fmap (H, W) into an EventQueue.

    Events are ordered by (column s, i, j) when ``interlaced`` (the paper's
    hazard-free read order), else by raster (i, j).  Events beyond
    ``capacity`` are dropped — exactly what a full hardware queue would do;
    capacity is calibrated so this never happens in practice
    (``calibrate_capacity``).
    """
    h, w = fmap.shape
    fmap = fmap.astype(bool)
    big = jnp.asarray(9 * h * w + 1, jnp.int32)
    key = jnp.where(fmap.ravel(), _order_keys(h, w, interlaced), big)
    sorted_key, perm = jax.lax.sort_key_val(key, jnp.arange(h * w, dtype=jnp.int32))
    take_n = min(capacity, h * w)  # a queue deeper than the fmap just stays padded
    take = perm[:take_n]
    valid = sorted_key[:take_n] < big
    coords = jnp.stack([take // w, take % w], axis=-1)
    coords = jnp.where(valid[:, None], coords, -1)
    if take_n < capacity:
        pad = capacity - take_n
        coords = jnp.concatenate([coords, jnp.full((pad, 2), -1, coords.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    return EventQueue(coords=coords, valid=valid, count=jnp.sum(fmap).astype(jnp.int32))


def build_aeq_batched(fmaps: jax.Array, capacity: int, *,
                      interlaced: bool = True) -> BatchedEventQueue:
    """Compact a stack of binary fmaps (..., H, W) in one fused sort pass.

    Semantically identical to ``jax.vmap(build_aeq)`` over the flattened
    leading dims (bit-exact — tests/test_aeq.py asserts it) but compiles
    to a SINGLE batched ``sort_key_val`` over an (N, H*W) key matrix
    instead of N independent compactions, which is what lets the batched
    inference pipeline amortize queue construction across (B, T, C_in).
    All queues share one calibrated ``capacity`` (the hardware analogue:
    every BRAM queue instance is sized identically).
    """
    *lead, h, w = fmaps.shape
    n = int(np.prod(lead, dtype=np.int64)) if lead else 1
    flat = fmaps.reshape(n, h * w).astype(bool)
    big = jnp.asarray(9 * h * w + 1, jnp.int32)
    keys = jnp.where(flat, _order_keys(h, w, interlaced)[None, :], big)
    idx = jnp.broadcast_to(jnp.arange(h * w, dtype=jnp.int32)[None, :], keys.shape)
    sorted_keys, perm = jax.lax.sort_key_val(keys, idx, dimension=-1)
    take_n = min(capacity, h * w)
    take = perm[:, :take_n]
    valid = sorted_keys[:, :take_n] < big
    coords = jnp.stack([take // w, take % w], axis=-1)
    coords = jnp.where(valid[..., None], coords, -1)
    if take_n < capacity:
        pad = capacity - take_n
        coords = jnp.concatenate(
            [coords, jnp.full((n, pad, 2), -1, coords.dtype)], axis=1)
        valid = jnp.concatenate([valid, jnp.zeros((n, pad), bool)], axis=1)
    return BatchedEventQueue(
        coords=coords.reshape(*lead, capacity, 2),
        valid=valid.reshape(*lead, capacity),
        count=jnp.sum(flat, axis=-1).astype(jnp.int32).reshape(tuple(lead)),
    )


def scatter_aeq(queue: EventQueue, shape: tuple[int, int]) -> jax.Array:
    """Inverse of build_aeq: expand an EventQueue back into a binary fmap."""
    fmap = jnp.zeros(shape, jnp.bool_)
    i = jnp.where(queue.valid, queue.coords[:, 0], 0)
    j = jnp.where(queue.valid, queue.coords[:, 1], 0)
    return fmap.at[i, j].max(queue.valid)


def calibrate_capacity(spike_counts, *, percentile: float = 99.9, margin: float = 1.25,
                       align: int = 8) -> int:
    """Pick a queue capacity covering the observed spike-count distribution.

    This is the TPU analogue of sizing the FPGA queue BRAM: the dry-run /
    calibration pass records per-(layer, channel, t) spike counts and the
    capacity is the ``percentile`` count times a safety ``margin``, rounded
    up to ``align`` (vector-friendly block multiple).
    """
    counts = np.asarray(spike_counts, dtype=np.float64).ravel()
    if counts.size == 0:
        return align
    cap = float(np.percentile(counts, percentile)) * margin
    cap = int(np.ceil(max(cap, 1.0) / align) * align)
    return cap


def calibrate_capacities(per_layer_counts, *, percentile: float = 99.9,
                         margin: float = 1.25, align: int = 8) -> list[int]:
    """Per-layer ``calibrate_capacity``: one queue depth per conv layer.

    ``per_layer_counts`` is a sequence with one spike-count array per
    layer (e.g. ``[st.in_spike_counts for st in stats]`` from a
    calibration run of ``snn_apply_batched``).  This is the two-tier
    adaptive capacity from the ROADMAP: each layer's queues are sized from
    *its own* distribution instead of one network-wide worst case — feed
    the result to ``plan_network(cfg, capacity=...)`` (which additionally
    caps each depth at the layer's H·W).
    """
    return [calibrate_capacity(c, percentile=percentile, margin=margin,
                               align=align) for c in per_layer_counts]


# ---------------------------------------------------------------------------
# Memory interlacing (paper Fig. 6) — functional model.
# ---------------------------------------------------------------------------

def interlace(vm: jax.Array) -> jax.Array:
    """(H, W) membrane potentials -> (9, ceil(H/3), ceil(W/3)) memory columns.

    Column s = 3*(i%3) + (j%3); within a column, the element of the 3x3
    macro-block (I, J) = (i//3, j//3) lives at address (I, J).  Any 3x3
    window of the original map touches each column exactly once — this is
    the invariant the FPGA exploits for 9 conflict-free ports, and the
    property test in tests/test_aeq.py asserts it.
    """
    h, w = vm.shape
    ph, pw = -h % 3, -w % 3
    vm = jnp.pad(vm, ((0, ph), (0, pw)))
    hh, ww = vm.shape
    # (H, W) -> (H/3, 3, W/3, 3) -> (3, 3, H/3, W/3) -> (9, H/3, W/3)
    blocks = vm.reshape(hh // 3, 3, ww // 3, 3).transpose(1, 3, 0, 2)
    return blocks.reshape(9, hh // 3, ww // 3)


def deinterlace(cols: jax.Array, shape: tuple[int, int]) -> jax.Array:
    """Inverse of ``interlace``; crops back to the original (H, W)."""
    _, bh, bw = cols.shape
    blocks = cols.reshape(3, 3, bh, bw).transpose(2, 0, 3, 1)
    return blocks.reshape(bh * 3, bw * 3)[: shape[0], : shape[1]]
