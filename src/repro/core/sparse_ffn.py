"""BEYOND-PAPER: the AEQ idea applied to transformer FFN activation sparsity.

The paper's core move — compact sparse activations into a fixed-capacity
queue at runtime and do work proportional to the queue, not the tensor —
transfers directly to ReLU-family transformer FFNs, where post-activation
sparsity of 85-95 % is well documented (e.g. "ReLU Strikes Back", "Deja
Vu").  For one token:

    h = relu(x @ W_up)            # (d_ff,) — mostly zeros
    queue = top-k / threshold compaction of h (capacity k)
    y = sum_{i in queue} h_i * W_down[i, :]   # k rows gathered, not d_ff

Compute and W_down traffic scale with the queue capacity — the paper's
"processing time scales with the number of spikes", with the calibrated
capacity playing exactly the role of the AEQ depth (aeq.calibrate_capacity
works unchanged on per-token active counts).

This module is an opt-in replacement for the dense MLP (off by default:
the assigned configs use SiLU/GeGLU and are reproduced faithfully); it is
exercised by tests and the capacity-sweep benchmark, and its exact-match
property (capacity >= true active count => identical output) mirrors the
event-conv bit-exactness property of the paper.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


def sparse_ffn_specs(d_model: int, d_ff: int) -> dict:
    return {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), "scaled"),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), "scaled"),
    }


def dense_relu_ffn(p: dict, x: jax.Array) -> jax.Array:
    """Oracle: the plain dense ReLU MLP."""
    return jax.nn.relu(x @ p["w_up"]) @ p["w_down"]


@partial(jax.jit, static_argnames=("capacity",))
def event_ffn(p: dict, x: jax.Array, *, capacity: int) -> jax.Array:
    """Event-driven FFN: per-token compaction of active hidden units.

    x: (..., d_model).  The top-``capacity`` hidden activations per token
    (its AEQ) select rows of W_down; everything below the queue is
    dropped, exactly like events past the queue depth in the paper.
    Output equals dense_relu_ffn whenever capacity >= #active units.
    """
    h = jax.nn.relu(x @ p["w_up"])                       # (..., d_ff)
    vals, idx = jax.lax.top_k(h, capacity)               # the token's AEQ
    rows = p["w_down"][idx]                              # (..., k, d_model)
    return jnp.einsum("...k,...kd->...d", vals, rows)


def active_counts(p: dict, x: jax.Array) -> jax.Array:
    """Per-token active hidden units — feed to aeq.calibrate_capacity."""
    return jnp.sum(jax.nn.relu(x @ p["w_up"]) > 0, axis=-1)


@partial(jax.jit, static_argnames=("capacity",))
def event_readout(drive: jax.Array, weights: jax.Array, *,
                  capacity: int) -> jax.Array:
    """AEQ-compacted classification-unit drive (the CSNN head connection).

    drive: (..., D) accumulated spike counts into the FC readout — mostly
    zeros, because only units under firing output spikes contribute.  The
    top-``capacity`` entries per sample are the head's event queue (the
    same top-k compaction as :func:`event_ffn`); they are scattered back
    into a zero (..., D) operand and the SAME dense contraction as the
    dense head runs on it.  Whenever ``capacity`` covers every nonzero
    entry the operand is value-identical to ``drive``, so the matmul is
    the identical dot_general and the logits are bit-exact vs the dense
    head — the paper's queue-deep-enough exactness property, transferred.
    (A gathered k-row einsum would change the reduction order and lose
    the last float bit; the scatter-back form trades nothing but the
    O(D - k) zero rows the hardware would skip.)
    """
    d = drive.shape[-1]
    if not 1 <= capacity <= d:
        raise ValueError(f"capacity={capacity} must be in [1, D={d}]")
    flat = drive.reshape(-1, d)
    vals, idx = jax.lax.top_k(flat, capacity)        # the head's AEQ
    rows = jnp.arange(flat.shape[0])[:, None]
    compact = jnp.zeros_like(flat).at[rows, idx].set(vals)
    return (compact.reshape(drive.shape) @ weights)


def drive_active_counts(drive: jax.Array) -> jax.Array:
    """Per-sample nonzero drive entries — feed to aeq.calibrate_capacity
    to size :func:`event_readout`'s queue."""
    return jnp.sum(drive != 0, axis=-1)


def event_ffn_flops(d_model: int, d_ff: int, capacity: int) -> tuple[float, float]:
    """(dense flops, event flops) per token — the napkin the paper makes."""
    dense = 2.0 * d_model * d_ff * 2
    event = 2.0 * d_model * d_ff + 2.0 * capacity * d_model
    return dense, event
