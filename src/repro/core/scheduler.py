"""Channel-multiplexed layer scheduling (paper Sec. V-D, Algorithm 1).

The accelerator holds membrane potentials for only a *single* channel in
MemPot and reuses that buffer across all output channels: for each
``c_out`` it simulates all T time steps, walking the input AEQ of every
``c_in`` each step, then thresholds and emits the output AEQ for
``(c_out, t)``.  Memory therefore scales with one fmap, not with
``C_out`` fmaps.

TPU adaptation: the sequential "one channel at a time" schedule is kept
(via ``lax.map`` over output-channel *blocks*) but each block is
vectorized over the lane dimension — MemPot becomes an
(H+2, W+2, block) VMEM-resident tile.  ``channel_block=1`` reproduces the
paper's schedule exactly; larger blocks are the beyond-paper throughput
knob (benchmarks/table1_parallelism.py sweeps it, the analogue of the
paper's xP parallelization sweep).

``run_conv_layer_batched_planned`` extends Algorithm 1 to a sample batch:
the channel-multiplexed schedule is unchanged, but all B samples' queues
for a given (t, c_in) are built in ONE fused compaction
(``build_aeq_batched``) and consumed by ONE kernel launch
(``event_conv_pallas_batched`` / ``apply_events_batched``), with the
self-timed early exit shared across the batch.  MemPot becomes a
(B, H+2, W+2, block) stack of tiles.  Bit-exact vs ``vmap`` over the
single-sample path (tests/test_batched.py).

Plan/execute split: the ``*_planned`` runners are the real implementation
— all resource sizing (queue depth, channel block, event block, event
parallelism) lives in a static :class:`~repro.core.plan.LayerPlan`
derived once per network by ``plan_network``.  The legacy kwargs
signatures remain as deprecation shims that derive a single-layer plan on
the fly, bit-exact vs the planned path (tests/test_plan.py).

Kernel variants (``LayerPlan.resolve_variant``: an explicitly pinned
``LayerPlan.variant`` — e.g. the measured autotuner's winner — takes
precedence; otherwise ``event_par`` + backend decide):

* ``"sequential"`` — the sequential conv unit: walk each (t, c_in)
  queue one event at a time (``apply_events*`` on the jax backend,
  ``event_conv_pallas*`` on the pallas backend).
* ``"banked-jax"`` — the memory-interlaced event-parallel unit on the
  jax backend: the MemPot stack is held **banked** (9 RAM banks, paper
  Fig. 6) for the whole time step and each interlace column's events are
  applied as one vectorized masked select (``aeq.build_bank_masks`` +
  ``event_conv.apply_banked_columns``; no sort, no per-event loop).
* ``"interlaced-pallas"`` — the queues are segment-padded
  (``aeq.segment_pad``) and fed to ``event_conv_pallas_interlaced*``,
  which applies ``event_par`` hazard-free events per
  gather->add->scatter step.
* ``"fused-handoff"`` — the fused spike-emission path (ISSUE 10): the
  layer input arrives as the producer's halo-padded centre-bank masks
  (``aeq.FusedHandoff``, built inside the upstream threshold unit or by
  ``aeq.build_fused_handoff`` from dense spikes at the network edge) and
  the conv unit applies them through static per-(bank, column) slices
  (``event_conv.apply_banked_columns_fused``) — no deinterlace, no dense
  intermediate, no second compaction pass, and no pre-shifted 81-mask
  stack (the slices alias one padded carrier).

All variants are bit-exact vs the sequential schedule
(tests/test_interlaced.py); the choice is a pure perf knob, which is
what lets ``repro.tune`` pick the measured winner per layer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .aeq import (BatchedEventQueue, EventQueue, FusedHandoff, StreamState,
                  build_aeq_batched, build_bank_masks, build_fused_handoff,
                  fused_handoff_from_banks, segment_pad, stream_frames,
                  stream_queues)
from .event_conv import (apply_banked_columns, apply_banked_columns_fused,
                         apply_events, apply_events_batched, bank_vm, crop_vm,
                         dense_conv, pad_vm, shifted_bank_masks, tap_matrix,
                         unbank_vm)
from .plan import LayerPlan, plan_conv_layer
from .threshold import threshold_unit


class LayerStats(NamedTuple):
    """Per-layer observability used for Table III and capacity calibration."""

    in_spike_counts: jax.Array   # (T, C_in) events fed to the conv unit
    out_spike_counts: jax.Array  # (T, C_out) spikes after thresholding (pre-pool)
    in_sparsity: jax.Array       # () fraction of zeros in the input activations
    event_block: jax.Array = 0   # () chosen block_e (autotuned; perf record)
    event_par: jax.Array = 1     # () interlaced parallel width (1=sequential)


class ConvCarry(NamedTuple):
    """One conv layer's per-time-step carry over a sample batch.

    This is what Algorithm 1 keeps between time steps: the halo-padded
    MemPot stack and the m-TTFS spike-indicator latches.  Extracting it
    lets execution stop at any chunk boundary and resume bit-exactly
    (``run_conv_layer_batched_chunk``) — the basis of continuous batching
    in the serving engine.  Stored channel-flat (C_out last); the block
    split/merge happens inside the chunk runner.
    """

    vm: jax.Array     # (B, H+2hh, W+2hw, C_out) membrane potentials,
                      # halo-padded by the plan geometry (hh=kh//2, hw=kw//2)
    fired: jax.Array  # (B, H, W, C_out) spike-indicator bits


def init_conv_carry(lp: LayerPlan, batch: int, vm_dtype=None) -> ConvCarry:
    """Fresh (all-zero) carry for one conv layer and ``batch`` samples."""
    h, w = lp.in_hw
    hh, hw = lp.geometry.halo
    dt = lp.vm_dtype if vm_dtype is None else vm_dtype
    return ConvCarry(
        vm=jnp.zeros((batch, h + 2 * hh, w + 2 * hw, lp.c_out), dt),
        fired=jnp.zeros((batch, h, w, lp.c_out), jnp.bool_))


def run_conv_layer(
    spikes_in: jax.Array,
    kernels: jax.Array,
    bias: jax.Array,
    v_t,
    *,
    capacity: int,
    pool: Optional[int] = None,
    channel_block: int = 1,
    sat_bits: Optional[int] = None,
    vm_dtype=jnp.float32,
    backend: str = "jax",
) -> tuple[jax.Array, LayerStats]:
    """Deprecated kwargs shim over :func:`run_conv_layer_planned`.

    Derives a single-layer :class:`~repro.core.plan.LayerPlan` from the
    loose knobs and executes it — bit-exact vs the planned path by
    construction (the plan only rounds capacity the way this function
    always did).  New code should build plans via ``plan_network``.
    """
    t_steps, h, w, c_in = spikes_in.shape
    lp = plan_conv_layer(0, "conv", (h, w), c_in, kernels.shape[-1],
                         capacity=capacity, pool=pool,
                         channel_block=channel_block, sat_bits=sat_bits)
    return run_conv_layer_planned(spikes_in, kernels, bias, v_t, lp,
                                  backend=backend, vm_dtype=vm_dtype)


def run_conv_layer_planned(
    spikes_in: jax.Array,
    kernels: jax.Array,
    bias: jax.Array,
    v_t,
    lp: LayerPlan,
    *,
    backend: str = "jax",
    vm_dtype=None,
) -> tuple[jax.Array, LayerStats]:
    """Run one spiking conv layer for all T steps, Algorithm-1 style.

    spikes_in: (T, H, W, C_in) bool — the previous layer's output spikes.
    kernels:   (kh, kw, C_in, C_out) — *unrotated* trained weights; the
               window must match ``lp.geometry`` (3x3 in the paper).
    bias:      (C_out,) — integrated once per time step by the threshold unit.
    lp:        the layer's static resource plan (queue depth, channel
               block, event block, membrane tile — see core/plan.py).
    backend: "jax" (pure scan reference) or "pallas" (the event_conv TPU
        kernel in interpret mode — the production compute path).

    Returns (spikes_out (T, H', W', C_out) bool, LayerStats).
    """
    t_steps, h, w, c_in = spikes_in.shape
    c_out = kernels.shape[-1]
    channel_block = lp.channel_block
    vm_dtype = lp.vm_dtype if vm_dtype is None else vm_dtype
    variant = lp.resolve_variant(backend)
    banked = variant == "banked-jax"
    fused = variant == "fused-handoff"
    geom = lp.geometry
    hh, hw_ = geom.halo
    fmaps = spikes_in.transpose(0, 3, 1, 2)  # (T, C_in, H, W)
    if fused:
        # fused spike-emission path: the padded centre-bank carrier IS the
        # consumable representation — no pre-shifted mask stack at all
        ho = build_fused_handoff(spikes_in[None], lp.capacity, geom)
        smasks = ho.masks[:, :, 0]  # (T, C_in, n_banks, HB+2, WB+2)
        counts = ho.count[:, 0]     # (T, C_in)
    elif banked:
        # interlaced event-parallel path: sort-free bank-mask compaction,
        # write masks pre-shifted once and reused by every channel block
        events = build_bank_masks(fmaps, lp.capacity, geom)
        # (T, C_in, n_banks cols, n_banks banks, hb, wb)
        smasks = shifted_bank_masks(events.masks, geom)
        counts = events.count
    else:
        queues = build_aeq_batched(fmaps, lp.capacity, geometry=geom)
        if lp.event_par > 1:
            queues = segment_pad(queues, lp.event_par, geom)
        counts = queues.count

    def run_block(kernel_block: jax.Array, bias_block: jax.Array) -> jax.Array:
        # kernel_block: (kh, kw, C_in, B); bias_block: (B,)
        block = kernel_block.shape[-1]
        vm0 = pad_vm(jnp.zeros((h, w, block), vm_dtype), geom)  # MemPot, reused (Alg. 1 l.2)
        fired0 = jnp.zeros((h, w, block), jnp.bool_)
        if banked or fused:  # (C_in, cols, banks, block) tap routing, hoisted
            taps = jnp.moveaxis(tap_matrix(kernel_block), 2, 0).astype(vm_dtype)

        def apply_all_cins(vm, t):
            if banked or fused:
                if fused:
                    def apply(vb, m, tp):
                        return apply_banked_columns_fused(vb, m, tp, geom)
                else:
                    apply = apply_banked_columns
                vb = bank_vm(vm, geom)
                vb = jax.lax.fori_loop(
                    0, c_in,
                    lambda ci, vb: apply(vb, smasks[t, ci], taps[ci]),
                    vb)
                return unbank_vm(vb, h + 2 * hh, w + 2 * hw_, geom)

            def per_cin(ci, vm):
                if variant == "interlaced-pallas":
                    from repro.kernels.event_conv.kernel import \
                        event_conv_pallas_interlaced
                    return event_conv_pallas_interlaced(
                        vm, queues.coords[t, ci], queues.valid[t, ci],
                        kernel_block[:, :, ci, :].astype(vm.dtype),
                        block_e=lp.block_e, event_par=lp.event_par)
                if backend == "pallas":
                    from repro.kernels.event_conv.kernel import \
                        event_conv_pallas
                    return event_conv_pallas(
                        vm, queues.coords[t, ci], queues.valid[t, ci],
                        kernel_block[:, :, ci, :].astype(vm.dtype),
                        block_e=lp.block_e)
                q = EventQueue(queues.coords[t, ci], queues.valid[t, ci],
                               queues.count[t, ci])
                return apply_events(vm, q, kernel_block[:, :, ci, :])

            return jax.lax.fori_loop(0, c_in, per_cin, vm)

        def time_step(carry, t):
            vm, fired = carry
            vm = apply_all_cins(vm, t)
            inner = crop_vm(vm, geom)

            def thresh_one(v, f, b):
                r = threshold_unit(v, b, v_t, f, pool=None, sat_bits=lp.sat_bits)
                return r.v_m, r.fired, r.spikes

            v_new, fired, spk = jax.vmap(thresh_one, in_axes=(2, 2, 0), out_axes=2)(
                inner, fired, bias_block)
            vm = vm.at[hh:h + hh, hw_:w + hw_, :].set(v_new)
            return (vm, fired), spk

        (_, _), spikes = jax.lax.scan(time_step, (vm0, fired0), jnp.arange(t_steps))
        return spikes  # (T, H, W, B)

    kh, kw = kernels.shape[:2]
    kb = kernels.reshape(kh, kw, c_in, c_out // channel_block, channel_block)
    kb = jnp.moveaxis(kb, 3, 0)              # (n_blocks, kh, kw, C_in, B)
    bb = bias.reshape(c_out // channel_block, channel_block)
    spikes_blocks = jax.lax.map(lambda kb_bb: run_block(*kb_bb), (kb, bb))
    spikes_out = jnp.moveaxis(spikes_blocks, 0, 3)  # (T, H, W, n_blocks, B)
    spikes_out = spikes_out.reshape(t_steps, h, w, c_out)

    stats = LayerStats(
        in_spike_counts=counts,
        out_spike_counts=jnp.sum(spikes_out, axis=(1, 2)).astype(jnp.int32),
        in_sparsity=1.0 - jnp.mean(spikes_in.astype(jnp.float32)),
        event_block=jnp.asarray(lp.block_e, jnp.int32),
        event_par=jnp.asarray(lp.event_par, jnp.int32),
    )
    if lp.pool is not None:
        return _pool_all(spikes_out, lp.pool), stats
    return spikes_out, stats


def _pool_all(spikes: jax.Array, window: int) -> jax.Array:
    """OR-max-pool (..., H, W, C) binary maps over non-overlapping windows."""
    *lead, h, w, c = spikes.shape
    ph, pw = -h % window, -w % window
    pads = [(0, 0)] * len(lead) + [(0, ph), (0, pw), (0, 0)]
    s = jnp.pad(spikes.astype(bool), pads)
    hh, ww = s.shape[-3:-1]
    s = s.reshape(*lead, hh // window, window, ww // window, window, c)
    return jnp.any(s, axis=(-4, -2))


def run_conv_layer_dense(
    spikes_in: jax.Array,
    kernels: jax.Array,
    bias: jax.Array,
    v_t,
    *,
    pool: Optional[int] = None,
    vm_dtype=jnp.float32,
) -> jax.Array:
    """Frame-based oracle for run_conv_layer (sliding-window conv; SIES-style).

    Used (a) as the correctness oracle in tests and (b) as the dense
    baseline the paper compares against.
    """
    t_steps, h, w, c_in = spikes_in.shape
    c_out = kernels.shape[-1]

    def step(carry, x_t):
        vm, fired = carry
        x = x_t.astype(vm_dtype)[None]  # (1, H, W, C_in)
        u = jax.lax.conv_general_dilated(
            x, kernels.astype(vm_dtype), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
        vm = vm + u + bias.astype(vm_dtype)
        spikes = (vm > jnp.asarray(v_t, vm_dtype)) | fired
        return (vm, spikes), spikes

    vm0 = jnp.zeros((h, w, c_out), vm_dtype)
    fired0 = jnp.zeros((h, w, c_out), jnp.bool_)
    (_, _), spikes = jax.lax.scan(step, (vm0, fired0), spikes_in)
    return _pool_all(spikes, pool) if pool is not None else spikes


def run_conv_layer_batched(
    spikes_in: jax.Array,
    kernels: jax.Array,
    bias: jax.Array,
    v_t,
    *,
    capacity: int,
    pool: Optional[int] = None,
    channel_block: int = 1,
    sat_bits: Optional[int] = None,
    vm_dtype=jnp.float32,
    backend: str = "jax",
    event_block: Optional[int] = None,
) -> tuple[jax.Array, LayerStats]:
    """Deprecated kwargs shim over :func:`run_conv_layer_batched_planned`.

    Derives a single-layer plan from the loose knobs (``event_block=None``
    autotunes the event block) and executes it — bit-exact by construction.
    New code should build plans via ``plan_network``.
    """
    b_sz, t_steps, h, w, c_in = spikes_in.shape
    lp = plan_conv_layer(0, "conv", (h, w), c_in, kernels.shape[-1],
                         capacity=capacity, pool=pool,
                         channel_block=channel_block, block_e=event_block,
                         sat_bits=sat_bits)
    return run_conv_layer_batched_planned(spikes_in, kernels, bias, v_t, lp,
                                          backend=backend, vm_dtype=vm_dtype)


def run_conv_layer_batched_planned(
    spikes_in: jax.Array,
    kernels: jax.Array,
    bias: jax.Array,
    v_t,
    lp: LayerPlan,
    *,
    backend: str = "jax",
    vm_dtype=None,
) -> tuple[jax.Array, LayerStats]:
    """Algorithm 1 over a whole sample batch with amortized event handling.

    spikes_in: (B, T, H, W, C_in) bool — batch of previous-layer spikes.
    Remaining arguments match ``run_conv_layer_planned``.  One fused
    compaction builds every (t, b, c_in) queue; each (t, c_in) step then
    feeds all B queues to one batched conv-unit invocation (a 2-D-grid
    Pallas call for ``backend="pallas"``, a batch-vectorized event loop
    with shared early exit for ``backend="jax"``).

    Returns (spikes_out (B, T, H', W', C_out) bool, LayerStats with a
    leading batch dim: in_spike_counts (B, T, C_in), out_spike_counts
    (B, T, C_out), in_sparsity (B,)).  Bit-exact vs
    ``jax.vmap(run_conv_layer_planned)`` — the paper's per-sample schedule
    is preserved; only the launch structure is batched.  Implemented as
    one whole-T call of :func:`run_conv_layer_batched_chunk` from a fresh
    carry.
    """
    carry = init_conv_carry(lp, spikes_in.shape[0], vm_dtype=vm_dtype)
    spikes_out, _, stats = run_conv_layer_batched_chunk(
        spikes_in, kernels, bias, v_t, lp, carry, backend=backend,
        vm_dtype=vm_dtype)
    return spikes_out, stats


def _split_blocks(arr: jax.Array, n_blocks: int, cb: int) -> jax.Array:
    """(B, ..., C_out) -> (n_blocks, B, ..., Cb); channel c maps to block
    c // Cb, lane c % Cb — the same contiguous split as the kernel reshape."""
    out = arr.reshape(arr.shape[:-1] + (n_blocks, cb))
    return jnp.moveaxis(out, -2, 0)


def _merge_blocks(arr: jax.Array) -> jax.Array:
    """Inverse of ``_split_blocks``."""
    out = jnp.moveaxis(arr, 0, -2)
    return out.reshape(out.shape[:-2] + (-1,))


def run_conv_layer_batched_chunk(
    spikes_in: jax.Array,
    kernels: jax.Array,
    bias: jax.Array,
    v_t,
    lp: LayerPlan,
    carry: ConvCarry,
    *,
    backend: str = "jax",
    vm_dtype=None,
) -> tuple[jax.Array, ConvCarry, LayerStats]:
    """Step one conv layer through a CHUNK of time steps from ``carry``.

    spikes_in: (B, t_chunk, H, W, C_in) bool — any chunk length >= 1; OR
               an :class:`~repro.core.aeq.FusedHandoff` carrier when the
               layer is pinned to the ``"fused-handoff"`` variant and the
               producer already emitted the compacted representation
               (``csnn.snn_step_chunk`` threads it between layers).
    carry:     the layer's :class:`ConvCarry` at the chunk start (a fresh
               ``init_conv_carry`` at t=0, the previous chunk's result
               otherwise).

    Returns (spikes_out (B, t_chunk, H', W', C_out) bool, new carry,
    chunk LayerStats).  Per time step the computation is identical to the
    monolithic path — only the scan is cut at the chunk boundary — so
    chaining chunks over a T-step input is bit-exact vs one whole-T call
    (tests/test_chunked.py).  This is the device-side half of the serving
    engine's slot-level refill: the engine holds one shared carry batch
    and resets individual rows as slots retire and admit.
    """
    variant = lp.resolve_variant(backend)
    if variant == "fused-handoff":
        if isinstance(spikes_in, FusedHandoff):
            ho = spikes_in
        else:
            # network edge (or unfused producer): build the carrier here —
            # same cost class as the banked compaction, still no
            # pre-shifted mask stack downstream
            ho = build_fused_handoff(spikes_in, lp.capacity, lp.geometry)
        t_steps, c_in, b_sz = ho.masks.shape[:3]
        h, w = lp.in_hw
        # sparsity from the pre-truncation counts: 0/1 sums in f32 are
        # exact integers < 2^24, so this is bit-identical to
        # 1 - mean(dense spikes) without ever materializing the frames
        total = jnp.sum(ho.count.astype(jnp.float32), axis=(0, 2))
        sparsity = 1.0 - total / float(t_steps * h * w * c_in)
        # ho.masks is (t, C_in, B, ...) — already the scan's xs layout
        return _run_chunk_from_events(
            None, ho.masks, ho.count, sparsity,
            (b_sz, t_steps, h, w, c_in), kernels, bias, v_t, lp, carry,
            variant=variant, backend=backend, vm_dtype=vm_dtype)
    b_sz, t_steps, h, w, c_in = spikes_in.shape
    banked = variant == "banked-jax"
    # (B, t, H, W, C_in) -> per-(t, b, c_in) event sets, built in one pass
    fmaps = spikes_in.transpose(1, 0, 4, 2, 3)  # (t, B, C_in, H, W)
    if banked:
        # interlaced event-parallel path: compact straight into the 9
        # membrane RAM banks (sort-free) and pre-shift the write masks
        # once; every (t, c_in, channel-block) step below then applies a
        # whole hazard-free column per vectorized select.  The pre-shifted
        # stack is 81/9 x the bank masks and lives for the whole chunk —
        # the chunk length (plan.t_chunk) is the knob that bounds it; the
        # amortization across channel blocks AND time steps is what pays
        # for the banked path (recomputing per step would cost more than
        # the conv work it saves on wide-C_in layers).
        events = build_bank_masks(fmaps, lp.capacity, lp.geometry)
        # (t, B, C_in, cols, banks, hb, wb) -> (t, C_in, B, ...) for
        # scan + fori
        queues = None
        smasks = jnp.swapaxes(shifted_bank_masks(events.masks, lp.geometry),
                              1, 2)
        counts = events.count
    else:
        queues = build_aeq_batched(fmaps, lp.capacity, geometry=lp.geometry)
        if lp.event_par > 1:
            queues = segment_pad(queues, lp.event_par, lp.geometry)
        smasks, counts = None, queues.count
    sparsity = 1.0 - jnp.mean(spikes_in.astype(jnp.float32),
                              axis=(1, 2, 3, 4))
    return _run_chunk_from_events(
        queues, smasks, counts, sparsity, (b_sz, t_steps, h, w, c_in),
        kernels, bias, v_t, lp, carry, variant=variant, backend=backend,
        vm_dtype=vm_dtype)


def run_conv_layer_batched_chunk_streamed(
    stream: StreamState,
    kernels: jax.Array,
    bias: jax.Array,
    v_t,
    lp: LayerPlan,
    carry: ConvCarry,
    *,
    backend: str = "jax",
    vm_dtype=None,
) -> tuple[jax.Array, ConvCarry, LayerStats]:
    """Chunk runner over PRE-INGESTED input events instead of dense frames.

    stream: :class:`~repro.core.aeq.StreamState` with banks
    (B, t_chunk, C_in, n_banks, HB, WB) — raw DVS events appended incrementally
    by ``aeq.append_events*``.  The conv-unit schedule, thresholding and
    carry handling are byte-for-byte the ones of
    :func:`run_conv_layer_batched_chunk`; only the queue construction
    differs — ``aeq.stream_queues`` finalizes the banks sort-free (the
    sequential/pallas variants; ``segment_pad`` applies on top exactly as
    in the binned path), and the banked event-parallel variant compacts
    the streamed occupancy with the same ``build_bank_masks`` call the
    binned path uses.  ``lp.resolve_stream_finalize() == "sort"`` swaps
    the rank-based finalization for the binned compaction over the dense
    bank view (``build_aeq_batched``) — bit-exact by the
    streaming-equivalence theorem, and the variant the measured autotuner
    (and the fmap-size default) picks at small fmaps where the fused sort
    beats the rank cumsums' constant factor.  The ``"fused-handoff"``
    variant compacts the streamed banks straight into the padded carrier
    (``aeq.fused_handoff_from_banks``) — no dense frame view at all.
    Bit-exact vs binning the same events into frames and calling the
    dense-chunk runner either way (tests/test_streaming.py).
    """
    h, w = lp.in_hw
    b_sz, t_steps, c_in = stream.banks.shape[:3]
    variant = lp.resolve_variant(backend)
    banked = variant == "banked-jax"
    if variant == "fused-handoff":
        ho = fused_handoff_from_banks(stream.banks, lp.capacity, (h, w),
                                      lp.geometry)
        total = jnp.sum(ho.count.astype(jnp.float32), axis=(0, 2))
        sparsity = 1.0 - total / float(t_steps * h * w * c_in)
        return _run_chunk_from_events(
            None, ho.masks, ho.count, sparsity,
            (b_sz, t_steps, h, w, c_in), kernels, bias, v_t, lp, carry,
            variant=variant, backend=backend, vm_dtype=vm_dtype)
    # dense view only where the binned path itself is dense (sparsity
    # stat; bank-mask/sort compaction input) — a reshape/transpose, no sort
    frames = stream_frames(stream, (h, w), lp.geometry)  # (B, t, C_in, H, W)
    if banked:
        events = build_bank_masks(frames.transpose(1, 0, 2, 3, 4),
                                  lp.capacity, lp.geometry)
        queues = None
        smasks = jnp.swapaxes(shifted_bank_masks(events.masks, lp.geometry),
                              1, 2)
        counts = events.count
    else:
        if lp.resolve_stream_finalize() == "sort":
            # binned finalization: fused sort over the dense bank view,
            # already in the (t, B, C_in) lead layout the launches index
            queues = build_aeq_batched(frames.transpose(1, 0, 2, 3, 4),
                                       lp.capacity, geometry=lp.geometry)
        else:
            queues = stream_queues(stream, lp.capacity, (h, w),
                                   geometry=lp.geometry)
            # (B, t, C_in, ...) -> (t, B, C_in, ...): the layout the
            # per-(t, c_in) kernel launches below index
            queues = BatchedEventQueue(*(None if x is None
                                         else jnp.swapaxes(x, 0, 1)
                                         for x in queues))
        if lp.event_par > 1:
            queues = segment_pad(queues, lp.event_par, lp.geometry)
        smasks, counts = None, queues.count
    sparsity = 1.0 - jnp.mean(frames.astype(jnp.float32), axis=(1, 2, 3, 4))
    return _run_chunk_from_events(
        queues, smasks, counts, sparsity, (b_sz, t_steps, h, w, c_in),
        kernels, bias, v_t, lp, carry, variant=variant, backend=backend,
        vm_dtype=vm_dtype)


def _run_chunk_from_events(
    queues: Optional[BatchedEventQueue],
    smasks: Optional[jax.Array],
    counts: jax.Array,
    sparsity: jax.Array,
    shape: tuple[int, int, int, int, int],
    kernels: jax.Array,
    bias: jax.Array,
    v_t,
    lp: LayerPlan,
    carry: ConvCarry,
    *,
    variant: str,
    backend: str,
    vm_dtype=None,
) -> tuple[jax.Array, ConvCarry, LayerStats]:
    """Shared chunk body: consume pre-built per-(t, b, c_in) event sets
    (queues for the sequential/pallas variants, pre-shifted bank masks for
    the banked variant, the padded fused-handoff carrier for the fused
    variant — both ride the ``smasks`` slot) — the part of the chunk
    runner that is identical whether the events came from dense frames,
    the streaming ingestion path, or an upstream fused emission."""
    banked = variant == "banked-jax"
    fused = variant == "fused-handoff"
    b_sz, t_steps, h, w, c_in = shape
    c_out = kernels.shape[-1]
    channel_block = lp.channel_block
    vm_dtype = lp.vm_dtype if vm_dtype is None else vm_dtype
    block_e = lp.block_e
    geom = lp.geometry
    hh, hw_ = geom.halo

    def run_block(kernel_block, bias_block, vm0, fired0):
        # kernel_block: (kh, kw, C_in, Cb); bias_block: (Cb,)
        # vm0: (B, H+2hh, W+2hw, Cb); fired0: (B, H, W, Cb)
        if banked or fused:  # (C_in, cols, banks, Cb) tap routing, hoisted
            taps = jnp.moveaxis(tap_matrix(kernel_block), 2, 0).astype(vm_dtype)

        def apply_all_cins(vm, smasks_t, t):
            if banked or fused:
                if fused:
                    def apply(vb, m, tp):
                        return apply_banked_columns_fused(vb, m, tp, geom)
                else:
                    apply = apply_banked_columns
                vb = bank_vm(vm, geom)  # (B, n_banks, hb, wb, Cb)
                vb = jax.lax.fori_loop(
                    0, c_in,
                    lambda ci, vb: apply(vb, smasks_t[ci], taps[ci]),
                    vb)
                return unbank_vm(vb, h + 2 * hh, w + 2 * hw_, geom)

            def per_cin(ci, vm):
                coords = queues.coords[t, :, ci]   # (B, cap, 2)
                valid = queues.valid[t, :, ci]     # (B, cap)
                k_ci = kernel_block[:, :, ci, :]
                if variant == "interlaced-pallas":
                    from repro.kernels.event_conv.kernel import (
                        event_conv_pallas_interlaced_batched)
                    return event_conv_pallas_interlaced_batched(
                        vm, coords, valid, k_ci.astype(vm.dtype),
                        block_e=block_e, event_par=lp.event_par)
                if backend == "pallas":
                    from repro.kernels.event_conv.kernel import (
                        event_conv_pallas_batched)
                    return event_conv_pallas_batched(
                        vm, coords, valid, k_ci.astype(vm.dtype),
                        block_e=block_e)
                return apply_events_batched(
                    vm, coords, valid, queues.count[t, :, ci], k_ci,
                    block=block_e)

            return jax.lax.fori_loop(0, c_in, per_cin, vm)

        def time_step(carry, xs):
            smasks_t, t = xs
            vm, fired = carry
            vm = apply_all_cins(vm, smasks_t, t)
            inner = vm[:, hh:h + hh, hw_:w + hw_, :]

            def thresh_one(v, f, b):
                r = threshold_unit(v, b, v_t, f, pool=None, sat_bits=lp.sat_bits)
                return r.v_m, r.fired, r.spikes

            per_channel = jax.vmap(thresh_one, in_axes=(2, 2, 0), out_axes=2)
            v_new, fired, spk = jax.vmap(per_channel, in_axes=(0, 0, None))(
                inner, fired, bias_block)
            vm = vm.at[:, hh:h + hh, hw_:w + hw_, :].set(v_new)
            return (vm, fired), spk

        xs = (smasks if (banked or fused)
              else jnp.zeros((t_steps, 0), jnp.bool_),
              jnp.arange(t_steps))
        (vm, fired), spikes = jax.lax.scan(time_step, (vm0, fired0), xs)
        return spikes, vm, fired  # spikes: (t, B, H, W, Cb)

    n_blocks = c_out // channel_block
    kh, kw = kernels.shape[:2]
    kb = kernels.reshape(kh, kw, c_in, n_blocks, channel_block)
    kb = jnp.moveaxis(kb, 3, 0)              # (n_blocks, kh, kw, C_in, Cb)
    bb = bias.reshape(n_blocks, channel_block)
    vm_b = _split_blocks(carry.vm.astype(vm_dtype), n_blocks, channel_block)
    fired_b = _split_blocks(carry.fired, n_blocks, channel_block)
    spikes_blocks, vm_out, fired_out = jax.lax.map(
        lambda a: run_block(*a), (kb, bb, vm_b, fired_b))
    new_carry = ConvCarry(vm=_merge_blocks(vm_out),
                          fired=_merge_blocks(fired_out))
    spikes_out = jnp.moveaxis(spikes_blocks, 0, 4)  # (t, B, H, W, n_blocks, Cb)
    spikes_out = spikes_out.reshape(t_steps, b_sz, h, w, c_out)
    spikes_out = jnp.swapaxes(spikes_out, 0, 1)     # (B, t, H, W, C_out)

    stats = LayerStats(
        in_spike_counts=jnp.swapaxes(counts, 0, 1),  # (B, t, C_in)
        out_spike_counts=jnp.sum(spikes_out, axis=(2, 3)).astype(jnp.int32),
        in_sparsity=sparsity,
        event_block=jnp.asarray(lp.block_e, jnp.int32),
        event_par=jnp.asarray(lp.event_par, jnp.int32),
    )
    if lp.pool is not None:
        return _pool_all(spikes_out, lp.pool), new_carry, stats
    return spikes_out, new_carry, stats


def run_fc_head(spikes_in: jax.Array, weights: jax.Array, bias: jax.Array,
                capacity: Optional[int] = None) -> jax.Array:
    """Classification unit (paper Sec. V-A): integrate-only FC readout.

    spikes_in: (T, ...) binary; weights: (D, n_classes).  The output
    neurons integrate weighted spikes plus bias every step and are never
    thresholded; the class is the argmax of the final membrane potential.
    ``capacity`` opts the accumulated drive into the event-driven sparse
    head (``sparse_ffn.event_readout``: top-``capacity`` AEQ compaction +
    scatter-back) — bit-exact vs the dense contraction whenever the queue
    covers every nonzero drive entry.
    """
    t_steps = spikes_in.shape[0]
    flat = spikes_in.reshape(t_steps, -1).astype(weights.dtype)
    drive = flat.sum(0)
    if capacity is not None:
        from .sparse_ffn import event_readout
        return event_readout(drive, weights,
                             capacity=capacity) + t_steps * bias
    return drive @ weights + t_steps * bias


def run_fc_head_batched(spikes_in: jax.Array, weights: jax.Array,
                        bias: jax.Array,
                        capacity: Optional[int] = None) -> jax.Array:
    """Classification unit over a batch: (B, T, ...) -> (B, n_classes).

    One batched matmul replaces B vector-matrix products; numerically it
    is the same dot_general ``vmap(run_fc_head)`` lowers to.  ``capacity``
    opts into the event-driven sparse head exactly as in
    :func:`run_fc_head`.
    """
    b_sz, t_steps = spikes_in.shape[:2]
    flat = spikes_in.reshape(b_sz, t_steps, -1).astype(weights.dtype)
    drive = flat.sum(1)
    if capacity is not None:
        from .sparse_ffn import event_readout
        return event_readout(drive, weights,
                             capacity=capacity) + t_steps * bias
    return drive @ weights + t_steps * bias
