"""Input encoding: real-valued frames -> binary spike trains (paper Sec. VII).

The paper binarizes integer input frames with a strictly increasing set of
thresholds ``P = (p_1, ..., p_{T-1})`` "to mimic m-TTFS encoding": bright
pixels must spike *early* and — because the code is m-TTFS — keep spiking
afterwards.  We therefore apply the thresholds in decreasing order over
time: at t=0 only pixels above the largest threshold spike; each following
step lowers the threshold so previous spikers keep firing and dimmer
pixels join.  The resulting per-pixel spike trains are monotone
(0...0 1...1), which is exactly the m-TTFS firing pattern.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def mttfs_thresholds(t_steps: int, lo: float = 0.0, hi: float = 1.0) -> jax.Array:
    """A strictly increasing threshold set P with T-1 entries in (lo, hi)."""
    if t_steps < 2:
        raise ValueError("m-TTFS input encoding needs at least 2 time steps")
    return jnp.linspace(lo, hi, t_steps + 1)[1:-1]  # strictly inside (lo, hi)


def multi_threshold_encode(frames: jax.Array, thresholds: jax.Array, t_steps: int) -> jax.Array:
    """Encode frames into T binary spike maps using threshold set P.

    frames:     (...,) real-valued inputs (any shape).
    thresholds: (T-1,) strictly increasing.
    Returns:    (T, ...) boolean spike maps with monotone per-pixel trains.
    """
    thresholds = jnp.sort(jnp.asarray(thresholds))
    if thresholds.shape[0] != t_steps - 1:
        raise ValueError(f"need {t_steps - 1} thresholds for T={t_steps}, got {thresholds.shape[0]}")
    # Apply in decreasing order; the final step reuses the lowest threshold so
    # the monotone (m-TTFS) property holds across all T steps.
    order = jnp.concatenate([thresholds[::-1], thresholds[:1]])  # (T,)
    return frames[None, ...] > order.reshape((t_steps,) + (1,) * frames.ndim)


def rate_encode(frames: jax.Array, t_steps: int, rng: jax.Array) -> jax.Array:
    """Bernoulli rate coding baseline: P(spike at t) = pixel intensity in [0,1]."""
    p = jnp.clip(frames, 0.0, 1.0)
    return jax.random.bernoulli(rng, p[None, ...], (t_steps,) + frames.shape)


def spike_sparsity(spikes: jax.Array) -> jax.Array:
    """Fraction of zero entries — the paper's 'sparsity' metric (Table III)."""
    return 1.0 - jnp.mean(spikes.astype(jnp.float32))
