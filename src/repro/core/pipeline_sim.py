"""Cycle-level model of the 4-stage convolution-unit pipeline (paper Sec. VI-B).

The FPGA convolution unit is pipelined S1..S4 (address calc, MemPot read,
update, write-back).  Its throughput is 1 event/cycle except for:

* wind-up: 4 cycles until the pipeline is full (per queue start);
* empty queue columns: 1 wasted cycle each (invalid event read, paper
  Sec. VI-A);
* S2-S3 RAW hazards: a 1-cycle stall when two *immediately successive*
  events touch overlapping 3x3 neighbourhoods.  The interlaced AEQ
  ordering guarantees same-column events never overlap, so hazards can
  only occur at column switches.

The thresholding unit then sweeps ceil(H/3)*ceil(W/3) windows per
(c_out, t) with its own 5-stage wind-up.

This simulator reproduces the paper's "PE utilization" metric (Table III):
utilization = cycles in which the PEs process a valid event / total
cycles.  It has no TPU counterpart — it exists to validate our
reproduction against the paper's own numbers and to quantify how much of
the FPGA's stall overhead the TPU adaptation removes (the TPU pipeline
has no hazards because events are applied in program order inside one
kernel).  Pure numpy on purpose: it models hardware, not math.

P-parallel extension (``parallelism`` > 1): models the event-parallel
design the interlaced kernels implement (PULSE/ExSpike-style): up to P
*same-column* events issue together each cycle — hazard-free because the
interlacing guarantees their neighbourhoods are disjoint — so a column
with c events costs ceil(c/P) issue cycles.  Hazard checks move to group
boundaries at column switches (any cross-group neighbourhood overlap
stalls one cycle, as in the serial design).  ``pe_utilization`` then
counts event-lane occupancy: events / (P * conv cycles) — partial final
groups of a column leave lanes idle, which is exactly the utilization
cost of the parallel design that Table III's extension quantifies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

WINDUP_CONV = 4     # S1..S4
WINDUP_THRESH = 5   # S1..S5


@dataclass
class CycleReport:
    event_cycles: int       # cycles carrying >=1 valid event (issue cycles)
    hazard_stalls: int      # S2-S3 stalls
    empty_queue_cycles: int # wasted reads of empty columns
    windup_cycles: int      # pipeline fill
    threshold_cycles: int   # dense thresholding sweeps
    total_cycles: int
    parallelism: int = 1    # event lanes per issue cycle (P-parallel PEs)
    events: Optional[int] = None  # valid events processed (= event_cycles at P=1)

    @property
    def pe_utilization(self) -> float:
        """Event-lane occupancy / all conv-unit cycles (paper Table III;
        lanes = parallelism, so the serial design reduces to valid-event
        cycles over total)."""
        conv_total = (self.event_cycles + self.hazard_stalls
                      + self.empty_queue_cycles + self.windup_cycles)
        ev = self.event_cycles if self.events is None else self.events
        return ev / max(self.parallelism * conv_total, 1)


def _columns_of(events: np.ndarray) -> np.ndarray:
    return (events[:, 0] % 3) * 3 + (events[:, 1] % 3)


def _overlap(a: np.ndarray, b: np.ndarray) -> bool:
    """Do the 3x3 neighbourhoods of two events overlap?"""
    return bool(abs(int(a[0]) - int(b[0])) <= 2 and abs(int(a[1]) - int(b[1])) <= 2)


def _groups_of(events: np.ndarray, parallelism: int) -> list[np.ndarray]:
    """Chop an interlace-ordered queue into per-cycle issue groups: runs of
    same-column events, each run split into ceil(len/P) groups of <= P."""
    n = len(events)
    if n == 0:
        return []
    cols = _columns_of(events)
    groups = []
    start = 0
    for a in range(1, n + 1):
        if a == n or cols[a] != cols[start]:
            for g in range(start, a, parallelism):
                groups.append(events[g:min(g + parallelism, a)])
            start = a
    return groups


def simulate_conv_queue(events: np.ndarray,
                        parallelism: int = 1) -> tuple[int, int, int, int]:
    """Simulate one (c_in, t) queue pass through the conv unit.

    events: (N, 2) int array of (i, j), already in interlaced column order
    (aeq.build_aeq order).  Returns (event_cycles, hazard_stalls,
    empty_queue_cycles, windup_cycles); ``event_cycles`` is issue cycles —
    with ``parallelism`` P each cycle retires up to P same-column events,
    so a column of c events needs ceil(c/P) cycles.  Hazards can only
    occur between groups at a column switch (same-column groups are
    disjoint by the interlacing invariant); the serial P=1 case reduces to
    the paper's consecutive-event check.
    """
    events = np.asarray(events).reshape(-1, 2)
    n = len(events)
    cols_present = set(_columns_of(events).tolist()) if n else set()
    empty = 9 - len(cols_present)
    groups = _groups_of(events, parallelism)
    hazards = 0
    for a in range(1, len(groups)):
        prev, cur = groups[a - 1], groups[a]
        if _columns_of(prev[-1:])[0] != _columns_of(cur[:1])[0]:
            if any(_overlap(p, c) for p in prev for c in cur):
                hazards += 1
    windup = WINDUP_CONV if n else 0
    return len(groups), hazards, empty, windup


def simulate_layer(
    per_cin_t_events: list[list[np.ndarray]],
    c_out: int,
    fmap_hw: tuple[int, int],
    parallelism: int = 1,
) -> CycleReport:
    """Cycle model of Algorithm 1 for one layer.

    per_cin_t_events[t][c_in] = (N,2) events of the input AEQ.
    The conv unit runs for every (c_out, t, c_in) queue; the thresholding
    unit sweeps once per (c_out, t).  ``parallelism`` P models the
    interlaced event-parallel conv unit (P hazard-free events per cycle).
    """
    ev = st = em = wu = n_events = 0
    for t_events in per_cin_t_events:
        for q in t_events:
            q = np.asarray(q).reshape(-1, 2)
            e, h, m, w = simulate_conv_queue(q, parallelism)
            ev, st, em, wu = ev + e, st + h, em + m, wu + w
            n_events += len(q)
    # every output channel replays all input queues (Algorithm 1)
    ev, st, em, wu = ev * c_out, st * c_out, em * c_out, wu * c_out
    n_events *= c_out
    h, w = fmap_hw
    sweeps = (-(-h // 3)) * (-(-w // 3)) + WINDUP_THRESH
    thresh = sweeps * c_out * len(per_cin_t_events)
    total = ev + st + em + wu + thresh
    return CycleReport(ev, st, em, wu, thresh, total,
                       parallelism=parallelism, events=n_events)


def throughput_fps(report: CycleReport, clock_hz: float = 333e6, parallelism: int = 1) -> float:
    """Frames/s at the paper's 333 MHz clock with xP parallel units."""
    return clock_hz * parallelism / max(report.total_cycles, 1)
