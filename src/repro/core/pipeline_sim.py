"""Cycle-level model of the 4-stage convolution-unit pipeline (paper Sec. VI-B).

The FPGA convolution unit is pipelined S1..S4 (address calc, MemPot read,
update, write-back).  Its throughput is 1 event/cycle except for:

* wind-up: 4 cycles until the pipeline is full (per queue start);
* empty queue columns: 1 wasted cycle each (invalid event read, paper
  Sec. VI-A);
* S2-S3 RAW hazards: a 1-cycle stall when two *immediately successive*
  events touch overlapping 3x3 neighbourhoods.  The interlaced AEQ
  ordering guarantees same-column events never overlap, so hazards can
  only occur at column switches.

The thresholding unit then sweeps ceil(H/3)*ceil(W/3) windows per
(c_out, t) with its own 5-stage wind-up.

This simulator reproduces the paper's "PE utilization" metric (Table III):
utilization = cycles in which the PEs process a valid event / total
cycles.  It has no TPU counterpart — it exists to validate our
reproduction against the paper's own numbers and to quantify how much of
the FPGA's stall overhead the TPU adaptation removes (the TPU pipeline
has no hazards because events are applied in program order inside one
kernel).  Pure numpy on purpose: it models hardware, not math.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

WINDUP_CONV = 4     # S1..S4
WINDUP_THRESH = 5   # S1..S5


@dataclass
class CycleReport:
    event_cycles: int       # cycles carrying a valid event (PEs busy)
    hazard_stalls: int      # S2-S3 stalls
    empty_queue_cycles: int # wasted reads of empty columns
    windup_cycles: int      # pipeline fill
    threshold_cycles: int   # dense thresholding sweeps
    total_cycles: int

    @property
    def pe_utilization(self) -> float:
        """Valid-event cycles / all conv-unit cycles (paper Table III)."""
        conv_total = (self.event_cycles + self.hazard_stalls
                      + self.empty_queue_cycles + self.windup_cycles)
        return self.event_cycles / max(conv_total, 1)


def _columns_of(events: np.ndarray) -> np.ndarray:
    return (events[:, 0] % 3) * 3 + (events[:, 1] % 3)


def _overlap(a: np.ndarray, b: np.ndarray) -> bool:
    """Do the 3x3 neighbourhoods of two events overlap?"""
    return bool(abs(int(a[0]) - int(b[0])) <= 2 and abs(int(a[1]) - int(b[1])) <= 2)


def simulate_conv_queue(events: np.ndarray) -> tuple[int, int, int, int]:
    """Simulate one (c_in, t) queue pass through the conv unit.

    events: (N, 2) int array of (i, j), already in interlaced column order
    (aeq.build_aeq order).  Returns (event_cycles, hazard_stalls,
    empty_queue_cycles, windup_cycles).
    """
    n = len(events)
    cols_present = set(_columns_of(events).tolist()) if n else set()
    empty = 9 - len(cols_present)
    hazards = 0
    if n > 1:
        cols = _columns_of(events)
        for a in range(1, n):
            # hazard only possible when the column changed (same-column
            # events are >=3 apart by construction -> no overlap)
            if cols[a] != cols[a - 1] and _overlap(events[a - 1], events[a]):
                hazards += 1
    windup = WINDUP_CONV if n else 0
    return n, hazards, empty, windup


def simulate_layer(
    per_cin_t_events: list[list[np.ndarray]],
    c_out: int,
    fmap_hw: tuple[int, int],
) -> CycleReport:
    """Cycle model of Algorithm 1 for one layer.

    per_cin_t_events[t][c_in] = (N,2) events of the input AEQ.
    The conv unit runs for every (c_out, t, c_in) queue; the thresholding
    unit sweeps once per (c_out, t).
    """
    ev = st = em = wu = 0
    for t_events in per_cin_t_events:
        for q in t_events:
            e, h, m, w = simulate_conv_queue(np.asarray(q).reshape(-1, 2))
            ev, st, em, wu = ev + e, st + h, em + m, wu + w
    # every output channel replays all input queues (Algorithm 1)
    ev, st, em, wu = ev * c_out, st * c_out, em * c_out, wu * c_out
    h, w = fmap_hw
    sweeps = (-(-h // 3)) * (-(-w // 3)) + WINDUP_THRESH
    thresh = sweeps * c_out * len(per_cin_t_events)
    total = ev + st + em + wu + thresh
    return CycleReport(ev, st, em, wu, thresh, total)


def throughput_fps(report: CycleReport, clock_hz: float = 333e6, parallelism: int = 1) -> float:
    """Frames/s at the paper's 333 MHz clock with xP parallel units."""
    return clock_hz * parallelism / max(report.total_cycles, 1)
