"""Pytree optimizers (no optax in this environment): AdamW + Adafactor-lite.

Distributed-memory knobs (DESIGN.md Sec. 5):
* ``moment_dtype`` — keep Adam moments in bf16 to halve optimizer HBM
  (stochastic-rounding-free variant; fp32 master params stay in `params`);
* optimizer state inherits the parameters' sharding (ZeRO via the fsdp
  axis) because the update is elementwise;
* global-norm clipping is computed in fp32 regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Any
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Any, cfg: AdamWConfig) -> TrainState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      mu=jax.tree.map(zeros, params), nu=jax.tree.map(zeros, params))


def abstract_state(abstract_params: Any, cfg: AdamWConfig) -> TrainState:
    """ShapeDtypeStruct TrainState for dry-run lowering."""
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
    return TrainState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      params=abstract_params,
                      mu=jax.tree.map(sds, abstract_params),
                      nu=jax.tree.map(sds, abstract_params))


def state_logical_axes(param_axes: Any) -> TrainState:
    """Optimizer state shards exactly like the parameters."""
    return TrainState(step=(), params=param_axes, mu=param_axes, nu=param_axes)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(state: TrainState, grads: Any, cfg: AdamWConfig) -> TrainState:
    if cfg.clip_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                      + (1 - cfg.b1) * g.astype(jnp.float32)).astype(cfg.moment_dtype),
        state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: (cfg.b2 * v.astype(jnp.float32)
                      + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32))
                      ).astype(cfg.moment_dtype),
        state.nu, grads)

    def upd(p, m, v):
        mhat = m.astype(jnp.float32) / b1c
        vhat = v.astype(jnp.float32) / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    params = jax.tree.map(upd, state.params, mu, nu)
    return TrainState(step=step, params=params, mu=mu, nu=nu)
