"""Training loop with fault-tolerance hooks.

Wires together: model loss -> grad -> AdamW update (optionally through
gradient event-compression), periodic + preemption-triggered
checkpointing, heartbeat/straggler bookkeeping, and the elastic remesh
protocol (checkpoint -> replan mesh -> restore).  Runs unmodified from
the 1-device smoke tests to the 512-way dry-run configuration — only the
mesh and rules change.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.runtime.health import FaultPolicy
from repro.sharding.compression import EFState, compress_with_error_feedback, decompress
from . import optimizer as opt


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    grad_compression_density: Optional[float] = None  # e.g. 0.01; None = dense


def make_train_step(model, opt_cfg: opt.AdamWConfig,
                    compute_dtype=None) -> Callable:
    """Returns jit-able (state, batch) -> (state, metrics)."""

    def train_step(state: opt.TrainState, batch: dict):
        def loss_of(p):
            if compute_dtype is not None:
                p = jax.tree.map(
                    lambda t: t.astype(compute_dtype)
                    if t.dtype == jnp.float32 and t.ndim > 1 else t, p)
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(state.params)
        new_state = opt.adamw_update(state, grads, opt_cfg)
        return new_state, {"loss": loss, **metrics}

    return train_step


def make_compressed_train_step(model, opt_cfg: opt.AdamWConfig) -> Callable:
    """Train step with top-k gradient event-compression + error feedback.

    State carries the EF residuals; the transmitted gradient is the
    decompressed queue (what the wire-efficient all-reduce would deliver).
    """

    def train_step(carry, batch):
        state, ef = carry
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(state.params)
        comp, ef = compress_with_error_feedback(
            grads, ef, density=0.01)
        sparse_grads = jax.tree.map(
            lambda c, g: decompress(c).reshape(g.shape).astype(g.dtype),
            comp, grads,
            is_leaf=lambda x: hasattr(x, "indices"))
        new_state = opt.adamw_update(state, sparse_grads, opt_cfg)
        return (new_state, ef), {"loss": loss, **metrics}

    return train_step


def run(model, data_iter: Callable[[int], dict], loop_cfg: LoopConfig,
        opt_cfg: opt.AdamWConfig, rng: jax.Array,
        policy: Optional[FaultPolicy] = None,
        preempted: Callable[[], bool] = lambda: False,
        on_remesh: Optional[Callable] = None,
        param_dtype=jnp.float32) -> tuple[opt.TrainState, list]:
    """Train for total_steps with checkpoint/restart + FT hooks.

    data_iter(step) -> batch dict.  Resumes from the latest checkpoint in
    ckpt_dir if one exists (crash/preemption restart path).
    """
    params = model.init_params(rng, param_dtype)
    state = opt.init_state(params, opt_cfg)
    start = 0
    if loop_cfg.ckpt_dir and ckpt.latest_step(loop_cfg.ckpt_dir) is not None:
        state, start = ckpt.restore(state, loop_cfg.ckpt_dir)
        start = int(start)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    history = []
    for step in range(start, loop_cfg.total_steps):
        t0 = time.monotonic()
        state, metrics = step_fn(state, data_iter(step))
        dt = time.monotonic() - t0
        if policy is not None:
            decision = policy.decide(step, preempted=preempted())
            if decision == "checkpoint_now" and loop_cfg.ckpt_dir:
                ckpt.save(state, loop_cfg.ckpt_dir, step + 1)
                break  # yield to the preemption; restart resumes here
            if decision == "remesh":
                if loop_cfg.ckpt_dir:
                    ckpt.save(state, loop_cfg.ckpt_dir, step + 1)
                plan = policy.replan()
                if on_remesh is not None:
                    on_remesh(plan)  # launcher rebuilds mesh + restores
                break
        if loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save(state, loop_cfg.ckpt_dir, step + 1)
        if (step + 1) % loop_cfg.log_every == 0 or step == start:
            history.append({"step": step + 1, "loss": float(metrics["loss"]),
                            "sec": dt})
    return state, history
