import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_allow_excess_precision=false "
                           + os.environ.get("XLA_FLAGS", ""))
# ^^ MUST precede any jax import: jax locks the device count on first init.
# excess_precision=false: XLA:CPU otherwise elides our f32->bf16->f32
# mixed-precision casts (it has no native bf16 dots); a TPU backend keeps
# bf16 natively, so the flag makes CPU dry-run accounting match the target.

"""Multi-pod dry-run driver (deliverable e) — docstring after the env-var
preamble on purpose; see the two lines above.

For one (arch x shape x mesh) cell:
  1. build the production mesh (16x16 or 2x16x16),
  2. build the model + abstract params/optimizer state/caches
     (ShapeDtypeStructs — nothing is allocated),
  3. jit the step function with explicit in/out shardings,
  4. ``.lower(...).compile()`` — success proves the distribution config is
     coherent (shardings consistent, collectives supported, memory sane),
  5. print ``memory_analysis()`` + ``cost_analysis()`` and write the
     roofline terms (launch/roofline.py) to a JSON cell file.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape train_4k --mesh single --out results/
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Exit code 0 = every requested cell compiled (or was a documented skip).
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path


def _build_step(model, shape, mesh, rules, opt_cfg, compute_dtype=None,
                naive_decode=False):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    import jax
    import jax.numpy as jnp

    from repro.sharding.specs import replicated, tree_shardings
    from repro.train import optimizer as opt

    cfg = model.cfg
    batch_specs = model.input_specs(shape)
    batch_axes = model.input_axes(shape)
    batch_shardings = tree_shardings(mesh, batch_axes, batch_specs, rules)
    if shape.phase == "train":
        # param_dtype bf16 = production mixed precision: bf16 weights &
        # grads (collectives halve), fp32 Adam moments (optimizer.py
        # upcasts the update math)
        abstract_params = model.abstract_params(
            jnp.bfloat16 if compute_dtype is not None else jnp.float32)
        state = opt.abstract_state(abstract_params, opt_cfg)
        state_axes = opt.state_logical_axes(model.logical_axes())
        state_shardings = opt.TrainState(
            step=replicated(mesh),
            params=tree_shardings(mesh, state_axes.params, state.params, rules),
            mu=tree_shardings(mesh, state_axes.mu, state.mu, rules),
            nu=tree_shardings(mesh, state_axes.nu, state.nu, rules))

        def train_step(st, batch):
            def loss_of(p):
                if compute_dtype is not None:  # mixed precision: bf16 compute,
                    p = jax.tree.map(            # fp32 master params + moments
                        lambda t: t.astype(compute_dtype)
                        if t.dtype == jnp.float32 and t.ndim > 1 else t, p)
                return model.loss(p, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(st.params)
            new_state = opt.adamw_update(st, grads, opt_cfg)
            return new_state, (loss, metrics["ce"])

        fn = jax.jit(train_step,
                     in_shardings=(state_shardings, batch_shardings),
                     out_shardings=(state_shardings,
                                    (replicated(mesh), replicated(mesh))))
        return fn, (state, batch_specs)

    abstract_params = model.abstract_params(jnp.bfloat16)
    param_shardings = tree_shardings(mesh, model.logical_axes(), abstract_params, rules)
    max_seq = shape.seq_len
    if cfg.family == "vlm":
        max_seq += cfg.n_vision_tokens
    if shape.phase == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch, max_seq=max_seq)

        # let XLA choose cache/logit shardings; inputs pinned
        fn = jax.jit(prefill_step, in_shardings=(param_shardings, batch_shardings))
        return fn, (abstract_params, batch_specs)

    # decode
    cache, cache_axes = model.cache_structure(shape.global_batch, max_seq,
                                              abstract=True)
    cache_shardings = tree_shardings(mesh, cache_axes, cache, rules)

    def decode_fn(params, cache, batch):
        return model.decode(params, cache, batch)

    # donate the cache: the in-place dynamic-update-slice then aliases the
    # input buffer instead of copying ~GBs of KV per step
    donate = () if naive_decode else (1,)
    fn = jax.jit(decode_fn, donate_argnums=donate,
                 in_shardings=(param_shardings, cache_shardings, batch_shardings),
                 out_shardings=(None, cache_shardings))
    return fn, (abstract_params, cache, batch_specs)


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, out_dir: Path,
             rules_override=None, opt_cfg=None, tag: str = "baseline",
             verbose: bool = True, save_hlo: bool = False,
             compute_dtype=None, moe_impl: str = "gather",
             mesh_override=None, naive_decode: bool = False) -> dict:
    import jax

    from repro.configs import ARCHS, SHAPES, skip_reason
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import build_model
    from repro.sharding.specs import default_rules
    from repro.train.optimizer import AdamWConfig

    shape = SHAPES[shape_name]
    cell = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    reason = skip_reason(arch_id, shape_name)
    if reason:
        cell.update(status="skipped", reason=reason)
        return cell
    t0 = time.time()
    try:
        if mesh_override is not None:
            from repro.launch.mesh import make_custom_mesh
            mesh = make_custom_mesh(*mesh_override)
        else:
            mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        import dataclasses as _dc
        fcfg = ARCHS[arch_id].FULL
        if moe_impl != "gather" and fcfg.n_experts:
            fcfg = _dc.replace(fcfg, moe_impl=moe_impl)
        model = build_model(fcfg)
        long_ctx = shape_name == "long_500k"
        rules = rules_override or default_rules(phase=shape.phase,
                                                long_context=long_ctx)
        if naive_decode:  # pre-optimization serving layout (Perf baselines)
            rules = default_rules(phase="train", long_context=long_ctx)
        opt_cfg = opt_cfg or AdamWConfig()
        from repro.sharding.specs import set_constraint_mesh
        set_constraint_mesh(mesh, rules)
        fn, args = _build_step(model, shape, mesh, rules, opt_cfg,
                               compute_dtype=compute_dtype,
                               naive_decode=naive_decode)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            n_dev = mesh.devices.size
            n_active = _active_params(model)
            tokens = shape.global_batch * (shape.seq_len if shape.phase != "decode" else 1)
            mf = rl.model_flops_estimate(n_active, tokens, shape.phase)
            hlo_text = compiled.as_text()
            roof = rl.analyze(compiled, n_dev, model_flops=mf, hlo_text=hlo_text)
            if save_hlo:
                import gzip
                out_dir.mkdir(parents=True, exist_ok=True)
                with gzip.open(out_dir / f"{arch_id}__{shape_name}__{mesh_kind}__{tag}.hlo.txt.gz",
                               "wt") as fh:
                    fh.write(hlo_text)
        cell.update(status="ok", seconds_lower=round(t_lower, 1),
                    seconds_compile=round(t_compile, 1),
                    n_params=model.n_params(), n_params_active=n_active,
                    roofline=roof.to_dict())
        if verbose:
            print(f"[{arch_id} x {shape_name} x {mesh_kind}] OK "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
                  f"bottleneck={roof.bottleneck} "
                  f"t=(c {roof.t_compute*1e3:.1f} | m {roof.t_memory*1e3:.1f} "
                  f"| x {roof.t_collective*1e3:.1f}) ms")
            print("  memory_analysis:", (roof.memory_analysis or "")[:400])
    except Exception as e:
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{arch_id} x {shape_name} x {mesh_kind}] FAILED: {e}")
    finally:
        from repro.sharding.specs import set_constraint_mesh
        set_constraint_mesh(None)
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch_id}__{shape_name}__{mesh_kind}__{tag}.json"
    (out_dir / fname).write_text(json.dumps(cell, indent=1))
    return cell


def _active_params(model) -> float:
    """Active parameter count (MoE: routed top-k + shared + non-expert)."""
    import math

    from repro.models.common import is_spec
    import jax

    cfg = model.cfg
    total = 0.0
    for path, spec in jax.tree_util.tree_flatten_with_path(
            model.specs, is_leaf=is_spec)[0]:
        n = math.prod(spec.shape)
        keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if "we_" in keys and cfg.n_experts:  # routed expert tensors
            n = n * cfg.top_k / cfg.n_experts
        total += n
    return total


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run every (arch x shape)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--compute-dtype", choices=["f32", "bf16"], default="f32")
    ap.add_argument("--moe-impl", choices=["gather", "sharded"], default="gather")
    ap.add_argument("--naive-decode", action="store_true",
                    help="pre-optimization decode (no cache donation, FSDP "
                         "weight layout) — Perf baseline reproduction")
    ap.add_argument("--mesh-shape", default=None,
                    help="axis refactor of the same chip count, e.g. "
                         "'data=16,model=8,seq=2' (Perf experiments)")
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, SHAPES

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_dir = Path(args.out)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                import jax.numpy as _jnp
                cdt = _jnp.bfloat16 if args.compute_dtype == "bf16" else None
                mo = None
                if args.mesh_shape:
                    pairs = [kv.split("=") for kv in args.mesh_shape.split(",")]
                    mo = (tuple(int(v) for _, v in pairs),
                          tuple(k for k, _ in pairs))
                cell = run_cell(arch, shape, mesh_kind, out_dir, tag=args.tag,
                                save_hlo=args.save_hlo, compute_dtype=cdt,
                                moe_impl=args.moe_impl, mesh_override=mo,
                                naive_decode=args.naive_decode)
                failures += cell["status"] == "error"
    print(f"dry-run finished: {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
