"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md Sec. 6).

Per (arch x shape x mesh) cell:
  compute term    = per-device HLO FLOPs / peak_FLOPs_per_chip
  memory term     = per-device HLO bytes  / HBM bandwidth per chip
  collective term = per-device wire bytes / (links_per_chip * link BW)

FLOPs/bytes come from ``compiled.cost_analysis()`` (XLA reports the
post-SPMD, per-partition module — i.e. already per-device; we cross-check
against MODEL_FLOPS/chips napkin math and report the ratio).
Collective bytes are NOT in cost_analysis: we parse the post-partitioning
HLO text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, converted to on-wire
bytes with ring-algorithm multipliers (all-reduce 2(N-1)/N, all-gather
(N-1)/N of the output, etc.).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI with 4 links/chip (2D torus: 2 axes x 2 directions).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
LINKS_PER_CHIP = 4

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' -> bytes; tuples handled by summing every match."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, n_devices: int) -> int:
    """Participants per replica group of a collective op line."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)  # iota form
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float                 # per-device on-wire bytes (ring algs)
    payload_bytes: float              # raw operand bytes (no multipliers)
    counts: dict                      # op kind -> #ops
    by_kind: dict                     # op kind -> wire bytes

    def to_dict(self):
        return dataclasses.asdict(self)


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Sum on-wire bytes of all collectives in a (partitioned) HLO module."""
    counts: dict[str, int] = {}
    by_kind: dict[str, float] = {}
    wire = payload = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        # result shape appears left of '=', op kind right: "%x = f32[..] all-reduce(...)"
        m = re.match(r"%?[\w.\-]+ = (.+?) ([a-z\-]+)\(", s)
        if not m:
            continue
        kind = m.group(2)
        if kind not in _COLLECTIVES:
            # async forms ("all-reduce-start"); "-done" carries no new data
            base = kind[: -len("-start")] if kind.endswith("-start") else None
            if base in _COLLECTIVES:
                kind = base
            else:
                continue
        out_bytes = _shape_bytes(m.group(1))
        n = max(_group_size(s, n_devices), 1)
        if n == 1:
            continue  # degenerate groups move no data
        frac = (n - 1) / n
        if kind == "all-reduce":
            w = 2.0 * out_bytes * frac          # reduce-scatter + all-gather
        elif kind == "all-gather":
            w = out_bytes * frac                # output is the gathered buffer
        elif kind == "reduce-scatter":
            w = out_bytes * (n - 1)             # output is the scattered shard
        elif kind == "all-to-all":
            w = out_bytes * frac
        else:  # collective-permute
            w = out_bytes
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0.0) + w
        wire += w
        payload += out_bytes
    return CollectiveStats(wire, payload, counts, by_kind)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    n_devices: int
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: Optional[float] = None        # 6ND napkin (global)
    useful_flops_ratio: Optional[float] = None  # model / (hlo * devices)
    collectives: Optional[dict] = None
    memory_analysis: Optional[str] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, n_devices: int, model_flops: Optional[float] = None,
            hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from the compiled executable.

    FLOPs/bytes/collectives come from launch/hlo_cost.py (HLO walk with
    while-trip-count multipliers) because XLA's HloCostAnalysis counts
    scan bodies once — a 22x undercount on our layer-scanned models.
    ``xla_cost_analysis_*`` fields keep the raw XLA numbers as the
    cross-check column.
    """
    from .hlo_cost import HloCostModel

    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = HloCostModel(text, n_devices).entry_cost()
    flops, byts = cost.flops, cost.bytes
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = cost.wire / (LINKS_PER_CHIP * LINK_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    try:
        mem = str(compiled.memory_analysis())
    except Exception as e:  # XLA:CPU may not implement it
        mem = f"unavailable on this backend: {e}"
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]
    ratio = None
    if model_flops:
        ratio = model_flops / max(flops * n_devices, 1.0)
    return Roofline(
        flops_per_device=flops, bytes_per_device=byts,
        wire_bytes_per_device=cost.wire, n_devices=n_devices,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, bottleneck=bottleneck,
        model_flops=model_flops, useful_flops_ratio=ratio,
        collectives={"counts": cost.coll_counts, "wire_by_kind": cost.coll_wire,
                     "xla_flops_unscaled": float(xla_cost.get("flops", 0.0)),
                     "xla_bytes_unscaled": float(xla_cost.get("bytes accessed", 0.0))},
        memory_analysis=mem)


def model_flops_estimate(n_params_active: float, tokens: float, phase: str) -> float:
    """6*N*D for train, 2*N*D for inference forward passes."""
    return (6.0 if phase == "train" else 2.0) * n_params_active * tokens
