"""Serving launcher: batched generation through repro.serve.engine, plus
batched event-driven CSNN inference (the paper workload) as its own arch.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 4 --new-tokens 16

  PYTHONPATH=src python -m repro.launch.serve --arch csnn-paper --smoke \
      --requests 8

  # async micro-batching engine with plan + per-layer event counts:
  PYTHONPATH=src python -m repro.launch.serve --arch csnn-paper --smoke \
      --requests 8 --engine --verbose

  # continuous batching: slot-level refill instead of run-to-completion
  # flushes, with a slot-utilization report:
  PYTHONPATH=src python -m repro.launch.serve --arch csnn-paper --smoke \
      --requests 8 --engine --continuous --t-chunk 1

  # streaming DVS ingestion: requests are raw (t, y, x, polarity) event
  # traces (synthetic moving-edge scenes) admitted bank-scatter-style
  # with no per-frame encode or sort (implies --engine --continuous):
  PYTHONPATH=src python -m repro.launch.serve --arch csnn-paper --smoke \
      --requests 8 --stream
"""
import argparse
import sys
import time


def serve_csnn(args) -> int:
    """Serve a batch of image requests through the planned event pipeline.

    Default mode runs one pre-built batch through ``snn_apply_batched``;
    ``--engine`` routes the same requests through the async micro-batching
    ``CSNNEngine`` (enqueue individually, flush on batch/deadline);
    ``--stream`` serves raw DVS event traces (synthetic moving-edge
    scenes, 2-polarity) through the continuous engine's streaming
    admission — no per-frame threshold encode, no sort.  Compile time is
    measured separately from steady state (the first timed call used to
    include retrace on shape change); ``--verbose`` prints the derived
    NetworkPlan and per-layer event counts.
    """
    import statistics
    from dataclasses import replace

    import jax
    import jax.numpy as jnp

    from repro.configs import csnn_paper, csnn_wide
    from repro.core.csnn import encode_input, init_params, snn_apply_batched
    from repro.core.plan import plan_network

    # --stream implies --continuous implies --engine
    args.continuous = args.continuous or args.stream
    args.engine = args.engine or args.continuous
    mod = csnn_wide if args.arch == "csnn-wide" else csnn_paper
    cfg = mod.SMOKE if args.smoke else mod.FULL
    if args.stream:  # polarity (OFF/ON) maps onto the 2-channel input path
        cfg = replace(cfg, input_channels=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    h, w = cfg.input_hw
    if args.stream:
        from repro.data.dvs import dvs_moving_edges
        reqs, _ = dvs_moving_edges(args.requests, cfg.t_steps, (h, w),
                                   seed=1)
        n_events = sum(tr.shape[0] for tr in reqs)
    else:
        reqs = list(jax.random.uniform(
            jax.random.PRNGKey(1), (args.requests, h, w, cfg.input_channels)))
    batch_tile = args.batch_tile
    event_par = (None if args.event_par < 0
                 else args.event_par if args.event_par else 1)
    # tuning happens here, before any request is admitted — measured
    # micro-benchmarks (--tune measured) or a plan-cache load (--tune
    # cached) are warmup work, never hot-path work
    t0 = time.perf_counter()
    plan = plan_network(cfg, capacity=args.capacity,
                        channel_block=args.channel_block,
                        batch_tile=batch_tile, event_par=event_par,
                        ingest=args.stream, tune=args.tune)
    if args.tune != "analytic":
        print(f"tune: mode={args.tune} plan derived in "
              f"{time.perf_counter() - t0:.2f} s")
    if args.verbose:
        print(plan)

    if args.engine:
        from repro.serve.csnn_engine import CSNNEngine, CSNNServeConfig
        max_batch = -(-args.requests // batch_tile) * batch_tile
        engine = CSNNEngine(params, cfg, plan,
                            CSNNServeConfig(max_batch=max_batch,
                                            max_delay_ms=args.deadline_ms,
                                            continuous=args.continuous,
                                            t_chunk=args.t_chunk,
                                            stream=args.stream))
        compile_s = engine.warmup()
        times = []
        for _ in range(max(args.iters, 1)):
            t0 = time.perf_counter()
            logits = jnp.asarray(engine.run_requests(reqs))
            times.append(time.perf_counter() - t0)
        dt = statistics.median(times)
        steady = f"{args.requests / dt:.1f} samples/s (median of {len(times)})"
        if args.continuous:
            extra = (f"engine: chunks={engine.stats['chunks']} "
                     f"admitted={engine.stats['admitted']} "
                     f"refills={engine.stats['refills']} "
                     f"slot_utilization={engine.slot_utilization:.0%} "
                     f"wait_ms_max={engine.stats['wait_ms_max']:.1f} "
                     f"deadline_misses={engine.stats['deadline_misses']}")
            if args.stream:
                extra += (f"\nstream: events={n_events} "
                          f"({n_events / dt:.0f} events/s admitted)")
        else:
            extra = (f"engine: batches={engine.stats['batches']} "
                     f"full={engine.stats['flushes_full']} "
                     f"deadline={engine.stats['flushes_deadline']} "
                     f"padded_slots={engine.stats['padded_slots']}")
    else:
        fn = jax.jit(lambda s: snn_apply_batched(
            params, s, cfg, plan, collect_stats=False))
        spikes = encode_input(jnp.stack(reqs), cfg)
        t0 = time.perf_counter()
        logits = jax.block_until_ready(fn(spikes))
        compile_s = time.perf_counter() - t0  # first call: compile + run
        times = []
        for _ in range(max(args.iters, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(spikes))
            times.append(time.perf_counter() - t0)
        dt = statistics.median(times)
        steady = f"{args.requests / dt:.1f} samples/s (median of {len(times)})"
        extra = ""

    preds = jnp.argmax(logits, axis=-1)
    for i, p in enumerate(preds.tolist()):
        print(f"req {i}: class {p}")
    print(f"compile: {compile_s:.2f} s (excluded from throughput)")
    mode = ("stream" if args.stream
            else "continuous" if args.engine and args.continuous
            else "engine" if args.engine else "batched")
    print(f"throughput: {steady} "
          f"(batch={args.requests}, T={cfg.t_steps}, "
          f"capacity={args.capacity}, channel_block={args.channel_block}, "
          f"mode={mode})")
    if extra:
        print(extra)
    if args.verbose and not args.stream:
        spikes = encode_input(jnp.stack(reqs), cfg)
        _, stats = jax.jit(lambda s: snn_apply_batched(
            params, s, cfg, plan, collect_stats=True))(spikes)
        for lp, st in zip(plan.layers, stats):
            events = int(jnp.sum(st.in_spike_counts))
            peak = int(jnp.max(st.in_spike_counts))
            print(f"layer {lp.name}: events={events} peak_queue={peak} "
                  f"capacity={lp.capacity} block_e={int(st.event_block)}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--capacity", type=int, default=256,
                    help="AEQ depth per queue (csnn-paper only)")
    ap.add_argument("--channel-block", type=int, default=8,
                    help="output channels per MemPot tile (csnn-paper only)")
    ap.add_argument("--event-par", type=int, default=-1,
                    help="interlaced event-parallel width for csnn plans: "
                         "-1 autotunes per layer (default), 0/1 keeps the "
                         "sequential conv unit, >1 pins the width")
    ap.add_argument("--tune", default="analytic",
                    choices=("analytic", "measured", "cached"),
                    help="plan derivation: closed-form VMEM model "
                         "(analytic), measured micro-benchmark winners "
                         "persisted to the plan cache (measured), or a "
                         "cache load falling back to measuring on a miss "
                         "(cached; REPRO_PLAN_CACHE overrides the path)")
    ap.add_argument("--engine", action="store_true",
                    help="route requests through the async micro-batching "
                         "CSNNEngine (csnn-paper only)")
    ap.add_argument("--continuous", action="store_true",
                    help="with --engine: continuous batching — slot-level "
                         "refill between t_chunk steps instead of "
                         "run-to-completion flushes")
    ap.add_argument("--stream", action="store_true",
                    help="serve raw DVS event traces through the "
                         "continuous engine's streaming admission "
                         "(implies --engine --continuous; csnn-paper only)")
    ap.add_argument("--t-chunk", type=int, default=0,
                    help="continuous-mode refill granularity in time steps "
                         "(0 = plan default; snapped to a divisor of T)")
    ap.add_argument("--batch-tile", type=int, default=8,
                    help="engine pads partial batches to this multiple")
    ap.add_argument("--deadline-ms", type=float, default=10.0,
                    help="engine flush deadline for partial batches")
    ap.add_argument("--iters", type=int, default=3,
                    help="steady-state timing iterations")
    ap.add_argument("--verbose", action="store_true",
                    help="print the NetworkPlan and per-layer event counts")
    args = ap.parse_args(argv)

    if args.arch in ("csnn-paper", "csnn-wide"):
        return serve_csnn(args)

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS
    from repro.models.registry import build_model
    from repro.serve.engine import Engine, ServeConfig

    cfg = ARCHS[args.arch].SMOKE if args.smoke else ARCHS[args.arch].FULL
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.new_tokens + 8
    engine = Engine(model, params, max_seq=max_seq,
                    cfg=ServeConfig(max_new_tokens=args.new_tokens,
                                    temperature=args.temperature))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.requests, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.requests, cfg.n_vision_tokens, cfg.d_model))
        max_seq += cfg.n_vision_tokens
        engine.max_seq = max_seq
    if cfg.family == "encdec":
        extra["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.requests, cfg.enc_frames, cfg.d_model))
    out = engine.generate(prompts, jax.random.PRNGKey(3), extra=extra)
    for i, row in enumerate(out):
        print(f"req {i}: {row.tolist()[args.prompt_len:]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
