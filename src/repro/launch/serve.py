"""Serving launcher: batched generation through repro.serve.engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 4 --new-tokens 16
"""
import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS
    from repro.models.registry import build_model
    from repro.serve.engine import Engine, ServeConfig

    cfg = ARCHS[args.arch].SMOKE if args.smoke else ARCHS[args.arch].FULL
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.new_tokens + 8
    engine = Engine(model, params, max_seq=max_seq,
                    cfg=ServeConfig(max_new_tokens=args.new_tokens,
                                    temperature=args.temperature))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.requests, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.requests, cfg.n_vision_tokens, cfg.d_model))
        max_seq += cfg.n_vision_tokens
        engine.max_seq = max_seq
    if cfg.family == "encdec":
        extra["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.requests, cfg.enc_frames, cfg.d_model))
    out = engine.generate(prompts, jax.random.PRNGKey(3), extra=extra)
    for i, row in enumerate(out):
        print(f"req {i}: {row.tolist()[args.prompt_len:]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
