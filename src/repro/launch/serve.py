"""Serving launcher: batched generation through repro.serve.engine, plus
batched event-driven CSNN inference (the paper workload) as its own arch.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 4 --new-tokens 16

  PYTHONPATH=src python -m repro.launch.serve --arch csnn-paper --smoke \
      --requests 8
"""
import argparse
import sys
import time


def serve_csnn(args) -> int:
    """Serve a batch of image requests through ``snn_apply_batched``.

    The batched pipeline is the serving entry point: all requests' event
    queues are compacted in one fused pass and every conv-unit launch
    feeds the whole batch (vs vmap's per-sample schedule).  Prints one
    line per request plus the measured batched throughput.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import csnn_paper
    from repro.core.csnn import encode_input, init_params, snn_apply_batched

    cfg = csnn_paper.SMOKE if args.smoke else csnn_paper.FULL
    params = init_params(jax.random.PRNGKey(0), cfg)
    h, w = cfg.input_hw
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (args.requests, h, w, 1))
    spikes = encode_input(imgs, cfg)

    fn = jax.jit(lambda s: snn_apply_batched(
        params, s, cfg, capacity=args.capacity,
        channel_block=args.channel_block, collect_stats=False))
    logits = jax.block_until_ready(fn(spikes))  # includes compile
    t0 = time.perf_counter()
    jax.block_until_ready(fn(spikes))
    dt = time.perf_counter() - t0

    preds = jnp.argmax(logits, axis=-1)
    for i, p in enumerate(preds.tolist()):
        print(f"req {i}: class {p}")
    print(f"throughput: {args.requests / dt:.1f} samples/s "
          f"(batch={args.requests}, T={cfg.t_steps}, "
          f"capacity={args.capacity}, channel_block={args.channel_block})")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--capacity", type=int, default=256,
                    help="AEQ depth per queue (csnn-paper only)")
    ap.add_argument("--channel-block", type=int, default=8,
                    help="output channels per MemPot tile (csnn-paper only)")
    args = ap.parse_args(argv)

    if args.arch == "csnn-paper":
        return serve_csnn(args)

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS
    from repro.models.registry import build_model
    from repro.serve.engine import Engine, ServeConfig

    cfg = ARCHS[args.arch].SMOKE if args.smoke else ARCHS[args.arch].FULL
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.new_tokens + 8
    engine = Engine(model, params, max_seq=max_seq,
                    cfg=ServeConfig(max_new_tokens=args.new_tokens,
                                    temperature=args.temperature))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.requests, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.requests, cfg.n_vision_tokens, cfg.d_model))
        max_seq += cfg.n_vision_tokens
        engine.max_seq = max_seq
    if cfg.family == "encdec":
        extra["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.requests, cfg.enc_frames, cfg.d_model))
    out = engine.generate(prompts, jax.random.PRNGKey(3), extra=extra)
    for i, row in enumerate(out):
        print(f"req {i}: {row.tolist()[args.prompt_len:]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
