"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
      --mesh smoke --smoke --steps 50

``--mesh single|multi`` builds the production mesh (on real hardware;
under XLA_FLAGS=--xla_force_host_platform_device_count=512 for rehearsal)
and pins state/batch shardings from the logical-axis rules; ``--mesh
smoke`` runs the same code on one device.  Checkpoint/restart and the
fault policy come from repro.train.loop.
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--mesh", choices=["smoke", "single", "multi"], default="smoke")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compute-dtype", choices=["f32", "bf16"], default="f32")
    args = ap.parse_args(argv)

    if args.mesh == "multi" and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        print("note: multi-pod mesh on real hardware expects 512 devices")

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS
    from repro.data.synthetic import TokenStream
    from repro.launch.mesh import make_production_mesh, make_smoke_mesh
    from repro.models.registry import build_model
    from repro.sharding.specs import default_rules, set_constraint_mesh, tree_shardings
    from repro.train.loop import LoopConfig, make_train_step, run
    from repro.train.optimizer import AdamWConfig

    cfg = ARCHS[args.arch].SMOKE if args.smoke else ARCHS[args.arch].FULL
    model = build_model(cfg)
    print(f"{cfg.name}: {model.n_params() / 1e6:.1f}M params")
    mesh = (make_smoke_mesh() if args.mesh == "smoke"
            else make_production_mesh(multi_pod=args.mesh == "multi"))
    rules = default_rules(phase="train")
    set_constraint_mesh(mesh, rules)
    ts = TokenStream(vocab=cfg.vocab, seed=0)

    def data(step):
        b = ts.batch(step, args.batch, args.seq)
        return {k: jnp.asarray(v) for k, v in b.items()}

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                       total_steps=args.steps)
    with mesh:
        state, hist = run(model, data,
                          LoopConfig(total_steps=args.steps, ckpt_every=50,
                                     log_every=10, ckpt_dir=args.ckpt_dir),
                          ocfg, jax.random.PRNGKey(0))
    for h in hist:
        print(f"  step {h['step']:5d}  loss {h['loss']:.4f}  {h['sec']:.2f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
