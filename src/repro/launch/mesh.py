"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import to build these meshes on a CPU-only host.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip pod (data, model); 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1x1 mesh with the production axis names — lets every pjit code path
    run unchanged in single-device tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_custom_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic-scaling entry point: the runtime remesh planner
    (repro/runtime/health.py) picks a new factorization after failures and
    rebuilds the mesh here."""
    return jax.make_mesh(shape, axes)
