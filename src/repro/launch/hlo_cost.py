"""HLO-text cost model with while-loop trip-count multipliers.

Why this exists: ``compiled.cost_analysis()`` (HloCostAnalysis) visits a
``while`` body ONCE, so any lax.scan-based model (all of ours: layer
scans, q-block attention, chunked CE, linear-attention chunk scans) has
its FLOPs / bytes / collectives undercounted by the trip count (measured
22x on stablelm train).  XLA:CPU records ``known_trip_count`` in each
while's backend_config, so an exact fix is to walk the HLO call graph
ourselves and multiply.

Cost semantics (documented proxies, used consistently across all cells):
* flops      — 2 * prod(output dims) * prod(lhs contracting dims) per
               dot; convolutions approximated as dots; elementwise ops
               ignored (<1% of any transformer's FLOPs).
* hbm_bytes  — TPU-fusion approximation: only *materializing* ops touch
               HBM (dot/conv, reduces, data movement: copy/gather/
               scatter/(dynamic-)slice/dus/transpose/concat/pad/sort,
               and collectives), counted as operand+output bytes; pure
               elementwise chains (adds, converts, broadcasts, compares,
               selects — including the single-op kLoop fusions XLA:CPU
               wraps them in) are free, as a TPU would fuse them into
               neighboring kernels.  This slightly undercounts real
               fusion boundaries and is used consistently across cells.
* collective wire bytes — same ring multipliers as launch/roofline.py,
               with replica-group sizes parsed per op.
* while      — body x N, condition x (N+1); call/conditional x 1.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}
# ops whose operands/outputs really move HBM bytes on a fused (TPU) backend
_MATERIALIZING = {"dot", "convolution", "reduce", "reduce-window", "sort",
                  "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
                  "copy", "copy-start", "transpose", "concatenate", "pad",
                  "reverse", "slice", "select-and-scatter", "cholesky",
                  "triangular-solve", "rng", "rng-bit-generator",
                  "custom-call"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _dtype_width(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    return _DTYPE_BYTES.get(m.group(1), 0) if m else 0
_OP_RE = re.compile(r"^(?:ROOT )?%([\w.\-]+) = (.+?) ([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(.*\)? -> .*\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    """Dims of the FIRST array shape in the string."""
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class OpLine:
    name: str
    shape: str
    kind: str
    operands: list
    attrs: str
    arg_str: str = ""  # raw operand text (holds e.g. the parameter index)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)
    coll_wire: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "CompCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.wire += other.wire * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.coll_wire.items():
            self.coll_wire[k] = self.coll_wire.get(k, 0.0) + v * mult


class HloCostModel:
    """See module docstring. ``bytes_by_kind()`` attributes the byte proxy
    per op kind (with trip multipliers) for perf debugging."""

    def __init__(self, hlo_text: str, n_devices: int):
        self.n_devices = n_devices
        self.comps: dict[str, list[OpLine]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: dict[str, CompCost] = {}

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.strip()
            m = _COMP_RE.match(raw)  # computations start at col 0
            if m and raw[0] != " " and "{" in raw:
                cur = m.group(1)
                self.comps[cur] = []
                if raw.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None or not line or line == "}":
                if line == "}":
                    cur = None
                continue
            om = _OP_RE.match(line)
            if not om:
                continue
            name, shape, kind, rest = om.groups()
            # operands: %names inside the first (...) group
            depth, i, args = 1, 0, rest
            while i < len(args) and depth:
                if args[i] == "(":
                    depth += 1
                elif args[i] == ")":
                    depth -= 1
                i += 1
            operand_str, attrs = args[: i - 1], args[i:]
            operands = re.findall(r"%([\w.\-]+)", operand_str)
            self.comps[cur].append(OpLine(name, shape, kind, operands, attrs,
                                          operand_str))

    def _symtab(self, comp: str) -> dict:
        return {op.name: op.shape for op in self.comps[comp]}

    def _eff_bytes_map(self, comp: str) -> dict:
        """name -> effective operand bytes, looking through converts.

        XLA:CPU upcasts bf16 dots to f32 via explicit converts; a TPU
        would read the bf16 buffer directly.  Charge the pre-convert
        dtype so mixed-precision accounting matches the target hardware.
        """
        memo = self.__dict__.setdefault("_eff_memo", {})
        if comp in memo:
            return memo[comp]
        sym = self._symtab(comp)
        eff = {}
        for op in self.comps[comp]:
            out_b = _shape_bytes(op.shape)
            if op.kind == "convert" and op.operands:
                src = _shape_bytes(sym.get(op.operands[0], ""))
                eff[op.name] = min(out_b, src) if src else out_b
            elif op.kind == "fusion":
                called = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                w_out = _dtype_width(op.shape)
                w_eff = w_out
                if called:
                    w_in = self._fusion_narrow_width(called.group(1))
                    if w_in is not None:
                        w_eff = min(w_out, w_in)
                eff[op.name] = (out_b * w_eff // w_out) if w_out else out_b
            else:
                eff[op.name] = out_b
        memo[comp] = eff
        return eff

    def _fusion_narrow_width(self, comp: str):
        """Narrowest convert-result width inside a fused computation, or
        None if it contains no converts.  A value that passed through a
        bf16 rounding is stored bf16 on the target backend even though
        XLA:CPU keeps it f32 for its (upcasting) dot implementation."""
        widths = []
        for op in self.comps.get(comp, []):
            if op.kind == "convert":
                w = _dtype_width(op.shape)
                if w:
                    widths.append(w)
        return min(widths) if widths else None

    # ------------------------------------------------------------- costing
    def comp_cost(self, comp: str) -> CompCost:
        if comp in self._memo:
            return self._memo[comp]
        total = CompCost()
        self._memo[comp] = total  # break cycles defensively
        sym = self._symtab(comp)
        eff = self._eff_bytes_map(comp)
        for op in self.comps[comp]:
            if op.kind in _FREE_OPS:
                continue
            out_bytes = _shape_bytes(op.shape)
            opd_bytes = sum(eff.get(o, _shape_bytes(sym.get(o, "")))
                            for o in op.operands)
            if op.kind == "fusion":
                # recurse for flops; bytes only if the fused computation
                # contains a materializing op (else: elementwise chain, free)
                called = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if called and called.group(1) in self.comps:
                    cname = called.group(1)
                    sub = self.comp_cost(cname)
                    total.flops += sub.flops
                    if self._materializes(cname):
                        upd = self._dus_root_update_bytes(cname)
                        eff_out = 2 * upd if upd is not None else out_bytes
                        total.bytes += eff_out + self._fusion_operand_bytes(
                            cname, op, sym, eff)
                continue
            if op.kind == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                trip = 1.0
                tm = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', op.attrs)
                if tm:
                    trip = float(tm.group(1))
                if body and body.group(1) in self.comps:
                    total.add(self.comp_cost(body.group(1)), trip)
                if cond and cond.group(1) in self.comps:
                    total.add(self.comp_cost(cond.group(1)), trip + 1)
                continue
            if op.kind in ("call", "async-start"):
                called = re.search(r"(?:to_apply|called_computation)=%?([\w.\-]+)",
                                   op.attrs)
                if called and called.group(1) in self.comps:
                    total.add(self.comp_cost(called.group(1)))
                total.bytes += out_bytes
                continue
            if op.kind == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", op.attrs)
                names = re.findall(r"%([\w.\-]+)", branches[0]) if branches else []
                if names:
                    worst = CompCost()
                    for nm in names:
                        if nm in self.comps:
                            c = self.comp_cost(nm)
                            if c.flops + c.bytes > worst.flops + worst.bytes:
                                worst = c
                    total.add(worst)
                total.bytes += out_bytes
                continue
            if op.kind == "dot":
                lhs_shape = sym.get(op.operands[0], "") if op.operands else ""
                lhs_dims = _shape_dims(lhs_shape)
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
                contract = 1
                if cm and cm.group(1):
                    for d in cm.group(1).split(","):
                        contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
                out_elems = 1
                for d in _shape_dims(op.shape):
                    out_elems *= d
                total.flops += 2.0 * out_elems * contract
                total.bytes += out_bytes + opd_bytes
                continue
            if op.kind == "convolution":
                out_elems = 1
                for d in _shape_dims(op.shape):
                    out_elems *= d
                k_dims = _shape_dims(sym.get(op.operands[1], "")) if len(op.operands) > 1 else []
                k_elems = 1
                for d in k_dims[:-1]:  # kernel spatial x in-channels
                    k_elems *= d
                total.flops += 2.0 * out_elems * k_elems
                total.bytes += out_bytes + opd_bytes
                continue
            base = op.kind[:-len("-start")] if op.kind.endswith("-start") else op.kind
            if base in _COLLECTIVES:
                n = self._group_size(op.attrs)
                if n > 1:
                    frac = (n - 1) / n
                    if base == "all-reduce":
                        w = 2.0 * out_bytes * frac
                    elif base == "all-gather":
                        w = out_bytes * frac
                    elif base == "reduce-scatter":
                        w = out_bytes * (n - 1)
                    elif base == "all-to-all":
                        w = out_bytes * frac
                    else:
                        w = out_bytes
                    total.wire += w
                    total.coll_counts[base] = total.coll_counts.get(base, 0) + 1
                    total.coll_wire[base] = total.coll_wire.get(base, 0.0) + w
                total.bytes += out_bytes
                continue
            if op.kind in ("dynamic-slice", "gather"):
                # a slice reads only the sliced bytes, not the source buffer
                total.bytes += 2 * out_bytes
                continue
            if op.kind == "dynamic-update-slice":
                # read-modify-write of the update region; the target buffer
                # aliases in place (donation) — full-buffer copy never happens
                upd = (_shape_bytes(sym.get(op.operands[1], ""))
                       if len(op.operands) > 1 else out_bytes)
                total.bytes += 2 * upd
                continue
            if op.kind in _MATERIALIZING:
                total.bytes += out_bytes + opd_bytes
            # remaining elementwise ops: fused away, free
        return total

    def _dus_root_update_bytes(self, cname: str):
        """If the fused computation's root is a dynamic-update-slice (through
        converts/bitcasts/copies), return the update operand's bytes: with
        buffer donation the full-size output aliases in place and only the
        updated region moves.  None if the root is not a dus."""
        inner = self.comps.get(cname, [])
        sym = {o.name: o for o in inner}
        root = next((o for o in inner if o.kind != "parameter"), None)
        for o in inner:
            # the ROOT marker is lost in parsing; take the last op as root
            root = o
        seen = 0
        while root is not None and root.kind in ("convert", "bitcast", "copy") \
                and root.operands and seen < 8:
            root = sym.get(root.operands[0])
            seen += 1
        if root is not None and root.kind == "dynamic-update-slice" \
                and len(root.operands) > 1:
            upd = sym.get(root.operands[1])
            return _shape_bytes(upd.shape) if upd is not None else None
        return None

    def _fusion_operand_bytes(self, cname: str, op: OpLine, sym: dict,
                              eff: dict) -> float:
        """Operand bytes of a fusion, honoring slice semantics: a fusion
        parameter consumed ONLY by dynamic-slice/gather reads just the
        sliced bytes; a dus target parameter aliases (0 read)."""
        inner = self.comps.get(cname, [])
        param_names = {}
        for iop in inner:
            if iop.kind == "parameter" and iop.arg_str.strip().isdigit():
                param_names[int(iop.arg_str.strip())] = iop.name
        consumers: dict[str, list] = {}
        for iop in inner:
            for o in iop.operands:
                consumers.setdefault(o, []).append(iop)
        total = 0.0
        for i, operand in enumerate(op.operands):
            full = eff.get(operand, _shape_bytes(sym.get(operand, "")))
            pname = param_names.get(i)
            cons = consumers.get(pname, []) if pname else []
            if not cons:
                total += full
                continue
            # transitive walk through dtype/layout chains: XLA:CPU wraps dus
            # targets in full-buffer convert round-trips a TPU wouldn't emit
            acc = 0.0
            stack = [(pname, c) for c in cons]
            hops = 0
            while stack and acc < full and hops < 64:
                hops += 1
                src, c = stack.pop()
                if c.kind in ("convert", "bitcast", "copy"):
                    stack.extend((c.name, c2) for c2 in consumers.get(c.name, []))
                elif c.kind in ("dynamic-slice", "gather"):
                    acc += _shape_bytes(c.shape)
                elif (c.kind == "dynamic-update-slice" and c.operands
                      and c.operands[0] == src):
                    acc += 0.0  # dus target: aliased in place
                else:
                    acc = full
            total += min(acc, full)
        return total

    def _materializes(self, comp: str) -> bool:
        """Does the fused computation contain any HBM-moving op?"""
        memo = self.__dict__.setdefault("_mat_memo", {})
        if comp in memo:
            return memo[comp]
        memo[comp] = False  # break recursion defensively
        out = False
        for op in self.comps.get(comp, []):
            if op.kind in _MATERIALIZING:
                out = True
                break
            if op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m and self._materializes(m.group(1)):
                    out = True
                    break
        memo[comp] = out
        return out

    def _group_size(self, attrs: str) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", attrs)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", attrs)
        if m:
            return len(m.group(1).split(","))
        return self.n_devices

    def entry_cost(self) -> CompCost:
        # ENTRY reaches whiles/fusions via direct ops; nested computations are
        # reached through their callers, so costing ENTRY covers the program.
        if self.entry is None:
            raise ValueError("no ENTRY computation found in HLO text")
        return self.comp_cost(self.entry)


    def bytes_by_kind(self) -> dict:
        """Entry-weighted byte attribution per op kind (debug/perf tool)."""
        mult: dict[str, float] = {}

        def walk(comp: str, m: float):
            mult[comp] = mult.get(comp, 0.0) + m
            for op in self.comps[comp]:
                if op.kind == "while":
                    body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                    trip = 1.0
                    tm = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', op.attrs)
                    if tm:
                        trip = float(tm.group(1))
                    if body and body.group(1) in self.comps:
                        walk(body.group(1), m * trip)
                elif op.kind == "call":
                    called = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
                    if called and called.group(1) in self.comps:
                        walk(called.group(1), m)

        walk(self.entry, 1.0)
        agg: dict[str, float] = {}
        for comp, m in mult.items():
            sym = self._symtab(comp)
            for op in self.comps[comp]:
                if op.kind in _FREE_OPS or op.kind == "while":
                    continue
                b = _shape_bytes(op.shape)
                if op.kind not in ("call", "conditional"):
                    b += sum(_shape_bytes(sym.get(o, "")) for o in op.operands)
                agg[op.kind] = agg.get(op.kind, 0.0) + b * m
        return dict(sorted(agg.items(), key=lambda kv: -kv[1]))

    def top_buffers(self, n: int = 12) -> list:
        """Entry-weighted top byte contributors [(bytes, kind, shape, op_name)]
        under the same accounting rules as entry_cost (perf debugging)."""
        mult: dict[str, float] = {}

        def walk(comp, mm):
            mult[comp] = mult.get(comp, 0.0) + mm
            for op in self.comps[comp]:
                if op.kind == "while":
                    b = re.search(r"body=%?([\w.\-]+)", op.attrs)
                    tm = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', op.attrs)
                    t = float(tm.group(1)) if tm else 1.0
                    if b and b.group(1) in self.comps:
                        walk(b.group(1), mm * t)
                elif op.kind == "call":
                    c = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
                    if c and c.group(1) in self.comps:
                        walk(c.group(1), mm)

        walk(self.entry, 1.0)
        agg: dict = {}
        for comp, mm in mult.items():
            sym = self._symtab(comp)
            eff = self._eff_bytes_map(comp)
            for op in self.comps[comp]:
                b = None
                if op.kind == "fusion":
                    mo = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                    if not (mo and self._materializes(mo.group(1))):
                        continue
                    upd = self._dus_root_update_bytes(mo.group(1))
                    out_b = 2 * upd if upd is not None else _shape_bytes(op.shape)
                    b = out_b + self._fusion_operand_bytes(mo.group(1), op, sym, eff)
                elif op.kind in ("dynamic-slice", "gather"):
                    b = 2 * _shape_bytes(op.shape)
                elif op.kind == "dynamic-update-slice":
                    upd = (_shape_bytes(sym.get(op.operands[1], ""))
                           if len(op.operands) > 1 else 0)
                    b = 2 * upd
                elif op.kind in _MATERIALIZING:
                    b = _shape_bytes(op.shape) + sum(
                        eff.get(o, _shape_bytes(sym.get(o, ""))) for o in op.operands)
                if b is None:
                    continue
                meta = re.search(r'op_name="([^"]*)"', op.attrs)
                nm = (meta.group(1) if meta else "?")[-70:]
                key = (op.kind, op.shape.split("{")[0][:40], nm)
                agg[key] = agg.get(key, 0.0) + b * mm
        return sorted(((v,) + k for k, v in agg.items()), reverse=True)[:n]
