"""Async micro-batching engine for event-driven CSNN inference.

Serving shape of the paper workload: requests (single images) arrive one
at a time; the batched event pipeline (``snn_apply_batched``) only pays
off when many samples share one fused queue compaction and one conv-unit
launch per (t, c_in, channel-block) step.  The engine bridges the two:

* ``submit`` enqueues a request and awaits its logits;
* a background flusher collects requests and flushes a micro-batch when
  either ``max_batch`` requests are pending (size flush) or the oldest
  request has waited ``max_delay_ms`` (deadline flush) — the standard
  batch/deadline threshold from LLM serving, applied to spike streams;
* partial batches are padded with zero images up to the plan's
  ``batch_tile`` multiple, so the jitted pipeline only ever sees a small
  fixed set of batch shapes (no retrace per request count) — the batch
  analogue of padding event queues to the block size.

The compute itself runs synchronously inside the flush (CPU/TPU-bound;
requests queue up meanwhile), and every batch shape can be pre-compiled
with ``warmup()`` so steady-state latency never includes a retrace.
Observability lives in ``engine.stats`` (flush reasons, padded slots,
batch sizes) — tests/test_serve_csnn.py pins the flush semantics.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csnn import CSNNConfig, encode_input, snn_apply_batched
from repro.core.plan import NetworkPlan, plan_network

_STOP = object()


@dataclasses.dataclass
class CSNNServeConfig:
    max_batch: int = 8        # size-flush threshold (requests per batch)
    max_delay_ms: float = 10.0  # deadline-flush threshold for the oldest request


class CSNNEngine:
    """Micro-batching front-end over the planned batched event pipeline.

    Use as an async context manager::

        engine = CSNNEngine(params, cfg, plan)
        async with engine:
            logits = await engine.submit(image)   # (H, W, 1) -> (n_classes,)

    or drive a whole request list synchronously with ``run_requests``.
    """

    def __init__(self, params: dict, cfg: CSNNConfig,
                 plan: Optional[NetworkPlan] = None,
                 serve_cfg: CSNNServeConfig = CSNNServeConfig(), *,
                 backend: str = "jax"):
        self.cfg = cfg
        self.plan = plan if plan is not None else plan_network(
            cfg, batch_tile=serve_cfg.max_batch)
        self.serve_cfg = serve_cfg
        if serve_cfg.max_batch % self.plan.batch_tile != 0:
            raise ValueError(
                f"max_batch={serve_cfg.max_batch} must be a multiple of the "
                f"plan's batch_tile={self.plan.batch_tile}")
        self._infer = jax.jit(lambda sp: snn_apply_batched(
            params, sp, cfg, self.plan, collect_stats=False, backend=backend))
        self._queue: Optional[asyncio.Queue] = None
        self._flusher: Optional[asyncio.Task] = None
        self.stats = {"requests": 0, "batches": 0, "flushes_full": 0,
                      "flushes_deadline": 0, "padded_slots": 0,
                      "compile_s": 0.0}

    # ------------------------------------------------------------- lifecycle
    async def __aenter__(self) -> "CSNNEngine":
        self._queue = asyncio.Queue()
        self._flusher = asyncio.create_task(self._flush_loop())
        return self

    async def __aexit__(self, *exc) -> None:
        await self._queue.put(_STOP)
        await self._flusher
        self._queue = self._flusher = None

    def warmup(self) -> float:
        """Compile every batch shape the engine can emit (each multiple of
        ``batch_tile`` up to ``max_batch``); returns the seconds spent so
        serving latency can be reported compile-free."""
        h, w = self.cfg.input_hw
        t0 = time.perf_counter()
        tile = self.plan.batch_tile
        for b in range(tile, self.serve_cfg.max_batch + 1, tile):
            sp = encode_input(jnp.zeros((b, h, w, 1), jnp.float32), self.cfg)
            jax.block_until_ready(self._infer(sp))
        self.stats["compile_s"] = time.perf_counter() - t0
        return self.stats["compile_s"]

    # ------------------------------------------------------------- requests
    def submit_nowait(self, image) -> "asyncio.Future":
        """Enqueue one (H, W, 1) image; returns a future of its logits."""
        if self._queue is None:
            raise RuntimeError("engine is not running (use `async with`)")
        fut = asyncio.get_running_loop().create_future()
        self._queue.put_nowait((jnp.asarray(image), fut))
        self.stats["requests"] += 1
        return fut

    async def submit(self, image) -> np.ndarray:
        """Enqueue one (H, W, 1) image and await its (n_classes,) logits."""
        return await self.submit_nowait(image)

    def run_requests(self, images) -> np.ndarray:
        """Synchronous convenience: serve a request list through the
        engine's own batching loop; returns stacked (N, n_classes) logits."""

        async def _drive():
            async with self:
                futs = [self.submit_nowait(img) for img in images]
                return await asyncio.gather(*futs)

        return np.stack(asyncio.run(_drive()))

    # ------------------------------------------------------------- batching
    async def _flush_loop(self) -> None:
        loop = asyncio.get_running_loop()
        max_batch = self.serve_cfg.max_batch
        delay = self.serve_cfg.max_delay_ms / 1e3
        stopping = False
        while not stopping:
            first = await self._queue.get()
            if first is _STOP:
                return
            batch, deadline = [first], loop.time() + delay
            while len(batch) < max_batch:
                timeout = deadline - loop.time()
                if timeout <= 0:
                    break
                try:
                    nxt = await asyncio.wait_for(self._queue.get(), timeout)
                except asyncio.TimeoutError:
                    break
                if nxt is _STOP:
                    stopping = True
                    break
                batch.append(nxt)
            self.stats["flushes_full" if len(batch) >= max_batch
                       else "flushes_deadline"] += 1
            self._run_batch(batch)

    def _run_batch(self, batch: list) -> None:
        """Pad to the plan's batch tile, run the planned pipeline once,
        resolve every request future."""
        n = len(batch)
        tile = self.plan.batch_tile
        padded = -(-n // tile) * tile
        imgs = jnp.stack([img for img, _ in batch])
        if padded > n:  # zero images spike nowhere; pure pad slots
            imgs = jnp.concatenate(
                [imgs, jnp.zeros((padded - n,) + imgs.shape[1:], imgs.dtype)])
        logits = np.asarray(jax.block_until_ready(
            self._infer(encode_input(imgs, self.cfg))))
        self.stats["batches"] += 1
        self.stats["padded_slots"] += padded - n
        for i, (_, fut) in enumerate(batch):
            if not fut.done():
                fut.set_result(logits[i])
