"""Async micro-batching + continuous-batching engine for event-driven
CSNN inference.

Serving shape of the paper workload: requests (single images) arrive one
at a time; the batched event pipeline (``snn_apply_batched``) only pays
off when many samples share one fused queue compaction and one conv-unit
launch per (t, c_in, channel-block) step.  The engine bridges the two
with two scheduling modes:

**Micro-batching (default)** — ``submit`` enqueues a request and awaits
its logits; a background flusher collects requests and flushes a
micro-batch when either ``max_batch`` requests are pending (size flush)
or the oldest request has waited ``max_delay_ms`` (deadline flush) — the
standard batch/deadline threshold from LLM serving, applied to spike
streams.  Partial batches are padded with zero images up to the plan's
``batch_tile`` multiple, so the jitted pipeline only ever sees a small
fixed set of batch shapes.  Each flush runs to completion: a request
arriving just after a flush starts waits out the whole T-step pipeline.

**Continuous batching (``CSNNServeConfig(continuous=True)``)** — the
serving analogue of the paper's self-timed scheduling, where PEs are
never idle waiting for a frame boundary.  The engine owns a fixed table
of ``slots`` batch rows and one shared :class:`~repro.core.csnn.CSNNState`
carry; the device advances every row by ``t_chunk`` time steps per call
(``snn_step_chunk``).  Between chunks, slots whose request has consumed
all T steps are read out (``snn_readout``), their futures resolve, and
the freed rows are re-zeroed and refilled with newly arrived requests —
mid-flight, without waiting for the other slots.  The host encodes newly
arrived images while the device executes the current chunk
(``jax.block_until_ready`` only happens on readout, never on the
admission path).  Per-request results are bit-exact vs the
run-to-completion engine: state rows are per-sample independent, so a
request sees exactly the same T-step computation whichever slots its
neighbours occupy (tests/test_continuous.py).

**Streaming DVS ingestion (``CSNNServeConfig(stream=True)``, continuous
mode only)** — requests are raw DVS event streams ((N, 4) int32 rows of
(t, y, x, polarity)) instead of images.  Host-side admission becomes a
cheap bank append (``data.dvs.events_to_banks``: one vectorized scatter
into the interlace-column layout) instead of a jitted multi-threshold
encode, and each device chunk receives a
:class:`~repro.core.aeq.StreamState` window whose input queues are
finalized sort-free on device (``aeq.stream_queues``) — no dense frame,
no per-frame sort anywhere on the admission path.  Logits are bit-exact
vs binning the same events into frames and serving those
(tests/test_streaming.py).

Every batch/chunk shape can be pre-compiled with ``warmup()`` so
steady-state latency never includes a retrace.  Observability lives in
``engine.stats`` (flush reasons, padded slots, chunk counts, slot
occupancy, admission waits) — tests/test_serve_csnn.py pins the flush
semantics, tests/test_continuous.py the refill semantics.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aeq import StreamState
from repro.core.csnn import (CSNNConfig, ConvSpec, encode_input, init_state,
                             snn_apply_batched, snn_readout, snn_step_chunk)
from repro.core.plan import NetworkPlan, plan_network, snap_t_chunk
from repro.data.dvs import events_to_banks

_STOP = object()


def _n_classes(cfg: CSNNConfig) -> int:
    heads = [s for s in cfg.layers if not isinstance(s, ConvSpec)]
    if not heads:
        raise ValueError("cfg has no FC head layer")
    return heads[-1].features


def _reset_rows(state, mask: jax.Array):
    """Zero every state leaf's rows where ``mask`` (B,) is True — used to
    recycle retired/newly-admitted slots without touching in-flight ones."""
    def zero_rows(leaf):
        m = mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))
        return jnp.where(m, jnp.zeros((), leaf.dtype), leaf)
    return jax.tree_util.tree_map(zero_rows, state)


@dataclasses.dataclass
class CSNNServeConfig:
    max_batch: int = 8          # size-flush threshold (requests per batch)
    max_delay_ms: float = 10.0  # flush deadline (micro-batching) / admission
                                # -wait SLO counted as a deadline miss
                                # (continuous)
    continuous: bool = False    # slot-level refill instead of run-to-completion
    slots: int = 0              # continuous slot-table size (0 = max_batch)
    t_chunk: int = 0            # refill granularity in time steps
                                # (0 = plan.t_chunk, else 1; snapped to a
                                # divisor of T)
    stream: bool = False        # requests are raw DVS event streams (N, 4)
                                # admitted by bank append, not images
                                # (continuous mode only)


class CSNNEngine:
    """Micro/continuous-batching front-end over the planned event pipeline.

    Use as an async context manager::

        engine = CSNNEngine(params, cfg, plan)
        async with engine:
            logits = await engine.submit(image)   # (H, W, C) -> (n_classes,)

    or drive a whole request list synchronously with ``run_requests``.
    ``CSNNServeConfig(continuous=True)`` switches the background loop to
    slot-level refill (see module docstring); submit/await semantics are
    identical and per-request logits are bit-exact across modes.
    """

    def __init__(self, params: dict, cfg: CSNNConfig,
                 plan: Optional[NetworkPlan] = None,
                 serve_cfg: Optional[CSNNServeConfig] = None, *,
                 backend: str = "jax", tune: str = "analytic"):
        # a fresh default per engine: a shared CSNNServeConfig() default
        # instance would alias mutable serving knobs across engines
        if serve_cfg is None:
            serve_cfg = CSNNServeConfig()
        self.cfg = cfg
        # tuning (measured micro-benchmarks or a plan-cache load) happens
        # HERE, at engine construction — i.e. at warmup, never on the
        # request hot path; an explicit plan always wins over `tune`
        self.plan = plan if plan is not None else plan_network(
            cfg, batch_tile=serve_cfg.max_batch, tune=tune)
        self.serve_cfg = serve_cfg
        if serve_cfg.stream and not serve_cfg.continuous:
            raise ValueError(
                "CSNNServeConfig(stream=True) requires continuous=True — "
                "streaming admission rides the slot-level refill loop")
        if (not serve_cfg.continuous
                and serve_cfg.max_batch % self.plan.batch_tile != 0):
            # continuous mode never tile-pads: its batch shape is the slot
            # table, so the micro-batching alignment rule does not apply
            raise ValueError(
                f"max_batch={serve_cfg.max_batch} must be a multiple of the "
                f"plan's batch_tile={self.plan.batch_tile}")
        self._params = params
        self._infer = jax.jit(lambda sp: snn_apply_batched(
            params, sp, cfg, self.plan, collect_stats=False, backend=backend))
        # jitted per-shape: eager multi-threshold encoding costs tens of ms
        # per request, which would dominate the admission path
        self._encode = jax.jit(lambda im: encode_input(im, cfg))
        self._queue: Optional[asyncio.Queue] = None
        self._flusher: Optional[asyncio.Task] = None
        self._inflight: set = set()  # unresolved request futures
        self.stats = {"requests": 0, "batches": 0, "flushes_full": 0,
                      "flushes_deadline": 0, "flushes_stop": 0,
                      "padded_slots": 0, "compile_s": 0.0,
                      # continuous-mode slot table observability
                      "chunks": 0, "admitted": 0, "retired": 0, "refills": 0,
                      "slot_steps_busy": 0, "slot_steps_total": 0,
                      "wait_ms_max": 0.0, "deadline_misses": 0}
        if serve_cfg.continuous:
            self._slots = serve_cfg.slots or serve_cfg.max_batch
            requested = serve_cfg.t_chunk or (
                self.plan.t_chunk if self.plan.t_chunk is not None else 1)
            self._t_chunk = snap_t_chunk(cfg.t_steps, requested)
            # occupancy buckets: the chunk step is compiled once per
            # power-of-two batch size up to the slot count, and each chunk
            # packs the active slots into the smallest bucket that fits.
            # Without this, an idle slot row costs as much as an active one
            # (the dense threshold sweep and queue sort run over the whole
            # compiled batch) and slot-level refill degenerates into the
            # same waste as tile padding; with it, chunk cost scales with
            # occupancy — a lone straggler steps at bucket 1, not S.
            buckets, b = [], 1
            while b < self._slots:
                buckets.append(b)
                b *= 2
            buckets.append(self._slots)

            # one fused call per chunk and bucket: gather the active rows,
            # zero newly admitted ones, step, read the head out, scatter
            # the rows back.  Pad entries of ``idx`` are S — out of bounds,
            # so the gather clamps (harmless duplicate row, never read
            # back) and the scatter drops them.  The readout is a tiny
            # matmul riding along in the chunk's async dispatch window, so
            # retiring a slot never costs an extra dispatch+sync round
            # trip.  The full state is donated: the old carry is dead
            # after every chunk, and the refill loop is dispatch-bound on
            # CPU, so the copies would cost more than the arithmetic.
            def step_bucket(state_full, idx, sp, admit_mask):
                rows = jax.tree_util.tree_map(lambda l: l[idx], state_full)
                rows = _reset_rows(rows, admit_mask)
                rows = snn_step_chunk(params, rows, sp, cfg, self.plan,
                                      backend=backend)
                state_full = jax.tree_util.tree_map(
                    lambda lf, lb: lf.at[idx].set(lb), state_full, rows)
                # readout on the FULL slot table, not the bucket rows: the
                # head contraction must keep one fixed (slots, D) shape —
                # XLA's dot reduction order is shape-dependent, so a
                # bucket-sized readout would drift in the last bit vs the
                # run-to-completion engine (cf. snn_apply_sharded's
                # gathered head)
                logits = snn_readout(params, state_full, cfg)
                return state_full, logits

            self._buckets = buckets
            self._step = jax.jit(step_bucket, donate_argnums=0)

    @property
    def slot_utilization(self) -> float:
        """Busy slot-chunks / total slot-chunks over the engine lifetime —
        the serving analogue of the paper's PE utilization figure."""
        total = self.stats["slot_steps_total"]
        return self.stats["slot_steps_busy"] / total if total else 0.0

    # ------------------------------------------------------------- lifecycle
    async def __aenter__(self) -> "CSNNEngine":
        self._queue = asyncio.Queue()
        self._flusher = asyncio.create_task(self._run_flusher())
        return self

    async def _run_flusher(self) -> None:
        """Run the configured scheduling loop; if it dies, fail every
        in-flight future — a crashed flusher must surface as an error at
        the awaiting callers, never as a silent hang."""
        try:
            if self.serve_cfg.continuous:
                await self._continuous_loop()
            else:
                await self._flush_loop()
        except BaseException as e:
            for fut in list(self._inflight):
                if not fut.done():
                    fut.set_exception(
                        RuntimeError(f"engine flusher died: {e!r}"))
            raise

    async def __aexit__(self, *exc) -> None:
        await self._queue.put(_STOP)
        await self._flusher
        self._queue = self._flusher = None

    def warmup(self) -> float:
        """Compile every shape the engine can emit; returns the seconds
        spent so serving latency can be reported compile-free.  Batch mode
        compiles each multiple of ``batch_tile`` up to ``max_batch``;
        continuous mode compiles the chunk step, readout and slot reset at
        the fixed (slots, t_chunk) shape."""
        h, w = self.cfg.input_hw
        c = self.cfg.input_channels
        t0 = time.perf_counter()
        if self.serve_cfg.continuous:
            state = init_state(self._params, self.cfg, self.plan, self._slots)
            if not self.serve_cfg.stream:  # stream admission never encodes
                self._encode(jnp.zeros((1, h, w, c), jnp.float32))
            geom = self.plan.layers[0].geometry  # layer-0 window shapes the
            for b in self._buckets:  # one compile per occupancy bucket
                idx = np.full(b, self._slots, dtype=np.int32)  # all pads
                if self.serve_cfg.stream:  # stream banks, whatever the kxk
                    chunk = StreamState(banks=jnp.zeros(
                        (b, self._t_chunk, c, geom.n_banks,
                         -(-h // geom.kh), -(-w // geom.kw)), jnp.bool_))
                else:
                    chunk = jnp.zeros((b, self._t_chunk, h, w, c), jnp.bool_)
                state, logits = self._step(state, idx, chunk,
                                           np.zeros(b, dtype=bool))
                jax.block_until_ready(logits)
        else:
            tile = self.plan.batch_tile
            for b in range(tile, self.serve_cfg.max_batch + 1, tile):
                sp = self._encode(jnp.zeros((b, h, w, c), jnp.float32))
                jax.block_until_ready(self._infer(sp))
        self.stats["compile_s"] = time.perf_counter() - t0
        return self.stats["compile_s"]

    # ------------------------------------------------------------- requests
    def submit_nowait(self, image) -> "asyncio.Future":
        """Enqueue one (H, W, C) image; returns a future of its logits."""
        if self._queue is None:
            raise RuntimeError("engine is not running (use `async with`)")
        if self._flusher is not None and self._flusher.done():
            raise RuntimeError("engine flusher is not running (it stopped "
                               "or died); re-enter the context manager")
        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        self._inflight.add(fut)
        fut.add_done_callback(self._inflight.discard)
        self._queue.put_nowait((jnp.asarray(image), fut, loop.time()))
        self.stats["requests"] += 1
        return fut

    async def submit(self, image) -> np.ndarray:
        """Enqueue one (H, W, C) image and await its (n_classes,) logits."""
        return await self.submit_nowait(image)

    def run_requests(self, images) -> np.ndarray:
        """Synchronous convenience: serve a request list through the
        engine's own batching loop; returns stacked (N, n_classes) logits."""
        images = list(images)
        if not images:  # nothing to serve; nothing to stack either
            return np.zeros((0, _n_classes(self.cfg)), np.float32)

        async def _drive():
            async with self:
                futs = [self.submit_nowait(img) for img in images]
                return await asyncio.gather(*futs)

        return np.stack(asyncio.run(_drive()))

    # ------------------------------------------- run-to-completion batching
    async def _flush_loop(self) -> None:
        loop = asyncio.get_running_loop()
        max_batch = self.serve_cfg.max_batch
        delay = self.serve_cfg.max_delay_ms / 1e3
        stopping = False
        while not stopping:
            first = await self._queue.get()
            if first is _STOP:
                break
            batch, deadline = [first], loop.time() + delay
            while len(batch) < max_batch:
                timeout = deadline - loop.time()
                if timeout <= 0:
                    break
                try:
                    nxt = await asyncio.wait_for(self._queue.get(), timeout)
                except asyncio.TimeoutError:
                    break
                if nxt is _STOP:
                    stopping = True
                    break
                batch.append(nxt)
            if len(batch) >= max_batch:
                self.stats["flushes_full"] += 1
            elif stopping:  # stop-triggered flush, not a deadline expiry
                self.stats["flushes_stop"] += 1
            else:
                self.stats["flushes_deadline"] += 1
            self._run_batch(batch)
        # Drain on stop: requests enqueued after _STOP (submit_nowait racing
        # __aexit__) are still served instead of leaving their futures
        # hanging forever.
        leftovers = []
        while True:
            try:
                item = self._queue.get_nowait()
            except asyncio.QueueEmpty:
                break
            if item is not _STOP:
                leftovers.append(item)
        for k in range(0, len(leftovers), max_batch):
            self.stats["flushes_stop"] += 1
            self._run_batch(leftovers[k:k + max_batch])

    def _run_batch(self, batch: list) -> None:
        """Pad to the plan's batch tile, run the planned pipeline once,
        resolve every request future."""
        n = len(batch)
        tile = self.plan.batch_tile
        padded = -(-n // tile) * tile
        imgs = jnp.stack([img for img, *_ in batch])
        if padded > n:  # zero images spike nowhere; pure pad slots
            imgs = jnp.concatenate(
                [imgs, jnp.zeros((padded - n,) + imgs.shape[1:], imgs.dtype)])
        logits = np.asarray(jax.block_until_ready(
            self._infer(self._encode(imgs))))
        self.stats["batches"] += 1
        self.stats["padded_slots"] += padded - n
        for i, (_, fut, *_rest) in enumerate(batch):
            if not fut.done():
                fut.set_result(logits[i])

    # ------------------------------------------- continuous slot-level refill
    async def _continuous_loop(self) -> None:
        """Slot table + refill loop (see module docstring).

        Loop invariant: every active slot ``i`` has consumed ``slot_t[i]``
        of its T input steps and the shared ``state`` rows hold exactly
        the carry of those steps; free rows hold garbage and are re-zeroed
        at admission.  The only device sync is the readout when some slot
        finishes — dispatching the next chunk and admitting/encoding new
        arrivals never blocks on the device.
        """
        loop = asyncio.get_running_loop()
        S, tc, T = self._slots, self._t_chunk, self.cfg.t_steps
        h, w = self.cfg.input_hw
        c = self.cfg.input_channels
        geom = self.plan.layers[0].geometry  # shapes the stream bank layout
        state = init_state(self._params, self.cfg, self.plan, S)
        slot_spk = [None] * S   # per-slot (T, H, W, C) encoded inputs (host)
        slot_t = [0] * S        # input steps consumed per slot
        slot_fut = [None] * S
        active = [False] * S
        pending = []            # arrivals awaiting a free slot (lazily encoded)
        stop_seen = False

        stream = self.serve_cfg.stream

        def encoded(item):
            """Lazily encode a pending entry in place: [spk|None, img,
            fut, arrived].  The backlog is encoded in the window right
            after a chunk dispatch (host work concurrent with the
            device's async-dispatched execution); an entry admitted
            before that window pays its encode here, on demand.

            Stream mode skips the jitted threshold encode entirely: the
            payload is a raw (N, 4) event trace, scattered straight into
            the (T, C, 9, HB, WB) interlace-column banks — a single
            vectorized numpy assignment per request."""
            if item[0] is None:
                if stream:
                    item[0] = events_to_banks(
                        np.asarray(item[1]), T, (h, w), c, geometry=geom)
                else:
                    item[0] = np.asarray(
                        self._encode(jnp.asarray(item[1])[None])[0],
                        dtype=bool)
            return item[0]

        def drain_nowait():
            nonlocal stop_seen
            while True:
                try:
                    item = self._queue.get_nowait()
                except asyncio.QueueEmpty:
                    return
                if item is _STOP:
                    stop_seen = True
                else:
                    img, fut, arrived = item
                    pending.append([None, img, fut, arrived])

        while True:
            drain_nowait()
            # ---- admission: refill free slots; re-zero their state rows
            midflight = any(active[j] and slot_t[j] > 0 for j in range(S))
            admit = np.zeros(S, dtype=bool)
            now = loop.time()
            for i in range(S):
                if active[i] or not pending:
                    continue
                entry = pending.pop(0)
                spk = encoded(entry)
                _, _, fut, arrived = entry
                slot_spk[i], slot_t[i], slot_fut[i] = spk, 0, fut
                active[i], admit[i] = True, True
                wait_ms = (now - arrived) * 1e3
                self.stats["admitted"] += 1
                self.stats["wait_ms_max"] = max(self.stats["wait_ms_max"],
                                                wait_ms)
                if wait_ms > self.serve_cfg.max_delay_ms:
                    self.stats["deadline_misses"] += 1
                if midflight:  # joined while others are mid-T-step: a refill
                    self.stats["refills"] += 1
            n_active = sum(active)
            if n_active == 0:
                if stop_seen and not pending:
                    drain_nowait()  # serve submits racing __aexit__, like
                    if not pending:  # the micro-batching drain does
                        break
                    continue
                item = await self._queue.get()  # idle: wait for work or stop
                if item is _STOP:
                    stop_seen = True
                else:
                    img, fut, arrived = item
                    pending.append([None, img, fut, arrived])
                continue
            # ---- advance the active slots by one chunk, packed into the
            # smallest compiled occupancy bucket (pad rows carry idx == S:
            # clamped on gather, dropped on scatter)
            act = [i for i in range(S) if active[i]]
            b = next(bb for bb in self._buckets if bb >= n_active)
            idx = np.full(b, S, dtype=np.int32)
            chunk = np.zeros(
                (b, tc, c, geom.n_banks, -(-h // geom.kh), -(-w // geom.kw))
                if stream else (b, tc, h, w, c), dtype=bool)
            admit_b = np.zeros(b, dtype=bool)
            for j, i in enumerate(act):
                idx[j] = i
                chunk[j] = slot_spk[i][slot_t[i]:slot_t[i] + tc]
                admit_b[j] = admit[i]
            # fused gather + admit-reset + chunk step + readout + scatter,
            # async dispatch
            sp = jnp.asarray(chunk)
            if stream:
                sp = StreamState(banks=sp)
            state, logits_dev = self._step(state, idx, sp, admit_b)
            self.stats["chunks"] += 1
            self.stats["slot_steps_busy"] += n_active
            self.stats["slot_steps_total"] += b
            # ---- overlap: encode the waiting backlog on this thread while
            # the async-dispatched chunk executes on the device ...
            drain_nowait()
            for entry in pending:
                encoded(entry)
            # ... then pace the loop to the device from a worker thread so
            # the event loop keeps accepting submits during the chunk
            # (blocking here on the loop thread would batch admissions
            # into lockstep waves — the refill would be refill in name
            # only)
            await asyncio.to_thread(jax.block_until_ready, logits_dev)
            # ---- retire finished slots (the only device sync point)
            finished = []
            for i in range(S):
                if active[i]:
                    slot_t[i] += tc
                    if slot_t[i] >= T:
                        finished.append(i)
            if finished:
                logits = np.asarray(logits_dev)  # (S, n_classes), slot-indexed
                for i in finished:
                    if not slot_fut[i].done():
                        slot_fut[i].set_result(logits[i])
                    active[i] = False
                    slot_fut[i] = slot_spk[i] = None
                    self.stats["retired"] += 1
        # Failsafe: anything that slipped in after the final drain check is
        # failed explicitly so no future ever hangs (the drain above makes
        # this window practically unreachable).
        drain_nowait()
        for _, _, fut, _ in pending:
            if not fut.done():
                fut.set_exception(RuntimeError("engine stopped"))
