"""Batched serving engine: prefill + decode over any registry model.

Static-batch continuous decoding: requests are padded to a common prompt
length, prefilled once, then decoded step-by-step with per-request EOS
masking; finished slots stop contributing (their tokens are frozen).
Greedy or temperature sampling.  The decode step is jit-compiled once and
reused for every step — the production decode loop is exactly this plus
slot refill.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 = greedy
    eos_id: Optional[int] = None


class Engine:
    def __init__(self, model, params, max_seq: int,
                 cfg: Optional[ServeConfig] = None):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.cfg = cfg if cfg is not None else ServeConfig()
        self._decode = jax.jit(model.decode)

    def generate(self, prompts: jax.Array, rng: jax.Array, extra: Optional[dict] = None,
                 n_new: Optional[int] = None) -> jax.Array:
        """prompts: (B, S_prompt) int32 -> (B, S_prompt + n_new) tokens."""
        b, s = prompts.shape
        n_new = n_new or self.cfg.max_new_tokens
        assert s + n_new <= self.max_seq
        batch = {"tokens": prompts, **(extra or {})}
        logits, cache = self.model.prefill(self.params, batch, max_seq=self.max_seq)
        out = prompts
        done = jnp.zeros((b,), bool)
        tok = self._sample(logits, rng)
        for i in range(n_new):
            tok = jnp.where(done, jnp.zeros_like(tok), tok)
            out = jnp.concatenate([out, tok[:, None]], axis=1)
            if self.cfg.eos_id is not None:
                done = done | (tok == self.cfg.eos_id)
            if i == n_new - 1:
                break
            pos = jnp.asarray(s + i, jnp.int32)
            if self.model.cfg.family == "vlm":
                pos = pos + self.model.cfg.n_vision_tokens
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok[:, None], "pos": pos})
            rng, k = jax.random.split(rng)
            tok = self._sample(logits, k)
        return out

    def _sample(self, logits: jax.Array, rng: jax.Array) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(rng, logits / self.cfg.temperature).astype(jnp.int32)
