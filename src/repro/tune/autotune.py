"""Measured plan autotuner: the ``plan_network(tune=...)`` engine.

Two-stage search seeded by the analytic plan (the paper's self-timed
story in software: size the datapath to the *measured* workload):

1. **Per-layer** — each conv layer is micro-benchmarked alone, on the
   input spikes the seeded synthetic trace actually produces at that
   depth (``measure.propagate_inputs``), across the candidate
   (block_e, event_par, variant) tuples from ``candidates``.  Median-of-k
   AOT-compiled timings; ties break on candidate order, so selection is
   deterministic given the timings.
2. **Network-level** — with the per-layer winners pinned, whole-pipeline
   candidates toggle the knobs that couple layers: shared vs per-layer
   capacity sizing and the t_chunk ladder; for ingesting plans a final
   head-to-head ranks the streamed-queue finalization
   (``stream_finalize`` ranks vs sort).

Every winner is cross-checked against the HLO roofline model
(``crosscheck``) and logged when measurement disagrees with the model —
measured tuning exists precisely because the analytic prior mis-ranks
some backends.  Winners persist in the on-disk ``PlanCache``;
``mode="cached"`` rebuilds the plan from the stored knobs and re-audits
it (fixed-point + ``NetworkPlan.validate`` + ``repro.analysis``
contracts) before trusting it, falling back to measuring on any miss or
rejection.  Tuning is a pure scheduling choice: every candidate is
bit-exact, so the tuned plan's results are identical to the analytic
plan's — only the time changes.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional

from repro.core.aeq import calibrate_capacities
from repro.core.plan import NetworkPlan, plan_conv_layer, plan_network

from . import candidates as cand
from . import measure
from .cache import PlanCache, cache_key, env_descriptor, geometry_descriptor
from .crosscheck import log_deviation, model_microseconds

log = logging.getLogger("repro.tune")


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """Knobs of the tuning run itself (never of the tuned plan)."""

    seed: int = 0               # synthetic trace + params seed
    density: float = 0.15       # input Bernoulli spike density
    warmup: int = 1             # untimed runs per candidate
    iters: int = 3              # timed runs per candidate (median)
    batch: Optional[int] = None  # measurement batch; None = base batch_tile
    backend: str = "jax"        # scheduler backend the winners target
    include_pallas: Optional[bool] = None  # None = only off interpret mode
    max_block_candidates: int = 4
    deviation_factor: float = 4.0  # measured-vs-roofline log threshold


def plan_from_winners(cfg, base: dict, winners: dict) -> NetworkPlan:
    """Rebuild a plan from stored winner knobs, refusing stale entries.

    The stored knobs go back through ``plan_network`` (so every snapping
    rule and validation applies exactly as for a constructed plan) and
    the result must reproduce the recorded resolved values — a cache
    entry written under older snapping rules fails the fixed-point check
    instead of silently executing a different schedule.  The rebuilt plan
    is then validated against ``cfg`` and run through the full
    ``repro.analysis`` contract auditor: cache-loaded plans meet the same
    proof obligations as constructed ones.
    """
    kw = dict(base)
    kw.update(stats=None,
              capacity=winners["capacity"],
              per_layer=winners["per_layer"],
              t_chunk=winners["t_chunk"],
              stream_finalize=winners.get("stream_finalize"),
              block_e=[la["block_e"] for la in winners["layers"]],
              event_par=[la["event_par"] for la in winners["layers"]],
              variant=[la["variant"] for la in winners["layers"]])
    plan = plan_network(cfg, **kw)
    resolved = winners.get("resolved")
    if not resolved or len(resolved) != len(plan.layers):
        raise ValueError(
            f"cache entry records {len(resolved or [])} resolved layers, "
            f"plan has {len(plan.layers)}")
    for lp, rec in zip(plan.layers, resolved):
        got = dict(capacity=lp.capacity, block_e=lp.block_e,
                   event_par=lp.event_par, queue_depth=lp.queue_depth)
        want = {k: rec.get(k) for k in got}
        if got != want:
            raise ValueError(
                f"stale cache entry: {lp.name} rebuilds to {got}, entry "
                f"recorded {want} (snapping rules changed since it was "
                f"written)")
    plan.validate(cfg)
    from repro.analysis.contracts import audit_plan
    rep = audit_plan(plan, cfg, case="plan-cache")
    if not rep.ok:
        raise ValueError("cached plan fails the contract audit: "
                         + "; ".join(str(f) for f in rep.findings))
    return plan


def _candidate_layer_plan(lp, c: cand.Candidate, *, per_layer: bool,
                          batch_tile: int, vmem_budget: Optional[int]):
    """One layer's plan under candidate knobs — built through
    ``plan_conv_layer`` so block_e snapping matches the real planner."""
    return plan_conv_layer(
        lp.index, lp.name, lp.in_hw, lp.c_in, lp.c_out,
        capacity=lp.capacity, pool=lp.pool, channel_block=lp.channel_block,
        block_e=c.block_e, sat_bits=lp.sat_bits, per_layer=per_layer,
        batch_tile=batch_tile, vmem_budget=vmem_budget,
        event_par=c.event_par, variant=c.variant, geometry=lp.geometry)


def _measure_and_pick(cfg, base: dict, config: TuneConfig,
                      geom: dict, env: dict) -> tuple[NetworkPlan, dict]:
    batch = config.batch or max(base.get("batch_tile") or 1, 1)
    include_pallas = (config.include_pallas
                      if config.include_pallas is not None
                      else cand.default_include_pallas())
    vmem_budget = base.get("vmem_budget")
    per_layer0 = bool(base.get("per_layer", True))

    plan0 = plan_network(cfg, **base)
    params = measure.synth_params(cfg, config.seed)
    x0 = measure.synth_spikes(cfg, batch, config.seed, config.density)
    inputs, counts = measure.propagate_inputs(params, cfg, plan0, x0,
                                              backend=config.backend)
    occupancy = calibrate_capacities(counts)

    conv_keys = [f"conv{lp.index}" for lp in plan0.layers]
    measured: dict[str, float] = {}
    modelled: dict[str, float] = {}

    # -------- stage 1: per-layer (block_e, event_par, variant) ----------
    layer_winners = []
    for ci, lp in enumerate(plan0.layers):
        p = params[conv_keys[ci]]
        ranked = []
        for c in cand.layer_candidates(
                lp, batch_tile=batch, vmem_budget=vmem_budget,
                include_pallas=include_pallas,
                max_block_candidates=config.max_block_candidates):
            lp_c = _candidate_layer_plan(lp, c, per_layer=per_layer0,
                                         batch_tile=batch,
                                         vmem_budget=vmem_budget)
            us, hlo = measure.measure_layer(
                lp_c, inputs[ci], p["w"], p["b"], cfg.v_t,
                backend=config.backend, warmup=config.warmup,
                iters=config.iters)
            model_us = model_microseconds(hlo)
            ranked.append((us, model_us, c, lp_c))
            measured[f"{lp.name}/{c.label()}"] = us
            modelled[f"{lp.name}/{c.label()}"] = model_us
        ranked.sort(key=lambda r: r[0])
        log_deviation(lp.name, [(c.label(), us, m) for us, m, c, _ in ranked],
                      deviation_factor=config.deviation_factor)
        us, _, c, lp_c = ranked[0]
        log.info("tune[%s]: winner %s (%.1f us)", lp.name, c.label(), us)
        layer_winners.append((c, lp_c))

    winner_kw = dict(
        block_e=[lp_c.block_e for _, lp_c in layer_winners],
        event_par=[lp_c.event_par for _, lp_c in layer_winners],
        variant=[c.variant for c, _ in layer_winners])

    # -------- stage 2: network-level (capacity sharing, t_chunk) --------
    from repro.analysis.contracts import audit_plan
    best_net, best_us = None, None
    for i, nc in enumerate(cand.network_candidates(cfg, base)):
        plan_c = plan_network(cfg, **{**base, **winner_kw, **nc})
        label = f"per_layer={nc['per_layer']}/t_chunk={nc['t_chunk']}"
        # a candidate the contract auditor rejects could never be loaded
        # back from the cache (plan_from_winners re-audits) — skip it
        # before spending measurement time.  Candidate 0 is the caller's
        # own base config and is never skipped: if it fails the audit the
        # final plan_from_winners raises the real error.
        if i > 0 and not audit_plan(plan_c, cfg,
                                    case="tune-candidate").ok:
            log.info("tune[network]: %s fails the contract audit; skipped",
                     label)
            continue
        us, hlo = measure.measure_network(
            params, x0, cfg, plan_c, backend=config.backend,
            warmup=config.warmup, iters=config.iters)
        measured[f"network/{label}"] = us
        modelled[f"network/{label}"] = model_microseconds(hlo)
        if best_us is None or us < best_us:
            best_net, best_us = nc, us
    log.info("tune[network]: winner per_layer=%s t_chunk=%s (%.1f us)",
             best_net["per_layer"], best_net["t_chunk"], best_us)

    # -------- stage 3: streamed-queue finalization (ingest plans) -------
    stream_finalize = base.get("stream_finalize")
    if base.get("ingest") or base.get("ingest_capacity") is not None:
        ranked = []
        for fin in ("ranks", "sort"):
            plan_c = plan_network(cfg, **{**base, **winner_kw, **best_net,
                                          "stream_finalize": fin})
            lp0 = plan_c.layers[0]
            tc = plan_c.chunk_steps
            frames = x0[:, :tc].transpose(0, 1, 4, 2, 3)  # (B, t, C, H, W)
            p = params[conv_keys[0]]
            us, _ = measure.measure_streamed(
                lp0, frames, p["w"], p["b"], cfg.v_t,
                backend=config.backend, warmup=config.warmup,
                iters=config.iters)
            measured[f"stream_finalize/{fin}"] = us
            ranked.append((us, fin))
        ranked.sort()
        stream_finalize = ranked[0][1]
        log.info("tune[stream]: finalize winner %r (%.1f us)",
                 stream_finalize, ranked[0][0])

    final = plan_network(cfg, **{**base, **winner_kw, **best_net,
                                 "stream_finalize": stream_finalize})
    winners = {
        "capacity": (list(base["capacity"])
                     if isinstance(base["capacity"], (list, tuple))
                     else base["capacity"]),
        "per_layer": best_net["per_layer"],
        "t_chunk": best_net["t_chunk"],
        "stream_finalize": stream_finalize,
        "layers": [{"block_e": lp.block_e, "event_par": lp.event_par,
                    "variant": lp.variant} for lp in final.layers],
        "resolved": [{"capacity": lp.capacity, "block_e": lp.block_e,
                      "event_par": lp.event_par,
                      "queue_depth": lp.queue_depth}
                     for lp in final.layers],
    }
    entry = {"geometry": geom, "env": env, "winners": winners,
             "occupancy_capacities": occupancy,
             "measured_us": {k: round(v, 2) for k, v in measured.items()},
             "model_us": {k: round(v, 2) for k, v in modelled.items()}}
    return final, entry


def tune_network(cfg, *, mode: str, base: dict,
                 config: Optional[TuneConfig] = None,
                 cache_path=None) -> NetworkPlan:
    """Entry point behind ``plan_network(tune="measured"|"cached")``.

    ``base`` is the caller's full analytic-planning kwargs; ``mode``
    "cached" tries the on-disk cache first (any miss, stale entry, or
    audit failure falls back to measuring), "measured" always measures.
    Both persist the winners, so a measured run warms the cache for every
    later ``tune="cached"`` call with the same geometry and environment.
    """
    if mode not in ("measured", "cached"):
        raise ValueError(f"mode={mode!r} must be 'measured' or 'cached'")
    config = config if config is not None else TuneConfig()
    base = dict(base)
    if base.get("stats") is not None:
        # resolve calibration arrays to explicit capacities up front: the
        # cache key must fingerprint the resolved request, and two runs
        # with different calibration data must not collide
        base["capacity"] = calibrate_capacities(
            base["stats"], percentile=base.get("percentile", 99.9),
            margin=base.get("margin", 1.25))
        base["stats"] = None
    geom = geometry_descriptor(cfg, base)
    env = env_descriptor(config.backend, base.get("sat_bits"))
    key = cache_key(geom, env)
    cache = PlanCache(cache_path)
    if mode == "cached":
        entry = cache.get(key)
        if entry is not None:
            try:
                return plan_from_winners(cfg, base, entry["winners"])
            except (KeyError, TypeError, ValueError) as e:
                log.warning("plan cache entry %s rejected (%s); "
                            "re-measuring", key[:12], e)
        else:
            log.info("plan cache miss for %s (%s); measuring", key[:12],
                     cache.path)
    plan, entry = _measure_and_pick(cfg, base, config, geom, env)
    cache.put(key, entry)
    # round-trip through the winners record: proves at write time that
    # the entry rebuilds to this exact plan (the cached path's contract)
    return plan_from_winners(cfg, base, entry["winners"])
