"""Micro-benchmark primitives for the measured autotuner.

Every timed call goes through :func:`time_compiled`, which AOT-compiles
the candidate (``jit -> lower -> compile``) so timings see the steady
state, never jit dispatch or tracing, and returns the optimized HLO text
alongside the median so the roofline cross-check costs nothing extra.
The module-level ``_MEASUREMENT_RUNS`` counter increments once per
compiled-executable invocation (warmup included) — tests assert it stays
unchanged across a cache hit, which is the proof that ``tune="cached"``
never touches the timing path.

Candidate inputs come from :func:`propagate_inputs`: a seeded Bernoulli
spike train is pushed through the analytic plan layer by layer, so every
layer is measured on *its own* real input distribution (the calibrated
occupancy the AEQ capacities were sized for), not on a made-up density.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.aeq import StreamState, interlace
from repro.core.csnn import ConvSpec, init_params
from repro.core.scheduler import (init_conv_carry, run_conv_layer_batched_chunk,
                                  run_conv_layer_batched_chunk_streamed)

_MEASUREMENT_RUNS = 0


def measurement_runs() -> int:
    """Total compiled-candidate invocations this process has timed."""
    return _MEASUREMENT_RUNS


def time_compiled(fn, args: tuple, *, warmup: int = 1,
                  iters: int = 3) -> tuple[float, str]:
    """AOT-compile ``fn(*args)`` and return (median microseconds, HLO text).

    Median of ``iters`` timed runs after ``warmup`` untimed-but-counted
    ones; ties in downstream argmins break on candidate order, so given
    identical timings selection is deterministic.
    """
    global _MEASUREMENT_RUNS
    compiled = jax.jit(fn).lower(*args).compile()
    hlo = compiled.as_text()
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(compiled(*args))
        _MEASUREMENT_RUNS += 1
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        times.append(time.perf_counter() - t0)
        _MEASUREMENT_RUNS += 1
    return float(np.median(times)) * 1e6, hlo


def synth_params(cfg, seed: int = 0) -> dict:
    """Seeded random float32 params for the candidate runs (the tuner has
    no trained weights and does not need them — every candidate is
    bit-exact, so only the schedule's cost is being measured)."""
    return init_params(jax.random.PRNGKey(seed), cfg)


def synth_spikes(cfg, batch: int, seed: int = 0,
                 density: float = 0.15) -> jax.Array:
    """Seeded (B, T, H, W, C_in) Bernoulli input spike train."""
    h, w = cfg.input_hw
    return jax.random.bernoulli(
        jax.random.fold_in(jax.random.PRNGKey(seed), 1), density,
        (batch, cfg.t_steps, h, w, cfg.input_channels))


def propagate_inputs(params: dict, cfg, plan, x0: jax.Array, *,
                     backend: str = "jax") -> tuple[list, list]:
    """Run the analytic plan once to collect each conv layer's input.

    Returns (per-layer input spike chunks [(B, T, H, W, C), ...],
    per-layer LayerStats).  The stats are the same occupancy evidence
    ``aeq.calibrate_capacities`` consumes — deeper layers are measured at
    the spike rates the network actually produces from the seeded input,
    not at the input density.
    """
    inputs, stats, x, ci = [], [], x0, 0
    for idx, spec in enumerate(cfg.layers):
        if not isinstance(spec, ConvSpec):
            continue
        inputs.append(x)
        p = params[f"conv{idx}"]
        lp = plan.layers[ci]
        carry = init_conv_carry(lp, x.shape[0])
        x, _, st = run_conv_layer_batched_chunk(
            x, p["w"], p["b"], cfg.v_t, lp, carry, backend=backend)
        stats.append(jax.device_get(st.in_spike_counts))
        ci += 1
    return inputs, stats


def measure_layer(lp, spikes_in: jax.Array, w: jax.Array, b: jax.Array,
                  v_t, *, backend: str = "jax", warmup: int = 1,
                  iters: int = 3) -> tuple[float, str]:
    """Median microseconds + HLO for one candidate layer plan on one
    chunk of real inputs (the unit the per-layer search ranks)."""
    carry = init_conv_carry(lp, spikes_in.shape[0])

    def run(x, c):
        out, c2, _ = run_conv_layer_batched_chunk(
            x, w, b, v_t, lp, c, backend=backend)
        return out, c2.vm, c2.fired

    return time_compiled(run, (spikes_in, carry), warmup=warmup, iters=iters)


def measure_streamed(lp, frames: jax.Array, w: jax.Array, b: jax.Array,
                     v_t, *, backend: str = "jax", warmup: int = 1,
                     iters: int = 3) -> tuple[float, str]:
    """Median microseconds for the *streamed* layer-0 chunk step on a
    synthetic ingestion state holding ``frames`` (B, t, C, H, W) — the
    unit that ranks ``stream_finalize`` candidates."""
    stream = StreamState(banks=interlace(frames))
    carry = init_conv_carry(lp, frames.shape[0])

    def run(s, c):
        out, c2, _ = run_conv_layer_batched_chunk_streamed(
            s, w, b, v_t, lp, c, backend=backend)
        return out, c2.vm, c2.fired

    return time_compiled(run, (stream, carry), warmup=warmup, iters=iters)


def measure_network(params: dict, x0: jax.Array, cfg, plan, *,
                    backend: str = "jax", warmup: int = 1,
                    iters: int = 3) -> tuple[float, str]:
    """Median microseconds for the whole batched pipeline under ``plan``
    (the unit that ranks the network-level knobs: capacity sharing and
    t_chunk)."""
    from repro.core.csnn import snn_apply_batched

    def run(p, x):
        return snn_apply_batched(p, x, cfg, plan, collect_stats=False,
                                 backend=backend)

    return time_compiled(run, (params, x0), warmup=warmup, iters=iters)
