"""Measured plan autotuner with a persistent on-disk plan cache.

The software analogue of the paper's self-timed hardware sizing: instead
of trusting the analytic VMEM model (which demonstrably mis-tunes
off-TPU), candidate ``(block_e, event_par, t_chunk, kernel-variant)``
tuples are micro-benchmarked on seeded synthetic queues at calibrated
occupancy and the *measured* winners drive the plan.  Winners persist in
a versioned JSON cache keyed by (layer geometry + planning knobs, vm
dtype, backend, device kind, jax version) — ``REPRO_PLAN_CACHE``
overrides the location — and cache-loaded plans are re-audited
(fixed-point rebuild + ``NetworkPlan.validate`` + ``repro.analysis``
contracts) before they are trusted.

Use through ``plan_network(cfg, tune="measured")`` (always measure, warm
the cache) or ``tune="cached"`` (load winners; measure only on a miss);
``CSNNEngine(tune=...)`` and ``launch/serve.py --tune`` thread the same
knob through serving, where tuning runs at warmup and never on the hot
path.  Tuning is bit-exact by construction: every candidate is a valid
schedule of the same computation, so only wall-clock changes.
"""
from .autotune import TuneConfig, plan_from_winners, tune_network
from .cache import (CACHE_VERSION, PlanCache, cache_key, default_cache_path,
                    env_descriptor, geometry_descriptor)
from .measure import measurement_runs

__all__ = [
    "CACHE_VERSION",
    "PlanCache",
    "TuneConfig",
    "cache_key",
    "default_cache_path",
    "env_descriptor",
    "geometry_descriptor",
    "measurement_runs",
    "plan_from_winners",
    "tune_network",
]
