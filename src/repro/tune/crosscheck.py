"""Roofline cross-check of measured winners against the analytic model.

Every candidate's AOT-compiled HLO is costed through
``launch.hlo_cost.HloCostModel`` (trip-count-exact FLOPs/bytes) and
turned into a roofline time (``max(flops/PEAK_FLOPS, bytes/HBM_BW)``).
When the measured winner is not the model's pick — or the measured and
modelled times disagree by more than ``deviation_factor`` both ways —
the tuner logs it on the ``repro.tune`` logger.  The log line is the
design feedback loop: a systematic deviation on some backend means the
analytic VMEM/roofline priors mis-model that backend (exactly the
``planned_per_layer`` 0.89x story that motivated measuring at all), and
the priors should be revisited rather than silently out-voted forever.
"""
from __future__ import annotations

import logging

log = logging.getLogger("repro.tune")


def model_microseconds(hlo_text: str) -> float:
    """Roofline time of one compiled candidate, in microseconds."""
    from repro.launch.hlo_cost import HloCostModel
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS
    cost = HloCostModel(hlo_text, 1).entry_cost()
    return max(cost.flops / PEAK_FLOPS, cost.bytes / HBM_BW) * 1e6


def log_deviation(where: str, ranked: list, *,
                  deviation_factor: float = 4.0) -> None:
    """``ranked``: [(label, measured_us, model_us), ...] sorted by
    measured time; element 0 is the winner.  Logs when measurement and
    model disagree on the ranking or on the winner's magnitude."""
    if not ranked:
        return
    label, us, model_us = ranked[0]
    by_model = min(ranked, key=lambda r: r[2])
    if by_model[0] != label:
        log.info(
            "tune[%s]: measured winner %s (%.1f us) != model pick %s "
            "(model %.1f us vs %.1f us) — analytic prior mis-ranks this "
            "backend", where, label, us, by_model[0], model_us, by_model[2])
    if model_us > 0 and not (1 / deviation_factor
                             <= us / model_us <= deviation_factor):
        log.info(
            "tune[%s]: winner %s measured %.1f us vs %.1f us modelled "
            "(x%.2f) — outside the %.0fx roofline envelope for this "
            "device", where, label, us, model_us, us / model_us,
            deviation_factor)
