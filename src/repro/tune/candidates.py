"""Candidate generation for the measured autotuner.

The search space is seeded from the analytic priors, not enumerated: per
layer a handful of ``block_e`` values around the VMEM model's pick drive
the sequential variant, the banked-jax variant contributes one candidate
(it ignores ``block_e``/``event_par`` numerically — the bank masks are
applied whole-column), and the interlaced-pallas variant one per
autotuned parallel width — but only where the Pallas kernels actually
compile to machine code (``include_pallas``); under interpret-mode
emulation they lose by construction and measuring them is wasted time.
Network-level knobs (shared vs per-layer capacity, t_chunk, and
stream_finalize for ingesting plans) are generated separately because
they change every layer at once.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

from repro.core.plan import LayerPlan, snap_t_chunk
from repro.kernels.event_conv.ops import (autotune_event_par,
                                          candidate_block_es)


class Candidate(NamedTuple):
    """One per-layer knob tuple the tuner measures."""
    block_e: Optional[int]   # None = analytic autotune inside plan_conv_layer
    event_par: int
    variant: str             # one of plan.KERNEL_VARIANTS

    def label(self) -> str:
        be = "auto" if self.block_e is None else str(self.block_e)
        return f"{self.variant}/be={be}/ep={self.event_par}"


def default_include_pallas() -> bool:
    """Pallas candidates are only worth measuring where the kernels run
    compiled; under interpret-mode emulation (the CPU default) they are
    a pure-python simulation and always lose."""
    from repro.kernels.runtime import resolve_interpret
    return not resolve_interpret(None)


def layer_candidates(lp: LayerPlan, *, batch_tile: int,
                     vmem_budget: Optional[int] = None,
                     include_pallas: bool = False,
                     max_block_candidates: int = 4) -> list[Candidate]:
    """Candidate (block_e, event_par, variant) tuples for one layer."""
    vm_bytes = {None: 4, 8: 1, 16: 2}[lp.sat_bits]
    vm_tile = (max(batch_tile, 1),) + lp.vm_tile
    kw = {"vmem_budget": vmem_budget} if vmem_budget else {}
    bes = candidate_block_es(lp.capacity, vm_tile, vm_bytes=vm_bytes, **kw)
    cands = [Candidate(be, 1, "sequential")
             for be in bes[:max(max_block_candidates, 1)]]
    cands.append(Candidate(None, max(lp.event_par, 1), "banked-jax"))
    # fused-handoff skips the dense round trip between layers entirely —
    # like banked-jax it ignores block_e/event_par, so one candidate
    cands.append(Candidate(None, 1, "fused-handoff"))
    if include_pallas:
        ep = (lp.event_par if lp.event_par > 1
              else autotune_event_par(lp.capacity, vm_tile,
                                      vm_bytes=vm_bytes,
                                      geometry=lp.geometry, **kw))
        if ep > 1:
            cands.append(Candidate(None, ep, "interlaced-pallas"))
    return cands


def network_candidates(cfg, base: dict) -> list[dict]:
    """Network-level override dicts measured with the per-layer winners
    fixed: both capacity-sharing modes x a small t_chunk ladder (the
    caller's choice, monolithic, and half-T).  The base configuration is
    always candidate 0, so with flat timings the tuner is a no-op."""
    t = cfg.t_steps
    chunks = []
    for tc in (base.get("t_chunk"), None,
               snap_t_chunk(t, max(1, t // 2)) if t > 1 else None):
        if tc not in chunks:
            chunks.append(tc)
    base_pl = bool(base.get("per_layer", True))
    out = []
    for per_layer in (base_pl, not base_pl):
        for tc in chunks:
            out.append({"per_layer": per_layer, "t_chunk": tc})
    return out
