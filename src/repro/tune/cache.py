"""Versioned on-disk plan cache for the measured autotuner.

One JSON file holds every tuned network this machine has measured,
keyed by a digest of (layer geometry + planning knobs, vm dtype,
requested backend, XLA backend, device kind, jax version) — the exact
set of inputs that can change which schedule wins.  Location resolves
``cache_path`` arg > ``REPRO_PLAN_CACHE`` env var >
``~/.cache/repro/plan_cache.json``.

Entries store the *winning knobs* (block_e / event_par / variant per
layer, per_layer capacity sharing, t_chunk, stream_finalize), never a
pickled plan: on load the plan is rebuilt through ``plan_network`` and
must reproduce the recorded resolved values bit-for-bit (fixed-point
check), pass ``NetworkPlan.validate`` against the caller's config, and
pass the ``repro.analysis`` contract auditor — any mismatch (a stale
entry written by an older snapping rule, a hand-edited file, a corrupt
write) rejects the entry and falls back to measuring.  Writes are
atomic (tmp file + ``os.replace``) so a crashed tune never corrupts
previously cached winners.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Optional

# Bump whenever the winners schema or the knob-resolution rules change in
# a way that invalidates old entries wholesale.  v2: parametric conv
# geometry — layer fingerprints carry explicit kh/kw/stride, so winners
# measured under the hardwired-3x3 schema can never be replayed onto a
# plan with a different window.  v3: the fused-handoff kernel variant
# joined the candidate axis and the None stream_finalize default became
# fmap-size-dependent — winners measured without the fused candidate (or
# recorded under the old always-"ranks" default) are stale.
CACHE_VERSION = 3

ENV_VAR = "REPRO_PLAN_CACHE"
_DEFAULT = "~/.cache/repro/plan_cache.json"


def default_cache_path() -> Path:
    """``REPRO_PLAN_CACHE`` override or the per-user default location."""
    return Path(os.environ.get(ENV_VAR) or _DEFAULT).expanduser()


def geometry_descriptor(cfg, base: dict) -> dict:
    """JSON-serializable description of everything that shapes the plan
    search space: the network geometry plus the caller's planning knobs.

    ``base`` must already have ``stats`` resolved to explicit capacities
    (spike-count arrays are not serializable and two runs with different
    calibration data must not collide on one key).
    """
    from repro.core.csnn import ConvSpec
    if base.get("stats") is not None:
        raise ValueError("resolve stats to explicit capacities before "
                         "fingerprinting (arrays are not cache keys)")
    from repro.core.geometry import ConvGeometry
    layers = []
    for spec in cfg.layers:
        if isinstance(spec, ConvSpec):
            geom = ConvGeometry(spec.kernel, spec.kernel)
            layers.append({"kind": "conv", "channels": spec.channels,
                           "kernel": spec.kernel, "pool": spec.pool,
                           "kh": geom.kh, "kw": geom.kw,
                           "stride": geom.stride,
                           "n_banks": geom.n_banks})
        else:
            layers.append({"kind": "fc", "features": spec.features})

    def plain(v):
        return list(v) if isinstance(v, (list, tuple)) else v

    return {
        "input_hw": list(cfg.input_hw),
        "input_channels": cfg.input_channels,
        "t_steps": cfg.t_steps,
        "layers": layers,
        "capacity": plain(base.get("capacity")),
        "channel_block": plain(base.get("channel_block")),
        "sat_bits": base.get("sat_bits"),
        "batch_tile": base.get("batch_tile"),
        "per_layer": base.get("per_layer"),
        "fc_capacity": base.get("fc_capacity"),
        "t_chunk": base.get("t_chunk"),
        "vmem_budget": base.get("vmem_budget"),
        "ingest": bool(base.get("ingest")
                       or base.get("ingest_capacity") is not None),
        "ingest_capacity": base.get("ingest_capacity"),
    }


def env_descriptor(backend: str = "jax",
                   sat_bits: Optional[int] = None) -> dict:
    """The execution environment half of the cache key: a winner measured
    on one device kind / backend / jax version says nothing about
    another."""
    import jax
    return {
        "jax": jax.__version__,
        "xla_backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "backend": backend,
        "dtype": {None: "float32", 16: "int16", 8: "int8"}[sat_bits],
    }


def cache_key(geometry: dict, env: dict) -> str:
    """sha256 over the canonical JSON of (version, geometry, env)."""
    blob = json.dumps({"version": CACHE_VERSION, "geometry": geometry,
                       "env": env}, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class PlanCache:
    """Dict-of-entries JSON store with atomic writes and lenient reads.

    A missing, unreadable, non-JSON, or wrong-``version`` file reads as
    empty (a cache must never be able to break planning); ``get`` also
    rejects entries missing the required fields, so a truncated or
    hand-mangled entry is a miss, not a crash.
    """

    def __init__(self, path: Optional[os.PathLike | str] = None):
        self.path = Path(path) if path is not None else default_cache_path()

    def _load(self) -> dict:
        try:
            data = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return {}
        if (not isinstance(data, dict)
                or data.get("version") != CACHE_VERSION
                or not isinstance(data.get("entries"), dict)):
            return {}
        return data["entries"]

    def get(self, key: str) -> Optional[dict]:
        entry = self._load().get(key)
        if not isinstance(entry, dict):
            return None
        if not all(k in entry for k in ("geometry", "env", "winners")):
            return None  # truncated/corrupt entry == miss
        return entry

    def put(self, key: str, entry: dict) -> Path:
        entries = self._load()
        entries[key] = entry
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": CACHE_VERSION, "entries": entries},
                          f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return self.path
