"""Sharded, mesh-agnostic checkpointing (no orbax in this environment).

Layout (one directory per step, committed atomically by rename):

    ckpt_000042.tmp/ -> ckpt_000042/
        manifest.json            # treedef, per-leaf shape/dtype, step
        <leaf-path>__<shard>.npy # one file per (leaf, host-shard)

Design points for 1000+-node deployments (DESIGN.md Sec. 5):
* **Mesh-agnostic**: files store *global index bounds*, not mesh
  coordinates, so a checkpoint written on a 2x16x16 mesh restores onto
  any other factorization (elastic scaling / shrink-after-failure) —
  each restoring host reads only the byte ranges its new shards need.
* **Atomic**: a crash mid-save never corrupts the latest checkpoint;
  `latest_step` only sees fully renamed directories.
* **Keep-k GC** + preemption-time save hook (train/loop.py).

On this single-process container every process sees all shards; the
multi-host path (addressable_shards filtering) is the same code.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        out.append((name or "_root", leaf))
    return out


def _fname(leaf_name: str, bounds: tuple) -> str:
    b = "x".join(f"{lo}-{hi}" for lo, hi in bounds)
    return f"{leaf_name.replace('/', '.')}__{b}.npy"


def save(tree: Any, directory: str | os.PathLike, step: int, keep: int = 3) -> Path:
    """Save a pytree of (possibly sharded) jax arrays; returns final path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"ckpt_{step:09d}.tmp"
    final = directory / f"ckpt_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in leaves:
        arr = leaf if isinstance(leaf, jax.Array) else jnp.asarray(leaf)
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
        written = set()
        for shard in arr.addressable_shards:  # on multi-host: only local shards
            bounds = tuple(
                (idx.start or 0, idx.stop if idx.stop is not None else dim)
                for idx, dim in zip(shard.index, arr.shape)) or ((0, 1),)
            if bounds in written:
                continue  # replicated shards: write once
            written.add(bounds)
            data = np.asarray(shard.data)
            if data.dtype == jnp.bfloat16:
                data = data.view(np.uint16)  # np can't save bf16 natively
                manifest["leaves"][name]["bf16_as_u16"] = True
            np.save(tmp / _fname(name, bounds), data)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    os.replace(tmp, final)
    _gc(directory, keep)
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(m.group(1)) for p in directory.iterdir()
             if (m := re.fullmatch(r"ckpt_(\d+)", p.name))]
    return max(steps) if steps else None


def _gc(directory: Path, keep: int):
    steps = sorted(int(m.group(1)) for p in directory.iterdir()
                   if (m := re.fullmatch(r"ckpt_(\d+)", p.name)))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(directory / f"ckpt_{s:09d}", ignore_errors=True)


def _load_leaf_global(ckpt: Path, name: str, meta: dict) -> np.ndarray:
    """Assemble the full global array from shard files (byte-range reads in a
    real multi-host deployment; full read here)."""
    shape = tuple(meta["shape"])
    dtype = np.uint16 if meta.get("bf16_as_u16") else np.dtype(meta["dtype"])
    out = np.zeros(shape if shape else (1,), dtype)
    pattern = re.compile(re.escape(name.replace("/", ".")) + r"__(.+)\.npy$")
    found = False
    for f in ckpt.iterdir():
        m = pattern.fullmatch(f.name)
        if not m:
            continue
        found = True
        data = np.load(f)
        if not shape:
            return data.reshape(())
        bounds = [tuple(map(int, b.split("-"))) for b in m.group(1).split("x")]
        idx = tuple(slice(lo, hi) for lo, hi in bounds)
        out[idx] = data.reshape(out[idx].shape)
    if not found:
        raise FileNotFoundError(f"no shards for leaf {name} in {ckpt}")
    return out.reshape(shape)


def restore(template: Any, directory: str | os.PathLike, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> tuple[Any, int]:
    """Restore into the structure of ``template`` (arrays or SDS).

    ``shardings``: optional matching tree of NamedShardings for the TARGET
    mesh — this is what makes restore elastic: the global array is
    assembled and re-sliced onto whatever mesh the new job runs.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt = directory / f"ckpt_{step:09d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())
    names = [n for n, _ in _leaf_paths(template)]
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_t))
    out = []
    for name, tmpl, shd in zip(names, leaves_t, shard_leaves):
        meta = manifest["leaves"][name]
        arr = _load_leaf_global(ckpt, name, meta)
        if meta.get("bf16_as_u16"):
            arr = jax.numpy.asarray(arr).view(jnp.bfloat16)
        want_dtype = tmpl.dtype
        jarr = jnp.asarray(arr).astype(want_dtype).reshape(tmpl.shape)
        if shd is not None:
            jarr = jax.device_put(jarr, shd)
        out.append(jarr)
    return jax.tree_util.tree_unflatten(treedef, out), step
