"""Architecture registry: ``--arch <id>`` ids -> (FULL, SMOKE) configs."""
from . import (csnn_paper, csnn_wide, deepseek_v2, gemma3_1b, granite_34b,
               llama4_maverick, phi3_medium_14b, qwen2_vl_7b, rwkv6_1p6b,
               stablelm_3b, whisper_medium, zamba2_1p2b)
from .base import SHAPES, SMOKE_SHAPE, ArchConfig, ShapeConfig

ARCHS = {
    "zamba2-1.2b": zamba2_1p2b,
    "rwkv6-1.6b": rwkv6_1p6b,
    "stablelm-3b": stablelm_3b,
    "granite-34b": granite_34b,
    "phi3-medium-14b": phi3_medium_14b,
    "gemma3-1b": gemma3_1b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "whisper-medium": whisper_medium,
    "llama4-maverick-400b-a17b": llama4_maverick,
    "deepseek-v2-236b": deepseek_v2,
}

# (arch, shape) cells skipped at dry-run time, with the reason recorded in
# the roofline table (DESIGN.md Sec. 4).
LONG_CONTEXT_OK = {"zamba2-1.2b", "rwkv6-1.6b", "gemma3-1b"}


def skip_reason(arch_id: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch_id not in LONG_CONTEXT_OK:
        if arch_id == "whisper-medium":
            return "enc-dec audio model: 500k-token decode is not meaningful"
        return "pure full-attention arch: long_500k needs sub-quadratic attention"
    return None
