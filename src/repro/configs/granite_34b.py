"""granite-34b [dense, code]: 88L d=6144 48H MQA (kv=1), non-gated GELU
MLP ff=24576 (llama-arch w/ MQA). [arXiv:2405.04324; hf]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab=49152, gated_mlp=False,
)

SMOKE = ArchConfig(
    name="granite-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=1, d_ff=192, vocab=512,
    gated_mlp=False,
)
