"""zamba2-1.2b [hybrid]: 38 Mamba2 layers (d_state=64) + a weight-shared
attention(+MLP) block applied every 6 layers.  [arXiv:2411.15242; hf]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, shared_attn_every=6,
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
    vocab=512, ssm_state=16, ssm_head_dim=16, shared_attn_every=2,
    sub_quadratic=True,
)
