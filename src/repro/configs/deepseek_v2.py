"""deepseek-v2-236b [moe+MLA]: 60L d=5120 128H MLA (kv_lora=512,
q_lora=1536, qk 128 nope + 64 rope, v 128); layer 0 dense (ff 12288),
layers 1..59 MoE with 160 routed experts ff=1536 top-6 + 2 shared.
~236B total / ~21B active. [arXiv:2405.04434; hf]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=1536,
    vocab=102400, use_mla=True, q_lora=1536, kv_lora=512,
    qk_nope=128, qk_rope=64, v_head_dim=128,
    n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
    moe_every=1, n_dense_layers=1, dense_d_ff=12288,
)

SMOKE = ArchConfig(
    name="deepseek-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=512,
    use_mla=True, q_lora=32, kv_lora=24, qk_nope=16, qk_rope=8, v_head_dim=16,
    n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=96,
    moe_every=1, n_dense_layers=1, dense_d_ff=192,
    capacity_factor=8.0,
)
