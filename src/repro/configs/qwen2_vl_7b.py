"""qwen2-vl-7b [vlm]: 28L d=3584 28H GQA kv=4 ff=18944, M-RoPE
(sections 16/24/24); vision frontend is a stub providing patch
embeddings per the brief. [arXiv:2409.12191; hf]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24), n_vision_tokens=256, vision_grid=16,
)

SMOKE = ArchConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=512,
    mrope_sections=(2, 3, 3), n_vision_tokens=16, vision_grid=4,
)
