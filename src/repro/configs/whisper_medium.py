"""whisper-medium [audio enc-dec]: 24+24L d=1024 16H MHA ff=4096 GELU,
learned positions, conv frontend stubbed to precomputed frame embeddings
(per the brief).  max_target_positions extended to 32768 to exercise the
decode_32k cell (official: 448). [arXiv:2212.04356]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, enc_frames=1500, max_target_positions=32768,
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, enc_frames=32, max_target_positions=256,
)
