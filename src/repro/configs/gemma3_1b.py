"""gemma3-1b [dense]: 26L d=1152 4H MQA (kv=1) head_dim=256, 5 local
(sliding 512) : 1 global pattern, qk-norm, tied 262k embeddings.
[hf:google/gemma-3-1b-pt]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262144, d_head=256, sliding_window=512, global_every=6,
    use_qk_norm=True, tie_embeddings=True, rope_theta=1_000_000.0,
    sub_quadratic=True,   # decode cost dominated by 512-wide local windows
)

SMOKE = ArchConfig(
    name="gemma3-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
    vocab=512, d_head=32, sliding_window=16, global_every=2,
    use_qk_norm=True, tie_embeddings=True,
    sub_quadratic=True,
)
