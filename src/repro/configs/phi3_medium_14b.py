"""phi3-medium-14b [dense]: 40L d=5120 40H GQA kv=10, RoPE SwiGLU.
[arXiv:2404.14219]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
    vocab=100352,
)

SMOKE = ArchConfig(
    name="phi3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160, vocab=512,
)
