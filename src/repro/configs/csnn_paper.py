"""The paper's own architecture: 28x28-32C3-32C3-P3-10C3-F10 m-TTFS CSNN
(T=5), trained by ANN->SNN conversion (Sec. VII)."""
from repro.core.csnn import CSNNConfig, ConvSpec, FCSpec

FULL = CSNNConfig(
    input_hw=(28, 28),
    layers=(ConvSpec(32), ConvSpec(32, pool=3), ConvSpec(10), FCSpec(10)),
    t_steps=5,
)

SMOKE = CSNNConfig(
    input_hw=(12, 12),
    layers=(ConvSpec(8), ConvSpec(8, pool=3), FCSpec(10)),
    t_steps=4,
)
