"""rwkv6-1.6b "Finch" [ssm/linear-attn]: 24L, attention-free time mixing
with data-dependent decay, squared-ReLU channel mix. [arXiv:2404.05892]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="rwkv6-1.6b", family="rwkv",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab=65536, rwkv_head_dim=64,
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="rwkv6-smoke", family="rwkv",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=224,
    vocab=512, rwkv_head_dim=16,
    sub_quadratic=True,
)
