"""Architecture + shape configuration schema.

One ``ArchConfig`` instance per assigned architecture lives in
``repro/configs/<arch_id>.py`` (exact published hyper-parameters) together
with a ``smoke()`` reduction of the same family for CPU tests.  The four
assigned input shapes are global constants here.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None    # default: d_model // n_heads
    rope_theta: float = 10000.0
    use_qk_norm: bool = False
    tie_embeddings: bool = False
    gated_mlp: bool = True
    # --- sliding-window pattern (gemma3: 5 local : 1 global) ---
    sliding_window: Optional[int] = None
    global_every: int = 0           # every Nth layer is global (0 = all full)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: Optional[int] = None
    moe_every: int = 1              # a MoE layer every N layers
    n_dense_layers: int = 0         # leading dense layers (deepseek-v2: 1)
    dense_d_ff: Optional[int] = None  # ffn width of the non-MoE layers
    router_softmax: bool = True
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    moe_impl: str = "gather"   # gather (pjit scatter) | sharded (shard_map local)
    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head_dim: int = 128
    # --- SSM / hybrid (zamba2) ---
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_every: int = 0      # hybrid: shared attn+mlp block every N ssm layers
    # --- RWKV ---
    rwkv_head_dim: int = 64
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_frames: int = 1500
    max_target_positions: int = 32768
    # --- VLM (qwen2-vl) ---
    mrope_sections: Optional[Tuple[int, int, int]] = None
    n_vision_tokens: int = 0
    vision_grid: int = 16
    # --- capability flags ---
    sub_quadratic: bool = False     # eligible for long_500k
    has_decoder: bool = True        # encoder-only archs have no decode step
    remat: bool = True              # checkpoint layer bodies in train_step

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    phase: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# smoke-test shape: tiny everything, CPU-friendly
SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
