"""Wide-receptive-field CSNN demo: the paper net with a 5x5 first conv
layer (25 interlace banks), exercising the parametric k x k event
pipeline end to end — planning, AEQ interlacing, banked apply, and the
Pallas kernels all derive their layout from the 5x5 geometry instead of
the hardwired 3x3."""
from repro.core.csnn import CSNNConfig, ConvSpec, FCSpec

FULL = CSNNConfig(
    input_hw=(28, 28),
    layers=(ConvSpec(32, kernel=5), ConvSpec(32, pool=3), ConvSpec(10),
            FCSpec(10)),
    t_steps=5,
)

SMOKE = CSNNConfig(
    input_hw=(12, 12),
    layers=(ConvSpec(8, kernel=5), ConvSpec(8, pool=3), FCSpec(10)),
    t_steps=4,
)
