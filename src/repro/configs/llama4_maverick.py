"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H GQA kv=8; every 2nd
layer is MoE with 128 routed experts (top-1, sigmoid router) + 1 shared
expert (ff 8192); dense layers ff 16384.  ~400B total / ~17B active.
[hf:meta-llama/Llama-4 family]"""
from .base import ArchConfig

FULL = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, d_head=128, rope_theta=500_000.0,
    n_experts=128, top_k=1, n_shared_experts=1, d_ff_expert=8192,
    moe_every=2, dense_d_ff=16384, router_softmax=False,
)

SMOKE = ArchConfig(
    name="llama4-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    n_experts=8, top_k=1, n_shared_experts=1, d_ff_expert=128,
    moe_every=2, dense_d_ff=256, router_softmax=False,
    capacity_factor=8.0,
)
