"""Synthetic DVS event traces: MVSEC-style moving-edge scenes.

A real event camera emits an address event (t, y, x, polarity) whenever a
pixel's log-intensity changes past a contrast threshold — exactly the
sparse workload the paper's accelerator (and our streaming AEQ ingestion,
core/aeq.py ISSUE 6) is built for.  MVSEC-class automotive/indoor scenes
are dominated by moving intensity *edges*, so the generator here sweeps
an oriented edge band across the field of view: pixels the band newly
covers fire ON events (polarity 1), pixels it uncovers fire OFF events
(polarity 0), plus a uniform noise-event floor.  Event order inside a
trace is shuffled — sensor arbiters do not emit in raster order, and the
ingestion path must be order-invariant (tests/test_streaming.py).

Polarity maps onto the existing 2-channel input path
(``CSNNConfig.input_channels=2``): channel 0 = OFF, channel 1 = ON.

Host-side helpers mirror the two admission paths benchmarked in
``benchmarks/table6_streaming.py``:

* ``events_to_frames`` — the frame-binned reference: dense (T, H, W, C)
  bool frames, the input the legacy pipeline re-compacts with a sort;
* ``events_to_banks`` — the streaming admission: scatter events straight
  into the interlace-column bank layout of
  :class:`repro.core.aeq.StreamState` (a cheap numpy assignment — this
  is the engine's per-request "encode");
* ``iter_stream_chunks`` — slice a trace into fixed-buffer
  :class:`repro.core.aeq.StreamChunk` windows for jitted admission.
"""
from __future__ import annotations

import numpy as np

# (dy, dx) per direction class: right, left, down, up, and the diagonals
_DIRECTIONS = [(0, 1), (0, -1), (1, 0), (-1, 0),
               (1, 1), (-1, -1), (1, -1), (-1, 1)]


def dvs_moving_edges(
    n: int,
    t_bins: int,
    hw: tuple[int, int] = (28, 28),
    *,
    classes: int = 4,
    band: int = 2,
    noise_rate: float = 0.01,
    seed: int = 0,
) -> tuple[list[np.ndarray], np.ndarray]:
    """Generate ``n`` moving-edge event traces.

    Each trace is an oriented band of ``band`` pixels sweeping across the
    (H, W) field of view over ``t_bins`` time bins in one of ``classes``
    directions (the label).  Per bin, newly covered pixels emit ON
    events, newly uncovered ones OFF events; ``noise_rate`` adds
    uniform background events per pixel per bin.  Returns
    ``(traces, labels)`` where each trace is an (N_i, 4) int32 array of
    (t, y, x, polarity) rows in shuffled (non-raster) order — trace
    lengths vary with the scene, exactly like a real sensor.
    """
    if not 1 <= classes <= len(_DIRECTIONS):
        raise ValueError(f"classes must be in [1, {len(_DIRECTIONS)}]")
    h, w = hw
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w]
    labels = rng.integers(0, classes, size=n).astype(np.int32)
    traces = []
    for i in range(n):
        dy, dx = _DIRECTIONS[int(labels[i])]
        # signed distance of each pixel along the sweep direction
        proj = dy * yy + dx * xx
        lo, hi = int(proj.min()), int(proj.max())
        # the band front advances linearly from just outside the FOV;
        # jittered start/speed so traces of one class still differ
        speed = (hi - lo + band) / max(t_bins - 1, 1)
        speed *= rng.uniform(0.85, 1.15)
        start = lo - band + rng.uniform(-1.0, 1.0)
        rows = []
        prev = np.zeros((h, w), bool)
        for t in range(t_bins):
            front = start + speed * t
            cover = (proj >= front - band) & (proj < front)
            on = cover & ~prev
            off = prev & ~cover
            prev = cover
            for pol, mask in ((1, on), (0, off)):
                ys, xs = np.nonzero(mask)
                if ys.size:
                    rows.append(np.stack(
                        [np.full(ys.size, t), ys, xs,
                         np.full(ys.size, pol)], axis=-1))
            n_noise = rng.poisson(noise_rate * h * w)
            if n_noise:
                rows.append(np.stack(
                    [np.full(n_noise, t),
                     rng.integers(0, h, n_noise),
                     rng.integers(0, w, n_noise),
                     rng.integers(0, 2, n_noise)], axis=-1))
        ev = (np.concatenate(rows, axis=0) if rows
              else np.zeros((0, 4), np.int32)).astype(np.int32)
        rng.shuffle(ev, axis=0)  # arbiter order, not raster order
        traces.append(ev)
    return traces, labels


def events_to_frames(events: np.ndarray, t_bins: int, hw: tuple[int, int],
                     channels: int = 2) -> np.ndarray:
    """Bin raw events into dense (T, H, W, C) bool frames — the reference
    frame-binned input (the layout ``snn_step_chunk`` takes, matching
    ``encode_input``'s channel-last output).  Out-of-window events drop,
    duplicates dedupe, exactly like ``aeq.append_events``."""
    h, w = hw
    ev = np.asarray(events, dtype=np.int64).reshape(-1, 4)
    frames = np.zeros((t_bins, h, w, channels), bool)
    if ev.size:
        t, y, x, p = ev.T
        ok = ((t >= 0) & (t < t_bins) & (y >= 0) & (y < h)
              & (x >= 0) & (x < w) & (p >= 0) & (p < channels))
        frames[t[ok], y[ok], x[ok], p[ok]] = True
    return frames


def events_to_banks(events: np.ndarray, t_bins: int, hw: tuple[int, int],
                    channels: int = 2, geometry=None) -> np.ndarray:
    """Scatter raw events straight into the (T, C, n_banks, HB, WB) bool
    interlace-column banks of :class:`repro.core.aeq.StreamState` — the
    host-side streaming admission: one vectorized assignment per chunk,
    no threshold encode, no sort (numpy twin of ``aeq.append_events``).
    ``geometry`` is the first conv layer's window (default 3x3); the bank
    count and macro grid follow it."""
    if geometry is None:
        from repro.core.geometry import GEOM_3X3
        geometry = GEOM_3X3
    kh, kw = geometry.kh, geometry.kw
    h, w = hw
    hb, wb = -(-h // kh), -(-w // kw)
    ev = np.asarray(events, dtype=np.int64).reshape(-1, 4)
    banks = np.zeros((t_bins, channels, kh * kw, hb, wb), bool)
    if ev.size:
        t, y, x, p = ev.T
        ok = ((t >= 0) & (t < t_bins) & (y >= 0) & (y < h)
              & (x >= 0) & (x < w) & (p >= 0) & (p < channels))
        t, y, x, p = t[ok], y[ok], x[ok], p[ok]
        banks[t, p, (y % kh) * kw + x % kw, y // kh, x // kw] = True
    return banks


def iter_stream_chunks(events: np.ndarray, t_bins: int, window: int,
                       buffer: int):
    """Split a trace into per-window (t0, events, num) admission chunks.

    Yields one (t0, padded_events (buffer, 4) int32, num) triple per
    ``window``-bin slice of the trace, with event times re-based to the
    window start — the shape-stable unit a jitted ``append_events`` call
    admits.  A slice holding more than ``buffer`` events raises: the
    ingestion buffer (``LayerPlan.ingest_capacity``) is backpressure,
    not silent truncation.
    """
    ev = np.asarray(events, dtype=np.int32).reshape(-1, 4)
    for t0 in range(0, t_bins, window):
        sel = ev[(ev[:, 0] >= t0) & (ev[:, 0] < min(t0 + window, t_bins))]
        if sel.shape[0] > buffer:
            raise ValueError(
                f"window [{t0}, {t0 + window}) holds {sel.shape[0]} events "
                f"> ingest buffer {buffer}; deepen LayerPlan.ingest_capacity "
                f"or shorten the admission window")
        out = np.full((buffer, 4), -1, np.int32)
        out[:sel.shape[0]] = sel
        out[:sel.shape[0], 0] -= t0
        yield t0, out, sel.shape[0]
