"""Synthetic datasets.

Two generators:

* ``TokenStream`` — deterministic synthetic language-model data with
  learnable structure (a Zipfian unigram mixture + periodic copy motifs),
  so small LMs show decreasing loss within a few hundred steps.
* ``synth_digits`` — procedurally rendered 10-class digit-like glyphs for
  the CSNN experiments.  MNIST itself is not downloadable in this
  offline container; the substitution is recorded in EXPERIMENTS.md and
  the generator intentionally mimics MNIST's statistics (28x28, white
  strokes on black, ~19% active pixels).
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Deterministic, seekable synthetic token stream.

    Structure: tokens follow a Zipf distribution, but every ``motif_every``
    positions a motif of ``motif_len`` tokens is repeated from earlier in
    the sequence — an in-context copy signal that gives attention/SSM
    models something real to learn.
    """

    def __init__(self, vocab: int, seed: int = 0, motif_len: int = 8,
                 motif_every: int = 32):
        self.vocab = vocab
        self.seed = seed
        self.motif_len = motif_len
        self.motif_every = motif_every

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        """Returns {"tokens", "labels"} int32 arrays (B, S); labels are the
        inputs shifted by the model's loss (next-token), so labels==tokens."""
        rng = np.random.default_rng((self.seed, step))
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks ** 1.1
        probs /= probs.sum()
        toks = rng.choice(self.vocab, size=(batch_size, seq_len), p=probs)
        for row in toks:  # plant copy motifs
            for start in range(self.motif_every, seq_len - self.motif_len,
                               self.motif_every):
                src = start - self.motif_every
                row[start: start + self.motif_len] = row[src: src + self.motif_len]
        toks = toks.astype(np.int32)
        return {"tokens": toks, "labels": toks.copy()}


def synth_digits(n: int, seed: int = 0, hw: tuple[int, int] = (28, 28),
                 noise: float = 0.08) -> tuple[np.ndarray, np.ndarray]:
    """Procedural 10-class digit-like dataset -> (images (N,H,W,1) in [0,1],
    labels (N,)).  Classes are distinct stroke patterns (segments of a
    7-segment-like glyph plus diagonals), randomly jittered and blurred.
    """
    h, w = hw
    rng = np.random.default_rng(seed)
    # 7-segment style layout in a unit square: (x0,y0,x1,y1) strokes
    seg = {
        "top": (0.2, 0.15, 0.8, 0.15), "mid": (0.2, 0.5, 0.8, 0.5),
        "bot": (0.2, 0.85, 0.8, 0.85), "tl": (0.2, 0.15, 0.2, 0.5),
        "tr": (0.8, 0.15, 0.8, 0.5), "bl": (0.2, 0.5, 0.2, 0.85),
        "br": (0.8, 0.5, 0.8, 0.85), "diag": (0.2, 0.85, 0.8, 0.15),
    }
    digit_segs = {
        0: ["top", "bot", "tl", "tr", "bl", "br"],
        1: ["tr", "br"],
        2: ["top", "mid", "bot", "tr", "bl"],
        3: ["top", "mid", "bot", "tr", "br"],
        4: ["mid", "tl", "tr", "br"],
        5: ["top", "mid", "bot", "tl", "br"],
        6: ["top", "mid", "bot", "tl", "bl", "br"],
        7: ["top", "tr", "br", "diag"],
        8: ["top", "mid", "bot", "tl", "tr", "bl", "br"],
        9: ["top", "mid", "bot", "tl", "tr", "br"],
    }
    images = np.zeros((n, h, w), np.float32)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    yy, xx = np.mgrid[0:h, 0:w]
    for i in range(n):
        cls = labels[i]
        jx, jy = rng.uniform(-0.08, 0.08, 2)
        scale = rng.uniform(0.85, 1.1)
        thick = rng.uniform(0.035, 0.055)
        img = np.zeros((h, w), np.float32)
        for name in digit_segs[int(cls)]:
            x0, y0, x1, y1 = seg[name]
            x0, x1 = ((v - 0.5) * scale + 0.5 + jx for v in (x0, x1))
            y0, y1 = ((v - 0.5) * scale + 0.5 + jy for v in (y0, y1))
            px0, py0, px1, py1 = x0 * w, y0 * h, x1 * w, y1 * h
            # distance of each pixel to the stroke segment
            dx, dy = px1 - px0, py1 - py0
            ln2 = dx * dx + dy * dy + 1e-9
            t = np.clip(((xx - px0) * dx + (yy - py0) * dy) / ln2, 0, 1)
            dist2 = (xx - (px0 + t * dx)) ** 2 + (yy - (py0 + t * dy)) ** 2
            img = np.maximum(img, np.exp(-dist2 / (2 * (thick * w) ** 2)))
        img += rng.normal(0, noise, (h, w)).astype(np.float32)
        images[i] = np.clip(img, 0.0, 1.0)
    return images[..., None], labels


class ShardedBatcher:
    """Builds globally-sharded device batches from host data.

    On a real multi-host pod each process feeds only its addressable
    shards (`jax.make_array_from_callback` receives per-shard index maps);
    on this single-process container the same code path materializes every
    shard.  The iterator state is just (seed, step) — checkpointable and
    deterministic, so a restarted job resumes mid-epoch byte-identically.
    """

    def __init__(self, stream: TokenStream, batch_size: int, seq_len: int,
                 mesh=None, batch_axes=("pod", "data")):
        self.stream = stream
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.mesh = mesh
        self.batch_axes = batch_axes

    def __call__(self, step: int) -> dict:
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec

        host = self.stream.batch(step, self.batch_size, self.seq_len)
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        axes = tuple(a for a in self.batch_axes if a in self.mesh.shape)
        sharding = NamedSharding(self.mesh, PartitionSpec(axes or None, None))
        out = {}
        for k, v in host.items():
            out[k] = jax.make_array_from_callback(
                v.shape, sharding, lambda idx, v=v: v[idx])
        return out
