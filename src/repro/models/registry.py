"""Model registry: ArchConfig -> a uniform Model object.

``Model`` bundles the five things every launcher/test/benchmark needs:
parameter specs (real init / abstract / logical axes), the three step
functions (loss, prefill, decode), cache structure, and
``input_specs``/``make_inputs`` for every assigned input shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from . import encdec, transformer
from .common import abstract_tree, init_tree, logical_axes_tree, param_count


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    specs: Any

    # ---- params ----
    def init_params(self, rng: jax.Array, dtype=jnp.float32):
        return init_tree(rng, self.specs, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return abstract_tree(self.specs, dtype)

    def logical_axes(self):
        return logical_axes_tree(self.specs)

    def n_params(self) -> int:
        return param_count(self.specs)

    # ---- step functions ----
    def loss(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec.loss_fn(params, batch, self.cfg)
        return transformer.loss_fn(params, batch, self.cfg)

    def prefill(self, params, batch, max_seq: int, cache_dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return encdec.prefill(params, batch, self.cfg, max_seq, cache_dtype)
        return transformer.prefill(params, batch, self.cfg, max_seq, cache_dtype)

    def decode(self, params, cache, batch):
        if self.cfg.family == "encdec":
            return encdec.decode_step(params, cache, batch, self.cfg)
        return transformer.decode_step(params, cache, batch, self.cfg)

    def cache_structure(self, batch: int, max_seq: int, dtype=jnp.bfloat16,
                        abstract: bool = True):
        if self.cfg.family == "encdec":
            return encdec.cache_structure(self.cfg, batch, max_seq, dtype, abstract)
        return transformer.cache_structure(self.cfg, batch, max_seq, dtype, abstract)

    # ---- inputs ----
    def input_specs(self, shape: ShapeConfig, act_dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        if shape.phase == "train":
            batch = {"tokens": sds((b, s), jnp.int32), "labels": sds((b, s), jnp.int32)}
        elif shape.phase == "prefill":
            batch = {"tokens": sds((b, s), jnp.int32)}
        else:  # decode: one new token; the `s`-long context lives in the cache
            batch = {"tokens": sds((b, 1), jnp.int32), "pos": sds((), jnp.int32)}
        if cfg.family == "vlm" and shape.phase != "decode":
            batch["vision_embeds"] = sds((b, cfg.n_vision_tokens, cfg.d_model), act_dtype)
        if cfg.family == "encdec" and shape.phase != "decode":
            batch["frames"] = sds((b, cfg.enc_frames, cfg.d_model), act_dtype)
        return batch

    def make_inputs(self, rng: jax.Array, shape: ShapeConfig,
                    act_dtype=jnp.float32) -> dict:
        """Real random inputs matching input_specs (smoke tests / examples)."""
        cfg = self.cfg
        specs = self.input_specs(shape, act_dtype)
        out = {}
        for name, s in specs.items():
            rng, k = jax.random.split(rng)
            if name in ("tokens", "labels"):
                out[name] = jax.random.randint(k, s.shape, 0, min(cfg.vocab, 1000),
                                               jnp.int32)
            elif name == "pos":
                out[name] = jnp.asarray(shape.seq_len - 1, jnp.int32)
            else:
                out[name] = 0.02 * jax.random.normal(k, s.shape, act_dtype)
        return out

    def input_axes(self, shape: ShapeConfig) -> dict:
        """Logical axes for each input (consumed by the sharding rules)."""
        cfg = self.cfg
        if shape.phase == "decode":
            axes = {"tokens": ("batch", None), "pos": ()}
        else:
            axes = {"tokens": ("batch", "seq")}
            if shape.phase == "train":
                axes["labels"] = ("batch", "seq")
        if cfg.family == "vlm" and shape.phase != "decode":
            axes["vision_embeds"] = ("batch", None, "embed")
        if cfg.family == "encdec" and shape.phase != "decode":
            axes["frames"] = ("batch", None, "embed")
        return axes


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "encdec":
        specs = encdec.build_param_specs(cfg)
    else:
        specs = transformer.build_param_specs(cfg)
    return Model(cfg=cfg, specs=specs)
