"""Mamba2 (SSD) block — the state-space half of zamba2.

Faithful structure: fused in_proj -> [z | xBC | dt], causal depthwise
conv1d over xBC, SSD linear recurrence with per-head scalar decay
exp(dt*A), D skip connection, gated RMSNorm, out_proj.  The recurrence
runs through models.linear_attn.chunked (train/prefill) or single_step
(decode), with q=C, k=B, v=dt*x, log_w=dt*A broadcast over the state dim.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, rms_norm
from .linear_attn import chunked_scalar, single_step


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int
    d_state: int
    head_dim: int
    n_heads: int
    conv_w: int

    @staticmethod
    def make(d_model: int, d_state: int = 64, expand: int = 2, head_dim: int = 64,
             conv_w: int = 4) -> "SSMDims":
        d_inner = expand * d_model
        return SSMDims(d_model, d_inner, d_state, head_dim, d_inner // head_dim, conv_w)

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state  # xBC (n_groups = 1)

    @property
    def in_dim(self) -> int:
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads  # z|xBC|dt


def mamba2_specs(dims: SSMDims) -> dict:
    return {
        "in_proj": ParamSpec((dims.d_model, dims.in_dim), ("embed", "mlp"), "scaled"),
        "conv_w": ParamSpec((dims.conv_w, dims.conv_dim), (None, "mlp"), "scaled"),
        "conv_b": ParamSpec((dims.conv_dim,), ("mlp",), "zeros"),
        "a_log": ParamSpec((dims.n_heads,), ("heads",), "zeros"),
        "d_skip": ParamSpec((dims.n_heads,), ("heads",), "ones"),
        "dt_bias": ParamSpec((dims.n_heads,), ("heads",), "zeros"),
        "norm": ParamSpec((dims.d_inner,), ("mlp",), "zeros"),
        "out_proj": ParamSpec((dims.d_inner, dims.d_model), ("mlp", "embed"), "scaled"),
    }


def _split_proj(p, x, dims: SSMDims):
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., : dims.d_inner]
    xbc = zxbcdt[..., dims.d_inner: dims.d_inner + dims.conv_dim]
    dt = zxbcdt[..., dims.d_inner + dims.conv_dim:]
    return z, xbc, dt


def _ssd_core(p, z, x_in, b_in, c_in, dt, dims: SSMDims, state0=None, chunk=64):
    """Shared SSD math after the conv. Shapes: x_in (B,S,d_inner); b/c (B,S,state)."""
    bsz, s, _ = x_in.shape
    h, hd, ds = dims.n_heads, dims.head_dim, dims.d_state
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (H,) negative
    log_w = dt * a                                           # (B,S,H) scalar/head
    xh = x_in.reshape(bsz, s, h, hd)
    v = xh * dt[..., None].astype(xh.dtype)                  # fold dt into v
    k = jnp.broadcast_to(b_in[:, :, None, :], (bsz, s, h, ds))  # group-shared B
    q = jnp.broadcast_to(c_in[:, :, None, :], (bsz, s, h, ds))
    res = chunked_scalar(q, k, v, log_w, chunk=chunk, state0=state0)
    o = res.out + p["d_skip"].astype(xh.dtype)[None, None, :, None] * xh
    o = o.reshape(bsz, s, dims.d_inner)
    o = rms_norm(o * jax.nn.silu(z), p["norm"])
    return o @ p["out_proj"], res.state


def mamba2_forward(p: dict, x: jax.Array, dims: SSMDims, *, chunk: int = 64) -> jax.Array:
    """Full-sequence forward. x: (B, S, d_model)."""
    z, xbc, dt = _split_proj(p, x, dims)
    # causal depthwise conv1d, window conv_w
    pad = dims.conv_w - 1
    xbc_p = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(xbc_p[:, i: i + x.shape[1], :] * p["conv_w"][i][None, None, :]
               for i in range(dims.conv_w))
    xbc = jax.nn.silu(conv + p["conv_b"])
    x_in = xbc[..., : dims.d_inner]
    b_in = xbc[..., dims.d_inner: dims.d_inner + dims.d_state]
    c_in = xbc[..., dims.d_inner + dims.d_state:]
    out, _ = _ssd_core(p, z, x_in, b_in, c_in, dt, dims, chunk=chunk)
    return out


def mamba2_init_state(n_layers: int, batch: int, dims: SSMDims, dtype=jnp.float32) -> dict:
    return {
        "ssm": jnp.zeros((n_layers, batch, dims.n_heads, dims.d_state, dims.head_dim),
                         jnp.float32),
        "conv": jnp.zeros((n_layers, batch, dims.conv_w - 1, dims.conv_dim), dtype),
    }


def mamba2_state_axes() -> dict:
    return {"ssm": ("layers", "batch", "heads", None, None),
            "conv": ("layers", "batch", None, "mlp")}


def mamba2_decode(p: dict, x: jax.Array, layer_state: dict, dims: SSMDims):
    """One-token step. x: (B, 1, d_model); layer_state: {ssm, conv} (unstacked)."""
    bsz = x.shape[0]
    z, xbc, dt = _split_proj(p, x, dims)                     # (B,1,*)
    hist = jnp.concatenate([layer_state["conv"], xbc], axis=1)  # (B, conv_w, conv_dim)
    conv = jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(conv)[:, None, :]
    new_conv = hist[:, 1:, :]
    x_in = xbc_t[..., : dims.d_inner]
    b_in = xbc_t[..., dims.d_inner: dims.d_inner + dims.d_state]
    c_in = xbc_t[..., dims.d_inner + dims.d_state:]

    h, hd, ds = dims.n_heads, dims.head_dim, dims.d_state
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    log_w = jnp.broadcast_to((dtv * a)[..., None], (bsz, h, ds))
    xh = x_in[:, 0].reshape(bsz, h, hd)
    v_t = xh * dtv[..., None].astype(xh.dtype)
    k_t = jnp.broadcast_to(b_in[:, 0, None, :], (bsz, h, ds))
    q_t = jnp.broadcast_to(c_in[:, 0, None, :], (bsz, h, ds))
    st, o = single_step(layer_state["ssm"], q_t, k_t, v_t, log_w)
    o = o + p["d_skip"].astype(xh.dtype)[None, :, None] * xh
    o = o.reshape(bsz, 1, dims.d_inner)
    o = rms_norm(o * jax.nn.silu(z), p["norm"])
    return o @ p["out_proj"], {"ssm": st, "conv": new_conv}
