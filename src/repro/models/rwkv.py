"""RWKV6 ("Finch") block: attention-free time mixing with data-dependent
decay + squared-ReLU channel mixing.

Time mixing uses the five-way data-dependent token-shift interpolation
(ddlerp, low-rank) of the RWKV6 paper, per-channel decays
w_t = exp(-exp(base + lora(x))) and the current-token bonus u; the linear
recurrence itself runs through models.linear_attn in the exclusive+bonus
form.  Decode state per layer: two token-shift vectors + the (H, 64, 64)
wkv state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, layer_norm
from .linear_attn import chunked, single_step

_MIX = ("w", "k", "v", "r", "g")


class RWKVDims(NamedTuple):
    d_model: int
    d_ff: int
    head_dim: int
    lora_mix: int
    lora_decay: int

    @staticmethod
    def make(d_model: int, d_ff: int, head_dim: int = 64, lora_mix: int = 32,
             lora_decay: int = 64) -> "RWKVDims":
        return RWKVDims(d_model, d_ff, head_dim, lora_mix, lora_decay)

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def rwkv6_time_mix_specs(dims: RWKVDims) -> dict:
    d = dims.d_model
    s = {
        "maa_x": ParamSpec((d,), ("embed",), "zeros"),
        "maa_w1": ParamSpec((d, 5 * dims.lora_mix), ("embed", None), "scaled"),
        "maa_w2": ParamSpec((5, dims.lora_mix, d), (None, None, "embed"), "scaled"),
        "decay_base": ParamSpec((d,), ("embed",), "zeros"),
        "decay_w1": ParamSpec((d, dims.lora_decay), ("embed", None), "scaled"),
        "decay_w2": ParamSpec((dims.lora_decay, d), (None, "embed"), "scaled"),
        "bonus": ParamSpec((dims.n_heads, dims.head_dim), ("heads", "head_dim"), "zeros"),
        "wr": ParamSpec((d, d), ("embed", "heads_flat"), "scaled"),
        "wk": ParamSpec((d, d), ("embed", "heads_flat"), "scaled"),
        "wv": ParamSpec((d, d), ("embed", "heads_flat"), "scaled"),
        "wg": ParamSpec((d, d), ("embed", "heads_flat"), "scaled"),
        "wo": ParamSpec((d, d), ("heads_flat", "embed"), "scaled"),
        "ln_x_g": ParamSpec((d,), ("embed",), "ones"),
        "ln_x_b": ParamSpec((d,), ("embed",), "zeros"),
    }
    for m in _MIX:
        s[f"maa_{m}"] = ParamSpec((d,), ("embed",), "zeros")
    return s


def rwkv6_channel_mix_specs(dims: RWKVDims) -> dict:
    d = dims.d_model
    return {
        "maa_k": ParamSpec((d,), ("embed",), "zeros"),
        "maa_r": ParamSpec((d,), ("embed",), "zeros"),
        "wk": ParamSpec((d, dims.d_ff), ("embed", "mlp"), "scaled"),
        "wv": ParamSpec((dims.d_ff, d), ("mlp", "embed"), "scaled"),
        "wr": ParamSpec((d, d), ("embed", "embed2"), "scaled"),
    }


def _ddlerp(p: dict, x: jax.Array, shifted: jax.Array):
    """Data-dependent 5-way token-shift interpolation -> (xw, xk, xv, xr, xg)."""
    dx = shifted - x
    base = x + dx * p["maa_x"]
    lora = jnp.tanh(base @ p["maa_w1"])                       # (B,S,5*lm)
    lora = lora.reshape(*lora.shape[:-1], 5, -1)              # (B,S,5,lm)
    adj = jnp.einsum("bsfl,fld->bsfd", lora, p["maa_w2"])     # (B,S,5,d)
    outs = []
    for i, m in enumerate(_MIX):
        outs.append(x + dx * (p[f"maa_{m}"] + adj[..., i, :]))
    return outs


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Per-channel log decay (<= 0): -exp(base + lora(xw))."""
    lora = jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    # faithful RWKV range: w = exp(-exp(d)) with d <= ~1, so per-step
    # log-decay is >= -e; with chunk=16 the in-chunk span stays < 80.
    return -jnp.exp(jnp.clip(p["decay_base"].astype(jnp.float32)
                             + lora.astype(jnp.float32), -8.0, 1.0))


def _shift(x: jax.Array) -> jax.Array:
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def time_mix_forward(p: dict, x: jax.Array, dims: RWKVDims, *, chunk: int = 16):
    b, s, d = x.shape
    h, hd = dims.n_heads, dims.head_dim
    xw, xk, xv, xr, xg = _ddlerp(p, x, _shift(x))
    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    log_w = _decay(p, xw).reshape(b, s, h, hd)
    res = chunked(r, k, v, log_w, chunk=chunk, exclusive=True, u=p["bonus"])
    o = res.out.reshape(b, s, d)
    o = layer_norm(o, p["ln_x_g"], p["ln_x_b"])  # group-norm equivalent (per-layer)
    return (o * g) @ p["wo"]


def channel_mix_forward(p: dict, x: jax.Array):
    shifted = _shift(x)
    xk = x + (shifted - x) * p["maa_k"]
    xr = x + (shifted - x) * p["maa_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])


def rwkv6_init_state(n_layers: int, batch: int, dims: RWKVDims, dtype=jnp.bfloat16) -> dict:
    return {
        "wkv": jnp.zeros((n_layers, batch, dims.n_heads, dims.head_dim, dims.head_dim),
                         jnp.float32),
        "shift_tm": jnp.zeros((n_layers, batch, dims.d_model), dtype),
        "shift_cm": jnp.zeros((n_layers, batch, dims.d_model), dtype),
    }


def rwkv6_state_axes() -> dict:
    return {"wkv": ("layers", "batch", "heads", None, None),
            "shift_tm": ("layers", "batch", "embed"),
            "shift_cm": ("layers", "batch", "embed")}


def time_mix_decode(p: dict, x: jax.Array, wkv_state: jax.Array, shift: jax.Array,
                    dims: RWKVDims):
    """x: (B,1,d); shift: (B,d) previous token's input; wkv_state fp32."""
    b, _, d = x.shape
    h, hd = dims.n_heads, dims.head_dim
    xw, xk, xv, xr, xg = _ddlerp(p, x, shift[:, None, :])
    r = (xr @ p["wr"]).reshape(b, h, hd)
    k = (xk @ p["wk"]).reshape(b, h, hd)
    v = (xv @ p["wv"]).reshape(b, h, hd)
    g = jax.nn.silu(xg @ p["wg"])[:, 0]
    log_w = _decay(p, xw).reshape(b, h, hd)
    st, o = single_step(wkv_state, r, k, v, log_w, exclusive=True, u=p["bonus"])
    o = layer_norm(o.reshape(b, d), p["ln_x_g"], p["ln_x_b"])
    out = ((o * g) @ p["wo"])[:, None, :]
    return out, st, x[:, 0, :]


def channel_mix_decode(p: dict, x: jax.Array, shift: jax.Array):
    dx = shift[:, None, :] - x
    xk = x + dx * p["maa_k"]
    xr = x + dx * p["maa_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, 0, :]
