"""Attention blocks: GQA/MQA (full, sliding-window, cross) and DeepSeek MLA.

Each block exposes:
  *_specs(cfg)                      — ParamSpec tree for one layer
  *_forward(p, x, ...)              — full-sequence (train / prefill)
  *_decode(p, x, cache, pos, ...)   — single-token step against a KV cache

Caches are plain dicts of arrays so they shard/checkpoint like params.
Sliding-window layers use a ring-buffer cache of exactly ``window`` slots
(the reason gemma3-style models stay cheap at 500k context).
MLA decode uses the *absorbed* low-rank form: only the 512-dim latent and
the 64-dim shared rope key are cached, and W_UK/W_UV are folded into the
score/output projections — the memory-bound shape the roofline rewards.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ParamSpec, apply_mrope, apply_rope, causal_mask, rms_norm, sliding_mask

# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------


def gqa_specs(d_model: int, n_heads: int, n_kv: int, d_head: int,
              use_qk_norm: bool = False) -> dict:
    s = {
        "wq": ParamSpec((d_model, n_heads, d_head), ("embed", "heads", "head_dim"), "scaled"),
        "wk": ParamSpec((d_model, n_kv, d_head), ("embed", "kv_heads", "head_dim"), "scaled"),
        "wv": ParamSpec((d_model, n_kv, d_head), ("embed", "kv_heads", "head_dim"), "scaled"),
        "wo": ParamSpec((n_heads, d_head, d_model), ("heads", "head_dim", "embed"), "scaled"),
    }
    if use_qk_norm:
        s["q_norm"] = ParamSpec((d_head,), ("head_dim",), "zeros")
        s["k_norm"] = ParamSpec((d_head,), ("head_dim",), "zeros")
    return s


def _project_qkv(p: dict, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array]):
    """q: (B,Sq,H,D); k,v: (B,Sk,Kv,D); mask: (Sq,Sk) or (B,Sq,Sk) or None."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    group = h // kv
    q = q.reshape(b, sq, kv, group, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(d, jnp.float32))
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        scores = jnp.where(m[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, sq, h, v.shape[-1])


Q_BLOCK = 1024
_BLOCKED_MIN_SEQ = 2048  # below this the plain (S, S) path is cheaper


def _attend_qblocks(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: Optional[int] = None, q_block: int = Q_BLOCK):
    """Causal GQA attention scanned over query blocks.

    Bounds live score memory to (B, H, q_block, L) where L = Sk (full) or
    window + q_block (sliding — the KV slice is narrowed per block, so
    sliding layers are O(S*w) compute AND memory; this is what makes the
    gemma3-style 5:1 pattern and 32k prefills feasible).  The backward
    pass recomputes per block (scan remat).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    pad = -sq % q_block
    if pad:  # padded query rows see only kv[0], get cropped after
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (sq + pad) // q_block
    qb = q.reshape(b, nb, q_block, h, d).swapaxes(0, 1)       # (nb,B,blk,H,D)
    use_slice = window is not None and window + q_block < sk
    l_kv = window + q_block if use_slice else sk

    @jax.checkpoint  # backward recomputes per-block scores: without this the
    # scan stacks every block's softmax weights = the full (S, S) matrix
    def one_block(carry, xs):
        i, qi_blk = xs
        start_q = i * q_block
        if use_slice:
            start_k = jnp.clip(start_q + q_block - l_kv, 0, sk - l_kv)
            kk = jax.lax.dynamic_slice(k, (0, start_k, 0, 0),
                                       (b, l_kv, k.shape[2], d))
            vv = jax.lax.dynamic_slice(v, (0, start_k, 0, 0),
                                       (b, l_kv, v.shape[2], v.shape[3]))
        else:
            start_k = jnp.asarray(0, jnp.int32)
            kk, vv = k, v
        qi = start_q + jnp.arange(q_block)[:, None]
        kj = start_k + jnp.arange(l_kv)[None, :]
        m = kj <= qi
        if window is not None:
            m &= kj > qi - window
        out = _gqa_attend(qi_blk, kk, vv, jnp.broadcast_to(m[None], (b,) + m.shape))
        return carry, out

    _, outs = jax.lax.scan(one_block, (),
                           (jnp.arange(nb, dtype=jnp.int32), qb))
    out = outs.swapaxes(0, 1).reshape(b, sq + pad, h, v.shape[-1])
    return out[:, :sq]


def attend_causal(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: Optional[int] = None, q_offset: int = 0):
    """Causal (optionally sliding-window) attention; picks the blocked path
    for long sequences.  q_offset: absolute position of q[0] (vlm concat)."""
    sq = q.shape[1]
    if sq >= _BLOCKED_MIN_SEQ and q_offset == 0 and sq == k.shape[1]:
        return _attend_qblocks(q, k, v, window=window)
    sk = k.shape[1]
    mask = (sliding_mask(sq, sk, window, q_offset) if window is not None
            else causal_mask(sq, sk, q_offset))
    return _gqa_attend(q, k, v, mask)


def gqa_forward(p: dict, x: jax.Array, *, positions: jax.Array,
                rope_theta: float = 10000.0, window: Optional[int] = None,
                mrope_sections: Optional[tuple] = None,
                mrope_positions: Optional[jax.Array] = None,
                bidirectional: bool = False, use_rope: bool = True) -> jax.Array:
    """Full-sequence GQA. x: (B,S,D); positions: (B,S) int32."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x)
    if mrope_sections is not None:
        q = apply_mrope(q, mrope_positions, mrope_sections, rope_theta)
        k = apply_mrope(k, mrope_positions, mrope_sections, rope_theta)
    elif use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if bidirectional:
        out = _gqa_attend(q, k, v, None)
    else:
        out = attend_causal(q, k, v, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def gqa_init_cache(n_layers: int, batch: int, max_seq: int, n_kv: int, d_head: int,
                   window: Optional[int] = None, dtype=jnp.bfloat16) -> dict:
    """Stacked (over layers) KV cache; ring-buffer when ``window`` is set."""
    slots = min(window, max_seq) if window is not None else max_seq
    cache = {
        "k": jnp.zeros((n_layers, batch, slots, n_kv, d_head), dtype),
        "v": jnp.zeros((n_layers, batch, slots, n_kv, d_head), dtype),
    }
    if window is not None:
        cache["slot_pos"] = jnp.full((n_layers, slots), -1, jnp.int32)
    return cache


def cache_axes(window: Optional[int] = None) -> dict:
    """Logical axes of one stacked GQA cache (for sharding rules)."""
    kv = {"k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
          "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim")}
    if window is not None:
        kv["slot_pos"] = ("layers", "cache_seq")
    return kv


def gqa_fill_cache(p: dict, x: jax.Array, *, positions, rope_theta=10000.0,
                   window: Optional[int] = None, max_seq: int = 0,
                   mrope_sections=None, mrope_positions=None, use_rope: bool = True):
    """Prefill: run full-seq attention AND return this layer's cache entries."""
    q, k, v = _project_qkv(p, x)
    if mrope_sections is not None:
        q = apply_mrope(q, mrope_positions, mrope_sections, rope_theta)
        k = apply_mrope(k, mrope_positions, mrope_sections, rope_theta)
    elif use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    s = x.shape[1]
    out = attend_causal(q, k, v, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if window is not None:  # ring layout: absolute pos t lives at slot t % window
        b, _, n_kv, d_head = k.shape
        take = min(window, s)
        t_abs = jnp.arange(s - take, s, dtype=jnp.int32)
        idx = t_abs % window
        k_c = jnp.zeros((b, window, n_kv, d_head), k.dtype).at[:, idx].set(k[:, s - take:])
        v_c = jnp.zeros((b, window, n_kv, d_head), v.dtype).at[:, idx].set(v[:, s - take:])
        slot_abs = jnp.full((window,), -1, jnp.int32).at[idx].set(t_abs)
        return out, {"k": k_c, "v": v_c, "slot_pos": slot_abs}
    pad = max_seq - s
    k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, {"k": k_c, "v": v_c}


def gqa_decode(p: dict, x: jax.Array, layer_cache: dict, pos: jax.Array, *,
               rope_theta=10000.0, window: Optional[int] = None,
               mrope_sections=None, mrope_positions=None, use_rope: bool = True):
    """One-token step. x: (B,1,D); pos: () int32 current position.

    Returns (out (B,1,D), updated layer cache).
    """
    q, k, v = _project_qkv(p, x)
    pos_arr = jnp.full((x.shape[0], 1), pos, jnp.int32)
    if mrope_sections is not None:
        q = apply_mrope(q, mrope_positions, mrope_sections, rope_theta)
        k = apply_mrope(k, mrope_positions, mrope_sections, rope_theta)
    elif use_rope:
        q = apply_rope(q, pos_arr, rope_theta)
        k = apply_rope(k, pos_arr, rope_theta)
    slots = layer_cache["k"].shape[1]
    slot = (pos % slots) if window is not None else pos
    k_cache = jax.lax.dynamic_update_slice(
        layer_cache["k"], k.astype(layer_cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        layer_cache["v"], v.astype(layer_cache["v"].dtype), (0, slot, 0, 0))
    new_cache = {"k": k_cache, "v": v_cache}
    if window is not None:
        slot_pos = jax.lax.dynamic_update_slice(
            layer_cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
        new_cache["slot_pos"] = slot_pos
        valid = (slot_pos >= 0) & (slot_pos > pos - window) & (slot_pos <= pos)
        mask = valid[None, None, :]                       # (1,1,slots)
    else:
        mask = (jnp.arange(slots) <= pos)[None, None, :]
    out = _gqa_attend(q, k_cache, v_cache, jnp.broadcast_to(mask, (x.shape[0], 1, slots)))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_forward(p: dict, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array):
    """x: (B,S,D); enc_k/enc_v: (B,T,Kv,D) precomputed from encoder output."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    out = _gqa_attend(q, enc_k, enc_v, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_encode_kv(p: dict, enc_out: jax.Array):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): multi-head latent attention
# ---------------------------------------------------------------------------


def mla_specs(d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
              qk_nope: int, qk_rope: int, v_dim: int) -> dict:
    return {
        "wq_a": ParamSpec((d_model, q_lora), ("embed", "q_lora"), "scaled"),
        "q_norm": ParamSpec((q_lora,), ("q_lora",), "zeros"),
        "wq_b": ParamSpec((q_lora, n_heads, qk_nope + qk_rope),
                          ("q_lora", "heads", "head_dim"), "scaled"),
        "wkv_a": ParamSpec((d_model, kv_lora + qk_rope), ("embed", "kv_lora"), "scaled"),
        "kv_norm": ParamSpec((kv_lora,), ("kv_lora",), "zeros"),
        "wk_b": ParamSpec((kv_lora, n_heads, qk_nope), ("kv_lora", "heads", "head_dim"), "scaled"),
        "wv_b": ParamSpec((kv_lora, n_heads, v_dim), ("kv_lora", "heads", "head_dim"), "scaled"),
        "wo": ParamSpec((n_heads, v_dim, d_model), ("heads", "head_dim", "embed"), "scaled"),
    }


def _mla_qkv(p: dict, x: jax.Array, positions, rope_theta, qk_nope: int, qk_rope: int):
    c_q = rms_norm(x @ p["wq_a"], p["q_norm"])
    q = jnp.einsum("bsq,qhk->bshk", c_q, p["wq_b"])
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    kv_a = x @ p["wkv_a"]
    c_kv = rms_norm(kv_a[..., : p["kv_norm"].shape[0]], p["kv_norm"])  # (B,S,kv_lora)
    k_rope = kv_a[..., p["kv_norm"].shape[0]:][:, :, None, :]          # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p: dict, x: jax.Array, *, positions, rope_theta: float,
                qk_nope: int, qk_rope: int) -> jax.Array:
    """Full-sequence MLA, expanded form (train / prefill), q-blocked when
    long: q/k = [nope | rope] per head (the 1/sqrt(nope+rope) scale falls
    out of the concatenated head dim), v has its own dim."""
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, positions, rope_theta, qk_nope, qk_rope)
    k_nope = jnp.einsum("bsc,chk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsc,chv->bshv", c_kv, p["wv_b"])
    h = q_nope.shape[2]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (b, s, h, qk_rope))], axis=-1)
    out = attend_causal(q, k, v)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"])


def mla_init_cache(n_layers: int, batch: int, max_seq: int, kv_lora: int,
                   qk_rope: int, dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((n_layers, batch, max_seq, kv_lora), dtype),
        "k_rope": jnp.zeros((n_layers, batch, max_seq, qk_rope), dtype),
    }


def mla_cache_axes() -> dict:
    return {"c_kv": ("layers", "batch", "cache_seq", "kv_lora"),
            "k_rope": ("layers", "batch", "cache_seq", None)}


def mla_fill_cache(p: dict, x: jax.Array, *, positions, rope_theta, qk_nope,
                   qk_rope, max_seq: int):
    out = mla_forward(p, x, positions=positions, rope_theta=rope_theta,
                      qk_nope=qk_nope, qk_rope=qk_rope)
    _, _, c_kv, k_rope = _mla_qkv(p, x, positions, rope_theta, qk_nope, qk_rope)
    pad = max_seq - x.shape[1]
    return out, {
        "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
    }


def mla_decode(p: dict, x: jax.Array, layer_cache: dict, pos: jax.Array, *,
               rope_theta: float, qk_nope: int, qk_rope: int):
    """Absorbed-form single-token MLA: cache only (c_kv, k_rope).

    scores_t = q_nope W_UK c_kv_t + q_rope k_rope_t  (W_UK absorbed into q)
    out      = (attn @ c_kv) W_UV                    (W_UV absorbed after)
    """
    b = x.shape[0]
    pos_arr = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(
        p, x, pos_arr, rope_theta, qk_nope, qk_rope)
    c_cache = jax.lax.dynamic_update_slice(
        layer_cache["c_kv"], c_kv_new.astype(layer_cache["c_kv"].dtype), (0, pos, 0))
    r_cache = jax.lax.dynamic_update_slice(
        layer_cache["k_rope"], k_rope_new.astype(layer_cache["k_rope"].dtype),
        (0, pos, 0))
    q_eff = jnp.einsum("bshk,chk->bshc", q_nope, p["wk_b"])   # absorb W_UK
    scale = 1.0 / jnp.sqrt(jnp.asarray(qk_nope + qk_rope, jnp.float32))
    scores = (jnp.einsum("bshc,btc->bhst", q_eff, c_cache)
              + jnp.einsum("bshk,btk->bhst", q_rope, r_cache)).astype(jnp.float32) * scale
    slots = c_cache.shape[1]
    mask = (jnp.arange(slots) <= pos)[None, None, None, :]
    w = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1).astype(x.dtype)
    out_c = jnp.einsum("bhst,btc->bshc", w, c_cache)          # (B,1,H,kv_lora)
    out = jnp.einsum("bshc,chv->bshv", out_c, p["wv_b"])      # absorb W_UV
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return out, {"c_kv": c_cache, "k_rope": r_cache}
