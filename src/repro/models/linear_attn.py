"""Chunked decay linear attention — shared core for Mamba2 (SSD) and RWKV6.

Both architectures are linear RNNs over an outer-product state
S_t (d_k, d_v) with per-step, per-channel decay w_t in (0, 1]:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = q_t S_t                      (inclusive; Mamba2: q=C, k=B*dt, w=exp(dt*A))
    o_t = q_t S_{t-1} + (q_t*u . k_t) v_t   (exclusive+bonus; RWKV6: q=r, u=bonus)

The chunked algorithm processes the sequence in chunks of ``chunk``
steps: within a chunk, outputs come from a masked (T_c, T_c) "attention"
with per-channel decay factors folded into q~ and k~; across chunks the
state is carried by a `lax.scan`.  Complexity O(S * (chunk * d_k + d_k *
d_v)) per head — sub-quadratic in S, which is what qualifies these archs
for the long_500k shape.

Numerical note: the generic per-channel path folds decays as
q~ = q * exp(L_t) and k~ = k * exp(-L_s); this is exact only while the
in-chunk decay span stays within float32 range, so callers choose
chunk * max|log_w| < ~80 (RWKV6: decay >= -e^1 per step, chunk=16).
For *scalar-per-head* decays (Mamba2/SSD) use ``chunked_scalar`` below:
it builds the (T, T) decay matrix from pairwise differences (segsum, the
official SSD formulation), which is stable for arbitrarily strong decays.
Tests compare both against the exact recurrent reference.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

MAX_EXP = 80.0  # guard only; callers keep spans below this (see chunk sizes)


class LinAttnOut(NamedTuple):
    out: jax.Array    # (B, S, H, d_v)
    state: jax.Array  # (B, H, d_k, d_v) final state


def recurrent_reference(q, k, v, log_w, *, state0=None, exclusive=False, u=None):
    """Exact step-by-step recurrence (oracle + decode path).

    q/k: (B,S,H,dk); v: (B,S,H,dv); log_w: (B,S,H,dk) (<= 0).
    u: (H, dk) bonus for the exclusive (RWKV) form.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    st = jnp.zeros((b, h, dk, dv), jnp.float32) if state0 is None else state0.astype(jnp.float32)

    def step(st, inp):
        qt, kt, vt, lwt = inp  # (B,H,dk) etc.
        w = jnp.exp(lwt.astype(jnp.float32))
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32))
        if exclusive:
            eff = st + (u[None, :, :, None] * kv if u is not None else 0.0)
            ot = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), eff)
            st = w[..., None] * st + kv
        else:
            st = w[..., None] * st + kv
            ot = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), st)
        return st, ot

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), log_w.swapaxes(0, 1))
    st, outs = jax.lax.scan(step, st, xs)
    return LinAttnOut(outs.swapaxes(0, 1).astype(v.dtype), st)


def single_step(state, q_t, k_t, v_t, log_w_t, *, exclusive=False, u=None):
    """One decode step. state: (B,H,dk,dv) fp32; q_t/k_t/log_w_t: (B,H,dk); v_t: (B,H,dv)."""
    w = jnp.exp(log_w_t.astype(jnp.float32))
    kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32), v_t.astype(jnp.float32))
    if exclusive:
        eff = state + (u[None, :, :, None] * kv if u is not None else 0.0)
        o = jnp.einsum("bhk,bhkv->bhv", q_t.astype(jnp.float32), eff)
        state = w[..., None] * state + kv
    else:
        state = w[..., None] * state + kv
        o = jnp.einsum("bhk,bhkv->bhv", q_t.astype(jnp.float32), state)
    return state, o.astype(v_t.dtype)


@partial(jax.jit, static_argnames=("chunk", "exclusive"))
def chunked(q, k, v, log_w, *, chunk: int = 64, exclusive: bool = False,
            u: Optional[jax.Array] = None, state0: Optional[jax.Array] = None) -> LinAttnOut:
    """Chunk-parallel evaluation; matches recurrent_reference.

    Shapes as in recurrent_reference; S must be a multiple of ``chunk``
    (callers pad).  All state math in fp32.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    pad = -s % chunk
    if pad:  # zero k/v and log_w=0 leave the state untouched; outputs cropped
        q, k, v, log_w = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                          for t in (q, k, v, log_w))
    s_p = s + pad
    n = s_p // chunk
    f32 = jnp.float32

    def to_chunks(x):  # (B,S,H,*) -> (n, B, T_c, H, *)
        return x.reshape(b, n, chunk, h, -1).swapaxes(0, 1)

    qc, kc, vc, lwc = map(to_chunks, (q, k, v, log_w))
    st0 = jnp.zeros((b, h, dk, dv), f32) if state0 is None else state0.astype(f32)

    def chunk_step(st, inp):
        qt, kt, vt, lw = (x.astype(f32) for x in inp)   # (B,T,H,dk/dv)
        lcum = jnp.cumsum(lw, axis=1)                    # inclusive L_t
        lprev = lcum - lw                                # exclusive L_{t-1}
        l_end = lcum[:, -1:]                             # (B,1,H,dk)
        l_q = lprev if exclusive else lcum               # decay seen by q_t
        q_in = qt * jnp.exp(l_q)                         # <= 1
        k_dec = kt * jnp.exp(jnp.clip(-lcum, None, MAX_EXP))
        # intra-chunk "attention": scores (B,H,T,T) strictly causal
        scores = jnp.einsum("bthk,bshk->bhts", q_in, k_dec)
        ti = jnp.arange(chunk)
        mask = ti[:, None] > ti[None, :] if exclusive else ti[:, None] >= ti[None, :]
        scores = jnp.where(mask[None, None], scores, 0.0)
        o_intra = jnp.einsum("bhts,bshv->bthv", scores, vt)
        if exclusive and u is not None:  # current-token bonus term
            diag = jnp.einsum("bthk,hk,bthk->bth", qt, u.astype(f32), kt)
            o_intra = o_intra + diag[..., None] * vt
        # inter-chunk: contribution of the carried state
        o_inter = jnp.einsum("bthk,bhkv->bthv", q_in, st)
        # state update to chunk end
        k_end = kt * jnp.exp(l_end - lcum)               # decay s -> chunk end
        st = jnp.exp(l_end[:, 0])[..., None] * st + jnp.einsum(
            "bshk,bshv->bhkv", k_end, vt)
        return st, (o_intra + o_inter)

    st, outs = jax.lax.scan(chunk_step, st0, (qc, kc, vc, lwc))
    out = outs.swapaxes(0, 1).reshape(b, s_p, h, dv)[:, :s].astype(v.dtype)
    return LinAttnOut(out, st)


@partial(jax.jit, static_argnames=("chunk",))
def chunked_scalar(q, k, v, log_w, *, chunk: int = 64,
                   state0: Optional[jax.Array] = None) -> LinAttnOut:
    """Chunked linear attention for scalar-per-head decay (Mamba2 / SSD).

    q/k: (B,S,H,dk); v: (B,S,H,dv); log_w: (B,S,H) (<= 0, any magnitude).
    Inclusive form (o_t sees its own k_t v_t).  The intra-chunk decay
    matrix is exp(segsum) of pairwise differences, always <= 1 — stable.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    pad = -s % chunk
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0)))
    s_p = s + pad
    n = s_p // chunk
    f32 = jnp.float32

    qc = q.reshape(b, n, chunk, h, dk).swapaxes(0, 1)
    kc = k.reshape(b, n, chunk, h, dk).swapaxes(0, 1)
    vc = v.reshape(b, n, chunk, h, dv).swapaxes(0, 1)
    lwc = log_w.reshape(b, n, chunk, h).swapaxes(0, 1)
    st0 = jnp.zeros((b, h, dk, dv), f32) if state0 is None else state0.astype(f32)
    ti = jnp.arange(chunk)
    causal = ti[:, None] >= ti[None, :]

    def chunk_step(st, inp):
        qt, kt, vt, lw = (x.astype(f32) for x in inp)
        lcum = jnp.cumsum(lw, axis=1)                     # (B,T,H) inclusive
        l_end = lcum[:, -1, :]                            # (B,H)
        # decay matrix L[t,s] = exp(L_t - L_s), t >= s — differences first
        diff = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B,T,S,H)
        decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -jnp.inf))
        qk = jnp.einsum("bthk,bshk->bhts", qt, kt)
        o_intra = jnp.einsum("bhts,btsh,bshv->bthv",
                             qk, decay, vt)
        o_inter = jnp.einsum("bthk,bth,bhkv->bthv", qt, jnp.exp(lcum), st)
        k_end = kt * jnp.exp(l_end[:, None, :] - lcum)[..., None]
        st = jnp.exp(l_end)[..., None, None] * st + jnp.einsum(
            "bshk,bshv->bhkv", k_end, vt)
        return st, (o_intra + o_inter)

    st, outs = jax.lax.scan(chunk_step, st0, (qc, kc, vc, lwc))
    out = outs.swapaxes(0, 1).reshape(b, s_p, h, dv)[:, :s].astype(v.dtype)
    return LinAttnOut(out, st)
