"""Shared model machinery: parameter specs, norms, RoPE, losses.

Models are pure functions over pytrees.  Every parameter leaf is declared
by a ``ParamSpec`` carrying its shape, initializer and **logical axis
names** (e.g. ("embed", "mlp")); the same spec tree yields

* real initialized arrays            (smoke tests, examples),
* ShapeDtypeStruct stand-ins          (multi-pod dry-run, no allocation),
* NamedShardings via the logical->mesh rules in repro/sharding/specs.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]      # logical axis names, len == ndim
    init: str = "normal"                 # normal | zeros | ones | scaled
    scale: float = 1.0                   # stddev multiplier for "normal"/"scaled"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(rng: jax.Array, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "scaled":  # fan-in scaled normal
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return std * jax.random.normal(rng, spec.shape, dtype)
    return spec.scale * 0.02 * jax.random.normal(rng, spec.shape, dtype)


def init_tree(rng: jax.Array, specs: Any, dtype=jnp.float32) -> Any:
    """Materialize a spec tree into real parameter arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    rngs = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(r, s, dtype) for r, s in zip(rngs, leaves)])


def abstract_tree(specs: Any, dtype=jnp.bfloat16) -> Any:
    """Spec tree -> ShapeDtypeStruct tree (no allocation; dry-run path)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
                        is_leaf=is_spec)


def logical_axes_tree(specs: Any) -> Any:
    """Spec tree -> tree of logical-axis tuples (consumed by sharding rules)."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs: Any) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def stack_specs(spec_tree: Any, n: int, axis_name: Optional[str] = "layers") -> Any:
    """Prepend a stacking (scan) dimension to every leaf of a layer's specs."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        spec_tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(dt)


def rope_frequencies(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)           # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 10000.0) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the head dim is split into sections that
    rotate with different position streams (temporal, height, width).

    x: (B, S, H, D); positions: (n_sections, B, S); sum(sections) == D//2.
    """
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    bounds = jnp.cumsum(jnp.asarray((0,) + sections))
    sec_id = jnp.searchsorted(bounds, jnp.arange(d // 2), side="right") - 1  # (D/2,)
    pos = positions[sec_id]                                # (D/2, B, S) gather per freq
    angles = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross entropy. logits: (B,S,V) or (N,V); labels int.

    The gold logit is picked with an iota-compare reduction rather than
    take_along_axis: a vocab-sharded logits tensor then reduces locally +
    all-reduces a scalar, instead of all-gathering the whole vocab axis.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def chunked_softmax_ce(hidden: jax.Array, w: jax.Array, labels: jax.Array,
                       mask: jax.Array, chunk: int = 512) -> jax.Array:
    """Cross entropy without ever materializing the full (B, S, V) logits.

    hidden: (B, S, D) at the positions that predict ``labels`` (B, S);
    w: (D, V) output projection.  A scan over sequence chunks computes
    each chunk's logits, reduces them to (logz, gold) scalars-per-token,
    and frees them — bounding live logits memory to one chunk (the
    backward pass recomputes them per chunk, scan-remat style).  This is
    what keeps 262k-vocab training inside HBM (EXPERIMENTS.md §Perf).
    """
    b, s, d = hidden.shape
    pad = -s % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (s + pad) // chunk
    hc = hidden.reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        from repro.sharding.specs import constrain
        h, l, m = xs
        h = constrain(h, ("batch", None, None))
        logits = (h @ w).astype(jnp.float32)                 # (B, chunk, V)
        logits = constrain(logits, ("batch", None, "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(iota == l[..., None], logits, 0.0), axis=-1)
        nll = (logz - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), ()

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def causal_mask(s_q: int, s_k: int, q_offset: int = 0) -> jax.Array:
    """(s_q, s_k) boolean mask; True = attend.  q position i sits at i+q_offset."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_k)[None, :]
    return kj <= qi


def sliding_mask(s_q: int, s_k: int, window: int, q_offset: int = 0) -> jax.Array:
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_k)[None, :]
    return (kj <= qi) & (kj > qi - window)
