"""Generic decoder assembly for all decoder-only assigned architectures.

A config is compiled into a **layer plan**: a short list of *groups*, each
a repeating unit of layer descriptors scanned ``reps`` times with stacked
parameters (lax.scan keeps HLO size O(unique layers), which is what makes
the 88-layer / 512-device dry-runs compile).  The plan covers:

* dense GQA/MQA decoders (stablelm, granite, phi3)
* 5:1 local:global sliding-window patterns (gemma3)
* interleaved / leading-dense MoE (llama4-maverick, deepseek-v2)
* MLA attention (deepseek-v2)
* Mamba2 stacks with a weight-shared attention block every N layers
  (zamba2) — shared weights, per-application KV caches
* RWKV6 (attention-free)
* M-RoPE + stub vision frontend (qwen2-vl)

Three entry points per model: ``loss`` (train), ``prefill`` (full seq ->
cache + last logits), ``decode_step`` (one token against the cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import attention as attn
from . import ffn as ffn_lib
from . import rwkv as rwkv_lib
from . import ssm as ssm_lib
from .common import ParamSpec, chunked_softmax_ce, rms_norm, stack_specs
from .linear_attn import single_step  # noqa: F401  (re-export convenience)

# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    kind: str                      # attn | mamba | rwkv
    window: Optional[int] = None   # sliding window (attn)
    ffn: str = "mlp"               # mlp | moe | none
    d_ff: Optional[int] = None
    shared: bool = False           # params come from the shared block (zamba)


@dataclasses.dataclass(frozen=True)
class Group:
    descs: tuple
    reps: int


def build_plan(cfg: ArchConfig) -> list[Group]:
    f = cfg.family
    if f in ("dense", "vlm"):
        if cfg.global_every:
            loc = LayerDesc("attn", window=cfg.sliding_window)
            glb = LayerDesc("attn")
            unit = (loc,) * (cfg.global_every - 1) + (glb,)
            reps, rem = divmod(cfg.n_layers, cfg.global_every)
            groups = [Group(unit, reps)]
            if rem:
                groups.append(Group((loc,) * rem, 1))
            return groups
        return [Group((LayerDesc("attn"),), cfg.n_layers)]
    if f == "moe":
        groups = []
        if cfg.n_dense_layers:
            groups.append(Group((LayerDesc("attn", d_ff=cfg.dense_d_ff or cfg.d_ff),),
                                cfg.n_dense_layers))
        n_rest = cfg.n_layers - cfg.n_dense_layers
        if cfg.moe_every == 1:
            groups.append(Group((LayerDesc("attn", ffn="moe"),), n_rest))
        else:
            unit = tuple(
                LayerDesc("attn", ffn="moe") if j == cfg.moe_every - 1
                else LayerDesc("attn", d_ff=cfg.dense_d_ff or cfg.d_ff)
                for j in range(cfg.moe_every))
            reps, rem = divmod(n_rest, cfg.moe_every)
            groups.append(Group(unit, reps))
            if rem:
                groups.append(Group(
                    (LayerDesc("attn", d_ff=cfg.dense_d_ff or cfg.d_ff),) * rem, 1))
        return groups
    if f == "rwkv":
        return [Group((LayerDesc("rwkv", ffn="none"),), cfg.n_layers)]
    if f == "hybrid":
        m = LayerDesc("mamba", ffn="none")
        s = LayerDesc("attn", shared=True)
        n = cfg.shared_attn_every
        reps, rem = divmod(cfg.n_layers, n)
        groups = [Group((m,) * n + (s,), reps)]
        if rem:
            groups.append(Group((m,) * rem, 1))
        return groups
    raise ValueError(f"unknown family {f}")


# ---------------------------------------------------------------------------
# Per-desc specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ArchConfig) -> dict:
    if cfg.use_mla:
        return attn.mla_specs(cfg.d_model, cfg.n_heads, q_lora=cfg.q_lora,
                              kv_lora=cfg.kv_lora, qk_nope=cfg.qk_nope,
                              qk_rope=cfg.qk_rope, v_dim=cfg.v_head_dim)
    return attn.gqa_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                          cfg.use_qk_norm)


def desc_specs(desc: LayerDesc, cfg: ArchConfig) -> dict:
    if desc.kind == "rwkv":
        dims = rwkv_lib.RWKVDims.make(cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim)
        return {"ln1": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
                "tm": rwkv_lib.rwkv6_time_mix_specs(dims),
                "ln2": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
                "cm": rwkv_lib.rwkv6_channel_mix_specs(dims)}
    if desc.kind == "mamba":
        dims = ssm_lib.SSMDims.make(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                                    cfg.ssm_head_dim, cfg.ssm_conv)
        return {"ln": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
                "mamba": ssm_lib.mamba2_specs(dims)}
    s = {"ln1": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
         "attn": _attn_specs(cfg),
         "ln2": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    if desc.ffn == "moe":
        s["ffn"] = ffn_lib.moe_specs(cfg.d_model, cfg.d_ff_expert or cfg.d_ff,
                                     cfg.n_experts, cfg.n_shared_experts)
    elif desc.ffn == "mlp":
        s["ffn"] = ffn_lib.mlp_specs(cfg.d_model, desc.d_ff or cfg.d_ff,
                                     gated=cfg.gated_mlp)
    return s


def build_param_specs(cfg: ArchConfig) -> dict:
    plan = build_plan(cfg)
    specs: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"), "scaled")
    groups = []
    for g in plan:
        per_desc = tuple(
            {} if d.shared else
            (desc_specs(d, cfg) if g.reps == 1 else stack_specs(desc_specs(d, cfg), g.reps))
            for d in g.descs)
        groups.append(per_desc)
    specs["groups"] = groups
    if any(d.shared for g in plan for d in g.descs):
        shared = desc_specs(LayerDesc("attn", d_ff=cfg.d_ff), cfg)
        specs["shared_attn"] = shared
    return specs


# ---------------------------------------------------------------------------
# Context & positions
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Ctx:
    cfg: ArchConfig
    positions: jax.Array                    # (B, S)
    mrope_positions: Optional[jax.Array] = None   # (3, B, S)
    phase: str = "train"


def _mrope_ids(cfg: ArchConfig, batch: int, n_vis: int, s_text: int) -> jax.Array:
    g = cfg.vision_grid
    vi = jnp.arange(n_vis)
    vis = jnp.stack([jnp.zeros_like(vi), vi // g, vi % g])           # (3, Nv)
    start = (n_vis + g - 1) // g + 1
    ti = start + jnp.arange(s_text)
    txt = jnp.stack([ti, ti, ti])                                    # (3, St)
    ids = jnp.concatenate([vis, txt], axis=1)                        # (3, S)
    return jnp.broadcast_to(ids[:, None, :], (3, batch, n_vis + s_text))


# ---------------------------------------------------------------------------
# Layer application — full sequence (train / prefill without cache)
# ---------------------------------------------------------------------------


def apply_layer(desc: LayerDesc, p: dict, x: jax.Array, ctx: Ctx) -> tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    if desc.kind == "rwkv":
        dims = rwkv_lib.RWKVDims.make(cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim)
        x = x + rwkv_lib.time_mix_forward(p["tm"], rms_norm(x, p["ln1"]), dims)
        x = x + rwkv_lib.channel_mix_forward(p["cm"], rms_norm(x, p["ln2"]))
        return x, aux
    if desc.kind == "mamba":
        dims = ssm_lib.SSMDims.make(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                                    cfg.ssm_head_dim, cfg.ssm_conv)
        x = x + ssm_lib.mamba2_forward(p["mamba"], rms_norm(x, p["ln"]), dims)
        return x, aux
    h = rms_norm(x, p["ln1"])
    if cfg.use_mla:
        a = attn.mla_forward(p["attn"], h, positions=ctx.positions,
                             rope_theta=cfg.rope_theta, qk_nope=cfg.qk_nope,
                             qk_rope=cfg.qk_rope)
    else:
        a = attn.gqa_forward(p["attn"], h, positions=ctx.positions,
                             rope_theta=cfg.rope_theta, window=desc.window,
                             mrope_sections=cfg.mrope_sections,
                             mrope_positions=ctx.mrope_positions)
    x = x + a
    h = rms_norm(x, p["ln2"])
    if desc.ffn == "moe":
        out, aux = _moe(p["ffn"], h, cfg)
        x = x + out
    elif desc.ffn == "mlp":
        x = x + ffn_lib.mlp_forward(p["ffn"], h)
    return x, aux


def _moe(pf, h, cfg):
    if cfg.moe_impl == "sharded":
        return ffn_lib.moe_forward_sharded(
            pf, h, top_k=cfg.top_k, n_experts=cfg.n_experts,
            capacity_factor=cfg.capacity_factor,
            router_softmax=cfg.router_softmax)
    return ffn_lib.moe_forward(pf, h, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               router_softmax=cfg.router_softmax)


def forward(params: dict, x: jax.Array, cfg: ArchConfig, ctx: Ctx) -> tuple[jax.Array, jax.Array]:
    """Run all groups; returns (hidden (B,S,D), total aux loss)."""
    from repro.sharding.specs import constrain
    x = constrain(x, ("batch", "seq", None))
    plan = build_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    for gi, g in enumerate(plan):
        gp = params["groups"][gi]
        if g.reps == 1:
            for di, d in enumerate(g.descs):
                p = params["shared_attn"] if d.shared else gp[di]
                x, aux = apply_layer(d, p, x, ctx)
                aux_total = aux_total + aux
        else:
            def body(carry, xs):
                xc, auxc = carry
                for di, d in enumerate(g.descs):
                    p = params["shared_attn"] if d.shared else xs[di]
                    xc, aux = apply_layer(d, p, xc, ctx)
                    auxc = auxc + aux
                from repro.sharding.specs import constrain as _c
                xc = _c(xc, ("batch", "seq", None))
                return (xc, auxc), ()

            if cfg.remat and ctx.phase == "train":
                body = jax.checkpoint(body)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), gp)
    return rms_norm(x, params["final_norm"]), aux_total


def logits_of(params: dict, hidden: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return hidden @ params["embed"].T
    return hidden @ params["lm_head"]


def embed_tokens(params: dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.tie_embeddings:  # gemma-style embedding scaling
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return x


# ---------------------------------------------------------------------------
# Train / prefill / decode entry points
# ---------------------------------------------------------------------------


def loss_fn(params: dict, batch: dict, cfg: ArchConfig) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    mrope = None
    if cfg.family == "vlm":
        vis = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        mrope = _mrope_ids(cfg, b, vis.shape[1], s)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                     (b, x.shape[1]))
    ctx = Ctx(cfg, positions, mrope, phase="train")
    hidden, aux = forward(params, x, cfg, ctx)
    if cfg.family == "vlm":
        hidden = hidden[:, -s:, :]
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    w_out = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # positions 0..S-2 predict labels 1..S-1; chunked CE never materializes
    # the full (B, S, V) logits (see models/common.py)
    ce = chunked_softmax_ce(hidden[:, :-1], w_out, jnp.maximum(labels[:, 1:], 0),
                            mask[:, 1:])
    total = ce + cfg.aux_loss_coef * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def _desc_cache_layout(desc: LayerDesc, cfg: ArchConfig, batch: int, max_seq: int,
                       dtype=jnp.bfloat16) -> dict:
    """name -> (shape-without-reps, logical axes, dtype)."""
    if desc.kind == "rwkv":
        dims = rwkv_lib.RWKVDims.make(cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim)
        return {
            "wkv": ((batch, dims.n_heads, dims.head_dim, dims.head_dim),
                    ("batch", "heads", None, None), jnp.float32),
            "shift_tm": ((batch, cfg.d_model), ("batch", "embed"), dtype),
            "shift_cm": ((batch, cfg.d_model), ("batch", "embed"), dtype),
        }
    if desc.kind == "mamba":
        dims = ssm_lib.SSMDims.make(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                                    cfg.ssm_head_dim, cfg.ssm_conv)
        return {
            "ssm": ((batch, dims.n_heads, dims.d_state, dims.head_dim),
                    ("batch", "heads", None, None), jnp.float32),
            "conv": ((batch, dims.conv_w - 1, dims.conv_dim),
                     ("batch", None, "mlp"), dtype),
        }
    if cfg.use_mla:
        return {
            "c_kv": ((batch, max_seq, cfg.kv_lora),
                     ("batch", "cache_seq", "kv_lora"), dtype),
            "k_rope": ((batch, max_seq, cfg.qk_rope),
                       ("batch", "cache_seq", None), dtype),
        }
    slots = min(desc.window, max_seq) if desc.window else max_seq
    lay = {
        "k": ((batch, slots, cfg.n_kv_heads, cfg.head_dim),
              ("batch", "cache_seq", "kv_heads", "head_dim"), dtype),
        "v": ((batch, slots, cfg.n_kv_heads, cfg.head_dim),
              ("batch", "cache_seq", "kv_heads", "head_dim"), dtype),
    }
    if desc.window:
        lay["slot_pos"] = ((slots,), ("cache_seq",), jnp.int32)
    return lay


def cache_structure(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
                    abstract: bool = True):
    """Returns (cache pytree, logical-axes pytree) for the whole model."""
    plan = build_plan(cfg)
    caches, axes = [], []
    for g in plan:
        g_cache, g_axes = [], []
        for d in g.descs:
            layout = _desc_cache_layout(d, cfg, batch, max_seq, dtype)
            c, a = {}, {}
            for name, (shape, ax, dt) in layout.items():
                full = (g.reps,) + shape if g.reps > 1 else shape
                full_ax = (("layers",) + ax) if g.reps > 1 else ax
                c[name] = (jax.ShapeDtypeStruct(full, dt) if abstract
                           else jnp.zeros(full, dt))
                a[name] = full_ax
            g_cache.append(c)
            g_axes.append(a)
        caches.append(tuple(g_cache))
        axes.append(tuple(g_axes))
    return {"groups": caches}, {"groups": axes}


# ---------------------------------------------------------------------------
# Prefill (full sequence -> cache) and decode (single token)
# ---------------------------------------------------------------------------


def _fill_layer(desc: LayerDesc, p: dict, x: jax.Array, ctx: Ctx, max_seq: int,
                cache_dtype=jnp.bfloat16):
    """Full-seq layer application that also emits this layer's cache."""
    cfg = ctx.cfg
    if desc.kind == "rwkv":
        dims = rwkv_lib.RWKVDims.make(cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim)
        h1 = rms_norm(x, p["ln1"])
        out, st = _rwkv_tm_prefill(p["tm"], h1, dims)
        x = x + out
        h2 = rms_norm(x, p["ln2"])
        x = x + rwkv_lib.channel_mix_forward(p["cm"], h2)
        cache = {"wkv": st, "shift_tm": h1[:, -1, :].astype(cache_dtype),
                 "shift_cm": h2[:, -1, :].astype(cache_dtype)}
        return x, cache
    if desc.kind == "mamba":
        dims = ssm_lib.SSMDims.make(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                                    cfg.ssm_head_dim, cfg.ssm_conv)
        h = rms_norm(x, p["ln"])
        out, st = _mamba_prefill(p["mamba"], h, dims, cache_dtype)
        return x + out, st
    h = rms_norm(x, p["ln1"])
    if cfg.use_mla:
        a, cache = attn.mla_fill_cache(p["attn"], h, positions=ctx.positions,
                                       rope_theta=cfg.rope_theta, qk_nope=cfg.qk_nope,
                                       qk_rope=cfg.qk_rope, max_seq=max_seq)
    else:
        a, cache = attn.gqa_fill_cache(p["attn"], h, positions=ctx.positions,
                                       rope_theta=cfg.rope_theta, window=desc.window,
                                       max_seq=max_seq,
                                       mrope_sections=cfg.mrope_sections,
                                       mrope_positions=ctx.mrope_positions)
    cache = jax.tree.map(lambda t: t.astype(cache_dtype)
                         if t.dtype != jnp.int32 else t, cache)
    x = x + a
    h = rms_norm(x, p["ln2"])
    if desc.ffn == "moe":
        out, _ = _moe(p["ffn"], h, cfg)
        x = x + out
    elif desc.ffn == "mlp":
        x = x + ffn_lib.mlp_forward(p["ffn"], h)
    return x, cache


def _rwkv_tm_prefill(p, xn, dims):
    b, s, d = xn.shape
    h, hd = dims.n_heads, dims.head_dim
    xw, xk, xv, xr, xg = rwkv_lib._ddlerp(p, xn, rwkv_lib._shift(xn))
    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    log_w = rwkv_lib._decay(p, xw).reshape(b, s, h, hd)
    res = rwkv_lib.chunked(r, k, v, log_w, chunk=16, exclusive=True, u=p["bonus"])
    o = rwkv_lib.layer_norm(res.out.reshape(b, s, d), p["ln_x_g"], p["ln_x_b"])
    return (o * g) @ p["wo"], res.state


def _mamba_prefill(p, xn, dims, cache_dtype):
    b, s, _ = xn.shape
    z, xbc, dt = ssm_lib._split_proj(p, xn, dims)
    pad = dims.conv_w - 1
    xbc_p = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(xbc_p[:, i: i + s, :] * p["conv_w"][i][None, None, :]
               for i in range(dims.conv_w))
    xbc_act = jax.nn.silu(conv + p["conv_b"])
    x_in = xbc_act[..., : dims.d_inner]
    b_in = xbc_act[..., dims.d_inner: dims.d_inner + dims.d_state]
    c_in = xbc_act[..., dims.d_inner + dims.d_state:]
    out, st = ssm_lib._ssd_core(p, z, x_in, b_in, c_in, dt, dims)
    conv_state = xbc[:, -(dims.conv_w - 1):, :].astype(cache_dtype)
    return out, {"ssm": st, "conv": conv_state}


def _decode_layer(desc: LayerDesc, p: dict, c: dict, x: jax.Array, pos: jax.Array,
                  ctx: Ctx):
    cfg = ctx.cfg
    if desc.kind == "rwkv":
        dims = rwkv_lib.RWKVDims.make(cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim)
        h1 = rms_norm(x, p["ln1"])
        out, wkv, sh_tm = rwkv_lib.time_mix_decode(
            p["tm"], h1, c["wkv"], c["shift_tm"].astype(h1.dtype), dims)
        x = x + out
        h2 = rms_norm(x, p["ln2"])
        out2, sh_cm = rwkv_lib.channel_mix_decode(p["cm"], h2,
                                                  c["shift_cm"].astype(h2.dtype))
        x = x + out2
        return x, {"wkv": wkv, "shift_tm": sh_tm.astype(c["shift_tm"].dtype),
                   "shift_cm": sh_cm.astype(c["shift_cm"].dtype)}
    if desc.kind == "mamba":
        dims = ssm_lib.SSMDims.make(cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                                    cfg.ssm_head_dim, cfg.ssm_conv)
        h = rms_norm(x, p["ln"])
        out, st = ssm_lib.mamba2_decode(
            p["mamba"], h, {"ssm": c["ssm"], "conv": c["conv"].astype(h.dtype)}, dims)
        return x + out, {"ssm": st["ssm"], "conv": st["conv"].astype(c["conv"].dtype)}
    h = rms_norm(x, p["ln1"])
    if cfg.use_mla:
        a, cache = attn.mla_decode(p["attn"], h, c, pos, rope_theta=cfg.rope_theta,
                                   qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope)
    else:
        a, cache = attn.gqa_decode(p["attn"], h, c, pos, rope_theta=cfg.rope_theta,
                                   window=desc.window,
                                   mrope_sections=cfg.mrope_sections,
                                   mrope_positions=ctx.mrope_positions)
    x = x + a
    h = rms_norm(x, p["ln2"])
    if desc.ffn == "moe":
        out, _ = _moe(p["ffn"], h, cfg)
        x = x + out
    elif desc.ffn == "mlp":
        x = x + ffn_lib.mlp_forward(p["ffn"], h)
    return x, cache


def prefill(params: dict, batch: dict, cfg: ArchConfig, max_seq: int,
            cache_dtype=jnp.bfloat16):
    """Full-sequence forward emitting the KV/state cache.

    Returns (last-token logits (B, V), cache pytree).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    mrope = None
    if cfg.family == "vlm":
        vis = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        mrope = _mrope_ids(cfg, b, vis.shape[1], s)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 (b, x.shape[1]))
    ctx = Ctx(cfg, positions, mrope, phase="prefill")
    plan = build_plan(cfg)
    caches = []
    for gi, g in enumerate(plan):
        gp = params["groups"][gi]
        if g.reps == 1:
            g_cache = []
            for di, d in enumerate(g.descs):
                p = params["shared_attn"] if d.shared else gp[di]
                x, cache = _fill_layer(d, p, x, ctx, max_seq, cache_dtype)
                g_cache.append(cache)
            caches.append(tuple(g_cache))
        else:
            def body(xc, xs):
                new_caches = []
                for di, d in enumerate(g.descs):
                    p = params["shared_attn"] if d.shared else xs[di]
                    xc, cache = _fill_layer(d, p, xc, ctx, max_seq, cache_dtype)
                    new_caches.append(cache)
                return xc, tuple(new_caches)

            x, g_cache = jax.lax.scan(body, x, gp)
            caches.append(g_cache)
    hidden = rms_norm(x, params["final_norm"])
    logits = logits_of(params, hidden[:, -1:, :], cfg)[:, 0, :]
    return logits, {"groups": caches}


def decode_step(params: dict, cache: dict, batch: dict, cfg: ArchConfig):
    """One decode step. batch: {"tokens": (B,1), "pos": ()} -> (logits, cache)."""
    tokens = batch["tokens"]
    pos = batch["pos"]
    b = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    mrope = None
    if cfg.family == "vlm":
        # `pos` counts concat-space slots; map the text index into mrope space
        start = (cfg.n_vision_tokens + cfg.vision_grid - 1) // cfg.vision_grid + 1
        mp = jnp.broadcast_to(pos - cfg.n_vision_tokens + start, (b, 1)).astype(jnp.int32)
        mrope = jnp.stack([mp, mp, mp])
    ctx = Ctx(cfg, positions, mrope, phase="decode")
    plan = build_plan(cfg)
    new_caches = []
    for gi, g in enumerate(plan):
        gp = params["groups"][gi]
        gc = cache["groups"][gi]
        if g.reps == 1:
            g_new = []
            for di, d in enumerate(g.descs):
                p = params["shared_attn"] if d.shared else gp[di]
                x, nc = _decode_layer(d, p, gc[di], x, pos, ctx)
                g_new.append(nc)
            new_caches.append(tuple(g_new))
        else:
            def body(xc, xs):
                layer_params, layer_cache = xs
                new_c = []
                for di, d in enumerate(g.descs):
                    p = params["shared_attn"] if d.shared else layer_params[di]
                    xc, nc = _decode_layer(d, p, layer_cache[di], xc, pos, ctx)
                    new_c.append(nc)
                return xc, tuple(new_c)

            x, g_new = jax.lax.scan(body, x, (gp, gc))
            new_caches.append(g_new)
    hidden = rms_norm(x, params["final_norm"])
    logits = logits_of(params, hidden, cfg)[:, 0, :]
    return logits, {"groups": new_caches}
