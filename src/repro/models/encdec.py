"""Whisper-style encoder-decoder backbone (assigned arch: whisper-medium).

Per the brief the audio conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, enc_frames, d_model).  The transformer
backbone is faithful: pre-LN encoder with bidirectional self-attention
and learned positions, decoder with causal self-attention + cross
attention, no RoPE (whisper uses absolute embeddings).

Decode caches: decoder self-attention KV (ring-free, full) plus the
cross-attention K/V computed once from the encoder output at prefill.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import attention as attn
from .common import ParamSpec, chunked_softmax_ce, layer_norm, stack_specs
from .ffn import mlp_specs


def _mlp_gelu(p, x):
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


def _mlp_gelu_specs(d_model, d_ff):
    return {"w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), "scaled"),
            "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), "scaled")}


def _ln_specs(d):
    return {"g": ParamSpec((d,), ("embed",), "ones"),
            "b": ParamSpec((d,), ("embed",), "zeros")}


def _ln(p, x):
    return layer_norm(x, p["g"], p["b"])


def build_param_specs(cfg: ArchConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    enc_layer = {"ln1": _ln_specs(d), "attn": attn.gqa_specs(d, h, kv, dh),
                 "ln2": _ln_specs(d), "mlp": _mlp_gelu_specs(d, cfg.d_ff)}
    dec_layer = {"ln1": _ln_specs(d), "self_attn": attn.gqa_specs(d, h, kv, dh),
                 "ln2": _ln_specs(d), "cross_attn": attn.gqa_specs(d, h, kv, dh),
                 "ln3": _ln_specs(d), "mlp": _mlp_gelu_specs(d, cfg.d_ff)}
    return {
        "enc_pos": ParamSpec((cfg.enc_frames, d), (None, "embed")),
        "enc_layers": stack_specs(enc_layer, cfg.n_enc_layers),
        "enc_norm": _ln_specs(d),
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed")),
        "dec_pos": ParamSpec((cfg.max_target_positions, d), (None, "embed")),
        "dec_layers": stack_specs(dec_layer, cfg.n_layers),
        "dec_norm": _ln_specs(d),
    }


def encode(params: dict, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, T, D) stub embeddings -> encoder hidden states."""
    t = frames.shape[1]
    x = frames + params["enc_pos"][:t][None]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), frames.shape[:2])

    def body(xc, lp):
        h = _ln(lp["ln1"], xc)
        xc = xc + attn.gqa_forward(lp["attn"], h, positions=positions,
                                   bidirectional=True, use_rope=False)
        h = _ln(lp["ln2"], xc)
        return xc + _mlp_gelu(lp["mlp"], h), ()

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(params["enc_norm"], x)


def _decoder(params: dict, tokens: jax.Array, enc_out: jax.Array, cfg: ArchConfig,
             phase: str, return_hidden: bool = False) -> jax.Array:
    b, s = tokens.shape
    x = params["embed"][tokens] + params["dec_pos"][:s][None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(xc, lp):
        h = _ln(lp["ln1"], xc)
        xc = xc + attn.gqa_forward(lp["self_attn"], h, positions=positions,
                                   use_rope=False)
        h = _ln(lp["ln2"], xc)
        ek, ev = attn.cross_encode_kv(lp["cross_attn"], enc_out)
        xc = xc + attn.cross_forward(lp["cross_attn"], h, ek, ev)
        h = _ln(lp["ln3"], xc)
        return xc + _mlp_gelu(lp["mlp"], h), ()

    if cfg.remat and phase == "train":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(params["dec_norm"], x)
    if return_hidden:
        return x
    return x @ params["embed"].T  # whisper ties the output projection


def loss_fn(params: dict, batch: dict, cfg: ArchConfig):
    hidden = _decoder(params, batch["tokens"], encode(params, batch["frames"], cfg),
                      cfg, "train", return_hidden=True)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    ce = chunked_softmax_ce(hidden[:, :-1], params["embed"].T,
                            jnp.maximum(labels[:, 1:], 0), mask[:, 1:])
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def cache_structure(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
                    abstract: bool = True):
    l, kv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else (
        lambda s, dt: jnp.zeros(s, dt))
    cache = {
        "self_k": mk((l, batch, max_seq, kv, dh), dtype),
        "self_v": mk((l, batch, max_seq, kv, dh), dtype),
        "cross_k": mk((l, batch, cfg.enc_frames, kv, dh), dtype),
        "cross_v": mk((l, batch, cfg.enc_frames, kv, dh), dtype),
    }
    axes = {
        "self_k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
        "self_v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
        "cross_k": ("layers", "batch", None, "kv_heads", "head_dim"),
        "cross_v": ("layers", "batch", None, "kv_heads", "head_dim"),
    }
    return cache, axes


def prefill(params: dict, batch: dict, cfg: ArchConfig, max_seq: int,
            cache_dtype=jnp.bfloat16):
    """Encode frames + run the decoder prompt; emit self+cross caches."""
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens] + params["dec_pos"][:s][None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(xc, lp):
        h = _ln(lp["ln1"], xc)
        a, kvs = attn.gqa_fill_cache(lp["self_attn"], h, positions=positions,
                                     max_seq=max_seq, use_rope=False)
        xc = xc + a
        h = _ln(lp["ln2"], xc)
        ek, ev = attn.cross_encode_kv(lp["cross_attn"], enc_out)
        xc = xc + attn.cross_forward(lp["cross_attn"], h, ek, ev)
        h = _ln(lp["ln3"], xc)
        xc = xc + _mlp_gelu(lp["mlp"], h)
        out = {"self_k": kvs["k"].astype(cache_dtype),
               "self_v": kvs["v"].astype(cache_dtype),
               "cross_k": ek.astype(cache_dtype), "cross_v": ev.astype(cache_dtype)}
        return xc, out

    x, cache = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(params["dec_norm"], x)
    logits = (x[:, -1:, :] @ params["embed"].T)[:, 0]
    return logits, cache


def decode_step(params: dict, cache: dict, batch: dict, cfg: ArchConfig):
    tokens, pos = batch["tokens"], batch["pos"]
    b = tokens.shape[0]
    x = params["embed"][tokens] + jax.lax.dynamic_slice(
        params["dec_pos"], (pos, 0), (1, cfg.d_model))[None]

    def body(xc, xs):
        lp, lc = xs
        h = _ln(lp["ln1"], xc)
        a, kv_new = attn.gqa_decode(lp["self_attn"], h,
                                    {"k": lc["self_k"], "v": lc["self_v"]}, pos,
                                    use_rope=False)
        xc = xc + a
        h = _ln(lp["ln2"], xc)
        xc = xc + attn.cross_forward(lp["cross_attn"], h, lc["cross_k"], lc["cross_v"])
        h = _ln(lp["ln3"], xc)
        xc = xc + _mlp_gelu(lp["mlp"], h)
        out = {"self_k": kv_new["k"], "self_v": kv_new["v"],
               "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}
        return xc, out

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = _ln(params["dec_norm"], x)
    return (x @ params["embed"].T)[:, 0], new_cache
