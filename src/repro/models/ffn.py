"""Feed-forward blocks: SwiGLU MLP and capacity-bounded Mixture-of-Experts.

The MoE dispatch deliberately reuses the paper's core primitive — compact
the *active set* into a fixed-capacity buffer and make compute scale with
it (DESIGN.md Sec. 4): each expert gathers the tokens routed to it into a
``capacity``-bounded buffer (sort-free ranking via cumsum over the
routing mask), computes one dense (E, C, d) batch, and scatters back with
the gate weights.  No (T, E, C) one-hot dispatch einsum is ever built, so
HLO FLOPs stay proportional to the ACTIVE parameter count — which is what
makes the MoE rooflines honest.

Sharding: expert tensors carry the "experts" logical axis (mapped to the
mesh "model" axis). Under pjit, XLA partitions the (E, C, d) expert
batches across the model axis; the gather/scatter lower to all-to-all-
free masked ops because routing tensors are replicated on that axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from .common import ParamSpec


def mlp_specs(d_model: int, d_ff: int, gated: bool = True) -> dict:
    s = {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), "scaled"),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), "scaled"),
    }
    if gated:
        s["w_gate"] = ParamSpec((d_model, d_ff), ("embed", "mlp"), "scaled")
    return s


def mlp_forward(p: dict, x: jax.Array) -> jax.Array:
    if "w_gate" in p:  # SwiGLU
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:  # plain GELU MLP (granite-style)
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


def moe_specs(d_model: int, d_ff: int, n_experts: int, n_shared: int = 0) -> dict:
    s = {
        "router": ParamSpec((d_model, n_experts), ("embed", "experts"), "scaled"),
        "we_gate": ParamSpec((n_experts, d_model, d_ff), ("experts", "embed", "expert_mlp"), "scaled"),
        "we_up": ParamSpec((n_experts, d_model, d_ff), ("experts", "embed", "expert_mlp"), "scaled"),
        "we_down": ParamSpec((n_experts, d_ff, d_model), ("experts", "expert_mlp", "embed"), "scaled"),
    }
    if n_shared:
        s["shared"] = mlp_specs(d_model, d_ff * n_shared)
    return s


def moe_forward(p: dict, x: jax.Array, *, top_k: int, capacity_factor: float = 1.25,
                router_softmax: bool = True) -> tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE with per-expert capacity.

    x: (B, S, D).  Returns (out (B,S,D), aux_loss ()).

    Dispatch = the AEQ idea: per expert, rank the tokens routed to it with
    a cumsum over the routing mask (position-in-queue), drop overflow
    (capacity), gather into (E, C, D), batch-matmul, scatter-add back.
    """
    b, s, d = x.shape
    n_experts = p["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt @ p["router"]).astype(jnp.float32)           # (T, E)
    if router_softmax:
        probs = jax.nn.softmax(logits, axis=-1)
    else:
        probs = jax.nn.sigmoid(logits)
    gate_vals, idx = jax.lax.top_k(probs, top_k)              # (T, k)
    if router_softmax and top_k > 1:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = int(max(1, round(t * top_k * capacity_factor / n_experts)))
    # routing mask (T, k, E) -> position of each (token, slot) inside its
    # expert's queue, via exclusive cumsum over the flattened (T*k) order.
    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(t * top_k, n_experts)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat           # exclusive
    pos_in_expert = jnp.sum(pos_in_expert * flat, axis=1).reshape(t, top_k)
    keep = (pos_in_expert < capacity) & (onehot.sum(-1) > 0).astype(bool)

    # gather tokens into (E, C, D) queues; dropped tokens target slot ==
    # capacity, which mode="drop" discards (never clobbers a real slot).
    expert_of = idx                                            # (T, k)
    slot = jnp.where(keep, pos_in_expert, capacity)
    token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, top_k))
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    buf = buf.at[expert_of, slot].set(xt[token_ids], mode="drop")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["we_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["we_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["we_down"])      # (E, C, D)

    # scatter back with gate weights
    gathered = out_buf[expert_of, slot]                        # (T, k, D)
    gathered = gathered * jnp.where(keep, gate_vals, 0.0).astype(x.dtype)[..., None]
    out = gathered.sum(axis=1).reshape(b, s, d)

    if "shared" in p:
        out = out + mlp_forward(p["shared"], x)

    # load-balancing aux loss (Switch/GShard form)
    me = probs.mean(axis=0)                                    # (E,)
    ce = flat.reshape(t, top_k, n_experts).sum(axis=(0, 1)) / max(t * top_k, 1)
    aux = n_experts * jnp.sum(me * ce)
    return out, aux


def moe_forward_sharded(p: dict, x: jax.Array, *, top_k: int, n_experts: int,
                        capacity_factor: float = 1.25, router_softmax: bool = True,
                        mesh=None, expert_axis: str = "model",
                        batch_axes=("pod", "data")) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map: local-expert masked compaction.

    The pjit global-scatter dispatch in ``moe_forward`` makes XLA
    all-gather the (T, k, D)-sized scatter indices across the mesh
    (measured: 128 GB u32 per step on deepseek-v2 train_4k — the single
    largest collective in the fleet).  Here the routing stays local:
    tokens are replicated over the expert (model) axis, each shard
    compacts ONLY the tokens routed to its own experts (the paper's
    fixed-capacity queue build), computes its expert batch, and the
    shards' partial outputs are combined with one bf16 psum — the only
    collective this layer emits.

    x: (B, S, D) sharded batch-over-``batch_axes``; expert tensors
    sharded (expert_axis, None, None).  Falls back to the dense-dispatch
    path when no mesh is registered (single-device tests).
    """
    if mesh is None:
        from repro.sharding.specs import _CONSTRAINT_MESH
        mesh = _CONSTRAINT_MESH[0]
    if mesh is None or expert_axis not in getattr(mesh, "shape", {}) \
            or n_experts % mesh.shape[expert_axis] != 0:
        return moe_forward(p, x, top_k=top_k, capacity_factor=capacity_factor,
                           router_softmax=router_softmax)
    from jax.sharding import PartitionSpec as P

    n_shards = mesh.shape[expert_axis]
    e_loc = n_experts // n_shards
    b, s, d = x.shape
    baxes = tuple(a for a in batch_axes if a in mesh.shape)

    def body(xb, router, we_gate, we_up, we_down):
        t = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(t, d)
        logits = (xt @ router).astype(jnp.float32)               # (T_loc, E)
        probs = jax.nn.softmax(logits, -1) if router_softmax else jax.nn.sigmoid(logits)
        gate_vals, idx = jax.lax.top_k(probs, top_k)             # (T_loc, k)
        if router_softmax and top_k > 1:
            gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
        shard = jax.lax.axis_index(expert_axis)
        local = idx // e_loc == shard                            # (T_loc, k) mine?
        idx_loc = jnp.where(local, idx % e_loc, e_loc)           # e_loc = drop slot
        capacity = int(max(1, round(t * top_k * capacity_factor / n_experts)))
        onehot = (idx_loc[..., None] ==
                  jnp.arange(e_loc)[None, None, :]).astype(jnp.int32)  # (T,k,El)
        flat = onehot.reshape(t * top_k, e_loc)
        pos = (jnp.cumsum(flat, axis=0) - flat)
        pos = jnp.sum(pos.reshape(t, top_k, e_loc) * onehot, axis=-1)  # (T, k)
        keep = local & (pos < capacity)
        slot = jnp.where(keep, pos, capacity)                    # OOB drops
        # NOTE (Perf iteration, refuted): scattering per top-k slot to avoid
        # the (T, k, D) gather measured 8% WORSE — k scatter passes re-read
        # the token buffer and re-touch buf k times. Single-gather kept.
        token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, top_k))
        buf = jnp.zeros((e_loc, capacity, d), xb.dtype)
        buf = buf.at[jnp.where(keep, idx_loc, e_loc), slot].set(
            xt[token_ids], mode="drop")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we_gate))
        h = h * jnp.einsum("ecd,edf->ecf", buf, we_up)
        out_buf = jnp.einsum("ecf,efd->ecd", h, we_down)
        gathered = out_buf[jnp.minimum(idx_loc, e_loc - 1), jnp.minimum(slot, capacity - 1)]
        gathered = gathered * jnp.where(keep, gate_vals, 0.0).astype(xb.dtype)[..., None]
        out = gathered.sum(axis=1).reshape(xb.shape)
        out = jax.lax.psum(out, expert_axis)                     # combine shards
        # local aux estimate (router replicated; idx covers global experts)
        me = probs.mean(axis=0)
        ce = jnp.zeros((n_experts,)).at[idx.reshape(-1)].add(1.0) / max(t * top_k, 1)
        aux = n_experts * jnp.sum(me * ce)
        if baxes:
            aux = jax.lax.pmean(aux, baxes)  # average the per-shard estimates
        return out, aux

    in_specs = (P(baxes if baxes else None, None, None), P(),
                P(expert_axis, None, None), P(expert_axis, None, None),
                P(expert_axis, None, None))
    out_specs = (P(baxes if baxes else None, None, None), P())
    out, aux = shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)(
        x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    if "shared" in p:
        out = out + mlp_forward(p["shared"], x)
    return out, aux
