"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships three files: kernel.py (pl.pallas_call + BlockSpec VMEM
tiling, validated in interpret mode), ops.py (jit'd public wrapper with
padding/tiling glue and shape validation), ref.py (pure-jnp oracle the
tests sweep against).

* event_conv      — the convolution unit (paper Sec. VI-B): VMEM-resident
                    membrane-potential tile, grid over AEQ event blocks,
                    channel-lane parallelism, saturating int8/16 adders.
                    Two schedules: the sequential one-event-per-step unit
                    and the memory-interlaced event-parallel unit
                    (``event_conv_pallas_interlaced*``: ``event_par``
                    same-column hazard-free events per vectorized
                    gather->add->scatter step, selected by
                    ``LayerPlan.event_par``).
* threshold_pool  — the thresholding unit (Sec. VI-C): fused bias +
                    compare + m-TTFS indicator + kxk OR-max-pool, plus
                    optional fused spike emission (ISSUE 10): with
                    ``emit_capacity`` set, the unit also returns the
                    (post-pool) spikes already compacted into the next
                    layer's padded interlace-bank carrier (occupancy
                    masks + per-column segment counts, the sort-free
                    cumulative-rank truncation of ``aeq.ranked_keep``) —
                    the producer-side queue handoff the ``"fused-handoff"``
                    scheduler variant consumes without any dense
                    intermediate.

Both are wired into the Algorithm-1 scheduler via
core.scheduler.run_conv_layer*(backend="pallas").

Interpret mode is a single switch (``kernels.runtime.resolve_interpret``):
every wrapper defaults to ``interpret=None``, which resolves from the
REPRO_PALLAS_INTERPRET env var, else to interpret-on unless the default
backend is a real TPU — so validating on hardware is a one-line flip
(``REPRO_PALLAS_INTERPRET=0``) instead of an every-call-site edit.
"""
