"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships three files: kernel.py (pl.pallas_call + BlockSpec VMEM
tiling, validated in interpret mode), ops.py (jit'd public wrapper with
padding/tiling glue), ref.py (pure-jnp oracle the tests sweep against).

* event_conv      — the convolution unit (paper Sec. VI-B): VMEM-resident
                    membrane-potential tile, grid over AEQ event blocks,
                    channel-lane parallelism, saturating int8/16 adders.
* threshold_pool  — the thresholding unit (Sec. VI-C): fused bias +
                    compare + m-TTFS indicator + 3x3 OR-max-pool.

Both are wired into the Algorithm-1 scheduler via
core.scheduler.run_conv_layer(backend="pallas").
"""
