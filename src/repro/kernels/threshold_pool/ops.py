"""Jitted public wrapper for the threshold_pool kernel: pads H/W to the
pool window and C to the lane block, dispatches kernel vs oracle, crops."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.geometry import GEOM_3X3, ConvGeometry

from .kernel import threshold_pool_pallas
from .ref import threshold_pool_ref

_NEG = {jnp.float32.dtype: -3e38, jnp.bfloat16.dtype: -3e38,
        jnp.int8.dtype: -128, jnp.int16.dtype: -32768}


@partial(jax.jit, static_argnames=("v_t", "pool", "block_c", "use_kernel",
                                   "interpret", "emit_capacity", "emit_geometry"))
def threshold_pool(
    vm: jax.Array,
    bias: jax.Array,
    fired: jax.Array,
    *,
    v_t: float,
    pool: int | None = None,
    block_c: int = 128,
    use_kernel: bool = True,
    interpret: bool | None = None,
    emit_capacity: int | None = None,
    emit_geometry: ConvGeometry = GEOM_3X3,
):
    """Fused bias + threshold + m-TTFS indicator + optional OR-max-pool.

    vm: (H, W, C) any supported dtype; bias: (C,); fired: (H, W, C) bool/int8.
    Returns (vm_out (H,W,C), fired_out bool (H,W,C), spikes_out bool
    (H,W,C) or pooled (ceil(H/p), ceil(W/p), C)).

    ``emit_capacity`` turns on fused spike emission (ISSUE 10): two extra
    outputs — bank masks bool (n_banks, HBp+2, WBp+2, C) and seg_counts
    int32 (n_banks, C) — carrying the (post-pool) output already compacted
    into the next layer's fused-handoff layout under ``emit_geometry``.
    The pool padding makes the pooled map exactly (ceil(H/p), ceil(W/p)),
    so emission needs no spatial crop; padded channels never spike (the
    ``_NEG`` fill) and are cropped from the channel axis.
    """
    if vm.ndim != 3:
        raise ValueError(f"vm must be (H, W, C), got shape {vm.shape}")
    if vm.dtype not in _NEG:
        supported = ", ".join(str(d) for d in _NEG)
        raise ValueError(f"unsupported vm dtype {vm.dtype}; expected one of {supported}")
    h, w, c = vm.shape
    if bias.shape != (c,):
        raise ValueError(f"bias must have shape ({c},) to match vm channels, got {bias.shape}")
    if fired.shape != vm.shape:
        raise ValueError(f"fired shape {fired.shape} must match vm shape {vm.shape}")
    if pool is not None and pool < 1:
        raise ValueError(f"pool must be >= 1, got {pool}")
    if emit_capacity is not None and emit_capacity < 1:
        raise ValueError(f"emit_capacity must be >= 1, got {emit_capacity}")
    pw = pool if pool is not None else 1
    pad_h, pad_w = -h % pw, -w % pw
    pad_c = -c % block_c
    neg = _NEG[vm.dtype]  # padded cells must never spike
    vm_p = jnp.pad(vm, ((0, pad_h), (0, pad_w), (0, pad_c)), constant_values=neg)
    bias_p = jnp.pad(bias, (0, pad_c))
    fired_p = jnp.pad(fired.astype(jnp.int8), ((0, pad_h), (0, pad_w), (0, pad_c)))
    fn = threshold_pool_pallas if use_kernel else threshold_pool_ref
    kwargs = dict(v_t=v_t, pool=pool,
                  emit_capacity=emit_capacity, emit_geometry=emit_geometry)
    if use_kernel:
        kwargs.update(block_c=block_c, interpret=interpret)
    out = fn(vm_p, bias_p, fired_p, **kwargs)
    vm_out, spikes, pooled = out[:3]
    vm_out = vm_out[:h, :w, :c]
    fired_out = spikes[:h, :w, :c] != 0
    if pool is None:
        spikes_out = fired_out
    else:
        oh, ow = -(-h // pool), -(-w // pool)
        spikes_out = pooled[:oh, :ow, :c] != 0
    if emit_capacity is None:
        return vm_out, fired_out, spikes_out
    masks, seg_counts = out[3], out[4]
    return (vm_out, fired_out, spikes_out,
            masks[..., :c] != 0, seg_counts[..., :c])
