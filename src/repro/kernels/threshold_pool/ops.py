"""Jitted public wrapper for the threshold_pool kernel: pads H/W to the
pool window and C to the lane block, dispatches kernel vs oracle, crops."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import threshold_pool_pallas
from .ref import threshold_pool_ref

_NEG = {jnp.float32.dtype: -3e38, jnp.bfloat16.dtype: -3e38,
        jnp.int8.dtype: -128, jnp.int16.dtype: -32768}


@partial(jax.jit, static_argnames=("v_t", "pool", "block_c", "use_kernel", "interpret"))
def threshold_pool(
    vm: jax.Array,
    bias: jax.Array,
    fired: jax.Array,
    *,
    v_t: float,
    pool: int | None = None,
    block_c: int = 128,
    use_kernel: bool = True,
    interpret: bool | None = None,
):
    """Fused bias + threshold + m-TTFS indicator + optional OR-max-pool.

    vm: (H, W, C) any supported dtype; bias: (C,); fired: (H, W, C) bool/int8.
    Returns (vm_out (H,W,C), fired_out bool (H,W,C), spikes_out bool
    (H,W,C) or pooled (ceil(H/p), ceil(W/p), C)).
    """
    h, w, c = vm.shape
    pw = pool if pool is not None else 1
    pad_h, pad_w = -h % pw, -w % pw
    pad_c = -c % block_c
    neg = _NEG[vm.dtype]  # padded cells must never spike
    vm_p = jnp.pad(vm, ((0, pad_h), (0, pad_w), (0, pad_c)), constant_values=neg)
    bias_p = jnp.pad(bias, (0, pad_c))
    fired_p = jnp.pad(fired.astype(jnp.int8), ((0, pad_h), (0, pad_w), (0, pad_c)))
    fn = threshold_pool_pallas if use_kernel else threshold_pool_ref
    kwargs = dict(v_t=v_t, pool=pool)
    if use_kernel:
        kwargs.update(block_c=block_c, interpret=interpret)
    vm_out, spikes, pooled = fn(vm_p, bias_p, fired_p, **kwargs)
    vm_out = vm_out[:h, :w, :c]
    fired_out = spikes[:h, :w, :c] != 0
    if pool is None:
        return vm_out, fired_out, fired_out
    oh, ow = -(-h // pool), -(-w // pool)
    return vm_out, fired_out, pooled[:oh, :ow, :c] != 0
