"""Pure-jnp oracle for the threshold_pool Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aeq import place_padded_banks, ranked_keep
from repro.core.geometry import GEOM_3X3, ConvGeometry

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def emit_banked(spikes_map: jax.Array, *, capacity: int,
                geometry: ConvGeometry = GEOM_3X3
                ) -> tuple[jax.Array, jax.Array]:
    """Fused spike emission: bank an output spike map as it leaves the
    threshold unit (ISSUE 10 tentpole, shared by kernel and oracle).

    spikes_map: (H', W', C) bool/int8 — the unit's (post-pool) output.
    Returns (masks (n_banks, HBp+2, WBp+2, C) bool, seg_counts
    (n_banks, C) int32): per channel, the next layer's fused-handoff
    centre-bank occupancy (``aeq.FusedHandoff`` layout, channel-last for
    the kernel's channel-block grid) and the kept events per interlace
    column.  Reuses the sort-free cumulative-rank truncation
    (``aeq.ranked_keep``) and the static bank placement
    (``aeq.place_padded_banks``) — identical content to
    ``aeq.build_fused_handoff`` over the same map
    (tests/test_fused_handoff.py).
    """
    sp = spikes_map != 0
    h, w, c = sp.shape
    kh, kw = geometry.kh, geometry.kw
    ph, pw = -h % kh, -w % kw
    x = jnp.pad(sp, ((0, ph), (0, pw), (0, 0)))
    hb, wb = (h + ph) // kh, (w + pw) // kw
    # channel-first interlace (same bank order as ``aeq.interlace``)
    il = x.reshape(hb, kh, wb, kw, c).transpose(4, 1, 3, 0, 2)
    il = il.reshape(c, geometry.n_banks, hb, wb)
    kept_il, _, seg_counts = ranked_keep(il, capacity, (h, w))
    masks = place_padded_banks(kept_il, (h, w), geometry)
    return jnp.moveaxis(masks, 0, -1), jnp.moveaxis(seg_counts, 0, -1)


def threshold_pool_ref(vm: jax.Array, bias: jax.Array, fired: jax.Array, *,
                       v_t: float, pool: int | None,
                       emit_capacity: int | None = None,
                       emit_geometry: ConvGeometry = GEOM_3X3):
    sat = _SAT_RANGE.get(vm.dtype)
    b = bias.reshape(1, 1, -1)
    if sat is not None:
        wide = vm.astype(jnp.int32) + b.astype(jnp.int32)
        vm_new = jnp.clip(wide, sat[0], sat[1]).astype(vm.dtype)
    else:
        vm_new = vm + b
    spikes = (vm_new > jnp.asarray(v_t, vm_new.dtype)) | (fired != 0)
    if pool is not None:
        h, w, c = spikes.shape
        s = spikes.reshape(h // pool, pool, w // pool, pool, c)
        pooled = jnp.any(s, axis=(1, 3))
    else:
        pooled = spikes
    if emit_capacity is None:
        return vm_new, spikes.astype(jnp.int8), pooled.astype(jnp.int8)
    masks, seg_counts = emit_banked(pooled, capacity=emit_capacity,
                                    geometry=emit_geometry)
    return (vm_new, spikes.astype(jnp.int8), pooled.astype(jnp.int8),
            masks.astype(jnp.int8), seg_counts)
