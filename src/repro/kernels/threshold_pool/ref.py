"""Pure-jnp oracle for the threshold_pool Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def threshold_pool_ref(vm: jax.Array, bias: jax.Array, fired: jax.Array, *,
                       v_t: float, pool: int | None):
    sat = _SAT_RANGE.get(vm.dtype)
    b = bias.reshape(1, 1, -1)
    if sat is not None:
        wide = vm.astype(jnp.int32) + b.astype(jnp.int32)
        vm_new = jnp.clip(wide, sat[0], sat[1]).astype(vm.dtype)
    else:
        vm_new = vm + b
    spikes = (vm_new > jnp.asarray(v_t, vm_new.dtype)) | (fired != 0)
    if pool is not None:
        h, w, c = spikes.shape
        s = spikes.reshape(h // pool, pool, w // pool, pool, c)
        pooled = jnp.any(s, axis=(1, 3))
    else:
        pooled = spikes
    return vm_new, spikes.astype(jnp.int8), pooled.astype(jnp.int8)
