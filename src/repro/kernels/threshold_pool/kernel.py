"""Pallas TPU kernel: fused thresholding unit (paper Secs. V-C / VI-C).

One VMEM pass per channel block fuses the paper's 5-stage thresholding
pipeline: bias add (saturating for integer datapaths), threshold compare,
m-TTFS spike-indicator OR, and the 3x3 OR-max-pool reduction.  The dense
sweep of the FPGA (stride-3 3x3 windows, 9 comparators) becomes one
vectorized tile op; the pool is a reshape-reduce over sublanes.

Grid: over channel blocks (channels are independent).  The firing
threshold V_t is layer-static and baked into the kernel as a constant —
exactly like the synthesized comparator constant on the FPGA.

Fused emission (ISSUE 10): ``emit_capacity`` extends the same VMEM pass
with the producer-side queue compaction — the output spikes leave the
unit already as the next layer's fused-handoff bank masks plus
per-column segment counts (``ref.emit_banked``: sort-free cumulative
ranks, the ``aeq.stream_queues`` machinery), the TPU analogue of the
paper's runtime AEQ-builder circuitry sitting right behind the
comparators.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.geometry import GEOM_3X3, ConvGeometry
from repro.kernels.runtime import resolve_interpret

from .ref import emit_banked

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def _threshold_pool_kernel(vm_ref, bias_ref, fired_ref, vm_out_ref,
                           spikes_ref, pooled_ref, *emit_refs, v_t, pool,
                           emit_capacity, emit_geometry):
    vm = vm_ref[...]
    bias = bias_ref[...]  # (1, 1, block_c) broadcast over the tile
    sat = _SAT_RANGE.get(vm.dtype)
    if sat is not None:
        wide = vm.astype(jnp.int32) + bias.astype(jnp.int32)
        vm_new = jnp.clip(wide, sat[0], sat[1]).astype(vm.dtype)
    else:
        vm_new = vm + bias
    spikes = (vm_new > jnp.asarray(v_t, vm_new.dtype)) | (fired_ref[...] != 0)
    vm_out_ref[...] = vm_new
    spikes_ref[...] = spikes.astype(jnp.int8)
    if pool is not None:
        h, w, c = spikes.shape
        s = spikes.reshape(h // pool, pool, w // pool, pool, c)
        pooled = jnp.any(jnp.any(s, axis=3), axis=1)
    else:
        pooled = spikes
    pooled_ref[...] = pooled.astype(jnp.int8)
    if emit_capacity is not None:
        masks_ref, seg_ref = emit_refs
        masks, seg_counts = emit_banked(pooled, capacity=emit_capacity,
                                        geometry=emit_geometry)
        masks_ref[...] = masks.astype(jnp.int8)
        seg_ref[...] = seg_counts


@partial(jax.jit, static_argnames=("v_t", "pool", "block_c", "interpret",
                                   "emit_capacity", "emit_geometry"))
def threshold_pool_pallas(
    vm: jax.Array,
    bias: jax.Array,
    fired: jax.Array,
    *,
    v_t: float,
    pool: int | None,
    block_c: int = 128,
    interpret: bool | None = None,
    emit_capacity: int | None = None,
    emit_geometry: ConvGeometry = GEOM_3X3,
):
    """Fused threshold unit over (H, W, C) membrane potentials.

    vm:    (H, W, C); H and W must already be multiples of ``pool``.
    bias:  (C,) per-output-channel bias (paper applies it every step).
    fired: (H, W, C) int8 m-TTFS indicator bits.

    Returns (vm_out, spikes int8 (H,W,C), pooled int8 (H/p, W/p, C)); when
    ``pool`` is None the third output duplicates ``spikes``.

    ``emit_capacity`` additionally emits the fused-handoff compaction of
    the (post-pool) output inside the same pass — two extra outputs,
    masks int8 (n_banks, HBp+2, WBp+2, C) and seg_counts int32
    (n_banks, C) in the ``ref.emit_banked`` layout, with the AEQ capacity
    truncation applied per channel under ``emit_geometry`` (the NEXT
    layer's window).  Bit-exact vs the oracle (analysis kernel audit).
    """
    h, w, c = vm.shape
    if pool is not None and (h % pool or w % pool):
        raise ValueError(f"H,W=({h},{w}) must be multiples of pool={pool} (pad first)")
    if c % block_c != 0:
        raise ValueError(f"C={c} must be a multiple of block_c={block_c} (pad first)")
    ph, pw = (h // pool, w // pool) if pool is not None else (h, w)
    grid = (c // block_c,)
    in_specs = [
        pl.BlockSpec((h, w, block_c), lambda b: (0, 0, b)),
        pl.BlockSpec((1, 1, block_c), lambda b: (0, 0, b)),
        pl.BlockSpec((h, w, block_c), lambda b: (0, 0, b)),
    ]
    out_specs = [
        pl.BlockSpec((h, w, block_c), lambda b: (0, 0, b)),
        pl.BlockSpec((h, w, block_c), lambda b: (0, 0, b)),
        pl.BlockSpec((ph, pw, block_c), lambda b: (0, 0, b)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((h, w, c), vm.dtype),
        jax.ShapeDtypeStruct((h, w, c), jnp.int8),
        jax.ShapeDtypeStruct((ph, pw, c), jnp.int8),
    ]
    if emit_capacity is not None:
        geo = emit_geometry
        hh, hw_ = geo.halo
        nb = geo.n_banks
        hbp = -(-(ph + 2 * hh) // geo.kh) + 2
        wbp = -(-(pw + 2 * hw_) // geo.kw) + 2
        out_specs += [
            pl.BlockSpec((nb, hbp, wbp, block_c), lambda b: (0, 0, 0, b)),
            pl.BlockSpec((nb, block_c), lambda b: (0, b)),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((nb, hbp, wbp, c), jnp.int8),
            jax.ShapeDtypeStruct((nb, c), jnp.int32),
        ]
    return pl.pallas_call(
        partial(_threshold_pool_kernel, v_t=v_t, pool=pool,
                emit_capacity=emit_capacity, emit_geometry=emit_geometry),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(vm, bias.reshape(1, 1, c), fired)
