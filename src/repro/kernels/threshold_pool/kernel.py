"""Pallas TPU kernel: fused thresholding unit (paper Secs. V-C / VI-C).

One VMEM pass per channel block fuses the paper's 5-stage thresholding
pipeline: bias add (saturating for integer datapaths), threshold compare,
m-TTFS spike-indicator OR, and the 3x3 OR-max-pool reduction.  The dense
sweep of the FPGA (stride-3 3x3 windows, 9 comparators) becomes one
vectorized tile op; the pool is a reshape-reduce over sublanes.

Grid: over channel blocks (channels are independent).  The firing
threshold V_t is layer-static and baked into the kernel as a constant —
exactly like the synthesized comparator constant on the FPGA.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def _threshold_pool_kernel(vm_ref, bias_ref, fired_ref, vm_out_ref, spikes_ref,
                           pooled_ref, *, v_t, pool):
    vm = vm_ref[...]
    bias = bias_ref[...]  # (1, 1, block_c) broadcast over the tile
    sat = _SAT_RANGE.get(vm.dtype)
    if sat is not None:
        wide = vm.astype(jnp.int32) + bias.astype(jnp.int32)
        vm_new = jnp.clip(wide, sat[0], sat[1]).astype(vm.dtype)
    else:
        vm_new = vm + bias
    spikes = (vm_new > jnp.asarray(v_t, vm_new.dtype)) | (fired_ref[...] != 0)
    vm_out_ref[...] = vm_new
    spikes_ref[...] = spikes.astype(jnp.int8)
    if pool is not None:
        h, w, c = spikes.shape
        s = spikes.reshape(h // pool, pool, w // pool, pool, c)
        pooled = jnp.any(jnp.any(s, axis=3), axis=1)
        pooled_ref[...] = pooled.astype(jnp.int8)
    else:
        pooled_ref[...] = spikes.astype(jnp.int8)


@partial(jax.jit, static_argnames=("v_t", "pool", "block_c", "interpret"))
def threshold_pool_pallas(
    vm: jax.Array,
    bias: jax.Array,
    fired: jax.Array,
    *,
    v_t: float,
    pool: int | None,
    block_c: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused threshold unit over (H, W, C) membrane potentials.

    vm:    (H, W, C); H and W must already be multiples of ``pool``.
    bias:  (C,) per-output-channel bias (paper applies it every step).
    fired: (H, W, C) int8 m-TTFS indicator bits.

    Returns (vm_out, spikes int8 (H,W,C), pooled int8 (H/p, W/p, C)); when
    ``pool`` is None the third output duplicates ``spikes``.
    """
    h, w, c = vm.shape
    if pool is not None and (h % pool or w % pool):
        raise ValueError(f"H,W=({h},{w}) must be multiples of pool={pool} (pad first)")
    if c % block_c != 0:
        raise ValueError(f"C={c} must be a multiple of block_c={block_c} (pad first)")
    ph, pw = (h // pool, w // pool) if pool is not None else (h, w)
    grid = (c // block_c,)
    return pl.pallas_call(
        partial(_threshold_pool_kernel, v_t=v_t, pool=pool),
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, w, block_c), lambda b: (0, 0, b)),
            pl.BlockSpec((1, 1, block_c), lambda b: (0, 0, b)),
            pl.BlockSpec((h, w, block_c), lambda b: (0, 0, b)),
        ],
        out_specs=[
            pl.BlockSpec((h, w, block_c), lambda b: (0, 0, b)),
            pl.BlockSpec((h, w, block_c), lambda b: (0, 0, b)),
            pl.BlockSpec((ph, pw, block_c), lambda b: (0, 0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w, c), vm.dtype),
            jax.ShapeDtypeStruct((h, w, c), jnp.int8),
            jax.ShapeDtypeStruct((ph, pw, c), jnp.int8),
        ],
        interpret=resolve_interpret(interpret),
    )(vm, bias.reshape(1, 1, c), fired)
