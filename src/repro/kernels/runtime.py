"""Kernel runtime knobs shared by every Pallas wrapper in kernels/*.

One switch decides whether Pallas kernels run in interpret mode (the
Mosaic interpreter, required off-TPU) or compiled (`interpret=False`, the
real-TPU path).  Historically every call site defaulted to
``interpret=True``, which meant validating on a real TPU required touching
each wrapper; now they all default to ``interpret=None`` and resolve here:

* explicit ``interpret=`` argument wins (tests pin it);
* else the ``REPRO_PALLAS_INTERPRET`` env var ("1"/"true"/"on" vs
  "0"/"false"/"off") — the one-line flip for the ROADMAP real-TPU item;
* else interpret is ON unless the default JAX backend is a TPU.

Resolution happens at trace time (the flag is a static jit argument), so
the env var is read the first time each wrapper traces a given shape;
later calls with ``interpret=None`` hit the jit cache keyed on the same
static ``None`` and do NOT re-read the env.  Treat the env var as a
process-level launch flag (set it before the first kernel call, as the
real-TPU validation flow does); to change modes within a live process,
pass ``interpret=`` explicitly — the explicit value is part of the cache
key, so it always takes effect.
"""
from __future__ import annotations

import os

_TRUE = {"1", "true", "on", "yes"}
_FALSE = {"0", "false", "off", "no"}

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve the effective interpret flag for a Pallas call."""
    if interpret is not None:
        return bool(interpret)
    raw = os.environ.get(INTERPRET_ENV)
    if raw is not None:
        v = raw.strip().lower()
        if v in _TRUE:
            return True
        if v in _FALSE:
            return False
        raise ValueError(
            f"{INTERPRET_ENV}={raw!r} is not a boolean; use one of "
            f"{sorted(_TRUE | _FALSE)}")
    import jax
    return jax.default_backend() != "tpu"
