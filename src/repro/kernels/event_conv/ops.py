"""Jitted public wrapper around the event_conv Pallas kernels.

Handles: halo padding, event padding to the block size, channel tiling to
the lane width, shape validation (clear errors *before* any Pallas
tracing), and the queue-exhausted early exit (the self-timed analogue —
see DESIGN.md Sec. 2).

Also home of the event-pipeline autotuners: ``block_e`` (events streamed
per grid step) and ``event_par`` (same-interlace-column events applied in
parallel per step) are pure perf knobs — every setting produces
bit-identical results (invalid slots contribute exact zeros; same-column
events write disjoint patches) — so both are derived from the padded
queue capacity and the VMEM budget instead of being hard-coded
(``autotune_block_e`` / ``autotune_event_par``).

Interpret mode is resolved centrally (``kernels.runtime``): pass
``interpret=None`` (the default everywhere) and the REPRO_PALLAS_INTERPRET
env var / backend default decides.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.aeq import BatchedEventQueue, EventQueue, segment_pad
from repro.core.event_conv import crop_vm, pad_vm
from repro.core.geometry import GEOM_3X3, ConvGeometry

from .kernel import (event_conv_pallas, event_conv_pallas_batched,
                     event_conv_pallas_interlaced,
                     event_conv_pallas_interlaced_batched)
from .ref import event_conv_ref, event_conv_ref_batched

# Bytes one queue slot streams through VMEM: (i, j) int32 coords + valid int8.
EVENT_BYTES = 2 * 4 + 1
# Per-core VMEM (TPU ~16 MB); the vm tile must stay resident against it.
VMEM_BUDGET = 16 * 1024 * 1024


def snap_divisor(n: int, requested: int) -> int:
    """Largest divisor of ``n`` <= ``requested``.  Snaps the throughput
    knobs (channel_block, block_e) onto values that tile evenly — they are
    perf knobs, never correctness constraints."""
    requested = max(1, min(requested, n))
    if n % requested == 0:
        return requested
    return max(d for d in range(1, requested + 1) if n % d == 0)


def autotune_block_e(capacity: int, vm_tile: tuple[int, ...] = (), *,
                     vm_bytes: int = 4, vmem_budget: int = VMEM_BUDGET) -> int:
    """Pick the event-block size for a queue of ``capacity`` slots.

    The grid streams ``block_e`` (coords, valid) entries per step while the
    ``vm_tile`` stays VMEM-resident (twice: input + aliased output), so the
    block must fit the spare budget double-buffered.  Below that ceiling we
    keep at least ~4 blocks per queue so the block-granular early exit
    (self-timed analogue) still skips work on sparse queues, with a floor
    of 64 entries to amortize grid-step overhead.  Always returns a
    divisor of ``capacity`` (the grid must tile the queue evenly).
    """
    if capacity <= 0:
        return 1
    resident = 2 * math.prod(vm_tile) * vm_bytes if vm_tile else 0
    spare = max(vmem_budget - resident, 2 * EVENT_BYTES)
    vmem_cap = max(spare // (2 * EVENT_BYTES), 1)
    granule = max(capacity // 4, 64)
    return snap_divisor(capacity, min(capacity, vmem_cap, granule))


def snap_block_e_for_par(depth: int, block_e: int, event_par: int) -> int:
    """Snap ``block_e`` onto the interlaced grid: a multiple of
    ``event_par`` that divides the segment-padded queue ``depth`` (which
    is itself a multiple of ``event_par``), so parallel groups tile every
    event block and blocks tile the queue.  The single source of this
    invariant — plan_conv_layer and both ops wrappers all go through it."""
    return event_par * snap_divisor(depth // event_par,
                                    max(1, block_e // event_par))


def autotune_event_par(capacity: int, vm_tile: tuple[int, ...] = (), *,
                       vm_bytes: int = 4, vmem_budget: int = VMEM_BUDGET,
                       max_par: int = 8,
                       geometry: ConvGeometry = GEOM_3X3) -> int:
    """Pick the interlaced event-parallel width for a queue.

    A parallel step holds ``event_par`` gathered kh x kw patches live
    next to the resident vm tile (double-buffered), so the width must fit
    the spare VMEM; below that ceiling it is capped so the average
    interlace column segment (capacity/n_banks events) spans at least ~2
    groups — shallower queues would spend the parallelism on segment
    padding.  Snapped to a power of two, floored at 1 (= sequential
    kernel).  Both VMEM and segment models scale with the geometry: a
    5x5 window gathers ~2.8x bigger patches across 25 (vs 9) thinner
    columns, so the tuned width naturally shrinks.
    """
    if capacity < 2:
        return 1
    nb = geometry.n_banks
    resident = 2 * math.prod(vm_tile) * vm_bytes if vm_tile else 0
    channels = vm_tile[-1] if vm_tile else 1
    patch_bytes = 2 * nb * channels * vm_bytes
    spare = max(vmem_budget - resident, 0)
    vmem_cap = spare // patch_bytes if patch_bytes else max_par
    target = min(max_par, vmem_cap, max(capacity // (2 * nb), 1))
    par = 1
    while par * 2 <= target:
        par *= 2
    return par


def candidate_block_es(capacity: int, vm_tile: tuple[int, ...] = (), *,
                       vm_bytes: int = 4,
                       vmem_budget: int = VMEM_BUDGET) -> list[int]:
    """Analytic-prior candidate set for the measured autotuner.

    The analytic pick (``autotune_block_e``) plus its neighbours one
    octave up and down, all snapped to divisors of ``capacity`` and kept
    under the same VMEM ceiling — the measured tuner searches this small
    set instead of every divisor, so tuning cost stays bounded while the
    prior's mis-tunes (the granule heuristic is a TPU model; CPU interpret
    backends often prefer bigger blocks) are still recoverable.  Sorted,
    deduplicated, always non-empty (contains the analytic pick).
    """
    prior = autotune_block_e(capacity, vm_tile, vm_bytes=vm_bytes,
                             vmem_budget=vmem_budget)
    if capacity <= 0:
        return [prior]
    resident = 2 * math.prod(vm_tile) * vm_bytes if vm_tile else 0
    spare = max(vmem_budget - resident, 2 * EVENT_BYTES)
    vmem_cap = max(spare // (2 * EVENT_BYTES), 1)
    cands = {prior}
    for req in (prior // 2, prior * 2, prior * 4, capacity):
        if req >= 1:
            cands.add(snap_divisor(capacity, min(req, vmem_cap)))
    return sorted(cands)


def validate_event_shapes(coords: jax.Array, valid: jax.Array,
                          vm_padded: jax.Array | None = None, *,
                          block_e: int | None = None,
                          event_par: int = 1,
                          batched: bool = False,
                          geometry: ConvGeometry | None = None) -> None:
    """Validate event-stream shapes with actionable messages.

    The raw kernels require E to already be a multiple of ``block_e`` (the
    grid must tile the queue) and formerly surfaced that as a bare
    ``E=... must be a multiple of block_e=...`` mid-trace; the ops
    wrappers call this *before* padding so mismatched queue/vm shapes fail
    fast with the fix spelled out.  Pass the planned ``geometry`` so the
    messages name the actual kernel window instead of assuming 3x3.
    """
    geo = f" [{geometry.describe()} geometry]" if geometry is not None else ""
    want = 3 if batched else 2
    kind = "batched " if batched else ""
    if coords.ndim != want or coords.shape[-1] != 2:
        raise ValueError(
            f"{kind}event coords must be {'(Q, E, 2)' if batched else '(E, 2)'}"
            f" (i, j) address pairs, got shape {coords.shape}{geo}")
    if valid.shape != coords.shape[:-1]:
        raise ValueError(
            f"valid bits shape {valid.shape} does not match event coords "
            f"{coords.shape} — expected {coords.shape[:-1]}{geo}")
    if batched and vm_padded is not None and vm_padded.shape[0] != coords.shape[0]:
        raise ValueError(
            f"queue count mismatch: vm stack has {vm_padded.shape[0]} tiles "
            f"but coords describe {coords.shape[0]} queues{geo}")
    if block_e is not None and block_e < 1:
        raise ValueError(f"block_e={block_e} must be >= 1{geo}")
    if event_par < 1:
        raise ValueError(f"event_par={event_par} must be >= 1{geo}")
    if event_par > 1 and block_e is not None and block_e % event_par != 0:
        raise ValueError(
            f"block_e={block_e} must be a multiple of event_par={event_par} "
            f"so parallel groups tile the event blocks evenly (plan_network "
            f"snaps both; pass block_e=None to autotune){geo}")
    if geometry is not None:
        geometry.require_event_compatible("event_conv")


def _pad_events(queue: EventQueue, block_e: int) -> tuple[jax.Array, jax.Array]:
    e = queue.capacity
    pad = -e % block_e
    coords = jnp.pad(queue.coords, ((0, pad), (0, 0)))
    valid = jnp.pad(queue.valid, (0, pad))
    return coords, valid


@partial(jax.jit, static_argnames=("block_e", "use_kernel", "interpret",
                                   "event_par"))
def event_conv(
    vm: jax.Array,
    queue: EventQueue,
    kernel: jax.Array,
    *,
    block_e: int | None = 128,
    use_kernel: bool = True,
    interpret: bool | None = None,
    event_par: int = 1,
) -> jax.Array:
    """Event-driven conv accumulation onto an *unpadded* (H, W, C) vm
    (the kernel window — 3x3 by default — is taken from the weight shape).

    The Pallas kernel (or the jnp oracle when ``use_kernel=False``) sees
    the halo-padded tile; this wrapper crops it back.  ``block_e=None``
    autotunes the event block from the queue capacity and VMEM budget.
    ``event_par > 1`` segment-pads the queue (``aeq.segment_pad``) and
    dispatches the interlace-parallel kernel — bit-exact vs the
    sequential kernel by hazard-freedom of same-column events.
    """
    if vm.ndim == 2:
        out = event_conv(vm[:, :, None], queue, kernel[:, :, None],
                         block_e=block_e, use_kernel=use_kernel,
                         interpret=interpret, event_par=event_par)
        return out[:, :, 0]
    geom = ConvGeometry.from_kernel_shape(kernel.shape)
    hh, hw = geom.halo
    validate_event_shapes(queue.coords, queue.valid, block_e=block_e,
                          event_par=event_par, geometry=geom)
    if event_par > 1:
        queue = segment_pad(queue, event_par, geom)
    if block_e is None:
        block_e = autotune_block_e(
            queue.capacity,
            (vm.shape[0] + 2 * hh, vm.shape[1] + 2 * hw) + vm.shape[2:],
            vm_bytes=vm.dtype.itemsize)
        if event_par > 1:
            block_e = snap_block_e_for_par(queue.capacity, block_e, event_par)
    coords, valid = _pad_events(queue, block_e)
    vm_p = pad_vm(vm, geom)
    if use_kernel and event_par > 1:
        out = event_conv_pallas_interlaced(
            vm_p, coords, valid, kernel, block_e=block_e,
            event_par=event_par, interpret=interpret)
    elif use_kernel:
        out = event_conv_pallas(vm_p, coords, valid, kernel,
                                block_e=block_e, interpret=interpret)
    else:
        out = event_conv_ref(vm_p, coords, valid, kernel)
    return crop_vm(out, geom)


@partial(jax.jit, static_argnames=("block_e", "use_kernel", "interpret",
                                   "event_par"))
def event_conv_batched(
    vm: jax.Array,
    queues: BatchedEventQueue,
    kernel: jax.Array,
    *,
    block_e: int | None = 128,
    use_kernel: bool = True,
    interpret: bool | None = None,
    event_par: int = 1,
) -> jax.Array:
    """Batched event-driven conv accumulation onto (Q, H, W, C) vm tiles.

    ``queues`` must have a single leading dim Q matching ``vm``; the
    (kh, kw, C) kernel is shared by every queue (its shape fixes the
    geometry).  One fused 2-D-grid pallas_call (or the vmapped jnp oracle
    when ``use_kernel=False``) processes all queues; the wrapper
    halo-pads, pads the event axis to ``block_e``, and crops back.
    ``block_e=None`` autotunes from the queue capacity and VMEM budget;
    ``event_par > 1`` segment-pads the queues and dispatches the
    interlace-parallel kernel.
    """
    if queues.coords.ndim != 3:
        raise ValueError("event_conv_batched expects queues with one leading "
                         f"dim, got coords shape {queues.coords.shape}")
    geom = ConvGeometry.from_kernel_shape(kernel.shape)
    hh, hw = geom.halo
    validate_event_shapes(queues.coords, queues.valid, vm, block_e=block_e,
                          event_par=event_par, batched=True, geometry=geom)
    if event_par > 1:
        queues = segment_pad(queues, event_par, geom)
    if block_e is None:
        block_e = autotune_block_e(
            queues.capacity,
            (vm.shape[1] + 2 * hh, vm.shape[2] + 2 * hw) + vm.shape[3:],
            vm_bytes=vm.dtype.itemsize)
        if event_par > 1:
            block_e = snap_block_e_for_par(queues.capacity, block_e, event_par)
    pad = -queues.capacity % block_e
    coords = jnp.pad(queues.coords, ((0, 0), (0, pad), (0, 0)))
    valid = jnp.pad(queues.valid, ((0, 0), (0, pad)))
    vm_p = jax.vmap(lambda v: pad_vm(v, geom))(vm)
    if use_kernel and event_par > 1:
        out = event_conv_pallas_interlaced_batched(
            vm_p, coords, valid, kernel, block_e=block_e,
            event_par=event_par, interpret=interpret)
    elif use_kernel:
        out = event_conv_pallas_batched(vm_p, coords, valid, kernel,
                                        block_e=block_e, interpret=interpret)
    else:
        out = event_conv_ref_batched(vm_p, coords, valid, kernel)
    return jax.vmap(lambda v: crop_vm(v, geom))(out)
