"""Jitted public wrapper around the event_conv Pallas kernel.

Handles: halo padding, event padding to the block size, channel tiling to
the lane width, and the queue-exhausted early exit (the self-timed
analogue — see DESIGN.md Sec. 2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.aeq import EventQueue
from repro.core.event_conv import crop_vm, pad_vm

from .kernel import event_conv_pallas
from .ref import event_conv_ref


def _pad_events(queue: EventQueue, block_e: int) -> tuple[jax.Array, jax.Array]:
    e = queue.capacity
    pad = -e % block_e
    coords = jnp.pad(queue.coords, ((0, pad), (0, 0)))
    valid = jnp.pad(queue.valid, (0, pad))
    return coords, valid


@partial(jax.jit, static_argnames=("block_e", "use_kernel", "interpret"))
def event_conv(
    vm: jax.Array,
    queue: EventQueue,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Event-driven 3x3 conv accumulation onto an *unpadded* (H, W, C) vm.

    The Pallas kernel (or the jnp oracle when ``use_kernel=False``) sees
    the halo-padded tile; this wrapper crops it back.
    """
    if vm.ndim == 2:
        out = event_conv(vm[:, :, None], queue, kernel[:, :, None],
                         block_e=block_e, use_kernel=use_kernel, interpret=interpret)
        return out[:, :, 0]
    coords, valid = _pad_events(queue, block_e)
    vm_p = pad_vm(vm)
    if use_kernel:
        out = event_conv_pallas(vm_p, coords, valid, kernel,
                                block_e=block_e, interpret=interpret)
    else:
        out = event_conv_ref(vm_p, coords, valid, kernel)
    return crop_vm(out)
