"""Jitted public wrapper around the event_conv Pallas kernel.

Handles: halo padding, event padding to the block size, channel tiling to
the lane width, and the queue-exhausted early exit (the self-timed
analogue — see DESIGN.md Sec. 2).

Also home of the event-block autotuner: ``block_e`` is a pure perf knob
(every block size produces bit-identical results — invalid slots
contribute exact zeros), so it is derived from the padded queue capacity
and the VMEM budget instead of being hard-coded (``autotune_block_e``).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.aeq import BatchedEventQueue, EventQueue
from repro.core.event_conv import crop_vm, pad_vm

from .kernel import event_conv_pallas, event_conv_pallas_batched
from .ref import event_conv_ref, event_conv_ref_batched

# Bytes one queue slot streams through VMEM: (i, j) int32 coords + valid int8.
EVENT_BYTES = 2 * 4 + 1
# Per-core VMEM (TPU ~16 MB); the vm tile must stay resident against it.
VMEM_BUDGET = 16 * 1024 * 1024


def snap_divisor(n: int, requested: int) -> int:
    """Largest divisor of ``n`` <= ``requested``.  Snaps the throughput
    knobs (channel_block, block_e) onto values that tile evenly — they are
    perf knobs, never correctness constraints."""
    requested = max(1, min(requested, n))
    if n % requested == 0:
        return requested
    return max(d for d in range(1, requested + 1) if n % d == 0)


def autotune_block_e(capacity: int, vm_tile: tuple[int, ...] = (), *,
                     vm_bytes: int = 4, vmem_budget: int = VMEM_BUDGET) -> int:
    """Pick the event-block size for a queue of ``capacity`` slots.

    The grid streams ``block_e`` (coords, valid) entries per step while the
    ``vm_tile`` stays VMEM-resident (twice: input + aliased output), so the
    block must fit the spare budget double-buffered.  Below that ceiling we
    keep at least ~4 blocks per queue so the block-granular early exit
    (self-timed analogue) still skips work on sparse queues, with a floor
    of 64 entries to amortize grid-step overhead.  Always returns a
    divisor of ``capacity`` (the grid must tile the queue evenly).
    """
    if capacity <= 0:
        return 1
    resident = 2 * math.prod(vm_tile) * vm_bytes if vm_tile else 0
    spare = max(vmem_budget - resident, 2 * EVENT_BYTES)
    vmem_cap = max(spare // (2 * EVENT_BYTES), 1)
    granule = max(capacity // 4, 64)
    return snap_divisor(capacity, min(capacity, vmem_cap, granule))


def _pad_events(queue: EventQueue, block_e: int) -> tuple[jax.Array, jax.Array]:
    e = queue.capacity
    pad = -e % block_e
    coords = jnp.pad(queue.coords, ((0, pad), (0, 0)))
    valid = jnp.pad(queue.valid, (0, pad))
    return coords, valid


@partial(jax.jit, static_argnames=("block_e", "use_kernel", "interpret"))
def event_conv(
    vm: jax.Array,
    queue: EventQueue,
    kernel: jax.Array,
    *,
    block_e: int | None = 128,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Event-driven 3x3 conv accumulation onto an *unpadded* (H, W, C) vm.

    The Pallas kernel (or the jnp oracle when ``use_kernel=False``) sees
    the halo-padded tile; this wrapper crops it back.  ``block_e=None``
    autotunes the event block from the queue capacity and VMEM budget.
    """
    if block_e is None:
        block_e = autotune_block_e(
            queue.capacity, (vm.shape[0] + 2, vm.shape[1] + 2) + vm.shape[2:],
            vm_bytes=vm.dtype.itemsize)
    if vm.ndim == 2:
        out = event_conv(vm[:, :, None], queue, kernel[:, :, None],
                         block_e=block_e, use_kernel=use_kernel, interpret=interpret)
        return out[:, :, 0]
    coords, valid = _pad_events(queue, block_e)
    vm_p = pad_vm(vm)
    if use_kernel:
        out = event_conv_pallas(vm_p, coords, valid, kernel,
                                block_e=block_e, interpret=interpret)
    else:
        out = event_conv_ref(vm_p, coords, valid, kernel)
    return crop_vm(out)


@partial(jax.jit, static_argnames=("block_e", "use_kernel", "interpret"))
def event_conv_batched(
    vm: jax.Array,
    queues: BatchedEventQueue,
    kernel: jax.Array,
    *,
    block_e: int | None = 128,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Batched event-driven conv accumulation onto (Q, H, W, C) vm tiles.

    ``queues`` must have a single leading dim Q matching ``vm``; the
    (3, 3, C) kernel is shared by every queue.  One fused 2-D-grid
    pallas_call (or the vmapped jnp oracle when ``use_kernel=False``)
    processes all queues; the wrapper halo-pads, pads the event axis to
    ``block_e``, and crops back.  ``block_e=None`` autotunes from the
    queue capacity and VMEM budget.
    """
    if queues.coords.ndim != 3:
        raise ValueError("event_conv_batched expects queues with one leading "
                         f"dim, got coords shape {queues.coords.shape}")
    if block_e is None:
        block_e = autotune_block_e(
            queues.capacity, (vm.shape[1] + 2, vm.shape[2] + 2) + vm.shape[3:],
            vm_bytes=vm.dtype.itemsize)
    pad = -queues.capacity % block_e
    coords = jnp.pad(queues.coords, ((0, 0), (0, pad), (0, 0)))
    valid = jnp.pad(queues.valid, ((0, 0), (0, pad)))
    vm_p = jax.vmap(pad_vm)(vm)
    if use_kernel:
        out = event_conv_pallas_batched(vm_p, coords, valid, kernel,
                                        block_e=block_e, interpret=interpret)
    else:
        out = event_conv_ref_batched(vm_p, coords, valid, kernel)
    return jax.vmap(crop_vm)(out)
