"""Jitted public wrapper around the event_conv Pallas kernel.

Handles: halo padding, event padding to the block size, channel tiling to
the lane width, and the queue-exhausted early exit (the self-timed
analogue — see DESIGN.md Sec. 2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.aeq import BatchedEventQueue, EventQueue
from repro.core.event_conv import crop_vm, pad_vm

from .kernel import event_conv_pallas, event_conv_pallas_batched
from .ref import event_conv_ref, event_conv_ref_batched


def _pad_events(queue: EventQueue, block_e: int) -> tuple[jax.Array, jax.Array]:
    e = queue.capacity
    pad = -e % block_e
    coords = jnp.pad(queue.coords, ((0, pad), (0, 0)))
    valid = jnp.pad(queue.valid, (0, pad))
    return coords, valid


@partial(jax.jit, static_argnames=("block_e", "use_kernel", "interpret"))
def event_conv(
    vm: jax.Array,
    queue: EventQueue,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Event-driven 3x3 conv accumulation onto an *unpadded* (H, W, C) vm.

    The Pallas kernel (or the jnp oracle when ``use_kernel=False``) sees
    the halo-padded tile; this wrapper crops it back.
    """
    if vm.ndim == 2:
        out = event_conv(vm[:, :, None], queue, kernel[:, :, None],
                         block_e=block_e, use_kernel=use_kernel, interpret=interpret)
        return out[:, :, 0]
    coords, valid = _pad_events(queue, block_e)
    vm_p = pad_vm(vm)
    if use_kernel:
        out = event_conv_pallas(vm_p, coords, valid, kernel,
                                block_e=block_e, interpret=interpret)
    else:
        out = event_conv_ref(vm_p, coords, valid, kernel)
    return crop_vm(out)


@partial(jax.jit, static_argnames=("block_e", "use_kernel", "interpret"))
def event_conv_batched(
    vm: jax.Array,
    queues: BatchedEventQueue,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Batched event-driven conv accumulation onto (Q, H, W, C) vm tiles.

    ``queues`` must have a single leading dim Q matching ``vm``; the
    (3, 3, C) kernel is shared by every queue.  One fused 2-D-grid
    pallas_call (or the vmapped jnp oracle when ``use_kernel=False``)
    processes all queues; the wrapper halo-pads, pads the event axis to
    ``block_e``, and crops back.
    """
    if queues.coords.ndim != 3:
        raise ValueError("event_conv_batched expects queues with one leading "
                         f"dim, got coords shape {queues.coords.shape}")
    pad = -queues.capacity % block_e
    coords = jnp.pad(queues.coords, ((0, 0), (0, pad), (0, 0)))
    valid = jnp.pad(queues.valid, ((0, 0), (0, pad)))
    vm_p = jax.vmap(pad_vm)(vm)
    if use_kernel:
        out = event_conv_pallas_batched(vm_p, coords, valid, kernel,
                                        block_e=block_e, interpret=interpret)
    else:
        out = event_conv_ref_batched(vm_p, coords, valid, kernel)
    return jax.vmap(crop_vm)(out)
