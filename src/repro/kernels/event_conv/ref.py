"""Pure-jnp oracle for the event_conv Pallas kernel.

Semantics: for every valid event (i, j), add the 180deg-rotated (kh, kw)
kernel into vm_padded[i:i+kh, j:j+kw, :] (the (kh//2, kw//2) halo makes
the event coordinate (i, j) land at padded centre (i+kh//2, j+kw//2);
the window is taken from the kernel shape, 3x3 in the paper).  Integer
dtypes saturate at the storage width after every event, matching the
FPGA PE adders — note that saturating per-event is NOT the same as
clipping once at the end, so the oracle replays events one by one too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def event_conv_ref(vm_padded: jax.Array, coords: jax.Array, valid: jax.Array,
                   kernel: jax.Array) -> jax.Array:
    k_rot = kernel[::-1, ::-1, :].astype(vm_padded.dtype)
    kh, kw = kernel.shape[:2]
    zero = jnp.zeros_like(k_rot)
    sat = _SAT_RANGE.get(vm_padded.dtype)

    def body(e, vm):
        v = valid[e]
        i = jnp.where(v, coords[e, 0], 0)
        j = jnp.where(v, coords[e, 1], 0)
        contrib = jnp.where(v, k_rot, zero)
        patch = jax.lax.dynamic_slice(vm, (i, j, 0), (kh, kw, vm.shape[2]))
        if sat is not None:
            wide = patch.astype(jnp.int32) + contrib.astype(jnp.int32)
            patch = jnp.clip(wide, sat[0], sat[1]).astype(vm.dtype)
        else:
            patch = patch + contrib
        return jax.lax.dynamic_update_slice(vm, patch, (i, j, 0))

    return jax.lax.fori_loop(0, coords.shape[0], body, vm_padded)


def event_conv_ref_batched(vm_padded: jax.Array, coords: jax.Array,
                           valid: jax.Array, kernel: jax.Array) -> jax.Array:
    """Oracle for the 2-D grid kernel: Q independent queue replays.

    vm_padded: (Q, H+2hh, W+2hw, C); coords: (Q, E, 2); valid: (Q, E);
    kernel: (kh, kw, C) shared across queues.  Each queue's events are
    applied sequentially (per-event saturation, same as the 1-queue
    oracle); queues are independent, so vmap is exact.
    """
    return jax.vmap(event_conv_ref, in_axes=(0, 0, 0, None))(
        vm_padded, coords, valid, kernel)
