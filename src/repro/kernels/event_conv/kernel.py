"""Pallas TPU kernel: event-driven 3x3 convolution (paper conv unit, C2+C3).

Maps the FPGA convolution unit onto the TPU memory hierarchy:

* The membrane-potential tile ``vm`` (H+2, W+2, C) lives **resident in
  VMEM** for the whole call — the analogue of the 9 interlaced BRAM
  columns hard-wired to the PEs.  The +1 halo replaces the FPGA's
  out-of-bounds detection (edge events write into the halo, which is
  cropped by the wrapper and never thresholded).
* The grid runs over **event blocks**; each step streams one block of
  queue entries (coords, valid) from HBM while vm stays put
  (``input_output_aliases`` accumulates in place across grid steps) —
  the analogue of the AEQ feeding the pipeline a steady event stream.
* Parallelism is over the **C output channels in the lane dimension**
  (the TPU-native replacement for the FPGA's 9 tap-parallel PEs); the
  events of a queue are applied sequentially, which preserves program
  order exactly, so the RAW hazards of the FPGA pipeline cannot occur.
* Integer dtypes use saturating adds (paper C7): the accumulation is
  widened to int32 and clamped back to the storage width.

Block shapes: the C axis should be a multiple of 128 (lane width) and the
vm tile must fit VMEM: (H+2)(W+2)*C*4B; for the paper's 28x28 layers with
C=128 that is ~0.46 MB — comfortable against ~16 MB VMEM.

Two entry points:

* ``event_conv_pallas``          — one queue, 1-D grid over event blocks;
* ``event_conv_pallas_batched``  — many queues, 2-D grid over
  (queue, event block): one ``pallas_call`` streams every queue's events
  against its own VMEM-resident vm tile (the multi-queue analogue of the
  self-timed AEQ feed; the batch dimension of the batched inference
  pipeline).  The event-block axis is innermost, so each queue's tile is
  loaded once and revisited until its stream is exhausted.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def _apply_event_block(coords_ref, valid_ref, kernel_ref, out_ref, *,
                       block_e, prefix=()):
    """Apply ``block_e`` queue entries to the VMEM-resident vm tile.

    Shared body of the 1-D and 2-D grid kernels — ``prefix`` is the
    leading ref index selecting the current queue's block ((0,) for the
    batched kernel's (1, ...) blocks, () for the single-queue kernel).
    vm arrives through out_ref thanks to input_output_aliases: every grid
    step accumulates into the same tile.
    """
    k_rot = kernel_ref[...][::-1, ::-1, :]  # 180deg rotation (paper Fig. 4)
    zero = jnp.zeros_like(k_rot)
    sat = _SAT_RANGE.get(out_ref.dtype)

    def body(e, _):
        i = coords_ref[prefix + (e, 0)]
        j = coords_ref[prefix + (e, 1)]
        v = valid_ref[prefix + (e,)] != 0
        # Invalid slots contribute zeros at the (0,0) corner — branch-free
        # masking, the AEQ valid bit in vector form.
        i = jnp.where(v, i, 0)
        j = jnp.where(v, j, 0)
        contrib = jnp.where(v, k_rot, zero)
        idx = prefix + (pl.dslice(i, 3), pl.dslice(j, 3), slice(None))
        patch = out_ref[idx]
        if sat is not None:  # saturating fixed-point PE adders (paper C7)
            wide = patch.astype(jnp.int32) + contrib.astype(jnp.int32)
            updated = jnp.clip(wide, sat[0], sat[1]).astype(out_ref.dtype)
        else:
            updated = patch + contrib
        out_ref[idx] = updated
        return ()

    jax.lax.fori_loop(0, block_e, body, ())


def _event_conv_kernel(coords_ref, valid_ref, kernel_ref, vm_ref, out_ref, *, block_e):
    """One grid step: apply ``block_e`` queue entries to the VMEM vm tile."""
    _apply_event_block(coords_ref, valid_ref, kernel_ref, out_ref,
                       block_e=block_e)


@partial(jax.jit, static_argnames=("block_e", "interpret"))
def event_conv_pallas(
    vm_padded: jax.Array,
    coords: jax.Array,
    valid: jax.Array,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Apply an event queue to halo-padded membrane potentials.

    vm_padded: (H+2, W+2, C) float32 / int16 / int8.
    coords:    (E, 2) int32 event addresses (i, j) in *unpadded* space.
    valid:     (E,) bool/int8 — AEQ valid bits.
    kernel:    (3, 3, C) unrotated weights, same dtype as vm.

    Returns the updated (H+2, W+2, C) tile.  E is padded up to a multiple
    of ``block_e`` by the wrapper in ops.py.
    """
    e = coords.shape[0]
    if e % block_e != 0:
        raise ValueError(f"E={e} must be a multiple of block_e={block_e}")
    hp, wp, c = vm_padded.shape
    grid = (e // block_e,)
    return pl.pallas_call(
        partial(_event_conv_kernel, block_e=block_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, 2), lambda b: (b, 0)),      # event coords stream
            pl.BlockSpec((block_e,), lambda b: (b,)),           # valid bits stream
            pl.BlockSpec((3, 3, c), lambda b: (0, 0, 0)),       # kernel, resident
            pl.BlockSpec((hp, wp, c), lambda b: (0, 0, 0)),     # vm, resident
        ],
        out_specs=pl.BlockSpec((hp, wp, c), lambda b: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, wp, c), vm_padded.dtype),
        input_output_aliases={3: 0},  # accumulate vm in place across grid steps
        interpret=interpret,
    )(coords, valid.astype(jnp.int8), kernel, vm_padded)


def _event_conv_batched_kernel(coords_ref, valid_ref, kernel_ref, vm_ref,
                               out_ref, *, block_e):
    """One (queue, event-block) grid step: apply ``block_e`` entries of the
    current queue to that queue's VMEM-resident vm tile."""
    _apply_event_block(coords_ref, valid_ref, kernel_ref, out_ref,
                       block_e=block_e, prefix=(0,))


@partial(jax.jit, static_argnames=("block_e", "interpret"))
def event_conv_pallas_batched(
    vm_padded: jax.Array,
    coords: jax.Array,
    valid: jax.Array,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Apply Q event queues to Q halo-padded membrane-potential tiles.

    vm_padded: (Q, H+2, W+2, C) float32 / int16 / int8 — one tile per queue
               (in the batched scheduler Q is the sample batch B).
    coords:    (Q, E, 2) int32 event addresses in *unpadded* space.
    valid:     (Q, E) bool/int8 — AEQ valid bits.
    kernel:    (3, 3, C) unrotated weights shared by every queue (all
               queues hold the same (c_in -> channel block) slice).

    One pallas_call, 2-D grid (queue, event block); E must be a multiple
    of ``block_e`` (ops.py pads).  Returns the updated (Q, H+2, W+2, C)
    tiles; per-queue program order is preserved exactly, so results are
    bit-identical to Q sequential ``event_conv_pallas`` calls.
    """
    q, e, _ = coords.shape
    if e % block_e != 0:
        raise ValueError(f"E={e} must be a multiple of block_e={block_e}")
    if vm_padded.shape[0] != q:
        raise ValueError(
            f"queue count mismatch: vm has {vm_padded.shape[0]} tiles, "
            f"coords describe {q} queues")
    _, hp, wp, c = vm_padded.shape
    grid = (q, e // block_e)
    return pl.pallas_call(
        partial(_event_conv_batched_kernel, block_e=block_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_e, 2), lambda qi, b: (qi, b, 0)),  # event stream
            pl.BlockSpec((1, block_e), lambda qi, b: (qi, b)),         # valid bits
            pl.BlockSpec((3, 3, c), lambda qi, b: (0, 0, 0)),          # kernel, resident
            pl.BlockSpec((1, hp, wp, c), lambda qi, b: (qi, 0, 0, 0)),  # vm tile
        ],
        out_specs=pl.BlockSpec((1, hp, wp, c), lambda qi, b: (qi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, hp, wp, c), vm_padded.dtype),
        input_output_aliases={3: 0},  # accumulate each tile in place
        interpret=interpret,
    )(coords, valid.astype(jnp.int8), kernel, vm_padded)
