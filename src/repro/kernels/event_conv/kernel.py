"""Pallas TPU kernels: event-driven k x k convolution (paper conv unit,
C2+C3; 3x3 in the paper, parametric odd windows here).

Maps the FPGA convolution unit onto the TPU memory hierarchy:

* The membrane-potential tile ``vm`` (H+2hh, W+2hw, C) lives **resident
  in VMEM** for the whole call — the analogue of the kh*kw interlaced
  BRAM columns hard-wired to the PEs (9 for 3x3).  The halo (kh//2,
  kw//2 per side) replaces the FPGA's out-of-bounds detection (edge
  events write into the halo, which is cropped by the wrapper and never
  thresholded).  The kernel window is derived from the weight shape, so
  every entry point serves any odd k x k geometry with one code path.
* The grid runs over **event blocks**; each step streams one block of
  queue entries (coords, valid) from HBM while vm stays put
  (``input_output_aliases`` accumulates in place across grid steps) —
  the analogue of the AEQ feeding the pipeline a steady event stream.
* Parallelism is over the **C output channels in the lane dimension**
  (the TPU-native replacement for the FPGA's 9 tap-parallel PEs).
* Integer dtypes use saturating adds (paper C7): the accumulation is
  widened to int32 and clamped back to the storage width.

Two schedules per entry point:

* **sequential** (``event_conv_pallas``/``_batched``): events are applied
  one at a time, preserving program order exactly, so RAW hazards cannot
  occur — the paper's one-event-per-cycle conv unit.
* **interlaced event-parallel** (``event_conv_pallas_interlaced``/
  ``_batched``): each grid step walks groups of ``event_par`` consecutive
  queue slots.  The AEQ emits events in interlace-column order
  (s = kw*(i%kh)+(j%kw)), and same-column events are >= kh apart in i or
  >= kw apart in j, so their window patches are DISJOINT: a
  column-homogeneous group is applied
  as one vectorized gather -> add -> scatter (all patch reads complete
  before any write; disjoint writes never reorder a single cell's
  accumulation, so the result is bit-exact vs the sequential kernel —
  saturating int paths included, since a cell sees at most one event per
  group).  A group that straddles a column boundary falls back to the
  sequential body for just that group.  Feeding the kernel a
  segment-padded queue (``aeq.segment_pad``; what the ops wrapper and the
  planned scheduler do) makes every group homogeneous by construction, so
  the fallback never fires and the serial dependence chain only remains
  *across* groups.  Invalid slots are replayed as copies of the group's
  first valid event — they re-write the identical updated patch, which is
  idempotent under the all-reads-first schedule.

Block shapes: the C axis should be a multiple of 128 (lane width) and the
vm tile must fit VMEM: (H+2)(W+2)*C*4B; for the paper's 28x28 layers with
C=128 that is ~0.46 MB — comfortable against ~16 MB VMEM.

Batched variants run a 2-D grid over (queue, event block): one
``pallas_call`` streams every queue's events against its own
VMEM-resident vm tile.  The event-block axis is innermost, so each
queue's tile is loaded once and revisited until its stream is exhausted.

Interpret mode is resolved by ``kernels.runtime.resolve_interpret``
(REPRO_PALLAS_INTERPRET env var; defaults on off-TPU).
"""
from __future__ import annotations

from functools import partial, reduce

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def _acc_patch(patch, contrib, dtype):
    sat = _SAT_RANGE.get(dtype)
    if sat is not None:  # saturating fixed-point PE adders (paper C7)
        wide = patch.astype(jnp.int32) + contrib.astype(jnp.int32)
        return jnp.clip(wide, sat[0], sat[1]).astype(dtype)
    return patch + contrib


def _apply_event_block(coords_ref, valid_ref, kernel_ref, out_ref, *,
                       block_e, prefix=()):
    """Apply ``block_e`` queue entries to the VMEM-resident vm tile.

    Shared body of the 1-D and 2-D grid kernels — ``prefix`` is the
    leading ref index selecting the current queue's block ((0,) for the
    batched kernel's (1, ...) blocks, () for the single-queue kernel).
    vm arrives through out_ref thanks to input_output_aliases: every grid
    step accumulates into the same tile.
    """
    k_rot = kernel_ref[...][::-1, ::-1, :]  # 180deg rotation (paper Fig. 4)
    kh, kw = k_rot.shape[:2]                # window from the weight shape
    zero = jnp.zeros_like(k_rot)

    def body(e, _):
        i = coords_ref[prefix + (e, 0)]
        j = coords_ref[prefix + (e, 1)]
        v = valid_ref[prefix + (e,)] != 0
        # Invalid slots contribute zeros at the (0,0) corner — branch-free
        # masking, the AEQ valid bit in vector form.
        i = jnp.where(v, i, 0)
        j = jnp.where(v, j, 0)
        contrib = jnp.where(v, k_rot, zero)
        idx = prefix + (pl.dslice(i, kh), pl.dslice(j, kw), slice(None))
        out_ref[idx] = _acc_patch(out_ref[idx], contrib, out_ref.dtype)
        return ()

    jax.lax.fori_loop(0, block_e, body, ())


def _apply_event_block_interlaced(coords_ref, valid_ref, kernel_ref, out_ref,
                                  *, block_e, event_par, prefix=()):
    """Apply ``block_e`` entries as ``event_par``-wide hazard-free groups.

    Per group: read the slots' (i, j, valid); pick the first valid event
    as the group anchor; if every valid slot shares the anchor's interlace
    column (always true on segment-padded queues), gather all patches,
    add, and scatter — reads complete before writes, and same-column
    disjointness makes the writes conflict-free.  Invalid slots replay the
    anchor (same patch, same contribution — an idempotent duplicate
    write); a group with no valid slots degenerates to writing the (0,0)
    patch back unchanged.  Otherwise fall back to the sequential body for
    this group only (the column-boundary case on unpadded queues).
    """
    k_rot = kernel_ref[...][::-1, ::-1, :]
    kh, kw = k_rot.shape[:2]                # window from the weight shape
    zero = jnp.zeros_like(k_rot)
    n_groups = block_e // event_par

    def group(g, _):
        base = g * event_par
        ii, jj, vv = [], [], []
        for p in range(event_par):
            ii.append(coords_ref[prefix + (base + p, 0)])
            jj.append(coords_ref[prefix + (base + p, 1)])
            vv.append(valid_ref[prefix + (base + p,)] != 0)
        cols = [(i % kh) * kw + (j % kw) for i, j in zip(ii, jj)]
        # first-valid anchor (coords + column); zeros when the group is empty
        zero_i = jnp.zeros_like(ii[0])
        ai, aj, acol, found = zero_i, zero_i, zero_i, jnp.asarray(False)
        for p in range(event_par):
            take = vv[p] & ~found
            ai = jnp.where(take, ii[p], ai)
            aj = jnp.where(take, jj[p], aj)
            acol = jnp.where(take, cols[p], acol)
            found = found | vv[p]
        homog = reduce(jnp.logical_and,
                       [~vv[p] | (cols[p] == acol) for p in range(event_par)])

        def patch_idx(i, j):
            return prefix + (pl.dslice(i, kh), pl.dslice(j, kw), slice(None))

        @pl.when(homog)
        def _parallel():
            mi = [jnp.where(vv[p], ii[p], ai) for p in range(event_par)]
            mj = [jnp.where(vv[p], jj[p], aj) for p in range(event_par)]
            contrib = [jnp.where(vv[p] | found, k_rot, zero)
                       for p in range(event_par)]
            patches = [out_ref[patch_idx(mi[p], mj[p])]
                       for p in range(event_par)]                 # gather
            updated = [_acc_patch(patches[p], contrib[p], out_ref.dtype)
                       for p in range(event_par)]                 # add
            for p in range(event_par):                            # scatter
                out_ref[patch_idx(mi[p], mj[p])] = updated[p]

        @pl.when(~homog)
        def _sequential():
            for p in range(event_par):
                i = jnp.where(vv[p], ii[p], 0)
                j = jnp.where(vv[p], jj[p], 0)
                contrib = jnp.where(vv[p], k_rot, zero)
                idx = patch_idx(i, j)
                out_ref[idx] = _acc_patch(out_ref[idx], contrib,
                                          out_ref.dtype)

        return ()

    jax.lax.fori_loop(0, n_groups, group, ())


def _event_conv_kernel(coords_ref, valid_ref, kernel_ref, vm_ref, out_ref, *, block_e):
    """One grid step: apply ``block_e`` queue entries to the VMEM vm tile."""
    _apply_event_block(coords_ref, valid_ref, kernel_ref, out_ref,
                       block_e=block_e)


@partial(jax.jit, static_argnames=("block_e", "interpret"))
def event_conv_pallas(
    vm_padded: jax.Array,
    coords: jax.Array,
    valid: jax.Array,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Apply an event queue to halo-padded membrane potentials.

    vm_padded: (H+2hh, W+2hw, C) float32 / int16 / int8, halo-padded for
               the kernel's geometry.
    coords:    (E, 2) int32 event addresses (i, j) in *unpadded* space.
    valid:     (E,) bool/int8 — AEQ valid bits.
    kernel:    (kh, kw, C) unrotated weights, same dtype as vm; the
               window (and hence the geometry) is taken from this shape.

    Returns the updated (H+2hh, W+2hw, C) tile.  E is padded up to a
    multiple of ``block_e`` by the wrapper in ops.py.
    """
    e = coords.shape[0]
    if e % block_e != 0:
        raise ValueError(
            f"event stream length E={e} must be a multiple of "
            f"block_e={block_e}: the grid tiles the queue evenly — go "
            f"through the ops.py wrappers, which pad the queue for you")
    hp, wp, c = vm_padded.shape
    kh, kw = kernel.shape[:2]
    grid = (e // block_e,)
    return pl.pallas_call(
        partial(_event_conv_kernel, block_e=block_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, 2), lambda b: (b, 0)),      # event coords stream
            pl.BlockSpec((block_e,), lambda b: (b,)),           # valid bits stream
            pl.BlockSpec((kh, kw, c), lambda b: (0, 0, 0)),     # kernel, resident
            pl.BlockSpec((hp, wp, c), lambda b: (0, 0, 0)),     # vm, resident
        ],
        out_specs=pl.BlockSpec((hp, wp, c), lambda b: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, wp, c), vm_padded.dtype),
        input_output_aliases={3: 0},  # accumulate vm in place across grid steps
        interpret=resolve_interpret(interpret),
    )(coords, valid.astype(jnp.int8), kernel, vm_padded)


def _event_conv_batched_kernel(coords_ref, valid_ref, kernel_ref, vm_ref,
                               out_ref, *, block_e):
    """One (queue, event-block) grid step: apply ``block_e`` entries of the
    current queue to that queue's VMEM-resident vm tile."""
    _apply_event_block(coords_ref, valid_ref, kernel_ref, out_ref,
                       block_e=block_e, prefix=(0,))


@partial(jax.jit, static_argnames=("block_e", "interpret"))
def event_conv_pallas_batched(
    vm_padded: jax.Array,
    coords: jax.Array,
    valid: jax.Array,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Apply Q event queues to Q halo-padded membrane-potential tiles.

    vm_padded: (Q, H+2hh, W+2hw, C) float32 / int16 / int8 — one tile per
               queue (in the batched scheduler Q is the sample batch B).
    coords:    (Q, E, 2) int32 event addresses in *unpadded* space.
    valid:     (Q, E) bool/int8 — AEQ valid bits.
    kernel:    (kh, kw, C) unrotated weights shared by every queue (all
               queues hold the same (c_in -> channel block) slice).

    One pallas_call, 2-D grid (queue, event block); E must be a multiple
    of ``block_e`` (ops.py pads).  Returns the updated (Q, H+2, W+2, C)
    tiles; per-queue program order is preserved exactly, so results are
    bit-identical to Q sequential ``event_conv_pallas`` calls.
    """
    q, e, _ = coords.shape
    if e % block_e != 0:
        raise ValueError(
            f"event stream length E={e} must be a multiple of "
            f"block_e={block_e}: the grid tiles the queue evenly — go "
            f"through the ops.py wrappers, which pad the queues for you")
    if vm_padded.shape[0] != q:
        raise ValueError(
            f"queue count mismatch: vm has {vm_padded.shape[0]} tiles, "
            f"coords describe {q} queues")
    _, hp, wp, c = vm_padded.shape
    kh, kw = kernel.shape[:2]
    grid = (q, e // block_e)
    return pl.pallas_call(
        partial(_event_conv_batched_kernel, block_e=block_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_e, 2), lambda qi, b: (qi, b, 0)),  # event stream
            pl.BlockSpec((1, block_e), lambda qi, b: (qi, b)),         # valid bits
            pl.BlockSpec((kh, kw, c), lambda qi, b: (0, 0, 0)),        # kernel, resident
            pl.BlockSpec((1, hp, wp, c), lambda qi, b: (qi, 0, 0, 0)),  # vm tile
        ],
        out_specs=pl.BlockSpec((1, hp, wp, c), lambda qi, b: (qi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, hp, wp, c), vm_padded.dtype),
        input_output_aliases={3: 0},  # accumulate each tile in place
        interpret=resolve_interpret(interpret),
    )(coords, valid.astype(jnp.int8), kernel, vm_padded)


def _check_interlaced_blocks(e: int, block_e: int, event_par: int) -> None:
    if event_par < 2:
        raise ValueError(
            f"event_par={event_par}: the interlaced kernel needs >= 2 "
            f"events per group (use event_conv_pallas for the sequential "
            f"schedule)")
    if block_e % event_par != 0:
        raise ValueError(
            f"block_e={block_e} must be a multiple of event_par="
            f"{event_par} so parallel groups tile each event block")
    if e % block_e != 0:
        raise ValueError(
            f"event stream length E={e} must be a multiple of "
            f"block_e={block_e}: the grid tiles the queue evenly — go "
            f"through the ops.py wrappers, which pad the queue for you")


def _event_conv_interlaced_kernel(coords_ref, valid_ref, kernel_ref, vm_ref,
                                  out_ref, *, block_e, event_par):
    _apply_event_block_interlaced(coords_ref, valid_ref, kernel_ref, out_ref,
                                  block_e=block_e, event_par=event_par)


@partial(jax.jit, static_argnames=("block_e", "event_par", "interpret"))
def event_conv_pallas_interlaced(
    vm_padded: jax.Array,
    coords: jax.Array,
    valid: jax.Array,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    event_par: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Interlace-parallel ``event_conv_pallas``: ``event_par`` same-column
    events per vectorized gather->add->scatter step.

    Same contract as ``event_conv_pallas``; feed it interlace-ordered
    queues (ideally ``aeq.segment_pad``-ed, which makes every aligned
    group column-homogeneous so the sequential fallback never fires).
    Bit-exact vs the sequential kernel for float32/int16/int8
    (tests/test_interlaced.py).
    """
    e = coords.shape[0]
    _check_interlaced_blocks(e, block_e, event_par)
    hp, wp, c = vm_padded.shape
    kh, kw = kernel.shape[:2]
    grid = (e // block_e,)
    return pl.pallas_call(
        partial(_event_conv_interlaced_kernel, block_e=block_e,
                event_par=event_par),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, 2), lambda b: (b, 0)),
            pl.BlockSpec((block_e,), lambda b: (b,)),
            pl.BlockSpec((kh, kw, c), lambda b: (0, 0, 0)),
            pl.BlockSpec((hp, wp, c), lambda b: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((hp, wp, c), lambda b: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, wp, c), vm_padded.dtype),
        input_output_aliases={3: 0},
        interpret=resolve_interpret(interpret),
    )(coords, valid.astype(jnp.int8), kernel, vm_padded)


def _event_conv_interlaced_batched_kernel(coords_ref, valid_ref, kernel_ref,
                                          vm_ref, out_ref, *, block_e,
                                          event_par):
    _apply_event_block_interlaced(coords_ref, valid_ref, kernel_ref, out_ref,
                                  block_e=block_e, event_par=event_par,
                                  prefix=(0,))


@partial(jax.jit, static_argnames=("block_e", "event_par", "interpret"))
def event_conv_pallas_interlaced_batched(
    vm_padded: jax.Array,
    coords: jax.Array,
    valid: jax.Array,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    event_par: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Interlace-parallel ``event_conv_pallas_batched`` (2-D grid over
    (queue, event block), ``event_par`` hazard-free events per step).

    Same contract as the sequential batched kernel and bit-exact vs it;
    per-queue segment padding (``aeq.segment_pad``) keeps every group
    column-homogeneous.
    """
    q, e, _ = coords.shape
    _check_interlaced_blocks(e, block_e, event_par)
    if vm_padded.shape[0] != q:
        raise ValueError(
            f"queue count mismatch: vm has {vm_padded.shape[0]} tiles, "
            f"coords describe {q} queues")
    _, hp, wp, c = vm_padded.shape
    kh, kw = kernel.shape[:2]
    grid = (q, e // block_e)
    return pl.pallas_call(
        partial(_event_conv_interlaced_batched_kernel, block_e=block_e,
                event_par=event_par),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_e, 2), lambda qi, b: (qi, b, 0)),
            pl.BlockSpec((1, block_e), lambda qi, b: (qi, b)),
            pl.BlockSpec((kh, kw, c), lambda qi, b: (0, 0, 0)),
            pl.BlockSpec((1, hp, wp, c), lambda qi, b: (qi, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hp, wp, c), lambda qi, b: (qi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, hp, wp, c), vm_padded.dtype),
        input_output_aliases={3: 0},
        interpret=resolve_interpret(interpret),
    )(coords, valid.astype(jnp.int8), kernel, vm_padded)
