"""Pallas TPU kernel: event-driven 3x3 convolution (paper conv unit, C2+C3).

Maps the FPGA convolution unit onto the TPU memory hierarchy:

* The membrane-potential tile ``vm`` (H+2, W+2, C) lives **resident in
  VMEM** for the whole call — the analogue of the 9 interlaced BRAM
  columns hard-wired to the PEs.  The +1 halo replaces the FPGA's
  out-of-bounds detection (edge events write into the halo, which is
  cropped by the wrapper and never thresholded).
* The grid runs over **event blocks**; each step streams one block of
  queue entries (coords, valid) from HBM while vm stays put
  (``input_output_aliases`` accumulates in place across grid steps) —
  the analogue of the AEQ feeding the pipeline a steady event stream.
* Parallelism is over the **C output channels in the lane dimension**
  (the TPU-native replacement for the FPGA's 9 tap-parallel PEs); the
  events of a queue are applied sequentially, which preserves program
  order exactly, so the RAW hazards of the FPGA pipeline cannot occur.
* Integer dtypes use saturating adds (paper C7): the accumulation is
  widened to int32 and clamped back to the storage width.

Block shapes: the C axis should be a multiple of 128 (lane width) and the
vm tile must fit VMEM: (H+2)(W+2)*C*4B; for the paper's 28x28 layers with
C=128 that is ~0.46 MB — comfortable against ~16 MB VMEM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SAT_RANGE = {jnp.int8.dtype: (-128, 127), jnp.int16.dtype: (-32768, 32767)}


def _event_conv_kernel(coords_ref, valid_ref, kernel_ref, vm_ref, out_ref, *, block_e):
    """One grid step: apply ``block_e`` queue entries to the VMEM vm tile."""
    # vm arrives through out_ref thanks to input_output_aliases: every grid
    # step accumulates into the same VMEM-resident tile.
    k_rot = kernel_ref[...][::-1, ::-1, :]  # 180deg rotation (paper Fig. 4)
    zero = jnp.zeros_like(k_rot)
    sat = _SAT_RANGE.get(out_ref.dtype)

    def body(e, _):
        i = coords_ref[e, 0]
        j = coords_ref[e, 1]
        v = valid_ref[e] != 0
        # Invalid slots contribute zeros at the (0,0) corner — branch-free
        # masking, the AEQ valid bit in vector form.
        i = jnp.where(v, i, 0)
        j = jnp.where(v, j, 0)
        contrib = jnp.where(v, k_rot, zero)
        patch = out_ref[pl.dslice(i, 3), pl.dslice(j, 3), :]
        if sat is not None:  # saturating fixed-point PE adders (paper C7)
            wide = patch.astype(jnp.int32) + contrib.astype(jnp.int32)
            updated = jnp.clip(wide, sat[0], sat[1]).astype(out_ref.dtype)
        else:
            updated = patch + contrib
        out_ref[pl.dslice(i, 3), pl.dslice(j, 3), :] = updated
        return ()

    jax.lax.fori_loop(0, block_e, body, ())


@partial(jax.jit, static_argnames=("block_e", "interpret"))
def event_conv_pallas(
    vm_padded: jax.Array,
    coords: jax.Array,
    valid: jax.Array,
    kernel: jax.Array,
    *,
    block_e: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Apply an event queue to halo-padded membrane potentials.

    vm_padded: (H+2, W+2, C) float32 / int16 / int8.
    coords:    (E, 2) int32 event addresses (i, j) in *unpadded* space.
    valid:     (E,) bool/int8 — AEQ valid bits.
    kernel:    (3, 3, C) unrotated weights, same dtype as vm.

    Returns the updated (H+2, W+2, C) tile.  E is padded up to a multiple
    of ``block_e`` by the wrapper in ops.py.
    """
    e = coords.shape[0]
    if e % block_e != 0:
        raise ValueError(f"E={e} must be a multiple of block_e={block_e}")
    hp, wp, c = vm_padded.shape
    grid = (e // block_e,)
    return pl.pallas_call(
        partial(_event_conv_kernel, block_e=block_e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, 2), lambda b: (b, 0)),      # event coords stream
            pl.BlockSpec((block_e,), lambda b: (b,)),           # valid bits stream
            pl.BlockSpec((3, 3, c), lambda b: (0, 0, 0)),       # kernel, resident
            pl.BlockSpec((hp, wp, c), lambda b: (0, 0, 0)),     # vm, resident
        ],
        out_specs=pl.BlockSpec((hp, wp, c), lambda b: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, wp, c), vm_padded.dtype),
        input_output_aliases={3: 0},  # accumulate vm in place across grid steps
        interpret=interpret,
    )(coords, valid.astype(jnp.int8), kernel, vm_padded)
