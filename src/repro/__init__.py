"""repro: Sparsely-Active CSNN Acceleration (Sommer et al., TCAD 2022)
rebuilt as a multi-pod JAX training/inference framework.

Subpackages: core (the paper), kernels (Pallas TPU), models (10-arch zoo),
configs, sharding, train, serve, checkpoint, runtime, launch, data.
See README.md / DESIGN.md / EXPERIMENTS.md at the repo root.
"""
