"""Repo-specific AST lint for the bug classes this codebase has shipped.

Rules (ids are stable; suppress a line with ``# analysis: ignore[rule]``
on the flagged line or the line above, with a justification comment):

* ``lint-mutable-default`` — mutable default values: ``x=[]`` /
  ``x={}`` / ``cfg=ServeConfig()`` in function signatures, and bare
  mutable class attributes in ``@dataclass`` bodies.  One shared
  instance leaks state across calls — the PR-4 ``CSNNServeConfig`` bug.
* ``lint-tracer-cast`` — ``int()`` / ``bool()`` / ``float()`` applied
  directly to a parameter of a jitted function.  Under ``jax.jit`` the
  parameter is a tracer and the cast raises ``ConcretizationTypeError``
  at trace time (or silently bakes a constant if it sneaks through via
  a weak type).
* ``lint-host-call-in-jit`` — ``np.random.*`` / ``time.*`` /
  ``random.*`` calls inside a jitted function: they execute once at
  trace time and freeze into the compiled executable, so every call
  after the first reuses the "random" number or timestamp.
* ``lint-pallas-call-outside-kernels`` — ``pl.pallas_call`` invoked
  outside ``src/repro/kernels/``.  Kernels live behind the plan/execute
  split; ad-hoc pallas_call sites bypass the autotuner, the interpret
  switch, and this auditor.
* ``lint-missing-donate`` — known hot entry points (the serving step
  functions, which rewrite multi-MB membrane state every tick) must be
  jitted with ``donate_argnums`` so XLA reuses the input buffers
  instead of doubling peak memory.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, Optional

from .report import Report

IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore\[([a-zA-Z0-9,\- ]+)\]")

# (path suffix, function name) pairs that must be jitted with donation.
DONATE_REGISTRY: frozenset[tuple[str, str]] = frozenset({
    ("serve/csnn_engine.py", "step_bucket"),
    ("launch/dryrun.py", "decode_fn"),
})

# Calls that are fine as defaults: immutable factories, plus
# dataclasses.field — the sanctioned per-instance construction hook.
_IMMUTABLE_FACTORIES = {"frozenset", "tuple", "dtype", "field"}
_CASTS = {"int", "bool", "float"}
_HOST_MODULES = {"time", "random"}
_NP_NAMES = {"np", "numpy"}


def _dotted(node: ast.AST) -> str:
    """'a.b.c' for an Attribute/Name chain, '' otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_expr(node: ast.AST) -> bool:
    """True if the expression applies jax.jit: ``jax.jit``, ``jit``,
    ``partial(jax.jit, ...)`` or ``jax.jit(...)`` / ``partial(...)``
    call heads used as decorators."""
    name = _dotted(node)
    if name in ("jit", "jax.jit"):
        return True
    if isinstance(node, ast.Call):
        head = _dotted(node.func)
        if head in ("jit", "jax.jit"):
            return True
        if head.endswith("partial"):
            return any(_is_jit_expr(a) for a in node.args)
    return False


class _Lints(ast.NodeVisitor):
    def __init__(self, rel: str, lines: list[str], in_kernels: bool,
                 report: Report) -> None:
        self.rel = rel
        self.lines = lines
        self.in_kernels = in_kernels
        self.rep = report
        self.jitted_names: set[str] = set()
        self._fn_stack: list[Optional[set[str]]] = []  # params if jitted

    # -- suppression ----------------------------------------------------
    def _suppressed(self, lineno: int, rule: str) -> bool:
        for ln in (lineno, lineno - 1):
            if 1 <= ln <= len(self.lines):
                m = IGNORE_RE.search(self.lines[ln - 1])
                if m and rule in {r.strip() for r in m.group(1).split(",")}:
                    return True
        return False

    def _flag(self, rule: str, node: ast.AST, message: str) -> None:
        if self._suppressed(node.lineno, rule):
            self.rep.proved(rule)
            return
        self.rep.flag("lint", rule, f"{self.rel}:{node.lineno}", message)

    # -- rule: mutable defaults ----------------------------------------
    def _check_default(self, node: ast.AST) -> None:
        if node is None:
            return
        bad = None
        if isinstance(node, (ast.List, ast.Dict, ast.Set)):
            bad = "a mutable literal"
        elif isinstance(node, ast.Call):
            head = _dotted(node.func).rsplit(".", 1)[-1]
            if head not in _IMMUTABLE_FACTORIES:
                bad = f"a call ({_dotted(node.func) or 'expression'}(...))"
        if bad is None:
            self.rep.proved("lint-mutable-default")
        else:
            self._flag(
                "lint-mutable-default", node,
                f"default value is {bad}, evaluated once and shared "
                f"across every call — use None and construct inside")

    def _visit_fn(self, node) -> None:
        for d in list(node.args.defaults) + list(node.args.kw_defaults):
            self._check_default(d)
        jitted = any(_is_jit_expr(d) for d in node.decorator_list) \
            or node.name in self.jitted_names
        params = None
        if jitted:
            a = node.args
            params = {p.arg for p in
                      a.posonlyargs + a.args + a.kwonlyargs}
        self._fn_stack.append(params)
        self.generic_visit(node)
        self._fn_stack.pop()
        if jitted:
            self.rep.proved("lint-tracer-cast")
            self.rep.proved("lint-host-call-in-jit")

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        is_dc = any("dataclass" in _dotted(
            d.func if isinstance(d, ast.Call) else d)
            for d in node.decorator_list)
        if is_dc:
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                    self._check_default(stmt.value)
                elif isinstance(stmt, ast.Assign):
                    self._check_default(stmt.value)
        self.generic_visit(node)

    # -- rules inside jitted bodies + pallas/jit call sites -------------
    def visit_Call(self, node: ast.Call) -> None:
        head = _dotted(node.func)
        tail = head.rsplit(".", 1)[-1]
        jit_params = self._fn_stack[-1] if self._fn_stack else None

        if tail == "pallas_call":
            if self.in_kernels:
                self.rep.proved("lint-pallas-call-outside-kernels")
            else:
                self._flag(
                    "lint-pallas-call-outside-kernels", node,
                    "pl.pallas_call outside kernels/ bypasses the "
                    "plan/execute split and the interpret switch")

        if jit_params is not None:
            if tail in _CASTS and node.args and isinstance(
                    node.args[0], ast.Name) and \
                    node.args[0].id in jit_params:
                self._flag(
                    "lint-tracer-cast", node,
                    f"{tail}() on parameter '{node.args[0].id}' of a "
                    f"jitted function concretizes a tracer")
            root = head.split(".", 1)[0]
            if (root in _NP_NAMES and ".random" in head) or \
                    root in _HOST_MODULES:
                self._flag(
                    "lint-host-call-in-jit", node,
                    f"'{head}' inside a jitted function runs at trace "
                    f"time only — its result is frozen into the "
                    f"compiled executable")

        if head in ("jit", "jax.jit"):
            target = node.args[0] if node.args else None
            tname = _dotted(target) if target is not None else ""
            if tname:
                self.jitted_names.add(tname.rsplit(".", 1)[-1])
            for suffix, fn in DONATE_REGISTRY:
                if self.rel.endswith(suffix) and \
                        tname.rsplit(".", 1)[-1] == fn:
                    if any(kw.arg == "donate_argnums"
                           for kw in node.keywords):
                        self.rep.proved("lint-missing-donate")
                    else:
                        self._flag(
                            "lint-missing-donate", node,
                            f"hot entry point '{fn}' jitted without "
                            f"donate_argnums — doubles peak membrane "
                            f"memory")
        self.generic_visit(node)


def lint_source(source: str, filename: str,
                report: Optional[Report] = None) -> Report:
    """Lint one file's source text (``filename`` is used for rule
    scoping: kernels/ exemption, donate registry matching)."""
    rep = report if report is not None else Report()
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as exc:
        rep.flag("lint", "lint-syntax", f"{filename}:{exc.lineno or 0}",
                 f"file does not parse: {exc.msg}")
        return rep
    in_kernels = "/kernels/" in filename.replace("\\", "/")
    visitor = _Lints(filename, source.splitlines(), in_kernels, rep)
    # two passes so `f = jax.jit(f)`-style module-level jitting marks the
    # function regardless of definition order
    visitor.visit(tree)
    if visitor.jitted_names:
        second = _Lints(filename, source.splitlines(), in_kernels, Report())
        second.jitted_names = set(visitor.jitted_names)
        second.visit(tree)
        known = {(f.rule, f.where) for f in rep.findings}
        for f in second.rep.findings:
            if (f.rule, f.where) not in known:
                rep.add(f)
    rep.proved("lint-pallas-call-outside-kernels")  # file scanned
    return rep


def _default_paths() -> list[Path]:
    root = Path(__file__).resolve().parents[3]
    return [root / "src" / "repro", root / "benchmarks", root / "examples"]


def _iter_py(paths: Iterable[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            yield from sorted(p.rglob("*.py"))


def run_lint(paths: Optional[Iterable[Path]] = None,
             report: Optional[Report] = None) -> Report:
    rep = report if report is not None else Report()
    root = Path(__file__).resolve().parents[3]
    for path in _iter_py(_default_paths() if paths is None else
                         [Path(p) for p in paths]):
        try:
            rel = str(path.relative_to(root))
        except ValueError:
            rel = str(path)
        lint_source(path.read_text(), rel, report=rep)
    return rep
