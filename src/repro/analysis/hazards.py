"""Symbolic hazard-freedom and static bounds checking of the kernel layer.

The correctness of every event-parallel path in this repo rests on one
structural theorem (paper Sec. "memory interlacing", Fig. 6): **two
distinct events of the same interlace column s = kw*(i%kh)+(j%kw) have
disjoint kh x kw write footprints** (s = 3(i%3)+(j%3) in the paper's
3x3), so applying a whole column (or any same-column group) in parallel
can never double-write a membrane cell.  PR 5 exploits it three ways —
the banked-select jax path (``event_conv.apply_banked_columns``), the
interlaced Pallas kernels, and the ``segment_pad`` queue layout that
feeds them.  This module *proves* the theorem and audits each
exploitation site statically, parameterized over the window geometry
(``run_hazards`` sweeps k in {1, 3, 5}):

* ``hazard-column-disjoint`` — exhaustive proof over one full congruence
  period (a 4k x 4k window: every residue pair appears, and footprint
  geometry only depends on those residues, so the finite check is a
  proof for all H, W at that k).
* ``hazard-mask-routing`` — the n_banks^2 static ``shifted_bank_masks``
  (column, bank) slices (81 at 3x3) are verified one-hot-by-one-hot
  against a brute force enumeration of where each kernel tap of each
  pixel must land (padded-space bank + macro cell), including the
  bank<->tap bijection per column (each of the n_banks banks receives
  exactly one tap).
* ``hazard-segment-homogeneous`` — ``segment_pad`` layouts are audited on
  adversarial feature maps: every aligned ``event_par`` group must be
  column-homogeneous with pairwise-disjoint footprints among its valid
  events (the precondition under which the interlaced Pallas kernel's
  all-reads-before-writes group schedule is exact), and the padded queue
  must hold exactly the original kept-event multiset in order.
* ``oob-event-patch`` — interval bounds of the ``pl.dslice`` gather/
  scatter in ``kernels/event_conv/kernel.py``: event coords are produced
  in unpadded space [0, H-1] (invalid slots are masked to 0), each event
  reads/writes a kh x kw patch at that offset in the halo-padded
  (H+2hh, W+2hw, C) tile, so the worst-case slice end (H-1)+kh =
  H+2(kh//2)+1 must stay within the padded extent — proven per sweep
  geometry, for both axes.
* ``oob-blockspec-bounds`` — every ``pl.BlockSpec`` index map of every
  ``pl.pallas_call`` in ``kernels/event_conv/kernel.py`` and
  ``kernels/threshold_pool/kernel.py`` is captured by tracing the real
  wrappers with an interposed ``pallas_call`` and evaluated over the full
  grid: all block offsets must stay inside the operand, the final blocks
  must reach the operand end (no silently untouched tail), and
  ``input_output_aliases`` must pair shape/dtype-identical operands.

The capture step runs the *actual shipped kernels* under
``jax.eval_shape`` (abstract values only — nothing executes), so the
audit cannot drift from the code it certifies.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.geometry import GEOM_3X3, ConvGeometry

from .report import Report

# Cap on exhaustively enumerated grid points per captured pallas_call.
_MAX_GRID_POINTS = 65536

#: Window geometries the proofs sweep — the paper's 3x3 plus the k=1 and
#: k=5 ends of the parametric generalization.
SWEEP_GEOMETRIES = (ConvGeometry(1, 1), GEOM_3X3, ConvGeometry(5, 5))


# ---------------------------------------------------------------------------
# Interlace-column disjointness: the hazard-freedom theorem.
# ---------------------------------------------------------------------------

def _footprint(i: int, j: int,
               geometry: ConvGeometry = GEOM_3X3) -> set[tuple[int, int]]:
    """Padded-space cells written by an event centred at unpadded (i, j):
    the kh x kw patch at padded offset (i, j) — rows i..i+kh-1, cols
    j..j+kw-1 (3x3 in the paper)."""
    kh, kw = geometry.window
    return {(i + a, j + b) for a in range(kh) for b in range(kw)}


def check_column_disjointness(window: Optional[int] = None, *,
                              geometry: ConvGeometry = GEOM_3X3,
                              column_of: Optional[Callable] = None,
                              report: Optional[Report] = None) -> Report:
    """Exhaustively prove same-column footprint disjointness on a window
    covering every congruence case (window >= 2*max(kh, kw) sees all
    residue pairs; the default 4*max(kh, kw) adds two full extra periods
    of margin — 12 for the paper's 3x3).

    Footprint geometry only depends on the coordinate residues modulo the
    kernel window, so the finite check is a proof for all H, W — at every
    odd k, not just 3.  ``column_of`` overrides the column assignment
    (i, j) -> s, which is how the self-test seeds a hazard-colliding
    interlace scheme.
    """
    rep = report if report is not None else Report()
    kh, kw = geometry.window
    if window is None:
        window = 4 * max(kh, kw)
    col = column_of if column_of is not None else geometry.column_index_py
    pixels = list(itertools.product(range(window), range(window)))
    checked = 0
    for (i1, j1), (i2, j2) in itertools.combinations(pixels, 2):
        if col(i1, j1) != col(i2, j2):
            continue
        checked += 1
        if _footprint(i1, j1, geometry) & _footprint(i2, j2, geometry):
            rep.flag("hazards", "hazard-column-disjoint",
                     f"window[{window}x{window},k={kh}x{kw}]",
                     f"events ({i1},{j1}) and ({i2},{j2}) share interlace "
                     f"column {col(i1, j1)} but their {kh}x{kw} write "
                     f"footprints overlap — parallel application would "
                     f"double-write")
    rep.proved("hazard-column-disjoint", checked)
    return rep


# ---------------------------------------------------------------------------
# shifted_bank_masks routing: the 81 (column, bank) static slices.
# ---------------------------------------------------------------------------

def check_mask_routing(hw: tuple[int, int] = (8, 9), *,
                       geometry: ConvGeometry = GEOM_3X3,
                       report: Optional[Report] = None) -> Report:
    """Verify the n_banks^2 ``shifted_bank_masks`` (column, bank) write
    masks against a brute-force enumeration, one one-hot event at a time
    (81 slices for the paper's 3x3).

    For an event at unpadded (i, j) (padded centre (i+hh, j+hw),
    interlace column s), tap (a, b) writes padded cell (i+a, j+b), which
    lives in bank t = kw*((i+a)%kh) + (j+b)%kw at macro cell
    ((i+a)//kh, (j+b)//kw).  The shifted masks must light exactly those
    n_banks cells in row s, one per bank (the bank<->tap bijection behind
    the FPGA's conflict-free ports), and every other row must stay dark.
    """
    import jax.numpy as jnp

    from repro.core.aeq import interlace
    from repro.core.event_conv import shifted_bank_masks

    rep = report if report is not None else Report()
    h, w = hw
    kh, kw = geometry.window
    hh, hw_ = geometry.halo
    nb = geometry.n_banks
    hb, wb = -(-(h + 2 * hh) // kh), -(-(w + 2 * hw_) // kw)
    for i in range(h):
        for j in range(w):
            s = geometry.column_index_py(i, j)
            # one-hot occupancy: pad the centre, bank it (the
            # build_bank_masks layout for this single kept event)
            fmap = np.zeros((h, w), bool)
            fmap[i, j] = True
            padded = np.pad(fmap, ((hh, hh), (hw_, hw_)))
            masks = np.asarray(interlace(jnp.asarray(padded), geometry))
            got = np.asarray(shifted_bank_masks(jnp.asarray(masks),
                                                geometry))
            want = np.zeros((nb, nb, hb, wb), bool)
            for a in range(kh):
                for b in range(kw):
                    r, c = i + a, j + b
                    t = kw * (r % kh) + (c % kw)
                    want[s, t, r // kh, c // kw] = True
            if not np.array_equal(got, want):
                bad = np.argwhere(got != want)
                rep.flag("hazards", "hazard-mask-routing",
                         f"event({i},{j})[k={kh}x{kw}]",
                         f"shifted_bank_masks routes column {s} wrongly at "
                         f"(col, bank, I, J)={tuple(bad[0])} — "
                         f"{len(bad)} cell(s) differ from the brute-force "
                         f"tap enumeration")
                continue
            banks_hit = {int(t) for t in np.argwhere(want[s].any((-2, -1)))
                         .ravel()}
            if banks_hit != set(range(nb)):
                rep.flag("hazards", "hazard-mask-routing",
                         f"event({i},{j})[k={kh}x{kw}]",
                         f"column {s} writes banks {sorted(banks_hit)} — "
                         f"the {nb}-tap footprint must hit each bank "
                         f"exactly once")
            rep.proved("hazard-mask-routing")
    return rep


def check_banked_masks(masks: np.ndarray, *,
                       geometry: ConvGeometry = GEOM_3X3,
                       where: str = "bank-masks",
                       report: Optional[Report] = None) -> Report:
    """Audit a concrete (n_banks, HB, WB) bank-occupancy mask set (the
    ``aeq.build_bank_masks`` output consumed by the banked conv path):
    every pair of occupied cells within one bank must map to padded
    positions >= kh (resp. kw) apart in some axis (same-bank cells share
    both residues, so this is disjointness of their kh x kw footprints),
    i.e. the mask set admits hazard-free whole-column application.

    A mask set violating this cannot come from the banked layout (cells
    of one bank are distinct macro addresses by construction) — the check
    exists so hand-built or corrupted mask sets (self-test fixtures, and
    any future non-grid mask producer) are rejected before use.
    """
    rep = report if report is not None else Report()
    kh, kw = geometry.window
    nb = geometry.n_banks
    m = np.asarray(masks)
    if m.ndim != 3 or m.shape[0] != nb:
        rep.flag("hazards", "hazard-banked-masks", where,
                 f"expected ({nb}, HB, WB) bank masks for the {kh}x{kw} "
                 f"geometry, got shape {m.shape}")
        return rep
    for t in range(nb):
        cells = np.argwhere(m[t])
        for (i1, j1), (i2, j2) in itertools.combinations(map(tuple, cells), 2):
            p1 = (kh * i1 + t // kw, kw * j1 + t % kw)
            p2 = (kh * i2 + t // kw, kw * j2 + t % kw)
            if abs(p1[0] - p2[0]) < kh and abs(p1[1] - p2[1]) < kw:
                rep.flag("hazards", "hazard-banked-masks", where,
                         f"bank {t} holds events at padded {p1} and {p2} "
                         f"with overlapping {kh}x{kw} footprints")
        rep.proved("hazard-banked-masks")
    return rep


# ---------------------------------------------------------------------------
# segment_pad layout: the interlaced Pallas kernel's precondition.
# ---------------------------------------------------------------------------

def _adversarial_fmaps(h: int, w: int,
                       geometry: ConvGeometry = GEOM_3X3
                       ) -> list[tuple[str, np.ndarray]]:
    """Feature maps that stress the queue layout: dense, empty, single
    pixel, checkerboard, one full interlace column, and a seeded random."""
    kh, kw = geometry.window
    rng = np.random.default_rng(0)
    full = np.ones((h, w), bool)
    empty = np.zeros((h, w), bool)
    single = np.zeros((h, w), bool)
    single[h // 2, w // 2] = True
    checker = np.indices((h, w)).sum(0) % 2 == 0
    one_col = np.zeros((h, w), bool)
    one_col[0::kh, 0::kw] = True
    rand = rng.random((h, w)) < 0.3
    return [("full", full), ("empty", empty), ("single", single),
            ("checker", checker), ("one-column", one_col), ("random", rand)]


def check_segment_layout(hw: tuple[int, int] = (11, 13),
                         capacities: Sequence[int] = (16, 64, 1024),
                         event_pars: Sequence[int] = (2, 4, 8), *,
                         geometry: ConvGeometry = GEOM_3X3,
                         report: Optional[Report] = None) -> Report:
    """Audit ``aeq.segment_pad`` output layouts on adversarial fmaps.

    Three obligations per (fmap, capacity, event_par) case:

    1. every aligned group of ``event_par`` slots is column-homogeneous
       among its valid events (the interlaced kernel's parallel-apply
       precondition — a heterogeneous group would fall back to the
       sequential body, or worse, double-write if the fallback were
       removed);
    2. valid events inside one group have pairwise-disjoint footprints
       (hazard freedom realized on the concrete layout, truncation
       included);
    3. the padded queue replays the exact kept-event sequence of the
       unpadded queue (padding inserts invalid no-ops only, order kept).
    """
    import jax.numpy as jnp

    from repro.core.aeq import build_aeq, segment_pad

    rep = report if report is not None else Report()
    h, w = hw
    kh, kw = geometry.window
    for (name, fmap), cap, par in itertools.product(
            _adversarial_fmaps(h, w, geometry), capacities, event_pars):
        where = f"segment_pad[{name},cap={cap},par={par},k={kh}x{kw}]"
        q = build_aeq(jnp.asarray(fmap), cap, geometry=geometry)
        qp = segment_pad(q, par, geometry)
        check_padded_queue(np.asarray(qp.coords), np.asarray(qp.valid), par,
                           geometry=geometry, where=where, report=rep)
        kept = [tuple(c) for c, v in zip(np.asarray(q.coords),
                                         np.asarray(q.valid)) if v]
        kept_p = [tuple(c) for c, v in zip(np.asarray(qp.coords),
                                           np.asarray(qp.valid)) if v]
        if kept != kept_p:
            rep.flag("hazards", "hazard-segment-homogeneous", where,
                     f"segment_pad changed the kept-event sequence "
                     f"({len(kept)} -> {len(kept_p)} events)")
        rep.proved("hazard-segment-replay")
    return rep


def check_padded_queue(coords: np.ndarray, valid: np.ndarray,
                       event_par: int, *,
                       geometry: ConvGeometry = GEOM_3X3,
                       where: str = "queue",
                       report: Optional[Report] = None) -> Report:
    """Check one concrete (E, 2) queue layout for group homogeneity and
    in-group footprint disjointness (seedable with hand-built queues)."""
    rep = report if report is not None else Report()
    kh, kw = geometry.window
    e = coords.shape[0]
    if e % event_par != 0:
        rep.flag("hazards", "hazard-segment-homogeneous", where,
                 f"queue depth {e} is not a multiple of "
                 f"event_par={event_par}")
        return rep
    for g in range(e // event_par):
        sl = slice(g * event_par, (g + 1) * event_par)
        ev = [tuple(map(int, c)) for c, v in zip(coords[sl], valid[sl]) if v]
        cols = {geometry.column_index_py(i, j) for i, j in ev}
        if len(cols) > 1:
            rep.flag("hazards", "hazard-segment-homogeneous", where,
                     f"aligned group {g} mixes interlace columns "
                     f"{sorted(cols)}: events {ev}")
        for (i1, j1), (i2, j2) in itertools.combinations(ev, 2):
            if abs(i1 - i2) < kh and abs(j1 - j2) < kw:
                rep.flag("hazards", "hazard-segment-homogeneous", where,
                         f"group {g} events ({i1},{j1}) and ({i2},{j2}) "
                         f"have overlapping {kh}x{kw} footprints — "
                         f"parallel apply would double-write")
        rep.proved("hazard-segment-homogeneous")
    return rep


# ---------------------------------------------------------------------------
# pallas_call capture: audit the real kernels' grids and BlockSpecs.
# ---------------------------------------------------------------------------

@dataclass
class CapturedCall:
    """One intercepted ``pl.pallas_call``: everything needed to bounds-
    check its BlockSpec index maps without executing the kernel."""

    name: str                         # wrapper entry point
    grid: tuple[int, ...]
    in_specs: list                    # pl.BlockSpec per operand
    out_specs: list                   # pl.BlockSpec per output
    arg_shapes: list[tuple[int, ...]]
    arg_dtypes: list
    out_shapes: list[tuple[int, ...]]
    out_dtypes: list
    aliases: dict = field(default_factory=dict)


def _spec_parts(spec) -> tuple[Optional[tuple], Optional[Callable]]:
    """(block_shape, index_map) from a pl.BlockSpec across jax versions
    (older releases took the arguments in the opposite order)."""
    bs = getattr(spec, "block_shape", None)
    im = getattr(spec, "index_map", None)
    if callable(bs) and not callable(im):
        bs, im = im, bs
    return bs, im


def capture_pallas_calls(
        geometry: ConvGeometry = GEOM_3X3) -> list[CapturedCall]:
    """Trace every Pallas kernel wrapper abstractly with ``pallas_call``
    interposed, recording grids/BlockSpecs/shapes of the *shipped* code.

    ``jax.eval_shape`` runs the wrappers on abstract values only; the
    interposer returns zeros of the declared out_shape, so no kernel body
    executes and no device memory is touched.  ``geometry`` sets the
    kernel window the event-conv wrappers are traced with (the wrappers
    derive their BlockSpecs from the kernel operand's shape).
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from repro.kernels.event_conv import kernel as ev_kernel
    from repro.kernels.threshold_pool import kernel as tp_kernel

    captured: list[CapturedCall] = []
    current: list[str] = ["?"]
    real_pallas_call = pl.pallas_call

    def interposer(body, *, grid=None, in_specs=None, out_specs=None,
                   out_shape=None, input_output_aliases=None, **kwargs):
        outs = out_shape if isinstance(out_shape, (list, tuple)) \
            else [out_shape]
        specs_out = out_specs if isinstance(out_specs, (list, tuple)) \
            else [out_specs]

        def run(*args):
            captured.append(CapturedCall(
                name=current[0],
                grid=tuple(grid) if grid is not None else (),
                in_specs=list(in_specs or []),
                out_specs=list(specs_out),
                arg_shapes=[tuple(a.shape) for a in args],
                arg_dtypes=[a.dtype for a in args],
                out_shapes=[tuple(o.shape) for o in outs],
                out_dtypes=[o.dtype for o in outs],
                aliases=dict(input_output_aliases or {})))
            zeros = [jnp.zeros(o.shape, o.dtype) for o in outs]
            return zeros if isinstance(out_shape, (list, tuple)) else zeros[0]

        return run

    # geometry representative enough to exercise every spec dimension
    h, w, c, e, q = 10, 12, 8, 64, 3
    kh, kw = geometry.window
    hh, hw_ = geometry.halo
    f32 = jnp.float32
    cases = [
        ("event_conv_pallas", ev_kernel.event_conv_pallas,
         (jax.ShapeDtypeStruct((h + 2 * hh, w + 2 * hw_, c), f32),
          jax.ShapeDtypeStruct((e, 2), jnp.int32),
          jax.ShapeDtypeStruct((e,), jnp.int8),
          jax.ShapeDtypeStruct((kh, kw, c), f32)),
         dict(block_e=16, interpret=True)),
        ("event_conv_pallas_batched", ev_kernel.event_conv_pallas_batched,
         (jax.ShapeDtypeStruct((q, h + 2 * hh, w + 2 * hw_, c), f32),
          jax.ShapeDtypeStruct((q, e, 2), jnp.int32),
          jax.ShapeDtypeStruct((q, e), jnp.int8),
          jax.ShapeDtypeStruct((kh, kw, c), f32)),
         dict(block_e=16, interpret=True)),
        ("event_conv_pallas_interlaced",
         ev_kernel.event_conv_pallas_interlaced,
         (jax.ShapeDtypeStruct((h + 2 * hh, w + 2 * hw_, c), f32),
          jax.ShapeDtypeStruct((e, 2), jnp.int32),
          jax.ShapeDtypeStruct((e,), jnp.int8),
          jax.ShapeDtypeStruct((kh, kw, c), f32)),
         dict(block_e=16, event_par=4, interpret=True)),
        ("event_conv_pallas_interlaced_batched",
         ev_kernel.event_conv_pallas_interlaced_batched,
         (jax.ShapeDtypeStruct((q, h + 2 * hh, w + 2 * hw_, c), f32),
          jax.ShapeDtypeStruct((q, e, 2), jnp.int32),
          jax.ShapeDtypeStruct((q, e), jnp.int8),
          jax.ShapeDtypeStruct((kh, kw, c), f32)),
         dict(block_e=16, event_par=4, interpret=True)),
        ("threshold_pool_pallas", tp_kernel.threshold_pool_pallas,
         (jax.ShapeDtypeStruct((9, 12, 8), f32),
          jax.ShapeDtypeStruct((8,), f32),
          jax.ShapeDtypeStruct((9, 12, 8), jnp.int8)),
         dict(v_t=1.0, pool=3, block_c=4, interpret=True)),
        ("threshold_pool_pallas_nopool", tp_kernel.threshold_pool_pallas,
         (jax.ShapeDtypeStruct((9, 12, 8), f32),
          jax.ShapeDtypeStruct((8,), f32),
          jax.ShapeDtypeStruct((9, 12, 8), jnp.int8)),
         dict(v_t=0.5, pool=None, block_c=8, interpret=True)),
    ]
    def invoke(raw, kwargs, *a):
        return raw(*a, **kwargs)

    pl.pallas_call = interposer
    try:
        for name, fn, avals, kwargs in cases:
            current[0] = name
            raw = getattr(fn, "__wrapped__", fn)  # bypass the jit cache
            jax.eval_shape(partial(invoke, raw, kwargs), *avals)
    finally:
        pl.pallas_call = real_pallas_call
    return captured


def check_blockspec_bounds(calls: Optional[list[CapturedCall]] = None, *,
                           geometry: ConvGeometry = GEOM_3X3,
                           report: Optional[Report] = None) -> Report:
    """Statically evaluate every captured BlockSpec index map over its
    full grid and bounds-check the addressed blocks.

    Obligations per (call, operand): every grid point's block offset
    (index * block_shape) stays inside the operand; the blocks reach the
    operand's end in every dimension (no untouched tail); aliased
    input/output pairs agree in shape and dtype.  ``geometry`` sets the
    kernel window the shipped wrappers are captured with when ``calls``
    is not supplied.
    """
    rep = report if report is not None else Report()
    if calls is None:
        calls = capture_pallas_calls(geometry)
    for call in calls:
        points = 1
        for g in call.grid:
            points *= max(g, 1)
        if points > _MAX_GRID_POINTS:
            rep.flag("hazards", "oob-blockspec-bounds", f"kernel:{call.name}",
                     f"grid {call.grid} too large to enumerate "
                     f"({points} points > {_MAX_GRID_POINTS}) — shrink the "
                     f"capture geometry")
            continue
        grid_points = list(itertools.product(
            *[range(g) for g in call.grid])) or [()]
        operands = (
            [("in", k, s, call.arg_shapes[k])
             for k, s in enumerate(call.in_specs)]
            + [("out", k, s, call.out_shapes[k])
               for k, s in enumerate(call.out_specs)])
        for kind, k, spec, shape in operands:
            if spec is None:
                continue
            block, index_map = _spec_parts(spec)
            if block is None or index_map is None:
                rep.flag("hazards", "oob-blockspec-bounds",
                         f"kernel:{call.name}",
                         f"{kind}[{k}] BlockSpec exposes no "
                         f"(block_shape, index_map) — cannot audit")
                continue
            lo = [None] * len(shape)
            hi = [0] * len(shape)
            bad = None
            for gp in grid_points:
                idx = index_map(*gp)
                idx = idx if isinstance(idx, tuple) else (idx,)
                if len(idx) != len(shape) or len(block) != len(shape):
                    bad = (gp, f"index map arity {len(idx)} / block rank "
                               f"{len(block)} vs operand rank {len(shape)}")
                    break
                for d, (ix, bd, dim) in enumerate(zip(idx, block, shape)):
                    off = int(ix) * bd
                    if off < 0 or off + bd > dim:
                        bad = (gp, f"dim {d}: block [{off}, {off + bd}) "
                                   f"outside operand extent {dim}")
                        break
                    lo[d] = off if lo[d] is None else min(lo[d], off)
                    hi[d] = max(hi[d], off + bd)
                if bad:
                    break
            if bad:
                rep.flag("hazards", "oob-blockspec-bounds",
                         f"kernel:{call.name}",
                         f"{kind}[{k}] shape {shape}: grid point {bad[0]} "
                         f"addresses out of bounds — {bad[1]}")
                continue
            uncovered = [d for d, dim in enumerate(shape)
                         if hi[d] < dim or (lo[d] or 0) > 0]
            if kind == "out" and uncovered:
                rep.flag("hazards", "oob-blockspec-bounds",
                         f"kernel:{call.name}",
                         f"out[{k}] shape {shape}: blocks cover only "
                         f"[{lo}, {hi}) — output tail is never written")
                continue
            rep.proved("oob-blockspec-bounds")
        for in_idx, out_idx in call.aliases.items():
            if (call.arg_shapes[in_idx] != call.out_shapes[out_idx]
                    or call.arg_dtypes[in_idx] != call.out_dtypes[out_idx]):
                rep.flag("hazards", "oob-blockspec-bounds",
                         f"kernel:{call.name}",
                         f"input_output_aliases {{{in_idx}: {out_idx}}} "
                         f"pairs mismatched operands "
                         f"{call.arg_shapes[in_idx]} vs "
                         f"{call.out_shapes[out_idx]}")
            else:
                rep.proved("oob-blockspec-bounds")
    return rep


def check_patch_bounds(h: int, w: int, *,
                       geometry: ConvGeometry = GEOM_3X3,
                       coord_hi: Optional[tuple[int, int]] = None,
                       where: Optional[str] = None,
                       report: Optional[Report] = None) -> Report:
    """Interval proof of the event-patch ``pl.dslice`` bounds.

    Event coords come from the AEQ in unpadded space — valid events lie
    in [0, H-1] x [0, W-1] and invalid slots are masked to (0, 0) inside
    the kernel — and each event addresses a kh x kw patch at that offset
    in the halo-padded (H+2hh, W+2hw, C) tile.  The audit checks
    max(coord) + window <= padded extent on both axes (and min >= 0),
    i.e. the halo exactly absorbs the worst-case slice.  ``coord_hi``
    overrides the coordinate upper bounds (self-test hook).
    """
    rep = report if report is not None else Report()
    kh, kw = geometry.window
    hp, wp = geometry.padded_hw(h, w)
    hi_i, hi_j = coord_hi if coord_hi is not None else (h - 1, w - 1)
    loc = where or f"event_conv[{h}x{w},k={kh}x{kw}]"
    for axis, hi, pad, win in (("i", hi_i, hp, kh), ("j", hi_j, wp, kw)):
        if hi + win > pad:
            rep.flag("hazards", "oob-event-patch", loc,
                     f"{axis}-axis: dslice({axis}={hi}, {win}) reaches "
                     f"{hi + win} > padded extent {pad} — the halo does "
                     f"not absorb the worst-case event patch")
        elif hi < 0:
            rep.flag("hazards", "oob-event-patch", loc,
                     f"{axis}-axis: coordinate upper bound {hi} < 0")
        else:
            rep.proved("oob-event-patch")
    return rep


def run_hazards(report: Optional[Report] = None) -> Report:
    """Run every hazard/bounds pass over the built-in sweep.

    Every pass runs once per :data:`SWEEP_GEOMETRIES` entry — the proofs
    are parameterized over the kernel window, so the 3x3 theorem the
    paper relies on is certified alongside its k=1 and k=5
    generalizations on every analysis run.
    """
    rep = report if report is not None else Report()
    for geom in SWEEP_GEOMETRIES:
        check_column_disjointness(geometry=geom, report=rep)
        check_mask_routing(geometry=geom, report=rep)
        check_segment_layout(geometry=geom, report=rep)
        for h, w in ((10, 10), (28, 28), (17, 13), (9, 16), (1, 1)):
            check_patch_bounds(h, w, geometry=geom, report=rep)
        check_blockspec_bounds(geometry=geom, report=rep)
    return rep
