"""``python -m repro.analysis`` — run the full static-analysis suite.

Exit status is nonzero iff any finding survives, so the module doubles
as a CI gate.  ``--json`` additionally writes ``ANALYSIS_report.json``
(machine-readable: findings + per-rule proof-obligation counts).
"""
from __future__ import annotations

import argparse
import sys

from .report import Report

PASSES = ("contracts", "hazards", "kernels", "lint")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Plan/kernel contract auditor + repo-specific JAX lint")
    ap.add_argument("--json", nargs="?", const="ANALYSIS_report.json",
                    metavar="PATH", default=None,
                    help="write a machine-readable report "
                         "(default: ANALYSIS_report.json)")
    ap.add_argument("--only", choices=PASSES, action="append",
                    help="run a subset of passes (repeatable)")
    ap.add_argument("--selftest", action="store_true",
                    help="also run the seeded-violation self-test "
                         "(every planted bug must be flagged)")
    args = ap.parse_args(argv)
    passes = tuple(args.only) if args.only else PASSES

    rep = Report()
    if "contracts" in passes:
        from .contracts import run_contracts
        run_contracts(report=rep)
    if "hazards" in passes:
        from .hazards import run_hazards
        run_hazards(report=rep)
    if "kernels" in passes:
        from .kernel_audit import run_kernel_audit
        run_kernel_audit(report=rep)
    if "lint" in passes:
        from .lint import run_lint
        run_lint(report=rep)
    if args.selftest:
        from .selftest import run_selftest
        run_selftest(report=rep)

    print(rep.summary())
    if args.json:
        path = rep.write_json(args.json)
        print(f"report written to {path}")
    return 0 if rep.ok else 1


if __name__ == "__main__":
    sys.exit(main())
