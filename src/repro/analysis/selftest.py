"""Seeded-violation self-test: every analyzer must flag every fixture.

A static analyzer that silently stops finding things is worse than none,
so CI runs this after the clean pass: each fixture below plants one known
violation — a corrupted plan, a hazard-colliding queue layout, an
oversized/out-of-bounds BlockSpec, a wrapping (non-saturating) adder, a
mutable-default dataclass — and the corresponding checker must produce a
finding with the expected rule id.  A fixture that passes clean becomes a
``selftest-missed`` finding, which fails the CLI (and the CI lane)
exactly like a real violation would.
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Optional

import numpy as np

from .report import Report


def _expect(out: Report, inner: Report, rule: str, fixture: str) -> None:
    """The seeded fixture must have produced >= 1 finding under `rule`."""
    if any(f.rule == rule for f in inner.findings):
        out.proved("selftest-seeded")
    else:
        out.flag("selftest", "selftest-missed", f"fixture:{fixture}",
                 f"seeded violation was NOT flagged under rule '{rule}' "
                 f"(findings: {[f.rule for f in inner.findings] or 'none'})")


def _broken_plans():
    """(fixture, rule, broken_plan) triples built by corrupting a real
    plan field-by-field — one violated contract each."""
    from repro.core.csnn import CSNNConfig
    from repro.core.geometry import ConvGeometry
    from repro.core.plan import plan_network

    plan = plan_network(CSNNConfig(), capacity=256, channel_block=8,
                        event_par=4)
    lp = plan.layers[0]

    def relayer(**kw):
        new0 = dataclasses.replace(lp, **kw)
        return dataclasses.replace(plan, layers=(new0,) + plan.layers[1:])

    class _DesyncedDepth:
        """Proxy of a LayerPlan whose allocated depth disagrees with the
        interlaced-capacity formula (the property is derived, so this
        corruption cannot be expressed with dataclasses.replace)."""

        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, name):
            return getattr(self._inner, name)

        @property
        def queue_depth(self):
            return self._inner.queue_depth + 1

    desynced = dataclasses.replace(
        plan, layers=(_DesyncedDepth(lp),) + plan.layers[1:])

    return [
        ("block-e-misaligned", "plan-block-e-divides-depth",
         relayer(block_e=lp.queue_depth - 1)),
        ("par-misaligned", "plan-block-e-par-aligned",
         relayer(block_e=lp.event_par + 1)),
        ("capacity-oversized", "plan-capacity-within-fmap",
         relayer(capacity=10 * lp.in_hw[0] * lp.in_hw[1])),
        ("depth-not-interlaced", "plan-queue-depth-interlaced", desynced),
        ("vm-tile-unpadded", "plan-vm-tile-geometry",
         relayer(vm_tile=(lp.in_hw[0], lp.in_hw[1], lp.channel_block))),
        ("vmem-blown", "plan-vmem-budget",
         dataclasses.replace(plan, batch_tile=1 << 20)),
        ("t-chunk-ragged", "plan-t-chunk-divides",
         dataclasses.replace(plan, t_chunk=plan.t_steps + 1)),
        ("ingest-halfset", "plan-ingest-sizing",
         relayer(ingest_capacity=64)),
        ("geometry-wrong-bank-count", "plan-vm-tile-geometry",
         # a 5x5 (25-bank) geometry stamped onto a layer whose VMEM tile
         # and queue were sized for the 3x3 (9-bank) layout
         relayer(geometry=ConvGeometry(5, 5))),
        ("variant-bogus", "plan-variant-valid",
         relayer(variant="fused-marvel")),
        ("fused-handoff-desynced-tile", "plan-fused-handoff-boundary",
         # fused consumer whose MemPot tile is not the halo-padded grid
         # the carrier's static bank placements index into
         relayer(variant="fused-handoff",
                 vm_tile=(lp.in_hw[0], lp.in_hw[1], lp.channel_block))),
        ("fused-handoff-capacity-overrun", "plan-fused-handoff-boundary",
         relayer(variant="fused-handoff",
                 capacity=lp.in_hw[0] * lp.in_hw[1] + 64)),
        ("variant-interlaced-seq-width", "plan-variant-valid",
         relayer(variant="interlaced-pallas", event_par=1)),
        ("finalize-on-inner-layer", "plan-variant-valid",
         dataclasses.replace(
             plan, layers=plan.layers[:1] + (dataclasses.replace(
                 plan.layers[1], stream_finalize="sort"),)
             + plan.layers[2:])),
    ]


def selftest_contracts(out: Report) -> None:
    from .contracts import audit_plan

    for fixture, rule, plan in _broken_plans():
        inner = Report()
        audit_plan(plan, None, case=f"selftest-{fixture}", report=inner)
        _expect(out, inner, rule, fixture)


def selftest_hazards(out: Report) -> None:
    from repro.core.geometry import ConvGeometry

    from .hazards import (CapturedCall, check_banked_masks,
                          check_blockspec_bounds, check_column_disjointness,
                          check_padded_queue, check_patch_bounds)

    # a hazard-colliding interlace scheme: period-2 columns put events 2
    # apart in the same column, whose 3x3 footprints overlap
    inner = Report()
    check_column_disjointness(
        column_of=lambda i, j: (i % 2) * 2 + (j % 2), report=inner)
    _expect(out, inner, "hazard-column-disjoint", "collider-column-map")

    # same failure at k=5: period-3 rows put events 3 apart in one
    # column, but a 5x5 footprint reaches 4 rows — they overlap
    inner = Report()
    check_column_disjointness(
        geometry=ConvGeometry(5, 5),
        column_of=lambda i, j: (i % 3) * 5 + (j % 5), report=inner)
    _expect(out, inner, "hazard-column-disjoint", "collider-column-map-k5")

    # malformed bank-occupancy mask set (wrong bank count)
    inner = Report()
    check_banked_masks(np.ones((4, 3, 3), bool), where="selftest",
                       report=inner)
    _expect(out, inner, "hazard-banked-masks", "malformed-bank-masks")

    # the 3x3 bank count shipped under a 5x5 geometry (25 banks needed)
    inner = Report()
    check_banked_masks(np.ones((9, 2, 2), bool),
                       geometry=ConvGeometry(5, 5), where="selftest",
                       report=inner)
    _expect(out, inner, "hazard-banked-masks", "wrong-bank-count-k5")

    # duplicate event inside one aligned group: same column, overlapping
    # footprints — the parallel scatter would drop one tap
    coords = np.array([[2, 2], [2, 2], [0, 0], [0, 1]], np.int32)
    valid = np.array([1, 1, 0, 0], bool)
    inner = Report()
    check_padded_queue(coords, valid, 2, where="selftest-dup", report=inner)
    _expect(out, inner, "hazard-segment-homogeneous", "duplicate-in-group")

    # column-heterogeneous aligned group (segment_pad contract broken)
    coords = np.array([[0, 0], [0, 1], [3, 3], [3, 3]], np.int32)
    valid = np.array([1, 1, 1, 0], bool)
    inner = Report()
    check_padded_queue(coords, valid, 2, where="selftest-mixed", report=inner)
    _expect(out, inner, "hazard-segment-homogeneous", "mixed-column-group")

    # oversized BlockSpec: second block of 32 rows overruns a 48-row
    # operand; and an alias pairing mismatched shapes
    call = CapturedCall(
        name="selftest", grid=(2,),
        in_specs=[SimpleNamespace(block_shape=(32, 2),
                                  index_map=lambda b: (b, 0))],
        out_specs=[SimpleNamespace(block_shape=(16, 2),
                                   index_map=lambda b: (b, 0))],
        arg_shapes=[(48, 2)], arg_dtypes=["int32"],
        out_shapes=[(64, 2)], out_dtypes=["int32"],
        aliases={0: 0})
    inner = Report()
    check_blockspec_bounds([call], report=inner)
    _expect(out, inner, "oob-blockspec-bounds", "oversized-blockspec")

    # event patch overrunning the halo
    inner = Report()
    check_patch_bounds(10, 10, coord_hi=(10, 9), where="selftest",
                       report=inner)
    _expect(out, inner, "oob-event-patch", "oob-event-patch")


def selftest_kernel_audit(out: Report) -> None:
    from .kernel_audit import check_saturation

    def wrapping_apply(vm_p, coords, valid, kernel):
        """A deliberately broken datapath: accumulates in storage width,
        so the max-fan-in drive wraps negative instead of saturating."""
        vm = np.asarray(vm_p).copy()
        k = np.asarray(kernel)
        for (i, j), v in zip(np.asarray(coords), np.asarray(valid)):
            if v:
                with np.errstate(over="ignore"):
                    vm[i:i + 3, j:j + 3, :] += k
        return vm

    inner = Report()
    check_saturation(wrapping_apply, report=inner)
    _expect(out, inner, "kernel-sat-overflow", "wrapping-adder")


_LINT_FIXTURES = [
    ("mutable-default-dataclass", "lint-mutable-default", "serve/cfgs.py",
     "import dataclasses\n"
     "@dataclasses.dataclass\n"
     "class Cfg:\n"
     "    buckets: list = []\n"),
    ("mutable-default-arg", "lint-mutable-default", "core/util.py",
     "class ServeConfig:\n"
     "    pass\n"
     "def make_engine(model, cfg=ServeConfig()):\n"
     "    return (model, cfg)\n"),
    ("tracer-cast", "lint-tracer-cast", "core/step.py",
     "import jax\n"
     "@jax.jit\n"
     "def step(x):\n"
     "    return int(x) + 1\n"),
    ("host-call-in-jit", "lint-host-call-in-jit", "core/noise.py",
     "import jax, numpy as np\n"
     "@jax.jit\n"
     "def noisy(x):\n"
     "    return x + np.random.rand()\n"),
    ("pallas-outside-kernels", "lint-pallas-call-outside-kernels",
     "serve/fastpath.py",
     "from jax.experimental import pallas as pl\n"
     "def fast(x):\n"
     "    return pl.pallas_call(lambda r, o: None, out_shape=x)(x)\n"),
    ("missing-donate", "lint-missing-donate", "serve/csnn_engine.py",
     "import jax\n"
     "def step_bucket(state):\n"
     "    return state\n"
     "step_bucket_jit = jax.jit(step_bucket)\n"),
]


def selftest_lint(out: Report) -> None:
    from .lint import lint_source

    for fixture, rule, fname, src in _LINT_FIXTURES:
        inner = Report()
        lint_source(src, fname, report=inner)
        _expect(out, inner, rule, fixture)
    # the ignore mechanism must actually suppress
    src = ("class C:\n"
           "    pass\n"
           "def f(c=C()):  # analysis: ignore[lint-mutable-default]\n"
           "    return c\n")
    inner = Report()
    lint_source(src, "core/ok.py", report=inner)
    if inner.ok:
        out.proved("selftest-seeded")
    else:
        out.flag("selftest", "selftest-missed", "fixture:ignore-mechanism",
                 "'# analysis: ignore[rule]' failed to suppress a finding")


def run_selftest(report: Optional[Report] = None) -> Report:
    rep = report if report is not None else Report()
    selftest_contracts(rep)
    selftest_hazards(rep)
    selftest_kernel_audit(rep)
    selftest_lint(rep)
    return rep
