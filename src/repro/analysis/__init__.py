"""Static verification layer for the sparse-CSNN accelerator repro.

The paper's hardware is correct by construction: queue depths, bank
assignments and PE tiling are fixed at design time and obey structural
invariants (hazard-free memory interlacing, Fig. 6; design-time queue
sizing, Sec. IV).  This package re-proves those invariants over the
*software* plan/kernel surface before any device work — run it with
``python -m repro.analysis [--json] [--selftest] [--only PASS]``.
It exits nonzero on any finding and writes ``ANALYSIS_report.json``
with findings plus per-rule proof-obligation counts (so a pass that
silently checked nothing is distinguishable from a clean one).

Passes and rules
================

``contracts`` — plan-time sizing invariants, proven over a geometry
sweep grid (paper net, small/rectangular fmaps, DVS ingestion, int8/16):

* ``plan-block-e-divides-depth`` — event-block grid tiles the queue.
* ``plan-block-e-par-aligned``   — event_par | block_e and depth.
* ``plan-capacity-within-fmap``  — AEQ capacity <= padded H*W.
* ``plan-queue-depth-interlaced``— depth = interlaced_capacity(...).
* ``plan-channel-block-divides`` — channel blocks tile C_out.
* ``plan-vm-tile-geometry``      — MemPot tile is halo-padded.
* ``plan-out-hw-pool``           — post-pool geometry is ceil-divided.
* ``plan-t-chunk-divides``       — t_chunk | T (slot alignment).
* ``plan-ingest-sizing``         — DVS ingest buffers cover the window.
* ``plan-vmem-budget``           — autotuner's VMEM model holds.
* ``plan-validate-agrees``       — NetworkPlan.validate(cfg) accepts.

``hazards`` — the memory-interlacing theorem and kernel addressing:

* ``hazard-column-disjoint``     — same-column events never share a
  membrane cell (exhaustive over one congruence period = a proof).
* ``hazard-mask-routing``        — the 81 shifted_bank_masks slices
  match brute-force tap enumeration, one tap per bank per column.
* ``hazard-banked-masks``        — concrete bank-occupancy sets admit
  hazard-free whole-column application.
* ``hazard-segment-homogeneous`` — segment_pad groups are column-pure
  with disjoint footprints; ``hazard-segment-replay`` — padding never
  reorders or drops kept events.
* ``oob-blockspec-bounds``       — every pl.BlockSpec index map of the
  shipped kernels (captured by tracing the real wrappers under
  ``jax.eval_shape`` with ``pallas_call`` interposed) stays in bounds
  and covers its operand; aliases pair identical operands.
* ``oob-event-patch``            — the 3x3 ``pl.dslice`` event patch
  always lands inside the halo-padded tile.

``kernels`` — abstract interpretation of kernel vs oracle:

* ``kernel-shape-contract``      — ``jax.eval_shape`` parity of every
  Pallas entry point against its ``ref.py`` oracle.
* ``kernel-value-parity``        — interpret-mode bit-exactness on
  adversarial inputs (corner events, duplicates, -1 sentinels).
* ``kernel-checkify``            — oracle datapaths run clean under
  ``checkify`` index + NaN checks.
* ``kernel-sat-overflow``        — int8/int16 saturation is reachable
  and clamps (never wraps) at maximum fan-in.

``lint`` — AST rules for bug classes this repo has shipped:

* ``lint-mutable-default``             — shared mutable defaults
  (the PR-4 ``CSNNServeConfig`` bug).
* ``lint-tracer-cast``                 — int()/bool()/float() on jitted
  parameters.
* ``lint-host-call-in-jit``            — np.random/time/random frozen
  at trace time.
* ``lint-pallas-call-outside-kernels`` — pallas_call sites outside
  ``kernels/``.
* ``lint-missing-donate``              — hot serving entry points
  jitted without ``donate_argnums``.

Ignore mechanism
================

Suppress a *lint* finding by appending ``# analysis: ignore[rule-id]``
(comma-separated ids allowed) to the flagged line or the line above,
with a justification.  The semantic passes (contracts/hazards/kernels)
have no ignore escape on purpose: a violated sizing or hazard invariant
is a real bug, not a style choice — fix the plan or the kernel.

Self-test
=========

``--selftest`` plants known violations (corrupted plans, a colliding
interlace scheme, duplicate events in an aligned group, an oversized
BlockSpec, a wrapping adder, mutable-default sources) and fails unless
every one is flagged — CI runs it so the auditor cannot rot silently.
"""
from .report import Finding, Report, merge

__all__ = ["Finding", "Report", "merge"]
