"""Abstract-interpretation audit of each Pallas kernel vs its oracle.

Every kernel in ``kernels/`` ships with a pure-jnp ``ref.py`` oracle and
a bit-exactness claim.  This pass re-verifies the *contract* between the
two statically and on adversarial inputs, per sweep geometry and dtype:

* ``kernel-shape-contract`` — ``jax.eval_shape`` of the Pallas entry
  point and of its oracle must agree on every output's shape and dtype
  (no data moves; this is the pure abstract-interpretation pass).  Runs
  over the geometry sweep (square/rectangular fmaps, pooled and
  unpooled, float32/int16/int8), not just the paper shapes.
* ``kernel-value-parity`` — interpret-mode differential on adversarial
  inputs the unit tests do not enumerate: corner events (the halo's
  worst case), duplicate events, invalid slots carrying the AEQ's -1
  coordinates, saturated membrane tiles.  Kernel output must equal the
  oracle bit for bit (the paper's bit-exactness story, C2/C3/C7).
* ``kernel-checkify`` — the oracle paths run under
  ``checkify.checkify`` with index + NaN/div checks enabled on the same
  adversarial inputs: the gather/scatter indexing must be provably
  in-bounds (a clamped OOB ``dynamic_slice`` would silently corrupt the
  halo contract) and the float datapath NaN-free.
* ``kernel-sat-overflow`` — int8/int16 saturation-overflow
  reachability: drive a membrane cell to the saturation bound through
  its maximum fan-in (kh*kw events — one per interlace column — each
  adding a maximal tap) and prove the datapath *clamps* instead of
  wrapping
  (output stays within the storage range, equals the per-event oracle,
  and actually reaches the bound, demonstrating the clamp is live, not
  dead code).  A datapath that accumulated in storage width without
  widening would wrap negative here and be flagged.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.geometry import GEOM_3X3, ConvGeometry

from .report import Report

_SAT = {8: (-128, 127), 16: (-32768, 32767)}


def _sweep():
    """(name, h, w, c, block_e, event_par, dtype-name, k) geometry grid:
    paper shapes plus rectangular/int corners at 3x3, and the parametric
    windows (1x1 pointwise, 5x5 wide) the planner now admits."""
    return [
        ("paper28", 28, 28, 8, 32, 4, "float32", 3),
        ("rect", 10, 12, 8, 16, 4, "float32", 3),
        ("rect-int16", 10, 12, 8, 16, 2, "int16", 3),
        ("small-int8", 7, 9, 4, 6, 2, "int8", 3),
        ("deep-queue", 6, 6, 4, 24, 8, "float32", 3),
        ("pointwise-k1", 10, 10, 4, 8, 2, "float32", 1),
        ("wide-k5", 13, 12, 4, 16, 4, "float32", 5),
        ("wide-k5-int8", 11, 11, 4, 8, 2, "int8", 5),
    ]


def check_shape_contracts(report: Optional[Report] = None) -> Report:
    """eval_shape parity: Pallas kernel vs oracle, all outputs."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.event_conv.kernel import (
        event_conv_pallas, event_conv_pallas_batched,
        event_conv_pallas_interlaced, event_conv_pallas_interlaced_batched)
    from repro.kernels.event_conv.ref import (event_conv_ref,
                                              event_conv_ref_batched)
    from repro.kernels.threshold_pool.kernel import threshold_pool_pallas
    from repro.kernels.threshold_pool.ref import threshold_pool_ref

    rep = report if report is not None else Report()

    def compare(name, got, want):
        got = got if isinstance(got, (list, tuple)) else [got]
        want = want if isinstance(want, (list, tuple)) else [want]
        if len(got) != len(want) or any(
                g.shape != w.shape or g.dtype != w.dtype
                for g, w in zip(got, want)):
            rep.flag("kernel_audit", "kernel-shape-contract",
                     f"kernel:{name}",
                     f"kernel outputs {[(g.shape, str(g.dtype)) for g in got]}"
                     f" != oracle {[(w.shape, str(w.dtype)) for w in want]}")
        else:
            rep.proved("kernel-shape-contract")

    for case, h, w, c, block_e, par, dt, kk in _sweep():
        dtype = jnp.dtype(dt)
        hh, hw = ConvGeometry(kk, kk).halo
        e = 4 * block_e
        q = 3
        vm = jax.ShapeDtypeStruct((h + 2 * hh, w + 2 * hw, c), dtype)
        vmb = jax.ShapeDtypeStruct((q, h + 2 * hh, w + 2 * hw, c), dtype)
        co = jax.ShapeDtypeStruct((e, 2), jnp.int32)
        cob = jax.ShapeDtypeStruct((q, e, 2), jnp.int32)
        va = jax.ShapeDtypeStruct((e,), jnp.int8)
        vab = jax.ShapeDtypeStruct((q, e), jnp.int8)
        k = jax.ShapeDtypeStruct((kk, kk, c), dtype)
        entries = [
            (f"event_conv_pallas[{case}]",
             lambda a, b, v_, d, be=block_e: event_conv_pallas(
                 a, b, v_, d, block_e=be, interpret=True),
             event_conv_ref, (vm, co, va, k)),
            (f"event_conv_pallas_batched[{case}]",
             lambda a, b, v_, d, be=block_e: event_conv_pallas_batched(
                 a, b, v_, d, block_e=be, interpret=True),
             event_conv_ref_batched, (vmb, cob, vab, k)),
            (f"event_conv_pallas_interlaced[{case}]",
             lambda a, b, v_, d, be=block_e, ep=par:
             event_conv_pallas_interlaced(
                 a, b, v_, d, block_e=be, event_par=ep, interpret=True),
             event_conv_ref, (vm, co, va, k)),
            (f"event_conv_pallas_interlaced_batched[{case}]",
             lambda a, b, v_, d, be=block_e, ep=par:
             event_conv_pallas_interlaced_batched(
                 a, b, v_, d, block_e=be, event_par=ep, interpret=True),
             event_conv_ref_batched, (vmb, cob, vab, k)),
        ]
        for name, kfn, rfn, avals in entries:
            compare(name,
                    jax.eval_shape(kfn, *avals),
                    jax.eval_shape(rfn, *avals))
        # threshold unit: H, W padded to the pool window by ops.py, C to
        # the channel block — the kernel-level contract takes them padded
        for pool in (3, None):
            hh = h + (-h % pool) if pool else h
            ww = w + (-w % pool) if pool else w
            tvm = jax.ShapeDtypeStruct((hh, ww, c), dtype)
            bias = jax.ShapeDtypeStruct((c,), dtype)
            fired = jax.ShapeDtypeStruct((hh, ww, c), jnp.int8)
            compare(
                f"threshold_pool_pallas[{case},pool={pool}]",
                jax.eval_shape(
                    lambda a, b, f_, p=pool, bc=c: threshold_pool_pallas(
                        a, b, f_, v_t=1.0, pool=p, block_c=bc,
                        interpret=True), tvm, bias, fired),
                jax.eval_shape(
                    lambda a, b, f_, p=pool: threshold_pool_ref(
                        a, b, f_, v_t=1.0, pool=p), tvm, bias, fired))
            # fused spike emission (ISSUE 10): the 5-output contract —
            # bank masks + per-column segment counts under the consumer's
            # window geometry — must agree kernel vs oracle too
            geomk = ConvGeometry(kk, kk)
            compare(
                f"threshold_pool_pallas[{case},pool={pool},emit]",
                jax.eval_shape(
                    lambda a, b, f_, p=pool, bc=c, g=geomk:
                    threshold_pool_pallas(
                        a, b, f_, v_t=1.0, pool=p, block_c=bc,
                        interpret=True, emit_capacity=16, emit_geometry=g),
                    tvm, bias, fired),
                jax.eval_shape(
                    lambda a, b, f_, p=pool, g=geomk: threshold_pool_ref(
                        a, b, f_, v_t=1.0, pool=p, emit_capacity=16,
                        emit_geometry=g), tvm, bias, fired))
    return rep


def _adversarial_queue(h: int, w: int, e: int, rng,
                       geometry: ConvGeometry = GEOM_3X3
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Raw (coords, valid) stressing the halo/masking contract: the four
    corner events, a kh x kw cluster (maximum per-cell fan-in),
    duplicates, and invalid slots carrying the AEQ's -1 sentinels."""
    hh, hw = geometry.halo
    ci, cj = h // 2, w // 2
    events = [(0, 0), (0, w - 1), (h - 1, 0), (h - 1, w - 1), (0, 0)]
    events += [(ci + di, cj + dj)
               for di in range(-hh, hh + 1) for dj in range(-hw, hw + 1)
               if 0 <= ci + di < h and 0 <= cj + dj < w]
    coords = np.full((e, 2), -1, np.int32)
    valid = np.zeros((e,), bool)
    n = min(len(events), e)
    coords[:n] = np.asarray(events[:n], np.int32)
    valid[:n] = True
    # a few valid events scattered into the tail, invalid gaps between
    for idx in range(n + 2, e, 3):
        coords[idx] = (rng.integers(0, h), rng.integers(0, w))
        valid[idx] = True
    return coords, valid


def check_value_parity(report: Optional[Report] = None) -> Report:
    """Interpret-mode differential: kernel == oracle bit for bit on
    adversarial inputs, sequential + interlaced + banked paths."""
    import jax.numpy as jnp

    from repro.core.aeq import build_aeq, build_bank_masks, \
        build_fused_handoff
    from repro.core.event_conv import (apply_events, apply_events_banked,
                                       pad_vm)
    from repro.kernels.event_conv.kernel import event_conv_pallas
    from repro.kernels.event_conv.ops import event_conv
    from repro.kernels.event_conv.ref import event_conv_ref
    from repro.kernels.threshold_pool.ops import threshold_pool

    rep = report if report is not None else Report()
    rng = np.random.default_rng(7)
    for case, h, w, c, block_e, par, dt, kk in _sweep():
        dtype = jnp.dtype(dt)
        geom = ConvGeometry(kk, kk)
        hh, hw = geom.halo
        e = 4 * block_e
        if dt == "float32":
            vm0 = rng.standard_normal((h, w, c)).astype(np.float32)
            kern = rng.standard_normal((kk, kk, c)).astype(np.float32)
        else:
            lo, hi = _SAT[int(dt[3:])]
            vm0 = rng.integers(lo // 2, hi // 2, (h, w, c)).astype(dt)
            kern = rng.integers(-20, 20, (kk, kk, c)).astype(dt)
        vm0, kern = jnp.asarray(vm0), jnp.asarray(kern)
        # raw adversarial queue (duplicates + -1 sentinels): sequential
        # kernel vs oracle at the kernel level
        coords, valid = _adversarial_queue(h, w, e, rng, geom)
        vm_p = pad_vm(vm0, geom)
        got = event_conv_pallas(vm_p, jnp.asarray(coords),
                                jnp.asarray(valid), kern,
                                block_e=block_e, interpret=True)
        want = event_conv_ref(vm_p, jnp.asarray(coords),
                              jnp.asarray(valid.astype(np.int8)), kern)
        if not np.array_equal(np.asarray(got), np.asarray(want)):
            rep.flag("kernel_audit", "kernel-value-parity",
                     f"kernel:event_conv_pallas[{case}]",
                     "sequential kernel diverges from the oracle on the "
                     "adversarial queue (corners/duplicates/-1 sentinels)")
        else:
            rep.proved("kernel-value-parity")
        # interlaced + banked paths on a real (deduped, interlace-ordered)
        # queue of the same geometry
        fmap = jnp.asarray(rng.random((h, w)) < 0.4)
        queue = build_aeq(fmap, e, geometry=geom)
        base = np.asarray(apply_events(vm_p, queue, kern))
        pallas_seq = np.asarray(event_conv(
            vm0, queue, kern, block_e=block_e, interpret=True))
        pallas_par = np.asarray(event_conv(
            vm0, queue, kern, block_e=block_e, event_par=par,
            interpret=True))
        banked = np.asarray(apply_events_banked(
            vm_p, build_bank_masks(fmap[None], e, geom).masks[0], kern))
        crop = base[hh:h + hh, hw:w + hw, :]
        for path, out in (("ops-sequential", pallas_seq),
                          ("ops-interlaced", pallas_par),
                          ("banked", banked[hh:h + hh, hw:w + hw, :])):
            if not np.array_equal(out, crop):
                rep.flag("kernel_audit", "kernel-value-parity",
                         f"kernel:event_conv[{case}]",
                         f"{path} path diverges from the sequential "
                         f"apply_events oracle")
            else:
                rep.proved("kernel-value-parity")
        # fused spike emission (ISSUE 10): the kernel's banked-emission
        # outputs must match the oracle bit for bit, and both must equal
        # what aeq.build_fused_handoff would compact from the same spike
        # map — a capacity below h*w keeps the rank-truncation path live
        bias = jnp.asarray(rng.standard_normal((c,)).astype(np.float32)
                           .astype(dtype))
        fired0 = jnp.asarray((rng.random((h, w, c)) < 0.3)
                             .astype(np.int8))
        cap = max(1, (h * w) // 2)
        for pool in (3, None):
            outs_k = threshold_pool(
                vm0, bias, fired0, v_t=0.0, pool=pool, block_c=c,
                use_kernel=True, interpret=True,
                emit_capacity=cap, emit_geometry=geom)
            outs_r = threshold_pool(
                vm0, bias, fired0, v_t=0.0, pool=pool, block_c=c,
                use_kernel=False,
                emit_capacity=cap, emit_geometry=geom)
            where = f"kernel:threshold_pool[{case},pool={pool},emit]"
            if any(not np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(outs_k, outs_r)):
                rep.flag("kernel_audit", "kernel-value-parity", where,
                         "fused-emission kernel diverges from the oracle "
                         "(masks/seg_counts not bit-identical)")
            else:
                rep.proved("kernel-value-parity")
            spikes_out = outs_r[2]
            ho = build_fused_handoff(
                spikes_out[None, None], cap, geom)
            want_masks = np.moveaxis(np.asarray(ho.masks[0, :, 0]), 0, -1)
            if not np.array_equal(np.asarray(outs_r[3]), want_masks):
                rep.flag("kernel_audit", "kernel-value-parity", where,
                         "emitted bank masks differ from the "
                         "build_fused_handoff compaction of the same "
                         "spike map — the handoff carrier would "
                         "desynchronize from the consumer's contract")
            else:
                rep.proved("kernel-value-parity")
    return rep


def check_checkify(report: Optional[Report] = None) -> Report:
    """Run the oracle datapaths under ``checkify`` (index + NaN/div
    checks) on the adversarial inputs: gather/scatter indexing must be
    provably in-bounds, float arithmetic NaN-free."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import checkify

    from repro.core.event_conv import pad_vm
    from repro.kernels.event_conv.ref import event_conv_ref
    from repro.kernels.threshold_pool.ref import threshold_pool_ref

    rep = report if report is not None else Report()
    rng = np.random.default_rng(11)
    errors = checkify.index_checks | checkify.float_checks
    for case, h, w, c, block_e, _par, dt, kk in _sweep():
        dtype = jnp.dtype(dt)
        geom = ConvGeometry(kk, kk)
        e = 4 * block_e
        coords, valid = _adversarial_queue(h, w, e, rng, geom)
        if dt == "float32":
            vm0 = rng.standard_normal((h, w, c)).astype(np.float32)
            kern = rng.standard_normal((kk, kk, c)).astype(np.float32)
        else:
            lo, hi = _SAT[int(dt[3:])]
            vm0 = rng.integers(lo, hi, (h, w, c)).astype(dt)
            kern = rng.integers(-20, 20, (kk, kk, c)).astype(dt)
        vm_p = pad_vm(jnp.asarray(vm0), geom)
        checked = checkify.checkify(
            jax.jit(event_conv_ref), errors=errors)
        err, _ = checked(vm_p, jnp.asarray(coords),
                         jnp.asarray(valid.astype(np.int8)),
                         jnp.asarray(kern))
        msg = err.get()
        if msg is not None:
            rep.flag("kernel_audit", "kernel-checkify",
                     f"kernel:event_conv_ref[{case}]",
                     f"checkify flagged the event gather/scatter: {msg}")
        else:
            rep.proved("kernel-checkify")
        pool = 3
        hh, ww = h + (-h % pool), w + (-w % pool)
        tvm = jnp.zeros((hh, ww, c), dtype)
        checked = checkify.checkify(
            jax.jit(lambda a, b, f: threshold_pool_ref(
                a, b, f, v_t=1.0, pool=pool)), errors=errors)
        err, _ = checked(tvm, jnp.zeros((c,), dtype),
                         jnp.zeros((hh, ww, c), jnp.int8))
        msg = err.get()
        if msg is not None:
            rep.flag("kernel_audit", "kernel-checkify",
                     f"kernel:threshold_pool_ref[{case}]",
                     f"checkify flagged the threshold datapath: {msg}")
        else:
            rep.proved("kernel-checkify")
    return rep


def check_saturation(apply_fn: Optional[Callable] = None, *,
                     geometry: ConvGeometry = GEOM_3X3,
                     report: Optional[Report] = None) -> Report:
    """int8/int16 saturation-overflow reachability proof.

    Builds the maximum-fan-in configuration — one membrane cell inside
    the footprint of kh*kw events (its full kh x kw neighbourhood of
    centres, which is also one event per interlace column), every tap at
    the maximal magnitude, the tile pre-charged near the bound — and
    checks the datapath clamps at the storage bound instead of wrapping.

    ``apply_fn(vm_padded, coords, valid, kernel) -> vm_padded`` defaults
    to the interpret-mode sequential Pallas kernel; the self-test passes
    a deliberately non-saturating adder here and must be flagged.
    """
    import jax.numpy as jnp

    from repro.core.event_conv import pad_vm
    from repro.kernels.event_conv.kernel import event_conv_pallas
    from repro.kernels.event_conv.ref import event_conv_ref

    rep = report if report is not None else Report()
    if apply_fn is None:
        def apply_fn(vm_p, co, va, k):
            return event_conv_pallas(vm_p, co, va, k, block_e=co.shape[0],
                                     interpret=True)
    kh, kw = geometry.window
    hh, hw = geometry.halo
    h = w = 2 * max(kh, kw) + 1
    c = 4
    ci, cj = h // 2, w // 2
    events = [(ci + di, cj + dj)
              for di in range(-hh, hh + 1) for dj in range(-hw, hw + 1)]
    coords = jnp.asarray(events, jnp.int32)
    valid = jnp.ones((len(events),), jnp.int8)
    ktag = "" if geometry == GEOM_3X3 else f",k={kh}x{kw}"
    for bits, (lo, hi) in _SAT.items():
        dtype = jnp.dtype(f"int{bits}")
        tap = hi // (geometry.n_banks + 1) + 1
        vm0 = jnp.full((h, w, c), hi - tap, dtype)   # one tap from the rail
        kern = jnp.full((kh, kw, c), tap, dtype)
        vm_p = pad_vm(vm0, geometry)
        got = np.asarray(apply_fn(vm_p, coords, valid, kern))
        want = np.asarray(event_conv_ref(vm_p, coords, valid, kern))
        where = f"kernel:event_conv[int{bits}{ktag}]"
        hot = got[hh + ci, hw + cj]                  # padded centre cell
        if got.max() > hi or got.min() < lo:
            rep.flag("kernel_audit", "kernel-sat-overflow", where,
                     f"int{bits} accumulation escapes the storage range "
                     f"[{lo}, {hi}] (max={got.max()}, min={got.min()}) — "
                     f"the adder wraps instead of saturating")
        elif not (hot == hi).all():
            rep.flag("kernel_audit", "kernel-sat-overflow", where,
                     f"max-fan-in cell ended at {hot} instead of the "
                     f"saturation bound {hi} — the overflow path either "
                     f"wrapped or under-accumulated")
        elif not np.array_equal(got, want):
            rep.flag("kernel_audit", "kernel-sat-overflow", where,
                     "saturating datapath diverges from the per-event "
                     "oracle at the bound")
        else:
            rep.proved("kernel-sat-overflow")
        # widening headroom: one widened add must fit the accumulator
        if 2 * hi + 1 > np.iinfo(np.int32).max:
            rep.flag("kernel_audit", "kernel-sat-overflow", where,
                     f"int{bits} patch+tap exceeds the int32 widened "
                     f"accumulator")
        else:
            rep.proved("kernel-sat-overflow")
    return rep


def run_kernel_audit(report: Optional[Report] = None) -> Report:
    rep = report if report is not None else Report()
    check_shape_contracts(report=rep)
    check_value_parity(report=rep)
    check_checkify(report=rep)
    for geom in (ConvGeometry(1, 1), GEOM_3X3, ConvGeometry(5, 5)):
        check_saturation(geometry=geom, report=rep)
    return rep
