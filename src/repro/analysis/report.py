"""Finding/Report plumbing shared by every analysis pass.

A *finding* is one violated invariant; a *report* is the machine-readable
result of an analysis run: every finding plus, per rule, the number of
proof obligations that were actually discharged (so "clean" is
distinguishable from "never ran" — an auditor that silently checks
nothing is worse than none at all).  ``python -m repro.analysis --json``
serializes the report to ``ANALYSIS_report.json`` and exits nonzero iff
any finding survives.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from pathlib import Path
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant.

    tool:  which pass produced it (contracts | hazards | kernel_audit | lint).
    rule:  stable kebab-case rule id (the id the ignore mechanism keys on).
    where: location — ``path.py:lineno`` for lint, ``plan[...]`` /
           ``kernel:<name>`` for the semantic passes.
    message: human-readable statement of the violation.
    """

    tool: str
    rule: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"{self.where}: [{self.tool}/{self.rule}] {self.message}"


class Report:
    """Accumulates findings and per-rule obligation counts across passes."""

    def __init__(self) -> None:
        self.findings: list[Finding] = []
        self.checked: Counter = Counter()   # rule id -> obligations proven

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def flag(self, tool: str, rule: str, where: str, message: str) -> None:
        self.add(Finding(tool=tool, rule=rule, where=where, message=message))

    def proved(self, rule: str, n: int = 1) -> None:
        """Record ``n`` discharged proof obligations for ``rule``."""
        self.checked[rule] += n

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.checked.update(other.checked)

    @property
    def ok(self) -> bool:
        return not self.findings

    def by_rule(self) -> dict[str, list[Finding]]:
        out: dict[str, list[Finding]] = {}
        for f in self.findings:
            out.setdefault(f.rule, []).append(f)
        return out

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "n_findings": len(self.findings),
            "obligations": dict(sorted(self.checked.items())),
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }

    def write_json(self, path: str | Path) -> Path:
        out = Path(path)
        out.write_text(json.dumps(self.to_dict(), indent=2) + "\n")
        return out

    def summary(self) -> str:
        lines = []
        for f in self.findings:
            lines.append(str(f))
        total = sum(self.checked.values())
        lines.append(
            f"analysis: {len(self.findings)} finding(s), "
            f"{total} obligation(s) proven across {len(self.checked)} rule(s)")
        return "\n".join(lines)


def merge(reports: Iterable[Report]) -> Report:
    out = Report()
    for r in reports:
        out.extend(r)
    return out
