"""Plan-time invariant registry: prove the LayerPlan/NetworkPlan contract.

The paper's accelerator is correct because every resource is *sized at
design time* — queue depths, PE tiling, interlaced membrane RAMs — and
the sizing obeys structural invariants (Secs. IV-V).  ``plan_network``
encodes those rules; this module re-proves them *from the outside* over a
geometry sweep grid, so a regression in the sizing logic (or a hand-built
plan that skips it) is caught before any device work:

* ``plan-block-e-divides-depth`` — the event-block grid must tile the
  allocated queue exactly (Pallas grid = depth / block_e steps).
* ``plan-block-e-par-aligned`` — with ``event_par > 1``, parallel groups
  must tile event blocks and the segment-padded depth must tile into
  aligned groups (the hazard-freedom precondition of the interlaced
  kernel's gather->add->scatter schedule).
* ``plan-capacity-within-fmap`` — effective AEQ capacity <= padded H*W:
  a queue deeper than the feature map wastes BRAM/VMEM and can never
  fill (the per-layer sizing theorem of the plan/execute split).
* ``plan-queue-depth-interlaced`` — allocated depth equals
  ``interlaced_capacity(capacity, event_par, n_banks)`` (the
  segment-padding worst case: kh*kw columns each padded to an
  event_par multiple).
* ``plan-channel-block-divides`` — channel blocks tile C_out exactly.
* ``plan-vm-tile-geometry`` — the VMEM-resident MemPot tile is the
  halo-padded (H+2*(kh//2), W+2*(kw//2), channel_block) shape the
  kernels index into.
* ``plan-out-hw-pool`` — post-pool geometry is the ceil-divided fmap
  (the OR-max-pool window contract chained into the next layer's plan).
* ``plan-t-chunk-divides`` — chunked execution needs equal-length chunks
  (slot alignment in continuous batching), so t_chunk | T.
* ``plan-ingest-sizing`` — streaming ingestion buffers: capacity/depth
  set together, only on the input layer, depth within [1, T], and the
  raw-event buffer covers the worst-case admission window
  (capacity * C_in * depth events) — undersizing silently turns
  admission backpressure into dropped sensor events.
* ``plan-vmem-budget`` — the analytic VMEM model the autotuner sizes
  against: double-buffered MemPot tile stack + event stream blocks +
  kernel taps must fit the per-core budget.  This is the invariant that
  keeps ``autotune_block_e``/``autotune_event_par`` honest when the
  real-TPU lowering lands (ROADMAP).
* ``plan-variant-valid`` — a pinned kernel variant names a real variant,
  ``interlaced-pallas`` pins require the event-parallel width the kernel
  walks in (> 1), and ``stream_finalize`` is a known finalization set
  only where streamed queues exist (the ingesting input layer).  This is
  the contract that makes *cache-loaded* plans trustworthy: the measured
  autotuner's winners re-enter through ``plan_network`` and must land on
  schedules the scheduler can actually dispatch.
* ``plan-fused-handoff-boundary`` — layers pinned to the fused
  spike-emission variant consume the producer's padded centre-bank
  carrier directly, so the handoff geometry must line up end to end:
  the producer's post-pool fmap equals this layer's input fmap, the
  MemPot tile is exactly the halo-padded grid the carrier's static
  placements assume (a mismatch desynchronizes bank rows silently, not
  loudly), and the AEQ capacity stays within the fmap so the carrier's
  rank truncation equals the queue truncation.
* ``plan-validate-agrees`` — ``NetworkPlan.validate(cfg)`` accepts the
  plan (cross-checks the sweep's own construction).

Every contract is a small pure function registered in ``CONTRACTS``;
``audit_plan`` runs all of them over one (plan, cfg) pair and
``run_contracts`` sweeps the built-in geometry grid (paper net, small
nets, rectangular fmaps, multi-channel DVS ingestion, int8/int16
datapaths, explicit and autotuned event_par) — not just the shipped
configuration.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.aeq import interlaced_capacity
from repro.core.csnn import CSNNConfig, ConvSpec, FCSpec
from repro.core.geometry import GEOM_3X3, ConvGeometry
from repro.core.plan import (KERNEL_VARIANTS, STREAM_FINALIZE, LayerPlan,
                             NetworkPlan, pad_capacity, plan_network)
from repro.kernels.event_conv.ops import EVENT_BYTES, VMEM_BUDGET

from .report import Report

# rule id -> (doc, checker).  A checker yields (where, message) pairs for
# violations and returns the number of obligations it discharged.
CONTRACTS: dict[str, tuple[str, Callable]] = {}


def contract(rule: str, doc: str):
    def register(fn):
        CONTRACTS[rule] = (doc, fn)
        return fn
    return register


def _layer_where(case: str, lp: LayerPlan) -> str:
    return f"plan[{case}].{lp.name}"


def _layer_geometry(lp) -> ConvGeometry:
    # Hand-built fixture plans (selftest proxies) may predate the
    # geometry field; they are audited as the 3x3 paper layout.
    return getattr(lp, "geometry", GEOM_3X3)


@contract("plan-block-e-divides-depth",
          "event-block grid tiles the allocated queue depth exactly")
def _check_block_e(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    n = 0
    for lp in plan.layers:
        n += 1
        if lp.block_e < 1 or lp.queue_depth % lp.block_e != 0:
            rep.flag("contracts", "plan-block-e-divides-depth",
                     _layer_where(case, lp),
                     f"block_e={lp.block_e} does not tile queue_depth="
                     f"{lp.queue_depth}")
    return n


@contract("plan-block-e-par-aligned",
          "event_par groups tile event blocks and the segment-padded depth")
def _check_par_alignment(plan: NetworkPlan, cfg, case: str,
                         rep: Report) -> int:
    n = 0
    for lp in plan.layers:
        if lp.event_par <= 1:
            continue
        n += 1
        if lp.block_e % lp.event_par != 0:
            rep.flag("contracts", "plan-block-e-par-aligned",
                     _layer_where(case, lp),
                     f"block_e={lp.block_e} is not a multiple of "
                     f"event_par={lp.event_par}")
        if lp.queue_depth % lp.event_par != 0:
            rep.flag("contracts", "plan-block-e-par-aligned",
                     _layer_where(case, lp),
                     f"queue_depth={lp.queue_depth} is not a multiple of "
                     f"event_par={lp.event_par}")
    return n


@contract("plan-capacity-within-fmap",
          "effective AEQ capacity bounded by the padded feature-map size")
def _check_capacity(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    n = 0
    for lp in plan.layers:
        n += 1
        hw = lp.in_hw[0] * lp.in_hw[1]
        if lp.capacity > pad_capacity(hw):
            rep.flag("contracts", "plan-capacity-within-fmap",
                     _layer_where(case, lp),
                     f"capacity={lp.capacity} exceeds padded fmap size "
                     f"pad64({lp.in_hw[0]}*{lp.in_hw[1]})={pad_capacity(hw)}")
        if lp.capacity < 1:
            rep.flag("contracts", "plan-capacity-within-fmap",
                     _layer_where(case, lp),
                     f"capacity={lp.capacity} must be >= 1")
    return n


@contract("plan-queue-depth-interlaced",
          "allocated depth equals the segment-padded interlaced capacity")
def _check_queue_depth(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    n = 0
    for lp in plan.layers:
        n += 1
        nb = _layer_geometry(lp).n_banks
        want = interlaced_capacity(lp.capacity, lp.event_par, nb)
        if lp.queue_depth != want:
            rep.flag("contracts", "plan-queue-depth-interlaced",
                     _layer_where(case, lp),
                     f"queue_depth={lp.queue_depth} != interlaced_capacity("
                     f"{lp.capacity}, {lp.event_par}, n_banks={nb})={want}")
    return n


@contract("plan-channel-block-divides",
          "channel blocks tile the output channels exactly")
def _check_channel_block(plan: NetworkPlan, cfg, case: str,
                         rep: Report) -> int:
    n = 0
    for lp in plan.layers:
        n += 1
        if lp.channel_block < 1 or lp.c_out % lp.channel_block != 0:
            rep.flag("contracts", "plan-channel-block-divides",
                     _layer_where(case, lp),
                     f"channel_block={lp.channel_block} does not divide "
                     f"c_out={lp.c_out}")
    return n


@contract("plan-vm-tile-geometry",
          "VMEM MemPot tile is the halo-padded (H+2hh, W+2hw, channel_block)")
def _check_vm_tile(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    n = 0
    for lp in plan.layers:
        n += 1
        hh, hw = _layer_geometry(lp).halo
        want = (lp.in_hw[0] + 2 * hh, lp.in_hw[1] + 2 * hw,
                lp.channel_block)
        if tuple(lp.vm_tile) != want:
            rep.flag("contracts", "plan-vm-tile-geometry",
                     _layer_where(case, lp),
                     f"vm_tile={lp.vm_tile} != halo-padded {want}")
    return n


@contract("plan-out-hw-pool",
          "post-pool geometry is the ceil-divided feature map")
def _check_out_hw(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    n = 0
    for lp in plan.layers:
        n += 1
        h, w = lp.in_hw
        if lp.pool:
            want = (-(-h // lp.pool), -(-w // lp.pool))
        else:
            want = (h, w)
        if tuple(lp.out_hw) != want:
            rep.flag("contracts", "plan-out-hw-pool",
                     _layer_where(case, lp),
                     f"out_hw={lp.out_hw} != {want} for pool={lp.pool}")
    return n


@contract("plan-t-chunk-divides",
          "chunk length divides T (equal-length chunks for slot refill)")
def _check_t_chunk(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    if plan.t_chunk is None:
        return 0
    if not (1 <= plan.t_chunk <= plan.t_steps
            and plan.t_steps % plan.t_chunk == 0):
        rep.flag("contracts", "plan-t-chunk-divides", f"plan[{case}]",
                 f"t_chunk={plan.t_chunk} does not divide "
                 f"t_steps={plan.t_steps}")
    return 1


@contract("plan-ingest-sizing",
          "streaming ingestion buffers sized for the admission window")
def _check_ingest(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    n = 0
    for li, lp in enumerate(plan.layers):
        if (lp.ingest_capacity is None) != (lp.ingest_depth is None):
            rep.flag("contracts", "plan-ingest-sizing",
                     _layer_where(case, lp),
                     f"ingest_capacity={lp.ingest_capacity} and "
                     f"ingest_depth={lp.ingest_depth} must be set together")
            n += 1
            continue
        if lp.ingest_capacity is None:
            continue
        n += 1
        if li != 0:
            rep.flag("contracts", "plan-ingest-sizing",
                     _layer_where(case, lp),
                     "only the input layer admits raw DVS events; inner "
                     "layers build their queues from upstream spikes")
        if not 1 <= lp.ingest_depth <= plan.t_steps:
            rep.flag("contracts", "plan-ingest-sizing",
                     _layer_where(case, lp),
                     f"ingest_depth={lp.ingest_depth} outside "
                     f"[1, t_steps={plan.t_steps}]")
        window = lp.capacity * lp.c_in * lp.ingest_depth
        if lp.ingest_capacity < window:
            rep.flag("contracts", "plan-ingest-sizing",
                     _layer_where(case, lp),
                     f"ingest_capacity={lp.ingest_capacity} cannot buffer a "
                     f"worst-case admission window of {window} events "
                     f"(capacity={lp.capacity} * c_in={lp.c_in} * "
                     f"depth={lp.ingest_depth})")
    return n


def vmem_model_bytes(lp: LayerPlan, batch_tile: int) -> int:
    """The analytic VMEM residency model behind the autotuners: a
    double-buffered MemPot tile stack, the double-buffered event-stream
    block, and the resident kernel taps (all in ``lp.vm_dtype`` bytes)."""
    vm_bytes = {None: 4, 8: 1, 16: 2}[lp.sat_bits]
    tile = max(batch_tile, 1)
    for d in lp.vm_tile:
        tile *= d
    resident = 2 * tile * vm_bytes
    stream = 2 * lp.block_e * EVENT_BYTES
    taps = _layer_geometry(lp).n_banks * lp.channel_block * vm_bytes
    return resident + stream + taps


@contract("plan-vmem-budget",
          "autotuner VMEM model: resident tiles + stream fit the budget")
def _check_vmem(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    n = 0
    for lp in plan.layers:
        n += 1
        used = vmem_model_bytes(lp, plan.batch_tile)
        if used > VMEM_BUDGET:
            rep.flag("contracts", "plan-vmem-budget",
                     _layer_where(case, lp),
                     f"modelled VMEM residency {used} B exceeds the "
                     f"{VMEM_BUDGET} B per-core budget (vm_tile={lp.vm_tile}"
                     f" x batch_tile={plan.batch_tile}, "
                     f"block_e={lp.block_e})")
    return n


@contract("plan-validate-agrees",
          "NetworkPlan.validate accepts the plan for its own config")
def _check_validate(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    if cfg is None:
        return 0
    try:
        plan.validate(cfg)
    except (ValueError, KeyError) as e:
        rep.flag("contracts", "plan-validate-agrees", f"plan[{case}]",
                 f"plan.validate(cfg) rejected the plan: {e}")
    return 1


@contract("plan-variant-valid",
          "pinned kernel variants and stream finalization are dispatchable")
def _check_variant(plan: NetworkPlan, cfg, case: str, rep: Report) -> int:
    n = 0
    for i, lp in enumerate(plan.layers):
        n += 1
        if lp.variant is not None and lp.variant not in KERNEL_VARIANTS:
            rep.flag("contracts", "plan-variant-valid",
                     _layer_where(case, lp),
                     f"variant={lp.variant!r} is not one of "
                     f"{KERNEL_VARIANTS}")
        if lp.variant == "interlaced-pallas" and lp.event_par <= 1:
            rep.flag("contracts", "plan-variant-valid",
                     _layer_where(case, lp),
                     f"variant='interlaced-pallas' with event_par="
                     f"{lp.event_par}: the interlaced kernel walks "
                     f"event_par-aligned groups and needs a width > 1")
        if lp.stream_finalize is not None:
            if lp.stream_finalize not in STREAM_FINALIZE:
                rep.flag("contracts", "plan-variant-valid",
                         _layer_where(case, lp),
                         f"stream_finalize={lp.stream_finalize!r} is not "
                         f"one of {STREAM_FINALIZE}")
            if i != 0:
                rep.flag("contracts", "plan-variant-valid",
                         _layer_where(case, lp),
                         "stream_finalize set on a non-input layer: only "
                         "the ingesting input layer finalizes streamed "
                         "queues")
    return n


@contract("plan-fused-handoff-boundary",
          "fused spike-emission handoff geometry lines up between layers")
def _check_fused_handoff(plan: NetworkPlan, cfg, case: str,
                         rep: Report) -> int:
    n = 0
    for i, lp in enumerate(plan.layers):
        if lp.variant != "fused-handoff":
            continue
        n += 1
        geom = _layer_geometry(lp)
        hh, hw = geom.halo
        h, w = lp.in_hw
        want = (h + 2 * hh, w + 2 * hw, lp.channel_block)
        if tuple(lp.vm_tile) != want:
            rep.flag("contracts", "plan-fused-handoff-boundary",
                     _layer_where(case, lp),
                     f"vm_tile={tuple(lp.vm_tile)} != halo-padded {want}: "
                     f"the carrier's static bank placements index a "
                     f"ceil({want[0]}/{geom.kh}) x ceil({want[1]}/{geom.kw}) "
                     f"macro grid; any other tile desynchronizes the banks")
        if lp.capacity > h * w:
            rep.flag("contracts", "plan-fused-handoff-boundary",
                     _layer_where(case, lp),
                     f"capacity={lp.capacity} exceeds the {h}x{w} fmap: the "
                     f"carrier's rank truncation must equal the effective "
                     f"AEQ truncation min(capacity, H*W)")
        if i > 0:
            prev = plan.layers[i - 1]
            if tuple(prev.out_hw) != (h, w):
                rep.flag("contracts", "plan-fused-handoff-boundary",
                         _layer_where(case, lp),
                         f"producer {prev.name} emits {tuple(prev.out_hw)} "
                         f"post-pool but this consumer expects in_hw="
                         f"{(h, w)}: the emitted carrier would carry the "
                         f"wrong bank grid")
    return n


def audit_plan(plan: NetworkPlan, cfg: Optional[CSNNConfig] = None, *,
               case: str = "plan", report: Optional[Report] = None) -> Report:
    """Run every registered contract over one (plan, cfg) pair."""
    rep = report if report is not None else Report()
    for rule, (_, fn) in CONTRACTS.items():
        rep.proved(rule, fn(plan, cfg, case, rep))
    return rep


# ---------------------------------------------------------------------------
# Geometry sweep grid: the plans the registry is proven over on every run.
# ---------------------------------------------------------------------------

def sweep_cases() -> list[tuple[str, CSNNConfig, dict]]:
    """(name, cfg, plan_network kwargs) grid covering the paper net plus
    the geometry corners the planner must stay sound on: small/rectangular
    fmaps, pool windows that do not divide H/W, multi-channel DVS inputs
    with streaming ingestion, saturating int datapaths, explicit and
    autotuned event_par, tiny and oversized requested capacities, and
    non-3x3 convolution windows (1x1 pointwise, 5x5 wide first layer)."""
    paper = CSNNConfig()
    small = CSNNConfig(input_hw=(10, 10),
                       layers=(ConvSpec(4), ConvSpec(4, pool=3), FCSpec(3)),
                       t_steps=4)
    rect = CSNNConfig(input_hw=(17, 13),
                      layers=(ConvSpec(6), ConvSpec(8, pool=3), FCSpec(4)),
                      t_steps=6)
    dvs = CSNNConfig(input_hw=(20, 24), input_channels=2,
                     layers=(ConvSpec(8, pool=2), ConvSpec(4), FCSpec(5)),
                     t_steps=8)
    k1 = CSNNConfig(input_hw=(12, 12),
                    layers=(ConvSpec(4, kernel=1), ConvSpec(4, kernel=1,
                                                            pool=2),
                            FCSpec(3)),
                    t_steps=4)
    wide = CSNNConfig(input_hw=(16, 14),
                      layers=(ConvSpec(6, kernel=5), ConvSpec(4, pool=3),
                              FCSpec(4)),
                      t_steps=5)
    return [
        ("paper", paper, dict(capacity=256, channel_block=8)),
        ("paper-autotuned-par", paper,
         dict(capacity=256, channel_block=8, event_par=None, block_e=None)),
        ("paper-int8-par4", paper,
         dict(capacity=256, channel_block=8, sat_bits=8, event_par=4)),
        ("paper-int16-chunked", paper,
         dict(capacity=256, sat_bits=16, t_chunk=1)),
        ("paper-oversized-capacity", paper, dict(capacity=4096)),
        ("small-tiny-capacity", small, dict(capacity=8)),
        ("small-par2", small, dict(capacity=100, event_par=2, t_chunk=2)),
        ("rect-autotuned", rect,
         dict(capacity=300, channel_block=[3, 4], event_par=None)),
        ("dvs-ingest", dvs,
         dict(capacity=128, event_par=None, t_chunk=4, ingest=True)),
        ("dvs-ingest-explicit", dvs,
         dict(capacity=64, t_chunk=2, ingest=True,
              ingest_capacity=pad_capacity(64 * 2 * 2))),
        ("paper-pinned-variants", paper,
         dict(capacity=256, channel_block=8, event_par=[1, 4, 4],
              variant=["sequential", "banked-jax", "interlaced-pallas"])),
        ("paper-fused-handoff", paper,
         dict(capacity=256, channel_block=8, t_chunk=5,
              variant=["fused-handoff", "fused-handoff", "fused-handoff"])),
        ("wide-5x5-fused", wide,
         dict(capacity=96, channel_block=2, sat_bits=16,
              variant=[None, "fused-handoff"])),
        ("dvs-ingest-sort-finalize", dvs,
         dict(capacity=128, event_par=None, t_chunk=4, ingest=True,
              variant="banked-jax", stream_finalize="sort")),
        ("k1-pointwise", k1, dict(capacity=64, event_par=2)),
        ("wide-5x5-autotuned", wide,
         dict(capacity=128, channel_block=2, event_par=None)),
        ("wide-5x5-int8-par", wide,
         dict(capacity=96, sat_bits=8, event_par=4, t_chunk=None)),
    ]


def run_contracts(report: Optional[Report] = None) -> Report:
    """Prove every contract over the whole geometry sweep grid."""
    rep = report if report is not None else Report()
    for case, cfg, kwargs in sweep_cases():
        plan = plan_network(cfg, **kwargs)
        audit_plan(plan, cfg, case=case, report=rep)
    return rep
