"""Fault-tolerance runtime: heartbeats, straggler detection, elastic remesh.

Hardware-independent control-plane logic, designed for 1000+-node jobs
and unit-tested with injectable clocks (no real cluster needed to verify
the policies):

* ``HeartbeatTracker`` — hosts report per-step heartbeats; silence beyond
  ``timeout`` marks a host dead (the signal a real deployment gets from
  the coordinator / GCP maintenance events).
* ``StragglerDetector`` — per-step durations per host; hosts slower than
  ``factor`` x running median for ``patience`` consecutive steps are
  flagged.  Policy hooks: log, exclude at next remesh, or (on TPU)
  trigger the backup-replica step (documented; needs real collectives).
* ``ElasticPlanner`` — given the healthy-host count and the model's
  parallelism constraints (model axis is fixed by tensor-parallel
  divisibility; data/pod axes are elastic), pick the largest valid
  (pod, data, model) factorization <= healthy devices.  The training
  loop then: checkpoint -> rebuild mesh (launch/mesh.make_custom_mesh)
  -> restore (checkpoints are mesh-agnostic, checkpoint/ckpt.py) ->
  continue.  This is the shrink/expand protocol.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional


@dataclasses.dataclass
class HostState:
    last_seen: float
    step_times: list = dataclasses.field(default_factory=list)
    slow_streak: int = 0


class HeartbeatTracker:
    def __init__(self, hosts: list[str], timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.timeout = timeout
        now = clock()
        self.hosts = {h: HostState(last_seen=now) for h in hosts}

    def beat(self, host: str):
        self.hosts[host].last_seen = self.clock()

    def dead_hosts(self) -> list[str]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_seen > self.timeout]

    def alive_hosts(self) -> list[str]:
        dead = set(self.dead_hosts())
        return [h for h in self.hosts if h not in dead]


class StragglerDetector:
    """Flags hosts persistently slower than the fleet median."""

    def __init__(self, factor: float = 1.5, patience: int = 3, window: int = 20):
        self.factor = factor
        self.patience = patience
        self.window = window
        self.hosts: dict[str, HostState] = {}

    def record(self, host: str, step_seconds: float):
        st = self.hosts.setdefault(host, HostState(last_seen=0.0))
        st.step_times.append(step_seconds)
        if len(st.step_times) > self.window:
            st.step_times.pop(0)

    def stragglers(self) -> list[str]:
        latest = {h: st.step_times[-1] for h, st in self.hosts.items()
                  if st.step_times}
        if len(latest) < 3:
            return []
        med = statistics.median(latest.values())
        out = []
        for h, t in latest.items():
            st = self.hosts[h]
            if t > self.factor * med:
                st.slow_streak += 1
            else:
                st.slow_streak = 0
            if st.slow_streak >= self.patience:
                out.append(h)
        return out


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    devices_used: int
    dropped: int


class ElasticPlanner:
    """Largest valid mesh under the current healthy-device count.

    model_parallel is fixed (tensor shapes constrain it); the data axis
    absorbs elasticity; a pod axis is re-introduced whenever the healthy
    count spans multiples of ``pod_size``.
    """

    def __init__(self, model_parallel: int = 16, pod_size: int = 256,
                 min_data: int = 1):
        self.mp = model_parallel
        self.pod_size = pod_size
        self.min_data = min_data

    def plan(self, healthy_devices: int) -> MeshPlan:
        if healthy_devices < self.mp * self.min_data:
            raise RuntimeError(
                f"{healthy_devices} healthy devices cannot host model_parallel="
                f"{self.mp} x min_data={self.min_data}")
        usable = (healthy_devices // self.mp) * self.mp
        data = usable // self.mp
        pods = max(1, usable // self.pod_size)
        if pods > 1 and data % pods == 0:
            shape = (pods, data // pods, self.mp)
            axes = ("pod", "data", "model")
            used = pods * (data // pods) * self.mp
        else:
            shape = (data, self.mp)
            axes = ("data", "model")
            used = data * self.mp
        return MeshPlan(shape=shape, axes=axes, devices_used=used,
                        dropped=healthy_devices - used)


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str           # "dead_host" | "straggler" | "preemption"
    hosts: list


class FaultPolicy:
    """Orchestration policy consumed by train/loop.py.

    decide() returns one of: "continue", "checkpoint_now", "remesh".
    """

    def __init__(self, tracker: HeartbeatTracker, detector: StragglerDetector,
                 planner: ElasticPlanner, devices_per_host: int = 4):
        self.tracker = tracker
        self.detector = detector
        self.planner = planner
        self.devices_per_host = devices_per_host
        self.events: list[FailureEvent] = []

    def decide(self, step: int, preempted: bool = False) -> str:
        if preempted:
            self.events.append(FailureEvent(step, "preemption", []))
            return "checkpoint_now"
        dead = self.tracker.dead_hosts()
        if dead:
            self.events.append(FailureEvent(step, "dead_host", dead))
            return "remesh"
        slow = self.detector.stragglers()
        if slow:
            self.events.append(FailureEvent(step, "straggler", slow))
            # policy: tolerate stragglers until they die or a remesh is due;
            # a real deployment would also divert their shards (backup steps)
            return "continue"
        return "continue"

    def replan(self) -> MeshPlan:
        healthy = len(self.tracker.alive_hosts()) * self.devices_per_host
        return self.planner.plan(healthy)
