"""Continuous batching (slot-level refill) + serving-path bugfixes.

Contracts pinned here (serve/csnn_engine.py):

* the continuous engine's per-request logits are bit-exact vs the
  run-to-completion engine and vs the planned batched pipeline — slot
  rows are per-sample independent, so a request sees the same T-step
  computation whichever slots its neighbours occupy;
* requests admitted mid-flight (while other slots are mid-T-step) are
  counted as refills and still come back exact;
* shutdown drains cleanly: requests enqueued around ``_STOP`` (e.g.
  ``submit_nowait`` racing ``__aexit__``) are served or failed, never
  left hanging, and stop-triggered flushes are not miscounted as
  deadline flushes;
* ``CSNNEngine()`` without a serve config no longer aliases one shared
  mutable ``CSNNServeConfig`` instance across engines;
* ``run_requests([])`` returns an empty (0, n_classes) array instead of
  crashing in ``np.stack``.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSNNConfig, ConvSpec, FCSpec, encode_input,
                        init_params, plan_network, snn_apply_batched)
from repro.serve.csnn_engine import CSNNEngine, CSNNServeConfig

jax.config.update("jax_platform_name", "cpu")

CFG = CSNNConfig(input_hw=(8, 8),
                 layers=(ConvSpec(4), ConvSpec(4, pool=2), FCSpec(3)),
                 t_steps=4)


def _setup(seed=0, n=4, **serve_kwargs):
    params = init_params(jax.random.PRNGKey(seed), CFG)
    plan = plan_network(CFG, capacity=64, channel_block=2, batch_tile=4)
    engine = CSNNEngine(params, CFG, plan, CSNNServeConfig(**serve_kwargs))
    imgs = jnp.asarray(np.random.default_rng(seed)
                       .random((n, 8, 8, 1)).astype(np.float32))
    return params, plan, engine, imgs


class TestContinuousBitExact:
    def test_wave_matches_direct_batched(self):
        params, plan, engine, imgs = _setup(
            n=7, max_batch=4, continuous=True, slots=4, t_chunk=2)
        got = engine.run_requests(list(imgs))
        want = snn_apply_batched(params, encode_input(imgs, CFG), CFG, plan,
                                 collect_stats=False)
        np.testing.assert_array_equal(got, np.asarray(want))

    def test_matches_run_to_completion_engine(self):
        params, plan, rtc, imgs = _setup(n=6, max_batch=4, max_delay_ms=20.0)
        cont = CSNNEngine(params, CFG, plan,
                          CSNNServeConfig(max_batch=4, continuous=True,
                                          slots=4, t_chunk=1))
        np.testing.assert_array_equal(cont.run_requests(list(imgs)),
                                      rtc.run_requests(list(imgs)))

    def test_refill_preserves_per_request_logits(self):
        """Requests arriving while earlier ones are mid-T-step join free
        slots (counted as refills) and still come back bit-exact.

        Deterministic staggering: the follow-up requests are submitted
        only once the first chunk is observed in flight (each chunk
        yields to the event loop while it waits out the device), so the
        admission is guaranteed to happen mid-T-step — no wall-clock
        timing involved.
        """
        params, plan, engine, imgs = _setup(
            n=7, max_batch=2, continuous=True, slots=2, t_chunk=1)
        engine.warmup()

        async def staggered():
            async with engine:
                first = engine.submit_nowait(imgs[0])
                while engine.stats["chunks"] == 0:  # first chunk in flight
                    await asyncio.sleep(0)
                rest = [engine.submit_nowait(imgs[i]) for i in range(1, 7)]
                return await asyncio.gather(first, *rest)

        got = np.stack(asyncio.run(staggered()))
        want = snn_apply_batched(params, encode_input(imgs, CFG), CFG, plan,
                                 collect_stats=False)
        np.testing.assert_array_equal(got, np.asarray(want))
        assert engine.stats["refills"] > 0
        assert engine.stats["admitted"] == engine.stats["retired"] == 7

    def test_slot_utilization_and_chunk_stats(self):
        params, plan, engine, imgs = _setup(
            n=4, max_batch=4, continuous=True, slots=4, t_chunk=2)
        engine.run_requests(list(imgs))
        assert engine.stats["chunks"] == CFG.t_steps // 2
        assert 0.0 < engine.slot_utilization <= 1.0

    def test_warmup_compiles_buckets(self):
        params, plan, engine, imgs = _setup(
            n=4, max_batch=4, continuous=True, slots=4)
        assert engine.warmup() > 0.0
        assert engine._buckets == [1, 2, 4]


class TestShutdownDrain:
    @pytest.mark.parametrize("continuous", [False, True])
    def test_submits_racing_aexit_are_not_lost(self, continuous):
        """Futures for requests enqueued just before (or racing) _STOP must
        resolve — previously they hung forever."""
        params, plan, engine, imgs = _setup(
            n=3, max_batch=4, max_delay_ms=500.0, continuous=continuous)

        async def race():
            async with engine:
                return [engine.submit_nowait(imgs[i]) for i in range(3)]

        futs = asyncio.run(race())
        assert all(f.done() for f in futs)
        served = [f for f in futs if f.exception() is None]
        assert served, "drain must serve (or explicitly fail) the leftovers"
        want = np.asarray(snn_apply_batched(
            params, encode_input(imgs, CFG), CFG, plan, collect_stats=False))
        for i, f in enumerate(futs):
            if f.exception() is None:
                np.testing.assert_array_equal(np.asarray(f.result()), want[i])

    def test_stop_flush_not_counted_as_deadline(self):
        """A stop-triggered partial flush increments flushes_stop, not
        flushes_deadline (which used to misreport)."""
        params, plan, engine, imgs = _setup(n=2, max_batch=8,
                                            max_delay_ms=10_000.0)

        async def drive():
            async with engine:
                futs = [engine.submit_nowait(imgs[i]) for i in range(2)]
                return futs

        futs = asyncio.run(drive())
        assert all(f.done() and f.exception() is None for f in futs)
        assert engine.stats["flushes_stop"] >= 1
        assert engine.stats["flushes_deadline"] == 0

    def test_concurrent_submits_during_shutdown(self):
        """Submitters overlapping __aexit__ either get served or see the
        engine-stopped error; nothing hangs."""
        params, plan, engine, imgs = _setup(n=4, max_batch=4,
                                            max_delay_ms=1.0)
        results = []

        async def drive():
            async def submitter(i):
                await asyncio.sleep(0.001 * i)
                try:
                    results.append(await engine.submit(imgs[i % 4]))
                except RuntimeError:
                    results.append(None)

            async with engine:
                tasks = [asyncio.create_task(submitter(i)) for i in range(4)]
                await asyncio.sleep(0.02)
            await asyncio.gather(*tasks, return_exceptions=True)

        asyncio.run(asyncio.wait_for(drive(), timeout=30.0))
        assert len(results) == 4


class TestFlusherCrashSafety:
    @pytest.mark.parametrize("continuous", [False, True])
    def test_bad_request_fails_future_instead_of_hanging(self, continuous):
        """A request that crashes the flusher loop (here: wrong image
        geometry) must surface as an exception on the future / context
        exit, never as an eternal hang."""
        params, plan, engine, _ = _setup(max_batch=4, max_delay_ms=5.0,
                                         continuous=continuous)
        bad = jnp.zeros((10, 10, 1))  # engine is configured for 8x8

        async def drive():
            fut = None
            try:
                async with engine:
                    fut = engine.submit_nowait(bad)
                    await fut
            except Exception:
                pass
            return fut

        fut = asyncio.run(asyncio.wait_for(drive(), timeout=60.0))
        assert fut is not None and fut.done()
        assert fut.exception() is not None


class TestServeConfigDefault:
    def test_engines_do_not_share_default_config(self):
        params = init_params(jax.random.PRNGKey(0), CFG)
        plan = plan_network(CFG, batch_tile=8)
        e1 = CSNNEngine(params, CFG, plan)
        e2 = CSNNEngine(params, CFG, plan)
        assert e1.serve_cfg is not e2.serve_cfg
        e1.serve_cfg.max_batch = 64
        assert e2.serve_cfg.max_batch == 8
        assert CSNNServeConfig().max_batch == 8

    def test_default_signature_is_none(self):
        import inspect
        sig = inspect.signature(CSNNEngine.__init__)
        assert sig.parameters["serve_cfg"].default is None


class TestEmptyRequests:
    @pytest.mark.parametrize("continuous", [False, True])
    def test_run_requests_empty(self, continuous):
        params, plan, engine, _ = _setup(max_batch=4, continuous=continuous)
        out = engine.run_requests([])
        assert out.shape == (0, 3)
        assert engine.stats["requests"] == 0
