"""Unit + property tests for the core SNN library (paper mechanisms C1-C9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CSNNConfig, ConvSpec, EventQueue, FCSpec, IFState, QuantSpec,
    ann_apply, apply_events, apply_events_blocked, build_aeq,
    calibrate_capacity, column_index, crop_vm, deinterlace, dense_conv,
    encode_input, init_params, interlace, mttfs_step, mttfs_thresholds,
    multi_threshold_encode, or_pool, pad_vm, quantize, rotate_kernel,
    run_conv_layer, run_conv_layer_dense, run_fc_head, saturating_add,
    scatter_aeq, snn_apply, snn_apply_dense, spike_sparsity, threshold_unit,
    ttfs_slope_step,
)
from repro.core.neuron import if_reset_step

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- neurons
class TestNeurons:
    def test_mttfs_fires_forever_once_crossed(self):
        """m-TTFS property: after the first spike the neuron spikes every step."""
        state = IFState.zeros(())
        spikes = []
        for cur in [0.4, 0.4, 0.4, -5.0, 0.0]:  # crosses v_t=1.0 at step 3
            state, s = mttfs_step(state, jnp.asarray(cur), 1.0)
            spikes.append(bool(s))
        assert spikes == [False, False, True, True, True]

    def test_if_reset_step(self):
        v = jnp.asarray(0.0)
        v, s0 = if_reset_step(v, jnp.asarray(1.5), 1.0)
        assert not bool(s0) and float(v) == 1.5
        v, s1 = if_reset_step(v, jnp.asarray(0.0), 1.0)  # fires, resets
        assert bool(s1) and float(v) == 0.0

    def test_ttfs_slope_single_spike(self):
        """Standard TTFS neurons spike at most once (Eq. 7)."""
        mu = jnp.asarray(0.6)
        v = jnp.asarray(0.0)
        fired = jnp.asarray(False)
        count = 0
        for _ in range(6):
            mu, v, fired, s = ttfs_slope_step(mu, v, fired, jnp.asarray(0.0), 1.0)
            count += int(s)
        assert count == 1


# ---------------------------------------------------------------- encoding
class TestEncoding:
    def test_monotone_trains(self):
        """m-TTFS input code: per-pixel spike trains are 0...0 1...1."""
        img = jnp.linspace(0, 1, 16).reshape(4, 4)
        spikes = multi_threshold_encode(img, mttfs_thresholds(5), 5)
        s = np.asarray(spikes, dtype=np.int32)
        diffs = np.diff(s, axis=0)
        assert (diffs >= 0).all()  # once spiking, keep spiking

    def test_bright_spikes_earlier(self):
        img = jnp.asarray([[0.95, 0.30]])
        spikes = np.asarray(multi_threshold_encode(img, mttfs_thresholds(5), 5))
        first = lambda tr: int(np.argmax(tr)) if tr.any() else 99
        assert first(spikes[:, 0, 0]) < first(spikes[:, 0, 1])

    def test_sparsity_metric(self):
        assert float(spike_sparsity(jnp.zeros((4, 4)))) == 1.0
        assert float(spike_sparsity(jnp.ones((4, 4)))) == 0.0


# ---------------------------------------------------------------- AEQ
class TestAEQ:
    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        fmap = jnp.asarray(rng.random((13, 9)) < 0.2)
        q = build_aeq(fmap, capacity=64)
        assert int(q.count) == int(fmap.sum())
        back = scatter_aeq(q, fmap.shape)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(fmap))

    def test_interlaced_column_order(self):
        """Events are emitted column 0..8 (the hazard-free read order)."""
        rng = np.random.default_rng(1)
        fmap = jnp.asarray(rng.random((12, 12)) < 0.3)
        q = build_aeq(fmap, capacity=80)
        coords = np.asarray(q.coords)[np.asarray(q.valid)]
        cols = (coords[:, 0] % 3) * 3 + coords[:, 1] % 3
        assert (np.diff(cols) >= 0).all()

    def test_capacity_drop(self):
        fmap = jnp.ones((6, 6), bool)
        q = build_aeq(fmap, capacity=10)
        assert int(q.valid.sum()) == 10  # overfull queue drops events

    def test_calibrate_capacity(self):
        cap = calibrate_capacity([10, 20, 30, 100], percentile=100.0, margin=1.0, align=8)
        assert cap == 104  # 100 -> align 8

    @given(st.integers(2, 30), st.integers(2, 30), st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_interlace_roundtrip(self, h, w, seed):
        rng = np.random.default_rng(seed)
        vm = jnp.asarray(rng.normal(size=(h, w)).astype(np.float32))
        cols = interlace(vm)
        assert cols.shape[0] == 9
        back = deinterlace(cols, (h, w))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(vm))

    @given(st.integers(3, 20), st.integers(3, 20), st.integers(0, 100))
    @settings(max_examples=20, deadline=None)
    def test_interlace_window_invariant(self, h, w, seed):
        """Any 3x3 window touches each of the 9 columns exactly once (Fig. 6)."""
        rng = np.random.default_rng(seed)
        i0 = int(rng.integers(0, h - 2))
        j0 = int(rng.integers(0, w - 2))
        ii, jj = np.meshgrid(np.arange(i0, i0 + 3), np.arange(j0, j0 + 3), indexing="ij")
        cols = np.asarray(column_index(jnp.asarray(ii), jnp.asarray(jj)))
        assert sorted(cols.ravel().tolist()) == list(range(9))


# ---------------------------------------------------------------- event conv
class TestEventConv:
    def _random_case(self, seed, h, w, density):
        rng = np.random.default_rng(seed)
        fmap = jnp.asarray(rng.random((h, w)) < density)
        kernel = jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))
        return fmap, kernel

    @pytest.mark.slow
    @given(st.integers(3, 24), st.integers(3, 24), st.floats(0.0, 1.0), st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_bitexact_vs_sliding_window(self, h, w, density, seed):
        """Core paper property (Fig. 4): event conv == sliding-window conv."""
        fmap, kernel = self._random_case(seed, h, w, density)
        q = build_aeq(fmap, capacity=h * w)
        vm = apply_events(pad_vm(jnp.zeros((h, w), jnp.float32)), q, kernel)
        got = crop_vm(vm)
        want = dense_conv(fmap, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_channel_vectorized(self):
        """(3,3,C_out) kernels update all output channels per event."""
        fmap, _ = self._random_case(3, 9, 11, 0.3)
        rng = np.random.default_rng(3)
        kernel = jnp.asarray(rng.normal(size=(3, 3, 5)).astype(np.float32))
        q = build_aeq(fmap, capacity=9 * 11)
        got = crop_vm(apply_events(pad_vm(jnp.zeros((9, 11, 5), jnp.float32)), q, kernel))
        want = dense_conv(fmap, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_blocked_early_exit_matches(self):
        fmap, kernel = self._random_case(7, 16, 16, 0.1)
        q = build_aeq(fmap, capacity=256)
        a = apply_events(pad_vm(jnp.zeros((16, 16), jnp.float32)), q, kernel)
        b = apply_events_blocked(pad_vm(jnp.zeros((16, 16), jnp.float32)), q, kernel, block=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_rotation(self):
        k = jnp.arange(9.0).reshape(3, 3)
        np.testing.assert_array_equal(np.asarray(rotate_kernel(k)),
                                      np.asarray(k)[::-1, ::-1])

    def test_halo_handles_edges(self):
        """Events on the fmap edge must not corrupt interior potentials."""
        fmap = jnp.zeros((5, 5), bool).at[0, 0].set(True)
        kernel = jnp.ones((3, 3), jnp.float32)
        q = build_aeq(fmap, capacity=8)
        got = crop_vm(apply_events(pad_vm(jnp.zeros((5, 5), jnp.float32)), q, kernel))
        want = dense_conv(fmap, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------- threshold
class TestThreshold:
    def test_or_pool(self):
        s = jnp.zeros((6, 6), bool).at[0, 0].set(True).at[5, 5].set(True)
        p = or_pool(s, 3)
        assert p.shape == (2, 2)
        assert bool(p[0, 0]) and bool(p[1, 1]) and not bool(p[0, 1])

    def test_or_pool_pads(self):
        s = jnp.ones((7, 8), bool)
        assert or_pool(s, 3).shape == (3, 3)

    def test_threshold_mttfs_indicator(self):
        vm = jnp.asarray([[0.5, 2.0]])
        fired = jnp.asarray([[True, False]])
        r = threshold_unit(vm, 0.0, 1.0, fired)
        np.testing.assert_array_equal(np.asarray(r.spikes), [[True, True]])
        np.testing.assert_array_equal(np.asarray(r.fired), [[True, True]])

    def test_saturating_bias(self):
        vm = jnp.asarray([[120]], jnp.int8)
        r = threshold_unit(vm, jnp.asarray(100, jnp.int8), 50, jnp.asarray([[False]]),
                           sat_bits=8)
        assert int(r.v_m[0, 0]) == 127  # clamped, no wraparound
        assert bool(r.spikes[0, 0])


# ---------------------------------------------------------------- quantization
class TestQuantization:
    def test_saturating_add_bounds(self):
        a = jnp.asarray([120, -120], jnp.int8)
        b = jnp.asarray([100, -100], jnp.int8)
        out = saturating_add(a, b, 8)
        np.testing.assert_array_equal(np.asarray(out), [127, -128])

    @given(st.integers(-127, 127), st.integers(-127, 127))
    @settings(max_examples=50, deadline=None)
    def test_saturating_add_matches_clamped_int(self, x, y):
        out = int(saturating_add(jnp.asarray(x, jnp.int8), jnp.asarray(y, jnp.int8), 8))
        assert out == max(-128, min(127, x + y))

    def test_quantize_roundtrip(self):
        spec = QuantSpec(bits=8, scale=0.05)
        x = jnp.asarray([0.1, -0.2, 6.35, -100.0])
        q = quantize(x, spec)
        assert q.dtype == jnp.int8
        assert int(q[2]) == 127 and int(q[3]) == -128


# ---------------------------------------------------------------- scheduler
class TestScheduler:
    def _layer_case(self, seed, t=3, h=8, w=8, cin=2, cout=4):
        rng = np.random.default_rng(seed)
        spikes = jnp.asarray(rng.random((t, h, w, cin)) < 0.15)
        k = jnp.asarray(rng.normal(size=(3, 3, cin, cout)).astype(np.float32) * 0.5)
        b = jnp.asarray(rng.normal(size=(cout,)).astype(np.float32) * 0.1)
        return spikes, k, b

    def test_event_matches_dense(self):
        """Algorithm-1 event scheduling == frame-based oracle, incl. pooling."""
        spikes, k, b = self._layer_case(0)
        out_e, stats = run_conv_layer(spikes, k, b, 1.0, capacity=64, pool=3)
        out_d = run_conv_layer_dense(spikes, k, b, 1.0, pool=3)
        np.testing.assert_array_equal(np.asarray(out_e), np.asarray(out_d))
        assert stats.in_spike_counts.shape == (3, 2)

    def test_channel_block_invariance(self):
        """channel_block is a perf knob — results must not change."""
        spikes, k, b = self._layer_case(1)
        out1, _ = run_conv_layer(spikes, k, b, 1.0, capacity=64, channel_block=1)
        out4, _ = run_conv_layer(spikes, k, b, 1.0, capacity=64, channel_block=4)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out4))

    def test_fc_head(self):
        spikes = jnp.asarray(np.random.default_rng(0).random((4, 3, 3, 2)) < 0.5)
        w = jnp.ones((18, 5), jnp.float32)
        b = jnp.full((5,), 0.5, jnp.float32)
        logits = run_fc_head(spikes, w, b)
        expected = float(np.asarray(spikes).sum()) + 4 * 0.5
        np.testing.assert_allclose(np.asarray(logits), expected, rtol=1e-6)


# ---------------------------------------------------------------- CSNN e2e
class TestCSNN:
    def small_cfg(self):
        return CSNNConfig(input_hw=(10, 10),
                          layers=(ConvSpec(4), ConvSpec(4, pool=3), FCSpec(3)),
                          t_steps=4)

    def test_ann_forward_shapes(self):
        cfg = self.small_cfg()
        params = init_params(jax.random.PRNGKey(0), cfg)
        imgs = jnp.ones((2, 10, 10, 1)) * 0.5
        logits = ann_apply(params, imgs, cfg)
        assert logits.shape == (2, 3)
        assert not bool(jnp.isnan(logits).any())

    def test_snn_event_equals_dense_oracle(self):
        cfg = self.small_cfg()
        params = init_params(jax.random.PRNGKey(1), cfg)
        img = jnp.asarray(np.random.default_rng(0).random((10, 10, 1)).astype(np.float32))
        spikes = encode_input(img[None], cfg)[0]
        logits_e, stats = snn_apply(params, spikes, cfg, capacity=128)
        logits_d = snn_apply_dense(params, spikes, cfg)
        np.testing.assert_allclose(np.asarray(logits_e), np.asarray(logits_d),
                                   rtol=1e-4, atol=1e-4)
        assert len(stats) == 2

    def test_paper_architecture_instantiates(self):
        """The exact 28x28-32C3-32C3-P3-10C3-F10 network runs one sample."""
        cfg = CSNNConfig()  # paper defaults
        params = init_params(jax.random.PRNGKey(2), cfg)
        img = jnp.asarray(np.random.default_rng(1).random((28, 28, 1)).astype(np.float32))
        spikes = encode_input(img[None], cfg)[0]
        logits = snn_apply(params, spikes, cfg, capacity=128, collect_stats=False)
        assert logits.shape == (10,)
        assert not bool(jnp.isnan(logits).any())
