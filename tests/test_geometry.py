"""Parametric k x k geometry (generalized interlaced event pipeline).

Property suite sweeping k in {1, 3, 5} x raster/interlaced orders x
int8/float32 datapaths, plus the 5x5 end-to-end differential and the
plan-cache geometry invalidation:

* ``ConvGeometry`` invariants: bank count, halo, congruence column map,
  and the even-window/stride rejections.
* queue-compaction equivalence: at full capacity both orders keep
  exactly the fmap's event set (``scatter_aeq`` inverts ``build_aeq``),
  interlaced queues are grouped by column s = kw*(i%kh) + (j%kw), and
  replaying either order through the sequential event conv produces the
  same membrane.
* banked-apply bit-exactness: the sort-free banked path equals both the
  sequential per-event walk (bit for bit) and the dense ``lax.conv``
  reference, for every geometry and dtype.
* ``csnn_wide`` end to end: the 5x5 first-layer net's event pipeline is
  bit-exact vs the dense frame-based oracle.
* plan cache: the v2 fingerprint carries explicit kh/kw/stride per
  layer, so a winner cached for the 3x3 net can never be replayed onto
  a 5x5 plan, and pre-geometry (version-1) cache files read as empty.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import csnn_wide
from repro.core.aeq import build_aeq, build_bank_masks, scatter_aeq
from repro.core.csnn import (CSNNConfig, ConvSpec, FCSpec, encode_input,
                             init_params, snn_apply_batched, snn_apply_dense)
from repro.core.event_conv import (apply_events, apply_events_banked,
                                   crop_vm, dense_conv, pad_vm)
from repro.core.geometry import GEOM_3X3, ConvGeometry
from repro.core.plan import plan_network

jax.config.update("jax_platform_name", "cpu")

GEOMS = [ConvGeometry(1, 1), GEOM_3X3, ConvGeometry(5, 5)]


def _spikes(rng, h, w, density):
    return jnp.asarray(rng.random((h, w)) < density)


class TestConvGeometry:
    def test_derived_quantities(self):
        for g, banks, halo in [(GEOMS[0], 1, (0, 0)), (GEOMS[1], 9, (1, 1)),
                               (GEOMS[2], 25, (2, 2))]:
            assert g.n_banks == banks
            assert g.halo == halo
            assert g.padded_hw(10, 8) == (10 + 2 * halo[0], 8 + 2 * halo[1])
        assert GEOM_3X3 == ConvGeometry(3, 3, 1)
        # strided geometries plan (ceil-div output) but are rejected by
        # the event pipeline (require_event_compatible, tested below)
        assert ConvGeometry(3, 3, 2).out_hw(9, 7) == (5, 4)
        assert ConvGeometry(5, 5).out_hw(9, 7) == (9, 7)

    def test_column_map_is_congruence(self):
        for g in GEOMS:
            cols = {g.column_index_py(i, j)
                    for i in range(3 * g.kh) for j in range(3 * g.kw)}
            assert cols == set(range(g.n_banks))
            # periodicity: the map only sees (i mod kh, j mod kw)
            assert g.column_index_py(5 * g.kh + 1 % g.kh, 7 * g.kw) \
                == g.column_index_py(1 % g.kh, 0)

    def test_rejections(self):
        for bad in [dict(kh=2, kw=3), dict(kh=3, kw=4), dict(kh=0, kw=1),
                    dict(kh=3, kw=3, stride=0)]:
            with pytest.raises(ValueError):
                ConvGeometry(**bad)
        with pytest.raises(ValueError):
            ConvGeometry(3, 3, 2).require_event_compatible("test")


class TestQueueCompaction:
    @given(st.sampled_from(GEOMS), st.booleans(), st.integers(5, 16),
           st.integers(5, 16), st.floats(0.0, 1.0), st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_both_orders_keep_the_fmap_event_set(self, geom, interlaced, h,
                                                 w, density, seed):
        rng = np.random.default_rng(seed)
        fmap = _spikes(rng, h, w, density)
        q = build_aeq(fmap, h * w, interlaced=interlaced, geometry=geom)
        np.testing.assert_array_equal(np.asarray(scatter_aeq(q, (h, w))),
                                      np.asarray(fmap))
        coords = np.asarray(q.coords)[np.asarray(q.valid)]
        if interlaced:  # grouped by interlace column, raster within
            keys = [(geom.column_index_py(i, j), i, j) for i, j in coords]
        else:           # plain raster order
            keys = [(i, j) for i, j in coords]
        assert keys == sorted(keys)

    @given(st.sampled_from(GEOMS), st.sampled_from(["int8", "float32"]),
           st.integers(5, 14), st.integers(5, 14), st.floats(0.1, 0.9),
           st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_replay_order_equivalence(self, geom, dt, h, w, density, seed):
        """Interlaced and raster queues drive the sequential event conv
        to the same membrane: compaction reorders events, never changes
        the applied work.  Integer adds commute exactly; float taps pick
        up reassociation ULPs, so the float case is allclose."""
        rng = np.random.default_rng(seed)
        fmap = _spikes(rng, h, w, density)
        if dt == "float32":
            kern = jnp.asarray(
                rng.standard_normal((geom.kh, geom.kw, 2)), jnp.float32)
        else:  # |tap| <= 3 keeps every k=5 cell within int8 (25*3 < 127)
            kern = jnp.asarray(rng.integers(-3, 4, (geom.kh, geom.kw, 2)),
                               jnp.int8)
        vm0 = pad_vm(jnp.zeros((h, w, 2), kern.dtype), geom)
        out = [np.asarray(crop_vm(apply_events(
            vm0, build_aeq(fmap, h * w, interlaced=il, geometry=geom),
            kern), geom)) for il in (True, False)]
        if dt == "float32":
            np.testing.assert_allclose(out[0], out[1], rtol=1e-5, atol=1e-6)
        else:
            np.testing.assert_array_equal(out[0], out[1])


class TestBankedApplyVsDense:
    @given(st.sampled_from(GEOMS), st.sampled_from(["int8", "float32"]),
           st.integers(5, 14), st.integers(5, 14), st.floats(0.0, 1.0),
           st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_banked_bit_exact_vs_sequential_and_dense(self, geom, dt, h, w,
                                                      density, seed):
        rng = np.random.default_rng(seed)
        fmap = _spikes(rng, h, w, density)
        c = 2
        if dt == "float32":
            kern = jnp.asarray(rng.standard_normal((geom.kh, geom.kw, c)),
                               jnp.float32)
        else:
            kern = jnp.asarray(rng.integers(-3, 4, (geom.kh, geom.kw, c)),
                               jnp.int8)
        vm0 = pad_vm(jnp.zeros((h, w, c), kern.dtype), geom)
        masks = build_bank_masks(fmap[None], h * w, geom).masks[0]
        banked = np.asarray(crop_vm(
            apply_events_banked(vm0, masks, kern), geom))
        seq = np.asarray(crop_vm(apply_events(
            vm0, build_aeq(fmap, h * w, geometry=geom), kern), geom))
        np.testing.assert_array_equal(banked, seq)
        if dt == "float32":
            np.testing.assert_allclose(
                banked, np.asarray(dense_conv(fmap, kern)),
                rtol=1e-5, atol=1e-5)
        else:  # non-saturating regime: integer paths agree exactly
            np.testing.assert_array_equal(
                banked,
                np.asarray(dense_conv(
                    fmap, kern.astype(jnp.int32))).astype(np.int8))


class TestWideEndToEnd:
    def test_csnn_wide_bit_exact_vs_dense_oracle(self):
        """The 5x5 first-layer net runs the whole planned event pipeline
        and lands bit-exact on the dense frame-based oracle (queues sized
        truncation-free: the oracle has no overflow-drop semantics)."""
        cfg = csnn_wide.SMOKE
        h, w = cfg.input_hw
        params = init_params(jax.random.PRNGKey(0), cfg)
        x = (jax.random.uniform(jax.random.PRNGKey(1),
                                (2, h, w, cfg.input_channels))
             < 0.4).astype(jnp.float32)
        spikes = encode_input(x, cfg)
        plan = plan_network(cfg, capacity=h * w, channel_block=4,
                            event_par=None)
        assert plan.layers[0].geometry.window == (5, 5)
        assert plan.layers[0].geometry.n_banks == 25
        assert plan.layers[1].geometry == GEOM_3X3  # mixed-geometry net
        got = snn_apply_batched(params, spikes, cfg, plan,
                                collect_stats=False)
        want = jax.vmap(lambda s: snn_apply_dense(params, s, cfg))(spikes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestPlanCacheGeometryKey:
    def test_geometry_change_invalidates_cached_winner(self, tmp_path):
        from repro.tune.cache import (CACHE_VERSION, PlanCache, cache_key,
                                      env_descriptor, geometry_descriptor)

        assert CACHE_VERSION == 3  # v3: fused-handoff on the candidate axis
        cfg3 = CSNNConfig(input_hw=(12, 12),
                          layers=(ConvSpec(8), ConvSpec(8, pool=3),
                                  FCSpec(10)),
                          t_steps=4)
        cfg5 = CSNNConfig(input_hw=(12, 12),
                          layers=(ConvSpec(8, kernel=5),
                                  ConvSpec(8, pool=3), FCSpec(10)),
                          t_steps=4)
        base = dict(capacity=64, channel_block=4)
        env = env_descriptor()
        g3, g5 = geometry_descriptor(cfg3, base), geometry_descriptor(cfg5,
                                                                      base)
        # the fingerprint carries the explicit window, not just a label
        assert g3["layers"][0] | {"kernel": 5, "kh": 5, "kw": 5,
                                  "n_banks": 25} == g5["layers"][0]
        assert g5["layers"][0]["stride"] == 1
        k3, k5 = cache_key(g3, env), cache_key(g5, env)
        assert k3 != k5
        cache = PlanCache(tmp_path / "plan_cache.json")
        cache.put(k3, {"geometry": g3, "env": env,
                       "winners": {"layers": []}})
        assert cache.get(k3) is not None
        # the 3x3 winner can never be replayed onto the 5x5 plan
        assert cache.get(k5) is None

    def test_version1_cache_files_read_as_empty(self, tmp_path):
        """Pre-geometry (version-1) caches are invalidated wholesale: the
        old schema had no per-layer window fields, so its winners are
        untrustworthy under parametric geometry."""
        import json

        from repro.tune.cache import PlanCache

        path = tmp_path / "plan_cache.json"
        entry = {"geometry": {}, "env": {}, "winners": {}}
        path.write_text(json.dumps(
            {"version": 1, "entries": {"deadbeef": entry}}))
        assert PlanCache(path).get("deadbeef") is None
