"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes, dtypes and densities (+ hypothesis properties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aeq import EventQueue, build_aeq
from repro.core.event_conv import dense_conv
from repro.kernels.event_conv.kernel import event_conv_pallas
from repro.kernels.event_conv.ops import event_conv
from repro.kernels.event_conv.ref import event_conv_ref
from repro.kernels.threshold_pool.ops import threshold_pool
from repro.kernels.threshold_pool.ref import threshold_pool_ref

jax.config.update("jax_platform_name", "cpu")


def _queue(rng, h, w, density, capacity):
    fmap = jnp.asarray(rng.random((h, w)) < density)
    return fmap, build_aeq(fmap, capacity)


class TestEventConvKernel:
    @pytest.mark.parametrize("h,w,c", [(6, 6, 8), (28, 28, 32), (13, 9, 16), (10, 10, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int16, jnp.int8])
    def test_matches_ref_sweep(self, h, w, c, dtype):
        rng = np.random.default_rng(hash((h, w, c, str(dtype))) % 2**32)
        fmap, q = _queue(rng, h, w, 0.25, capacity=h * w)
        if dtype == jnp.float32:
            kernel = jnp.asarray(rng.normal(size=(3, 3, c)).astype(np.float32))
            vm = jnp.asarray(rng.normal(size=(h + 2, w + 2, c)).astype(np.float32))
        else:
            kernel = jnp.asarray(rng.integers(-20, 20, size=(3, 3, c)), dtype)
            vm = jnp.asarray(rng.integers(-50, 50, size=(h + 2, w + 2, c)), dtype)
        coords = jnp.pad(q.coords, ((0, -q.capacity % 64), (0, 0)))
        valid = jnp.pad(q.valid, (0, -q.capacity % 64))
        got = event_conv_pallas(vm, coords, valid, kernel, block_e=64)
        want = event_conv_ref(vm, coords, valid, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_int8_saturates_per_event(self):
        """Per-event saturation (FPGA PE semantics) != clip-at-end."""
        vm = jnp.zeros((3, 3, 1), jnp.int8)
        # two events at the same location: +100 then -100 with saturation at
        # +127 gives 27... with +100+100 saturating gives 127 then -100 -> 27.
        coords = jnp.asarray([[0, 0], [0, 0], [0, 0]], jnp.int32)
        valid = jnp.asarray([True, True, True])
        kernel = jnp.full((3, 3, 1), 100, jnp.int8)
        got = event_conv_pallas(vm, coords, jnp.asarray([1, 1, 0], jnp.int8),
                                kernel, block_e=3)
        assert int(got[1, 1, 0]) == 127  # saturated, not 200 % 256

    @given(st.integers(4, 20), st.integers(4, 20), st.floats(0.0, 0.9),
           st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_wrapper_equals_dense_conv(self, h, w, density, seed):
        """ops.event_conv on a zero vm == SAME sliding-window convolution."""
        rng = np.random.default_rng(seed)
        fmap, q = _queue(rng, h, w, density, capacity=h * w)
        kernel = jnp.asarray(rng.normal(size=(3, 3, 4)).astype(np.float32))
        got = event_conv(jnp.zeros((h, w, 4), jnp.float32), q, kernel, block_e=32)
        want = dense_conv(fmap, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_empty_queue_is_noop(self):
        q = build_aeq(jnp.zeros((8, 8), bool), capacity=16)
        vm = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8, 4)).astype(np.float32))
        out = event_conv(vm, q, jnp.ones((3, 3, 4), jnp.float32), block_e=16)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(vm))


class TestThresholdPoolKernel:
    @pytest.mark.parametrize("h,w,c", [(9, 9, 8), (28, 28, 32), (10, 14, 130)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int16])
    @pytest.mark.parametrize("pool", [None, 3])
    def test_matches_ref_sweep(self, h, w, c, dtype, pool):
        rng = np.random.default_rng(hash((h, w, c, str(dtype), pool)) % 2**32)
        if dtype == jnp.float32:
            vm = jnp.asarray(rng.normal(size=(h, w, c)).astype(np.float32))
            bias = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
            v_t = 0.5
        else:
            vm = jnp.asarray(rng.integers(-100, 100, size=(h, w, c)), dtype)
            bias = jnp.asarray(rng.integers(-10, 10, size=(c,)), dtype)
            v_t = 20
        fired = jnp.asarray(rng.random((h, w, c)) < 0.1)
        vm_k, fired_k, out_k = threshold_pool(vm, bias, fired, v_t=v_t, pool=pool,
                                              block_c=64, use_kernel=True)
        vm_r, fired_r, out_r = threshold_pool(vm, bias, fired, v_t=v_t, pool=pool,
                                              use_kernel=False)
        np.testing.assert_allclose(np.asarray(vm_k), np.asarray(vm_r), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(fired_k), np.asarray(fired_r))
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))

    def test_mttfs_indicator_propagates(self):
        vm = jnp.full((3, 3, 4), -10.0)
        fired = jnp.zeros((3, 3, 4), bool).at[1, 1, 2].set(True)
        _, fired_out, spikes = threshold_pool(vm, jnp.zeros((4,)), fired, v_t=1.0)
        assert bool(fired_out[1, 1, 2]) and int(fired_out.sum()) == 1
        np.testing.assert_array_equal(np.asarray(spikes), np.asarray(fired_out))

    def test_pool_padding_never_spikes(self):
        """Cells added by pool padding must not fire even with huge bias."""
        vm = jnp.zeros((4, 4, 2))  # pads to 6x6 for pool=3
        bias = jnp.full((2,), 100.0)
        _, _, pooled = threshold_pool(vm, bias, jnp.zeros((4, 4, 2), bool),
                                      v_t=1.0, pool=3)
        assert pooled.shape == (2, 2, 2)
        assert bool(pooled.all())  # real cells all spike (0+100 > 1)...
        vm2 = jnp.full((4, 4, 2), -200.0)
        _, _, pooled2 = threshold_pool(vm2, bias, jnp.zeros((4, 4, 2), bool),
                                       v_t=1.0, pool=3)
        assert not bool(pooled2.any())  # ...but padding alone never does

    def test_int16_saturating_bias(self):
        vm = jnp.full((3, 3, 2), 32700, jnp.int16)
        bias = jnp.full((2,), 100, jnp.int16)
        vm_out, _, _ = threshold_pool(vm, bias, jnp.zeros((3, 3, 2), bool),
                                      v_t=10, pool=None)
        assert int(vm_out[0, 0, 0]) == 32767

    def test_non_dividing_pool_window_pads_to_exact_output(self):
        """H, W not multiples of the pool window: ops pads with the
        never-spikes fill and the pooled map is exactly (ceil(H/p),
        ceil(W/p)) — also directly at the kernel level, where the padded
        operand contract holds by construction."""
        from repro.kernels.threshold_pool.kernel import threshold_pool_pallas
        vm = jnp.zeros((7, 8, 2))
        _, _, pooled = threshold_pool(vm, jnp.full((2,), 5.0),
                                      jnp.zeros((7, 8, 2), bool),
                                      v_t=1.0, pool=3)
        assert pooled.shape == (3, 3, 2)
        outs = threshold_pool_pallas(jnp.zeros((9, 9, 2)), jnp.zeros((2,)),
                                     jnp.zeros((9, 9, 2), jnp.int8),
                                     v_t=1.0, pool=3, block_c=2,
                                     interpret=True)
        assert outs[2].shape == (3, 3, 2)


class TestThresholdPoolOpsValidation:
    """Every ``raise ValueError`` branch of threshold_pool/ops.py,
    asserted by message — the negative-path style of tests/test_plan.py's
    TestPlanValidationErrors."""

    VM = jnp.zeros((6, 6, 2))
    BIAS = jnp.zeros((2,))
    FIRED = jnp.zeros((6, 6, 2), bool)

    def test_rejects_wrong_vm_rank(self):
        with pytest.raises(ValueError, match=r"vm must be \(H, W, C\)"):
            threshold_pool(jnp.zeros((6, 6)), self.BIAS, self.FIRED,
                           v_t=1.0)

    def test_rejects_unsupported_dtype(self):
        with pytest.raises(ValueError, match="unsupported vm dtype"):
            threshold_pool(jnp.zeros((6, 6, 2), jnp.int32), self.BIAS,
                           self.FIRED, v_t=1.0)

    def test_rejects_bias_channel_mismatch(self):
        with pytest.raises(ValueError, match="bias must have shape"):
            threshold_pool(self.VM, jnp.zeros((3,)), self.FIRED, v_t=1.0)

    def test_rejects_fired_latch_shape_mismatch(self):
        with pytest.raises(ValueError, match="fired shape"):
            threshold_pool(self.VM, self.BIAS, jnp.zeros((5, 6, 2), bool),
                           v_t=1.0)

    def test_rejects_nonpositive_pool(self):
        with pytest.raises(ValueError, match="pool must be >= 1"):
            threshold_pool(self.VM, self.BIAS, self.FIRED, v_t=1.0,
                           pool=0)

    def test_rejects_nonpositive_emit_capacity(self):
        with pytest.raises(ValueError, match="emit_capacity must be >= 1"):
            threshold_pool(self.VM, self.BIAS, self.FIRED, v_t=1.0,
                           emit_capacity=0)


class TestConversionAndPipelineSim:
    def test_normalize_preserves_argmax(self):
        from repro.core.csnn import CSNNConfig, ConvSpec, FCSpec, ann_apply, init_params
        from repro.core.conversion import normalize_params
        cfg = CSNNConfig(input_hw=(8, 8), layers=(ConvSpec(4), FCSpec(3)), t_steps=3)
        params = init_params(jax.random.PRNGKey(0), cfg)
        imgs = jnp.asarray(np.random.default_rng(0).random((4, 8, 8, 1)).astype(np.float32))
        norm = normalize_params(params, imgs, cfg)
        a = ann_apply(params, imgs, cfg)
        b = ann_apply(norm, imgs, cfg)
        np.testing.assert_array_equal(np.argmax(np.asarray(a), -1),
                                      np.argmax(np.asarray(b), -1))

    def test_quantize_params_threshold_representable(self):
        from repro.core.conversion import quantize_params, quantized_threshold
        params = {"conv0": {"w": jnp.asarray([0.5, -0.25]), "b": jnp.asarray([0.1])}}
        qp, spec = quantize_params(params, bits=8, v_t=1.0)
        assert quantized_threshold(1.0, spec) <= 127
        assert qp["conv0"]["w"].dtype == jnp.int8

    def test_pipeline_sim_hazard_free_same_column(self):
        """Events in interlaced order from one column never stall (paper VI-B)."""
        from repro.core.pipeline_sim import simulate_conv_queue
        events = np.asarray([[0, 0], [0, 3], [3, 0], [3, 3]])  # all column 0
        ev, hz, em, wu = simulate_conv_queue(events)
        assert ev == 4 and hz == 0 and em == 8 and wu == 4

    def test_pipeline_sim_column_switch_hazard(self):
        from repro.core.pipeline_sim import simulate_conv_queue
        events = np.asarray([[0, 0], [0, 1]])  # col 0 then col 1, overlapping
        ev, hz, _, _ = simulate_conv_queue(events)
        assert ev == 2 and hz == 1

    def test_pipeline_sim_utilization_band(self):
        """Utilization must be < 1 and fall with extra stall sources."""
        from repro.core.pipeline_sim import simulate_layer
        rng = np.random.default_rng(0)
        evs = [[rng.integers(0, 28, size=(50, 2)) for _ in range(4)] for _ in range(5)]
        rep = simulate_layer(evs, c_out=8, fmap_hw=(28, 28))
        assert 0.0 < rep.pe_utilization < 1.0


class TestSchedulerPallasBackend:
    """The Pallas event_conv kernel as the Algorithm-1 compute path."""

    def test_pallas_backend_matches_jax(self):
        from repro.core.scheduler import run_conv_layer
        rng = np.random.default_rng(7)
        spikes = jnp.asarray(rng.random((3, 10, 10, 2)) < 0.2)
        k = jnp.asarray(rng.normal(size=(3, 3, 2, 4)).astype(np.float32) * 0.5)
        b = jnp.asarray(rng.normal(size=(4,)).astype(np.float32) * 0.1)
        out_j, _ = run_conv_layer(spikes, k, b, 1.0, capacity=100, pool=3,
                                  backend="jax")
        out_p, _ = run_conv_layer(spikes, k, b, 1.0, capacity=100, pool=3,
                                  backend="pallas")
        np.testing.assert_array_equal(np.asarray(out_j), np.asarray(out_p))

    def test_pallas_backend_full_csnn(self):
        """Whole-network equivalence: kernels as the production layer."""
        from repro.core.csnn import CSNNConfig, ConvSpec, FCSpec, encode_input, init_params
        from repro.core.scheduler import run_conv_layer, run_fc_head
        cfg = CSNNConfig(input_hw=(12, 12),
                         layers=(ConvSpec(4), ConvSpec(4, pool=3), FCSpec(3)),
                         t_steps=3)
        params = init_params(jax.random.PRNGKey(0), cfg)
        img = jnp.asarray(np.random.default_rng(0).random((12, 12, 1)).astype(np.float32))
        spikes = encode_input(img[None], cfg)[0]
        outs = {}
        for backend in ("jax", "pallas"):
            x = spikes
            for idx, spec in enumerate(cfg.layers):
                if isinstance(spec, ConvSpec):
                    p = params[f"conv{idx}"]
                    x, _ = run_conv_layer(x, p["w"], p["b"], cfg.v_t,
                                          capacity=144, pool=spec.pool,
                                          backend=backend)
                else:
                    p = params[f"fc{idx}"]
                    outs[backend] = run_fc_head(x, p["w"], p["b"])
        np.testing.assert_allclose(np.asarray(outs["jax"]),
                                   np.asarray(outs["pallas"]), rtol=1e-5)
