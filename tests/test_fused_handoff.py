"""Fused spike emission (ISSUE 10): threshold -> compact queue handoff.

Contracts pinned here:

* the :class:`~repro.core.aeq.FusedHandoff` carrier is exactly
  ``build_bank_masks`` over the same fmaps with one macro cell of zero
  padding per side and the (T, B, C) lead transposed to (T, C, B) —
  truncation included (the shared ``ranked_keep`` machinery);
* ``fused_handoff_from_banks`` (the streamed builder) is bit-exact vs
  ``build_fused_handoff`` over the binned frames of the same banks — the
  streaming-equivalence theorem extended to the fused carrier;
* the threshold unit's fused emission (``threshold_pool`` with
  ``emit_capacity``) returns, kernel and oracle alike, the exact masks
  ``build_fused_handoff`` would compact from its spike output;
* end to end, the ``"fused-handoff"`` variant is BIT-EXACT vs the
  ``banked-jax`` path — logits and full carry — across dtypes
  (float32/int16/int8) x window k in {1, 3, 5} x {batched, chunked,
  streamed} (the ISSUE 10 acceptance matrix).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aeq import (StreamState, build_bank_masks,
                            build_fused_handoff, fused_handoff_from_banks)
from repro.core.csnn import (CSNNConfig, ConvSpec, FCSpec, init_params,
                             init_state, snn_apply_batched, snn_readout,
                             snn_step_chunk)
from repro.core.geometry import ConvGeometry
from repro.core.plan import plan_network
from repro.kernels.threshold_pool.ops import threshold_pool

jax.config.update("jax_platform_name", "cpu")


def _cfg(k):
    return CSNNConfig(input_hw=(12, 12),
                      layers=(ConvSpec(4, kernel=k),
                              ConvSpec(4, kernel=k, pool=3), FCSpec(3)),
                      t_steps=4)


def _params(cfg, sat_bits, seed=0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    if sat_bits is None:
        return params
    dtype = {8: jnp.int8, 16: jnp.int16}[sat_bits]
    return jax.tree.map(
        lambda x: jnp.clip(jnp.round(x * 16), -100, 100).astype(dtype),
        params)


def _spikes(cfg, batch=2, density=0.3, seed=3):
    rng = np.random.default_rng(seed)
    h, w = cfg.input_hw
    return jnp.asarray(
        (rng.random((batch, cfg.t_steps, h, w, cfg.input_channels))
         < density).astype(np.float32))


def _random_banks(rng, lead, k, h, w, density):
    """Random ingestion banks respecting the stream invariant: bank cells
    past the field edge (i >= h or j >= w — unreachable by
    ``append_events``) are never occupied.  When k does not divide h/w,
    unmasked random data would plant phantom events there."""
    hb, wb = -(-h // k), -(-w // k)
    banks = rng.random((*lead, k * k, hb, wb)) < density
    for s in range(k * k):
        si, sj = divmod(s, k)
        banks[..., s, -(-(h - si) // k):, :] = False
        banks[..., s, :, -(-(w - sj) // k):] = False
    return jnp.asarray(banks)


# ------------------------------------------------------- carrier identity
class TestCarrierIdentity:
    @pytest.mark.parametrize("k", [1, 3, 5])
    @pytest.mark.parametrize("cap", [16, 11 * 13])  # truncating / covering
    def test_equals_padded_bank_masks(self, k, cap):
        geom = ConvGeometry(k, k)
        rng = np.random.default_rng(k * 100 + cap)
        spikes = jnp.asarray(rng.random((2, 3, 11, 13, 2)) < 0.4)
        ho = build_fused_handoff(spikes, cap, geom)
        # same fmaps through the banked consumer's reference compaction
        bm = build_bank_masks(jnp.transpose(spikes, (1, 4, 0, 2, 3)),
                              cap, geom)
        want = np.pad(np.asarray(bm.masks),
                      [(0, 0)] * 4 + [(1, 1), (1, 1)])
        np.testing.assert_array_equal(np.asarray(ho.masks), want)
        np.testing.assert_array_equal(
            np.asarray(ho.count), np.swapaxes(np.asarray(bm.count), 1, 2))

    @pytest.mark.parametrize("k", [1, 3, 5])
    def test_streamed_builder_matches_binned(self, k):
        """fused_handoff_from_banks over ingestion banks == binning the
        same occupancy to frames and building the carrier from those."""
        geom = ConvGeometry(k, k)
        h, w = 12, 12
        rng = np.random.default_rng(k)
        banks = _random_banks(rng, (2, 3, 2), k, h, w, 0.2)
        ho_s = fused_handoff_from_banks(banks, 40, (h, w), geom)
        # deinterlace the banks back to dense (B, T, H, W, C) frames
        b, t, c, nb, hb, wb = banks.shape
        frames = np.zeros((b, t, h, w, c), bool)
        bk = np.asarray(banks)
        for s in range(nb):
            si, sj = divmod(s, k)
            frames[:, :, si::k, sj::k, :] = np.moveaxis(
                bk[:, :, :, s, : -(-(h - si) // k), : -(-(w - sj) // k)],
                2, -1)
        ho_b = build_fused_handoff(jnp.asarray(frames), 40, geom)
        np.testing.assert_array_equal(np.asarray(ho_s.masks),
                                      np.asarray(ho_b.masks))
        np.testing.assert_array_equal(np.asarray(ho_s.count),
                                      np.asarray(ho_b.count))

    def test_streamed_builder_rejects_mismatched_banks(self):
        banks = jnp.zeros((1, 2, 1, 9, 4, 4), bool)
        with pytest.raises(ValueError, match="columns"):
            fused_handoff_from_banks(banks, 16, (12, 12),
                                     ConvGeometry(5, 5))
        with pytest.raises(ValueError, match="do not match"):
            fused_handoff_from_banks(banks, 16, (20, 20))


# ----------------------------------------------------- threshold emission
class TestFusedEmission:
    @pytest.mark.parametrize("k", [1, 3, 5])
    @pytest.mark.parametrize("pool", [None, 3])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int16])
    def test_kernel_oracle_and_builder_agree(self, k, pool, dtype):
        geom = ConvGeometry(k, k)
        h, w, c = 10, 11, 4
        rng = np.random.default_rng(hash((k, pool, str(dtype))) % 2**32)
        if dtype == jnp.float32:
            vm = jnp.asarray(rng.normal(size=(h, w, c)).astype(np.float32))
            bias = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
            v_t = 0.5
        else:
            vm = jnp.asarray(rng.integers(-100, 100, (h, w, c)), dtype)
            bias = jnp.asarray(rng.integers(-10, 10, (c,)), dtype)
            v_t = 20
        fired = jnp.asarray(rng.random((h, w, c)) < 0.1)
        cap = (h * w) // 2  # keeps the rank-truncation path live
        outs_k = threshold_pool(vm, bias, fired, v_t=v_t, pool=pool,
                                block_c=c, use_kernel=True,
                                emit_capacity=cap, emit_geometry=geom)
        outs_r = threshold_pool(vm, bias, fired, v_t=v_t, pool=pool,
                                use_kernel=False,
                                emit_capacity=cap, emit_geometry=geom)
        assert len(outs_k) == len(outs_r) == 5
        for a, b in zip(outs_k, outs_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the emitted masks ARE the carrier the consumer expects
        ho = build_fused_handoff(outs_r[2][None, None], cap, geom)
        np.testing.assert_array_equal(
            np.asarray(outs_r[3]),
            np.moveaxis(np.asarray(ho.masks[0, :, 0]), 0, -1))

    def test_emission_off_keeps_three_outputs(self):
        vm = jnp.zeros((6, 6, 2))
        outs = threshold_pool(vm, jnp.zeros((2,)), jnp.zeros((6, 6, 2),
                                                             bool), v_t=1.0)
        assert len(outs) == 3


# -------------------------- end to end: fused == banked, the full matrix
class TestFusedPipelineBitExact:
    """The ISSUE 10 acceptance matrix: fused-handoff vs banked-jax,
    dtypes x k x {batched, chunked, streamed}, logits AND carry."""

    @pytest.mark.parametrize("k", [1, 3, 5])
    @pytest.mark.parametrize("sat_bits", [None, 16, 8])
    def test_batched_and_chunked(self, k, sat_bits):
        cfg = _cfg(k)
        params = _params(cfg, sat_bits)
        sp = _spikes(cfg)
        n = len(cfg.layers) - 1
        kw = dict(capacity=64, channel_block=4, batch_tile=2,
                  sat_bits=sat_bits)
        banked = plan_network(cfg, **kw, variant="banked-jax", event_par=4)
        fused = plan_network(cfg, **kw, variant=["fused-handoff"] * n)
        out_b = np.asarray(snn_apply_batched(params, sp, cfg, banked,
                                             collect_stats=False))
        out_f = np.asarray(snn_apply_batched(params, sp, cfg, fused,
                                             collect_stats=False))
        np.testing.assert_array_equal(out_f, out_b)
        # chunked: same knobs with t_chunk=2, stepping the carry
        banked_c = plan_network(cfg, **kw, t_chunk=2, variant="banked-jax",
                                event_par=4)
        fused_c = plan_network(cfg, **kw, t_chunk=2,
                               variant=["fused-handoff"] * n)
        states, logits = [], []
        for plan in (banked_c, fused_c):
            state = init_state(params, cfg, plan, sp.shape[0])
            for t0 in range(0, cfg.t_steps, 2):
                state = snn_step_chunk(params, state, sp[:, t0:t0 + 2],
                                       cfg, plan)
            states.append(state)
            logits.append(np.asarray(snn_readout(params, state, cfg)))
        np.testing.assert_array_equal(logits[1], logits[0])
        for a, b in zip(jax.tree_util.tree_leaves(states[1]),
                        jax.tree_util.tree_leaves(states[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("k", [1, 3, 5])
    @pytest.mark.parametrize("sat_bits", [None, 16, 8])
    def test_streamed(self, k, sat_bits):
        """StreamState ingestion: the fused layer builds its carrier
        straight from the interlace banks — no dense frame view at all —
        and must still match the banked streamed step bit for bit."""
        cfg = _cfg(k)
        params = _params(cfg, sat_bits)
        h, w = cfg.input_hw
        rng = np.random.default_rng(17 + k)
        banks = _random_banks(rng, (2, cfg.t_steps, cfg.input_channels),
                              k, h, w, 0.15)
        n = len(cfg.layers) - 1
        kw = dict(capacity=64, channel_block=4, batch_tile=2,
                  sat_bits=sat_bits, ingest=True, t_chunk=2)
        banked = plan_network(cfg, **kw, variant="banked-jax", event_par=4)
        fused = plan_network(cfg, **kw, variant=["fused-handoff"] * n)
        states, logits = [], []
        for plan in (banked, fused):
            state = init_state(params, cfg, plan, banks.shape[0])
            for t0 in range(0, cfg.t_steps, 2):
                sp = StreamState(banks=banks[:, t0:t0 + 2])
                state = snn_step_chunk(params, state, sp, cfg, plan)
            states.append(state)
            logits.append(np.asarray(snn_readout(params, state, cfg)))
        np.testing.assert_array_equal(logits[1], logits[0])
        for a, b in zip(jax.tree_util.tree_leaves(states[1]),
                        jax.tree_util.tree_leaves(states[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fused_never_auto_selected(self):
        """resolve_variant must not pick fused-handoff on its own — it
        changes the inter-layer dataflow, so only a pin enables it."""
        cfg = _cfg(3)
        for ep in (1, 4, None):
            plan = plan_network(cfg, capacity=64, channel_block=4,
                                event_par=ep)
            for lp in plan.layers:
                assert lp.resolve_variant("jax") != "fused-handoff"

    def test_stream_finalize_default_resolves_by_fmap_size(self):
        cfg = _cfg(3)  # 12x12 = 144 <= 256 -> "sort"
        plan = plan_network(cfg, capacity=64, ingest=True)
        assert plan.layers[0].resolve_stream_finalize() == "sort"
        big = CSNNConfig()  # paper 28x28 = 784 -> "ranks"
        bplan = plan_network(big, capacity=256, ingest=True)
        assert bplan.layers[0].resolve_stream_finalize() == "ranks"
        pinned = plan_network(cfg, capacity=64, ingest=True,
                              stream_finalize="ranks")
        assert pinned.layers[0].resolve_stream_finalize() == "ranks"
