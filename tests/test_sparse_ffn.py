"""Beyond-paper sparse FFN: exact-match property + capacity scaling."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aeq import calibrate_capacity
from repro.core.sparse_ffn import (active_counts, dense_relu_ffn, event_ffn,
                                   event_ffn_flops, sparse_ffn_specs)
from repro.models.common import init_tree

jax.config.update("jax_platform_name", "cpu")


def _params(seed=0, d=32, f=128):
    return init_tree(jax.random.PRNGKey(seed), sparse_ffn_specs(d, f))


class TestSparseFFN:
    @given(st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_exact_when_capacity_covers_active(self, seed):
        """The paper's bit-exactness property transferred: a queue deep
        enough for every event reproduces the dense computation."""
        p = _params()
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(seed), (4, 32))
        counts = active_counts(p, x)
        cap = int(counts.max())
        got = event_ffn(p, x, capacity=max(cap, 1))
        want = dense_relu_ffn(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_truncation_degrades_gracefully(self):
        """Under-capacity keeps the largest-magnitude events (top-k AEQ)."""
        p = _params(1)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        want = dense_relu_ffn(p, x)
        errs = []
        for cap in (4, 16, 64, 128):
            got = event_ffn(p, x, capacity=cap)
            errs.append(float(jnp.linalg.norm(got - want)))
        assert errs == sorted(errs, reverse=True)  # error falls with capacity
        assert errs[-1] < 1e-4

    def test_capacity_calibration_pipeline(self):
        """aeq.calibrate_capacity works unchanged on FFN event counts."""
        p = _params(2)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (256, 32))
        counts = np.asarray(active_counts(p, x))
        cap = calibrate_capacity(counts, percentile=99.9, margin=1.1, align=8)
        assert cap >= np.percentile(counts, 99)
        got = event_ffn(p, x, capacity=min(cap, 128))
        want = dense_relu_ffn(p, x)
        # 99.9th-percentile capacity -> near-exact output
        denom = float(jnp.linalg.norm(want))
        assert float(jnp.linalg.norm(got - want)) / denom < 0.02

    def test_flops_napkin(self):
        dense, event = event_ffn_flops(4096, 16384, capacity=1600)
        assert event < 0.6 * dense  # ~90% sparsity -> ~2x fewer FLOPs
