"""Beyond-paper sparse FFN: exact-match property + capacity scaling,
plus the event-driven FC readout head (``plan.fc_capacity``) wired into
the CSNN pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aeq import calibrate_capacity
from repro.core.sparse_ffn import (active_counts, dense_relu_ffn,
                                   drive_active_counts, event_ffn,
                                   event_ffn_flops, event_readout,
                                   sparse_ffn_specs)
from repro.models.common import init_tree

jax.config.update("jax_platform_name", "cpu")


def _params(seed=0, d=32, f=128):
    return init_tree(jax.random.PRNGKey(seed), sparse_ffn_specs(d, f))


class TestSparseFFN:
    @given(st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_exact_when_capacity_covers_active(self, seed):
        """The paper's bit-exactness property transferred: a queue deep
        enough for every event reproduces the dense computation."""
        p = _params()
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(seed), (4, 32))
        counts = active_counts(p, x)
        cap = int(counts.max())
        got = event_ffn(p, x, capacity=max(cap, 1))
        want = dense_relu_ffn(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_truncation_degrades_gracefully(self):
        """Under-capacity keeps the largest-magnitude events (top-k AEQ)."""
        p = _params(1)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        want = dense_relu_ffn(p, x)
        errs = []
        for cap in (4, 16, 64, 128):
            got = event_ffn(p, x, capacity=cap)
            errs.append(float(jnp.linalg.norm(got - want)))
        assert errs == sorted(errs, reverse=True)  # error falls with capacity
        assert errs[-1] < 1e-4

    def test_capacity_calibration_pipeline(self):
        """aeq.calibrate_capacity works unchanged on FFN event counts."""
        p = _params(2)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (256, 32))
        counts = np.asarray(active_counts(p, x))
        cap = calibrate_capacity(counts, percentile=99.9, margin=1.1, align=8)
        assert cap >= np.percentile(counts, 99)
        got = event_ffn(p, x, capacity=min(cap, 128))
        want = dense_relu_ffn(p, x)
        # 99.9th-percentile capacity -> near-exact output
        denom = float(jnp.linalg.norm(want))
        assert float(jnp.linalg.norm(got - want)) / denom < 0.02

    def test_flops_napkin(self):
        dense, event = event_ffn_flops(4096, 16384, capacity=1600)
        assert event < 0.6 * dense  # ~90% sparsity -> ~2x fewer FLOPs


class TestEventReadoutHead:
    @given(st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_exact_at_nnz_capacity(self, seed):
        """Scatter-back compaction is the identity on the drive when the
        queue covers every active element, so the readout matmul is
        bit-exact vs dense — not merely close."""
        key = jax.random.PRNGKey(seed)
        drive = jnp.maximum(
            jax.random.normal(key, (4, 64)), 0.0)  # spike drives are >= 0
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 10))
        cap = max(int(drive_active_counts(drive).max()), 1)
        got = event_readout(drive, w, capacity=cap)
        want = drive @ w
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fc_head_differential_on_paper_net(self):
        """The FC readout drive routed through the event-driven sparse
        head (``plan.fc_capacity``) reproduces the dense head bit for
        bit on the paper net when the queue covers the whole drive."""
        from repro.core.csnn import (CSNNConfig, encode_input, init_params,
                                     snn_apply_batched)
        from repro.core.plan import plan_network

        cfg = CSNNConfig()  # the paper's 28x28-32C3-32C3-P3-10C3-F10 net
        params = init_params(jax.random.PRNGKey(0), cfg)
        x = (jax.random.uniform(
            jax.random.PRNGKey(1),
            (2, *cfg.input_hw, cfg.input_channels)) < 0.3).astype(jnp.float32)
        spikes = encode_input(x, cfg)
        dense_plan = plan_network(cfg, capacity=256, channel_block=8)
        last = dense_plan.layers[-1]
        d = last.out_hw[0] * last.out_hw[1] * last.c_out
        sparse_plan = plan_network(cfg, capacity=256, channel_block=8,
                                   fc_capacity=d)
        assert sparse_plan.fc_capacity == d
        want = snn_apply_batched(params, spikes, cfg, dense_plan,
                                 collect_stats=False)
        got = snn_apply_batched(params, spikes, cfg, sparse_plan,
                                collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
