"""Memory-interlaced event-parallel convolution (ISSUE 5).

Pins the interlace layout contracts promised by core/aeq.py and the
bit-exactness of every event-parallel variant vs the sequential conv
unit:

* AEQ column segments: each segment is contiguous and exhaustive, every
  event in segment s has s = 3(i%3)+(j%3), and any two events of one
  segment have non-overlapping 3x3 neighbourhoods (the hazard-freedom
  invariant the parallel kernels rely on) — property-tested.
* ``segment_pad``: event_par-aligned groups are column-homogeneous and
  replaying the padded queue sequentially is a no-op.
* ``build_bank_masks``: the sort-free banked compaction keeps exactly the
  queue's kept events (capacity truncation included).
* banked jax path and ``event_conv_pallas_interlaced{,_batched}``:
  bit-exact vs the sequential kernels for float32/int16/int8 across
  event_par widths, single and batched.
* plan: ``event_par`` autotuned/snapped alongside ``block_e``; the full
  pipeline with an event_par plan reproduces the sequential plan's
  logits bit for bit (monolithic and chunked).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aeq import (build_aeq, build_aeq_batched, build_bank_masks,
                            interlace, interlaced_capacity, scatter_aeq,
                            segment_pad)
from repro.core.csnn import (CSNNConfig, ConvSpec, FCSpec, encode_input,
                             init_params, init_state, snn_apply_batched,
                             snn_readout, snn_step_chunk)
from repro.core.event_conv import (apply_events, apply_events_banked,
                                   apply_events_banked_batched,
                                   apply_events_batched, pad_vm)
from repro.core.plan import plan_network
from repro.kernels.event_conv import ops
from repro.kernels.event_conv.kernel import (
    event_conv_pallas, event_conv_pallas_batched,
    event_conv_pallas_interlaced, event_conv_pallas_interlaced_batched)
from repro.kernels.runtime import INTERPRET_ENV, resolve_interpret

jax.config.update("jax_platform_name", "cpu")

SMOKE = CSNNConfig(input_hw=(10, 10),
                   layers=(ConvSpec(4), ConvSpec(4, pool=3), FCSpec(3)),
                   t_steps=4)


def _col(coords):
    return (coords[:, 0] % 3) * 3 + coords[:, 1] % 3


# ----------------------------------------------------------- column segments
class TestColumnSegments:
    @pytest.mark.slow
    @given(st.integers(3, 24), st.integers(3, 24), st.floats(0.05, 1.0),
           st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_segments_contiguous_exhaustive_and_hazard_free(
            self, h, w, density, seed):
        rng = np.random.default_rng(seed)
        fmap = jnp.asarray(rng.random((h, w)) < density)
        cap = max(1, (h * w) * 2 // 3)  # exercise truncation too
        q = build_aeq(fmap, cap)
        coords = np.asarray(q.coords)
        valid = np.asarray(q.valid)
        so, sc = np.asarray(q.seg_offsets), np.asarray(q.seg_counts)
        # exhaustive + contiguous: segments tile the valid prefix exactly
        assert sc.sum() == valid.sum()
        assert (so == np.concatenate([[0], np.cumsum(sc)[:-1]])).all()
        for s in range(9):
            seg = coords[so[s]:so[s] + sc[s]]
            assert valid[so[s]:so[s] + sc[s]].all()
            assert (_col(seg) == s).all()
            # hazard freedom: same-column events never overlap 3x3 windows
            for a in range(len(seg)):
                for b in range(a + 1, len(seg)):
                    di = abs(int(seg[a, 0]) - int(seg[b, 0]))
                    dj = abs(int(seg[a, 1]) - int(seg[b, 1]))
                    assert di > 2 or dj > 2, (seg[a], seg[b])

    def test_batched_segments_match_single(self):
        rng = np.random.default_rng(7)
        fmaps = jnp.asarray(rng.random((6, 11, 9)) < 0.4)
        bq = build_aeq_batched(fmaps, 50)
        for n in range(6):
            q = build_aeq(fmaps[n], 50)
            np.testing.assert_array_equal(np.asarray(bq.seg_offsets[n]),
                                          np.asarray(q.seg_offsets))
            np.testing.assert_array_equal(np.asarray(bq.seg_counts[n]),
                                          np.asarray(q.seg_counts))

    def test_raster_queue_has_no_segments(self):
        q = build_aeq(jnp.ones((5, 5), bool), 25, interlaced=False)
        assert q.seg_offsets is None and q.seg_counts is None


# ---------------------------------------------------------------- segment_pad
class TestSegmentPad:
    @pytest.mark.slow
    @given(st.integers(3, 20), st.integers(3, 20), st.floats(0.1, 1.0),
           st.sampled_from([2, 4, 8]), st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_groups_homogeneous_order_preserved(self, h, w, density, par, seed):
        rng = np.random.default_rng(seed)
        fmap = jnp.asarray(rng.random((h, w)) < density)
        q = build_aeq(fmap, h * w)
        qp = segment_pad(q, par)
        assert qp.capacity == interlaced_capacity(q.capacity, par)
        coords = np.asarray(q.coords)[np.asarray(q.valid)]
        pc, pv = np.asarray(qp.coords), np.asarray(qp.valid)
        np.testing.assert_array_equal(pc[pv], coords)  # order preserved
        for g in range(qp.capacity // par):
            grp = pc[g * par:(g + 1) * par][pv[g * par:(g + 1) * par]]
            if len(grp):
                assert (_col(grp) == _col(grp[:1])).all()

    def test_sequential_replay_of_padded_queue_is_exact(self):
        rng = np.random.default_rng(3)
        fmap = jnp.asarray(rng.random((9, 9)) < 0.6)
        kernel = jnp.asarray(rng.normal(size=(3, 3, 2)).astype(np.float32))
        vm = pad_vm(jnp.zeros((9, 9, 2), jnp.float32))
        q = build_aeq(fmap, 81)
        qp = segment_pad(q, 4)
        a = apply_events(vm, q, kernel)
        b = apply_events(vm, qp, kernel)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_requires_interlaced_queue(self):
        q = build_aeq(jnp.ones((4, 4), bool), 16, interlaced=False)
        with pytest.raises(ValueError, match="interlaced queue"):
            segment_pad(q, 4)


# ------------------------------------------------------------------ bank masks
class TestBankMasks:
    @pytest.mark.slow
    @given(st.integers(3, 20), st.integers(3, 20), st.floats(0.0, 1.0),
           st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_masks_equal_banked_queue_scatter(self, h, w, density, seed):
        """Sort-free banked compaction keeps exactly the queue's events —
        including which events a full queue drops."""
        rng = np.random.default_rng(seed)
        fmap = jnp.asarray(rng.random((h, w)) < density)
        cap = max(1, (h * w) // 2)
        q = build_aeq(fmap, cap)
        want = interlace(jnp.pad(scatter_aeq(q, (h, w)), ((1, 1), (1, 1))))
        got = build_bank_masks(fmap[None], cap)
        np.testing.assert_array_equal(np.asarray(got.masks[0]),
                                      np.asarray(want))
        assert int(got.count[0]) == int(q.count)
        np.testing.assert_array_equal(np.asarray(got.seg_counts[0]),
                                      np.asarray(q.seg_counts))


# ------------------------------------------------- banked jax path exactness
def _int_gen(rng, lo, hi):
    return lambda size: rng.integers(lo, hi, size)


class TestBankedApplyBitExact:
    @pytest.mark.parametrize("dtype,gen", [
        ("float32", None), ("int16", (-20000, 20000)), ("int8", (-90, 91))])
    def test_single_queue_all_dtypes(self, dtype, gen):
        rng = np.random.default_rng(11)
        dt = jnp.dtype(dtype)
        for (h, w, density, cap, c) in [(12, 12, 0.4, 64, 4), (9, 7, 1.0, 63, 2),
                                        (28, 28, 0.15, 128, 8), (5, 5, 0.9, 8, 3)]:
            fmap = jnp.asarray(rng.random((h, w)) < density)
            if gen is None:
                kernel = jnp.asarray(rng.normal(size=(3, 3, c)).astype(np.float32))
                vm = pad_vm(jnp.asarray(
                    rng.normal(size=(h, w, c)).astype(np.float32)))
            else:
                kernel = jnp.asarray(rng.integers(*gen, (3, 3, c)), dt)
                vm = pad_vm(jnp.asarray(rng.integers(*gen, (h, w, c)), dt))
            q = build_aeq(fmap, cap)
            masks = build_bank_masks(fmap[None], cap).masks[0]
            a = apply_events(vm, q, kernel)
            b = apply_events_banked(vm, masks, kernel)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batched_queues(self):
        rng = np.random.default_rng(12)
        b, h, w, c, cap = 5, 10, 13, 4, 60
        fmaps = jnp.asarray(rng.random((b, h, w)) < 0.5)
        kernel = jnp.asarray(rng.normal(size=(3, 3, c)).astype(np.float32))
        vm = jax.vmap(pad_vm)(jnp.asarray(
            rng.normal(size=(b, h, w, c)).astype(np.float32)))
        q = build_aeq_batched(fmaps, cap)
        a = apply_events_batched(vm, q.coords, q.valid, q.count, kernel)
        masks = build_bank_masks(fmaps, cap).masks
        out = apply_events_banked_batched(vm, masks, kernel)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(out))

    def test_int8_saturation_order(self):
        """Per-event saturation semantics survive the banked path."""
        fmap = jnp.ones((6, 6), bool)
        kernel = jnp.full((3, 3, 1), 100, jnp.int8)  # saturates after 2 events
        q = build_aeq(fmap, 36)
        vm = pad_vm(jnp.zeros((6, 6, 1), jnp.int8))
        a = apply_events(vm, q, kernel)
        masks = build_bank_masks(fmap[None], 36).masks[0]
        b = apply_events_banked(vm, masks, kernel)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(np.asarray(b).max()) == 127


# -------------------------------------------- pallas interlaced kernels exact
class TestPallasInterlacedBitExact:
    @pytest.mark.parametrize("dtype,lohi", [
        ("float32", None), ("int16", (-20000, 20000)), ("int8", (-90, 91))])
    @pytest.mark.parametrize("event_par", [2, 4, 8])
    def test_single_vs_sequential(self, dtype, lohi, event_par):
        rng = np.random.default_rng(event_par)
        dt = jnp.dtype(dtype)
        h, w, c, cap = 12, 11, 4, 64
        fmap = jnp.asarray(rng.random((h, w)) < 0.5)
        if lohi is None:
            kernel = jnp.asarray(rng.normal(size=(3, 3, c)).astype(np.float32))
            vm = pad_vm(jnp.asarray(rng.normal(size=(h, w, c)).astype(np.float32)))
        else:
            kernel = jnp.asarray(rng.integers(*lohi, (3, 3, c)), dt)
            vm = pad_vm(jnp.asarray(rng.integers(*lohi, (h, w, c)), dt))
        qp = segment_pad(build_aeq(fmap, cap), event_par)
        a = event_conv_pallas(vm, qp.coords, qp.valid, kernel,
                              block_e=qp.capacity)
        b = event_conv_pallas_interlaced(vm, qp.coords, qp.valid, kernel,
                                         block_e=qp.capacity,
                                         event_par=event_par)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("event_par", [2, 4])
    def test_column_boundary_fallback_on_unpadded_queue(self, event_par):
        """Groups straddling column boundaries take the sequential body
        and stay exact (the raw, non-segment-padded layout)."""
        rng = np.random.default_rng(5)
        h, w, c = 9, 9, 2
        fmap = jnp.asarray(rng.random((h, w)) < 0.9)
        kernel = jnp.asarray(rng.normal(size=(3, 3, c)).astype(np.float32))
        vm = pad_vm(jnp.zeros((h, w, c), jnp.float32))
        q = build_aeq(fmap, 80)
        pad = -q.capacity % event_par
        coords = jnp.pad(q.coords, ((0, pad), (0, 0)))
        valid = jnp.pad(q.valid, (0, pad))
        a = event_conv_pallas(vm, coords, valid, kernel,
                              block_e=coords.shape[0])
        b = event_conv_pallas_interlaced(vm, coords, valid, kernel,
                                         block_e=coords.shape[0],
                                         event_par=event_par)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batched_vs_sequential(self):
        rng = np.random.default_rng(9)
        b, h, w, c, cap, par = 3, 10, 11, 4, 48, 4
        fmaps = jnp.asarray(rng.random((b, h, w)) < 0.5)
        kernel = jnp.asarray(rng.normal(size=(3, 3, c)).astype(np.float32))
        vm = jax.vmap(pad_vm)(jnp.asarray(
            rng.normal(size=(b, h, w, c)).astype(np.float32)))
        qp = segment_pad(build_aeq_batched(fmaps, cap), par)
        a = event_conv_pallas_batched(vm, qp.coords, qp.valid, kernel,
                                      block_e=qp.capacity)
        out = event_conv_pallas_interlaced_batched(
            vm, qp.coords, qp.valid, kernel, block_e=qp.capacity,
            event_par=par)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(out))

    def test_ops_wrapper_dispatch_matches_sequential(self):
        rng = np.random.default_rng(2)
        h, w, c = 10, 11, 4
        fmap = jnp.asarray(rng.random((h, w)) < 0.5)
        kernel = jnp.asarray(rng.normal(size=(3, 3, c)).astype(np.float32))
        vm = jnp.zeros((h, w, c), jnp.float32)
        q = build_aeq(fmap, 48)
        a = ops.event_conv(vm, q, kernel, block_e=None)
        b = ops.event_conv(vm, q, kernel, block_e=None, event_par=4)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- ops validation
class TestOpsValidation:
    def test_block_e_not_multiple_of_event_par(self):
        q = build_aeq(jnp.ones((6, 6), bool), 36)
        vm = jnp.zeros((6, 6, 2), jnp.float32)
        k = jnp.zeros((3, 3, 2), jnp.float32)
        with pytest.raises(ValueError, match="multiple of event_par"):
            ops.event_conv(vm, q, k, block_e=6, event_par=4)

    def test_mismatched_valid_shape(self):
        q = build_aeq(jnp.ones((6, 6), bool), 36)
        bad = q._replace(valid=q.valid[:-1])
        vm = jnp.zeros((6, 6, 2), jnp.float32)
        k = jnp.zeros((3, 3, 2), jnp.float32)
        with pytest.raises(ValueError, match="does not match event coords"):
            ops.event_conv(vm, bad, k)

    def test_batched_queue_count_mismatch(self):
        q = build_aeq_batched(jnp.ones((3, 6, 6), bool), 36)
        vm = jnp.zeros((2, 6, 6, 2), jnp.float32)
        k = jnp.zeros((3, 3, 2), jnp.float32)
        with pytest.raises(ValueError, match="queue count mismatch"):
            ops.event_conv_batched(vm, q, k)

    def test_raw_kernel_error_mentions_ops_wrappers(self):
        vm = jnp.zeros((8, 8, 2), jnp.float32)
        k = jnp.zeros((3, 3, 2), jnp.float32)
        coords = jnp.zeros((30, 2), jnp.int32)
        valid = jnp.zeros((30,), bool)
        with pytest.raises(ValueError, match="ops.py wrappers"):
            event_conv_pallas(vm, coords, valid, k, block_e=64)


# ----------------------------------------------------------- plan integration
class TestPlanEventPar:
    def test_autotune_records_event_par_and_snaps_block_e(self):
        plan = plan_network(CSNNConfig(), capacity=256, channel_block=8,
                            event_par=None)
        for lp in plan.layers:
            assert lp.event_par >= 1
            assert lp.event_par & (lp.event_par - 1) == 0  # power of two
            if lp.event_par > 1:
                assert lp.block_e % lp.event_par == 0
                assert lp.queue_depth % lp.block_e == 0
                assert lp.queue_depth == interlaced_capacity(lp.capacity,
                                                             lp.event_par)
            else:
                assert lp.queue_depth == lp.capacity
        # the paper net's 28x28 layers are deep enough for full width
        assert plan.layers[0].event_par == 8

    def test_default_plans_stay_sequential(self):
        plan = plan_network(CSNNConfig(), capacity=256)
        assert all(lp.event_par == 1 for lp in plan.layers)

    def test_per_layer_event_par_sequence(self):
        plan = plan_network(SMOKE, capacity=64, event_par=[4, 1])
        assert [lp.event_par for lp in plan.layers] == [4, 1]

    def test_shallow_queue_autotunes_to_sequential(self):
        plan = plan_network(SMOKE, capacity=8, event_par=None)
        assert all(lp.event_par == 1 for lp in plan.layers)


class TestPipelineBitExact:
    @pytest.mark.parametrize("sat_bits", [None, 8, 16])
    def test_event_par_plan_matches_sequential_plan(self, sat_bits):
        rng = np.random.default_rng(0)
        params = init_params(jax.random.PRNGKey(0), SMOKE)
        sp = encode_input(jnp.asarray(
            rng.random((3, 10, 10, 1)), jnp.float32), SMOKE)
        seq = plan_network(SMOKE, capacity=100, sat_bits=sat_bits)
        par = plan_network(SMOKE, capacity=100, sat_bits=sat_bits,
                           event_par=4)
        a, sa = snn_apply_batched(params, sp, SMOKE, seq)
        b, sb = snn_apply_batched(params, sp, SMOKE, par)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for la, lb in zip(sa, sb):
            np.testing.assert_array_equal(np.asarray(la.in_spike_counts),
                                          np.asarray(lb.in_spike_counts))
        assert int(sb[0].event_par) == 4
        assert int(sa[0].event_par) == 1

    def test_chunked_event_par_matches_monolithic(self):
        rng = np.random.default_rng(1)
        params = init_params(jax.random.PRNGKey(1), SMOKE)
        sp = encode_input(jnp.asarray(
            rng.random((2, 10, 10, 1)), jnp.float32), SMOKE)
        plan = plan_network(SMOKE, capacity=100, event_par=4, t_chunk=2)
        whole = plan_network(SMOKE, capacity=100, event_par=4)
        a = snn_apply_batched(params, sp, SMOKE, whole, collect_stats=False)
        state = init_state(params, SMOKE, plan, 2)
        for k in range(0, SMOKE.t_steps, plan.chunk_steps):
            state = snn_step_chunk(params, state,
                                   sp[:, k:k + plan.chunk_steps], SMOKE, plan)
        b = snn_readout(params, state, SMOKE)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_vmap_single_sample_matches_batched(self):
        rng = np.random.default_rng(2)
        params = init_params(jax.random.PRNGKey(2), SMOKE)
        sp = encode_input(jnp.asarray(
            rng.random((3, 10, 10, 1)), jnp.float32), SMOKE)
        plan = plan_network(SMOKE, capacity=100, event_par=4)
        from repro.core.csnn import snn_apply
        a = jax.vmap(lambda s: snn_apply(params, s, SMOKE, plan,
                                         collect_stats=False))(sp)
        b = snn_apply_batched(params, sp, SMOKE, plan, collect_stats=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ interpret switch
class TestInterpretSwitch:
    def test_explicit_argument_wins(self, monkeypatch):
        monkeypatch.setenv(INTERPRET_ENV, "0")
        assert resolve_interpret(True) is True
        monkeypatch.setenv(INTERPRET_ENV, "1")
        assert resolve_interpret(False) is False

    def test_env_var_controls_default(self, monkeypatch):
        monkeypatch.setenv(INTERPRET_ENV, "off")
        assert resolve_interpret() is False
        monkeypatch.setenv(INTERPRET_ENV, "on")
        assert resolve_interpret() is True

    def test_backend_default_on_cpu(self, monkeypatch):
        monkeypatch.delenv(INTERPRET_ENV, raising=False)
        assert resolve_interpret() is True  # suite is CPU-pinned

    def test_garbage_env_value_rejected(self, monkeypatch):
        monkeypatch.setenv(INTERPRET_ENV, "maybe")
        with pytest.raises(ValueError, match=INTERPRET_ENV):
            resolve_interpret()
