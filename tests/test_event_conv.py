"""Event-driven convolution properties (promised by core/event_conv.py).

Core paper claim (Sec. V-B, Fig. 4): walking the AEQ and adding the
rotated kernel around each event is *bit-exact* sliding-window
convolution.  Verified here for `apply_events` and the self-timed
`apply_events_blocked` across densities, dtypes (float32 and the
saturating int16/int8 datapaths) and odd shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aeq import build_aeq
from repro.core.event_conv import (apply_events, apply_events_blocked,
                                   crop_vm, dense_conv, pad_vm)

jax.config.update("jax_platform_name", "cpu")


def _spikes(rng, h, w, density):
    return jnp.asarray(rng.random((h, w)) < density)


class TestBitExactVsDense:
    @given(st.integers(3, 25), st.integers(3, 25), st.floats(0.0, 1.0),
           st.integers(0, 10_000))
    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    def test_float32_any_density(self, h, w, density, seed):
        rng = np.random.default_rng(seed)
        fmap = _spikes(rng, h, w, density)
        kernel = jnp.asarray(rng.normal(size=(3, 3, 4)).astype(np.float32))
        q = build_aeq(fmap, capacity=h * w)
        got = crop_vm(apply_events(pad_vm(jnp.zeros((h, w, 4), jnp.float32)), q, kernel))
        want = dense_conv(fmap, kernel)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype,kmax", [(jnp.int16, 20), (jnp.int8, 3)])
    @given(st.integers(3, 19), st.integers(3, 19), st.floats(0.0, 1.0),
           st.integers(0, 10_000))
    @pytest.mark.slow
    @settings(max_examples=15, deadline=None)
    def test_integer_datapaths(self, dtype, kmax, h, w, density, seed):
        """In the non-saturating regime int event conv == int dense conv.

        |tap| <= kmax bounds every accumulated output by 9*kmax, so the
        saturating per-event adds never clip and integer arithmetic is
        exact in both paths.
        """
        rng = np.random.default_rng(seed)
        fmap = _spikes(rng, h, w, density)
        kernel = jnp.asarray(rng.integers(-kmax, kmax + 1, size=(3, 3, 2)), dtype)
        q = build_aeq(fmap, capacity=h * w)
        got = crop_vm(apply_events(pad_vm(jnp.zeros((h, w, 2), dtype)), q, kernel))
        want = dense_conv(fmap, kernel.astype(jnp.int32)).astype(dtype)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("h,w", [(3, 3), (3, 29), (29, 3), (7, 13), (17, 5)])
    def test_odd_shapes_full_density(self, h, w):
        """All-ones fmaps on skewed shapes: every halo edge case at once."""
        rng = np.random.default_rng(h * 100 + w)
        fmap = jnp.ones((h, w), bool)
        kernel = jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))
        q = build_aeq(fmap, capacity=h * w)
        got = crop_vm(apply_events(pad_vm(jnp.zeros((h, w), jnp.float32)), q, kernel))
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense_conv(fmap, kernel)),
                                   rtol=1e-5, atol=1e-5)


class TestBlockedEarlyExit:
    @given(st.integers(4, 20), st.integers(4, 20), st.floats(0.0, 0.6),
           st.integers(1, 97), st.integers(0, 10_000))
    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    def test_blocked_equals_unblocked(self, h, w, density, block, seed):
        """Self-timed early exit is invisible in the results, any block size."""
        rng = np.random.default_rng(seed)
        fmap = _spikes(rng, h, w, density)
        kernel = jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))
        q = build_aeq(fmap, capacity=h * w)
        a = apply_events(pad_vm(jnp.zeros((h, w), jnp.float32)), q, kernel)
        b = apply_events_blocked(pad_vm(jnp.zeros((h, w), jnp.float32)), q, kernel,
                                 block=block)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_blocked_int8_saturation(self):
        """Early exit must not change per-event saturation semantics."""
        fmap = jnp.ones((6, 6), bool)
        kernel = jnp.full((3, 3), 100, jnp.int8)  # saturates after 2 events
        q = build_aeq(fmap, capacity=64)
        a = apply_events(pad_vm(jnp.zeros((6, 6), jnp.int8)), q, kernel)
        b = apply_events_blocked(pad_vm(jnp.zeros((6, 6), jnp.int8)), q, kernel,
                                 block=16)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(np.asarray(a).max()) == 127  # clamped, not wrapped


class TestSaturationSemantics:
    def test_per_event_saturation_is_order_dependent(self):
        """+100 then +100 then -100 with int8 PE adders ends at 27, the
        clip-at-the-end answer would be 100 — the FPGA semantics we keep."""
        fmap = jnp.zeros((5, 5), bool).at[2, 2].set(True)
        q = build_aeq(fmap, capacity=8)
        vm = pad_vm(jnp.zeros((5, 5), jnp.int8))
        k_pos = jnp.full((3, 3), 100, jnp.int8)
        k_neg = jnp.full((3, 3), -100, jnp.int8)
        out = apply_events(apply_events(apply_events(vm, q, k_pos), q, k_pos), q, k_neg)
        assert int(crop_vm(out)[2, 2]) == 27  # 127 - 100, not 100

    def test_int16_headroom(self):
        fmap = jnp.ones((4, 4), bool)
        kernel = jnp.full((3, 3), 30_000, jnp.int16)
        q = build_aeq(fmap, capacity=16)
        out = crop_vm(apply_events(pad_vm(jnp.zeros((4, 4), jnp.int16)), q, kernel))
        assert int(np.asarray(out).max()) == 32767
