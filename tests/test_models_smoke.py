"""Per-architecture smoke tests (reduced configs, CPU): forward/train step
shape + NaN checks, plus decode-vs-full-forward consistency (cache
correctness) and linear-attention chunked-vs-recurrent equivalence."""
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE_SHAPE
from repro.configs.base import ShapeConfig
from repro.models.registry import build_model

jax.config.update("jax_platform_name", "cpu")

ARCH_IDS = list(ARCHS.keys())


def _model_and_inputs(arch_id, seq=64, batch=2):
    mod = ARCHS[arch_id]
    model = build_model(mod.SMOKE)
    params = model.init_params(jax.random.PRNGKey(0))
    shape = ShapeConfig("smoke", seq, batch, "train")
    batch_data = model.make_inputs(jax.random.PRNGKey(1), shape)
    return model, params, batch_data


class TestSmokeForward:
    @pytest.mark.parametrize("arch_id", ARCH_IDS)
    def test_train_step_shapes_and_finite(self, arch_id):
        model, params, batch = _model_and_inputs(arch_id)
        loss, metrics = model.loss(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch_id}: loss={loss}"
        assert bool(jnp.isfinite(metrics["ce"]))

    @pytest.mark.parametrize("arch_id", ["stablelm-3b", "deepseek-v2-236b",
                                         "zamba2-1.2b", "rwkv6-1.6b"])
    def test_grads_finite(self, arch_id):
        model, params, batch = _model_and_inputs(arch_id, seq=32)
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch_id}: NaN grads"
        assert any(float(jnp.abs(g).max()) > 0 for g in flat)


class TestDecodeConsistency:
    """prefill(S-1 tokens) + decode(token S-1) must equal the full forward
    logits at the last position — exercises every cache variant."""

    @pytest.mark.parametrize("arch_id", ARCH_IDS)
    def test_decode_matches_forward(self, arch_id):
        if arch_id == "whisper-medium":
            pytest.skip("covered by test_whisper_decode below")
        mod = ARCHS[arch_id]
        model = build_model(mod.SMOKE)
        cfg = mod.SMOKE
        params = model.init_params(jax.random.PRNGKey(0))
        b, s = 2, 24
        rng = jax.random.PRNGKey(3)
        tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab, jnp.int32)
        extra = {}
        max_seq = s
        if cfg.family == "vlm":
            extra["vision_embeds"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(4), (b, cfg.n_vision_tokens, cfg.d_model))
            max_seq = s + cfg.n_vision_tokens  # cache holds vision + text
        # full-sequence logits at the last position, via prefill over S tokens
        full_logits, _ = model.prefill(params, {"tokens": tokens, **extra},
                                       max_seq=max_seq, cache_dtype=jnp.float32)
        # prefill S-1 then decode token S-1
        _, cache = model.prefill(params, {"tokens": tokens[:, : s - 1], **extra},
                                 max_seq=max_seq, cache_dtype=jnp.float32)
        pos = jnp.asarray(s - 1, jnp.int32)
        if cfg.family == "vlm":
            pos = jnp.asarray(cfg.n_vision_tokens + s - 1, jnp.int32)
        dec_logits, _ = model.decode(params, cache,
                                     {"tokens": tokens[:, s - 1:], "pos": pos})
        np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                                   rtol=2e-2, atol=2e-3)

    def test_whisper_decode(self):
        mod = ARCHS["whisper-medium"]
        model = build_model(mod.SMOKE)
        cfg = mod.SMOKE
        params = model.init_params(jax.random.PRNGKey(0))
        b, s = 2, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab, jnp.int32)
        frames = 0.02 * jax.random.normal(jax.random.PRNGKey(2),
                                          (b, cfg.enc_frames, cfg.d_model))
        full_logits, _ = model.prefill(params, {"tokens": tokens, "frames": frames},
                                       max_seq=s, cache_dtype=jnp.float32)
        _, cache = model.prefill(params, {"tokens": tokens[:, : s - 1], "frames": frames},
                                 max_seq=s, cache_dtype=jnp.float32)
        dec_logits, _ = model.decode(params, cache,
                                     {"tokens": tokens[:, s - 1:],
                                      "pos": jnp.asarray(s - 1, jnp.int32)})
        np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                                   rtol=2e-2, atol=2e-3)

    def test_multi_step_decode_sliding_window(self):
        """Ring-buffer correctness: decode several steps past the window."""
        mod = ARCHS["gemma3-1b"]
        model = build_model(mod.SMOKE)
        cfg = mod.SMOKE
        params = model.init_params(jax.random.PRNGKey(0))
        b, s = 1, 40  # window is 16 in the smoke config
        tokens = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab, jnp.int32)
        full_logits, _ = model.prefill(params, {"tokens": tokens}, max_seq=s,
                                       cache_dtype=jnp.float32)
        n_steps = 8
        _, cache = model.prefill(params, {"tokens": tokens[:, : s - n_steps]},
                                 max_seq=s, cache_dtype=jnp.float32)
        logits = None
        for i in range(s - n_steps, s):
            logits, cache = model.decode(
                params, cache, {"tokens": tokens[:, i: i + 1],
                                "pos": jnp.asarray(i, jnp.int32)})
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                                   rtol=2e-2, atol=2e-3)


class TestLinearAttention:
    @pytest.mark.parametrize("exclusive", [False, True])
    def test_chunked_matches_recurrent(self, exclusive):
        from repro.models.linear_attn import chunked, recurrent_reference
        rng = np.random.default_rng(0)
        b, s, h, dk, dv = 2, 50, 3, 8, 8  # s deliberately not chunk-aligned
        q = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, h, dv)).astype(np.float32))
        log_w = jnp.asarray(-rng.uniform(0.01, 0.5, size=(b, s, h, dk)).astype(np.float32))
        u = jnp.asarray(rng.normal(size=(h, dk)).astype(np.float32)) if exclusive else None
        got = chunked(q, k, v, log_w, chunk=16, exclusive=exclusive, u=u)
        want = recurrent_reference(q, k, v, log_w, exclusive=exclusive, u=u)
        np.testing.assert_allclose(np.asarray(got.out), np.asarray(want.out),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(got.state), np.asarray(want.state),
                                   rtol=1e-4, atol=1e-4)

    def test_state_carry_across_calls(self):
        from repro.models.linear_attn import chunked
        rng = np.random.default_rng(1)
        b, s, h, dk, dv = 1, 32, 2, 4, 4
        mk = lambda *sh: jnp.asarray(rng.normal(size=sh).astype(np.float32))
        q, k, v = mk(b, s, h, dk), mk(b, s, h, dk), mk(b, s, h, dv)
        log_w = -jnp.abs(mk(b, s, h, dk)) * 0.1
        whole = chunked(q, k, v, log_w, chunk=8)
        first = chunked(q[:, :16], k[:, :16], v[:, :16], log_w[:, :16], chunk=8)
        second = chunked(q[:, 16:], k[:, 16:], v[:, 16:], log_w[:, 16:], chunk=8,
                         state0=first.state)
        np.testing.assert_allclose(np.asarray(second.out), np.asarray(whole.out[:, 16:]),
                                   rtol=1e-5, atol=1e-5)


class TestParamCounts:
    """FULL configs must land near their nominal sizes (catches wiring bugs)."""

    NOMINAL: ClassVar[dict] = {
        "zamba2-1.2b": 1.2e9, "rwkv6-1.6b": 1.6e9, "stablelm-3b": 2.8e9,
        "granite-34b": 34e9, "phi3-medium-14b": 14e9, "gemma3-1b": 1.0e9,
        "qwen2-vl-7b": 7.6e9, "whisper-medium": 0.8e9,
        "llama4-maverick-400b-a17b": 400e9, "deepseek-v2-236b": 236e9,
    }

    @pytest.mark.parametrize("arch_id", ARCH_IDS)
    def test_param_count(self, arch_id):
        model = build_model(ARCHS[arch_id].FULL)
        n = model.n_params()
        nominal = self.NOMINAL[arch_id]
        assert 0.6 * nominal < n < 1.45 * nominal, (
            f"{arch_id}: {n/1e9:.2f}B params vs nominal {nominal/1e9:.0f}B")
