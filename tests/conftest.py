"""Test-session bootstrap: CPU backend pin + hypothesis fallback + timeout.

* Pins JAX to the CPU platform before any test module imports jax, so the
  suite behaves identically on TPU hosts, CI runners and laptops (all
  Pallas kernels run in interpret mode on CPU).
* If the real `hypothesis` package is unavailable (the container does not
  ship it and installs are not allowed), installs the deterministic
  fallback from ``_hypothesis_fallback.py`` under that name so the
  property tests still collect and run.  CI installs real hypothesis and
  takes priority automatically.
* A SIGALRM-based per-test timeout (pytest-timeout is not available in
  the container) so a hung test — e.g. an engine future that never
  resolves — fails fast instead of stalling the whole suite.  Override
  with PYTEST_TEST_TIMEOUT (seconds, 0 disables).
"""
from __future__ import annotations

import importlib.util
import os
import signal
import sys
from pathlib import Path

import pytest

os.environ.setdefault("JAX_PLATFORM_NAME", "cpu")

import jax

jax.config.update("jax_platform_name", "cpu")

_TEST_TIMEOUT_S = int(os.environ.get("PYTEST_TEST_TIMEOUT", "300"))


@pytest.fixture(autouse=True)
def _per_test_timeout(request):
    """Fail any single test that exceeds the timeout (hang guard).

    SIGALRM only fires on the main thread and only interrupts Python-level
    code, which is exactly the hang class we care about (stuck asyncio
    loops, deadlocked futures); it is a no-op on non-Linux/main-thread
    edge cases.
    """
    if (_TEST_TIMEOUT_S <= 0 or not hasattr(signal, "SIGALRM")
            or signal.getsignal(signal.SIGALRM) not in
            (signal.SIG_DFL, signal.SIG_IGN, None)):
        yield
        return

    def on_timeout(signum, frame):
        pytest.fail(f"test exceeded {_TEST_TIMEOUT_S}s per-test timeout "
                    f"(PYTEST_TEST_TIMEOUT to adjust)", pytrace=False)

    old = signal.signal(signal.SIGALRM, on_timeout)
    signal.alarm(_TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)

try:
    import hypothesis  # noqa: F401  (real package wins when present)
except ModuleNotFoundError:
    _path = Path(__file__).with_name("_hypothesis_fallback.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies
