"""Test-session bootstrap: CPU backend pin + hypothesis fallback.

* Pins JAX to the CPU platform before any test module imports jax, so the
  suite behaves identically on TPU hosts, CI runners and laptops (all
  Pallas kernels run in interpret mode on CPU).
* If the real `hypothesis` package is unavailable (the container does not
  ship it and installs are not allowed), installs the deterministic
  fallback from ``_hypothesis_fallback.py`` under that name so the
  property tests still collect and run.  CI installs real hypothesis and
  takes priority automatically.
"""
from __future__ import annotations

import importlib.util
import os
import sys
from pathlib import Path

os.environ.setdefault("JAX_PLATFORM_NAME", "cpu")

import jax

jax.config.update("jax_platform_name", "cpu")

try:
    import hypothesis  # noqa: F401  (real package wins when present)
except ModuleNotFoundError:
    _path = Path(__file__).with_name("_hypothesis_fallback.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies
