"""End-to-end elastic failover: train sharded -> host dies -> fault policy
demands remesh -> checkpoint -> rebuild a SMALLER mesh -> restore (the
checkpoint is mesh-agnostic) -> training continues with identical state.

Runs in a subprocess with 8 forced host devices (jax pins the device
count at first init)."""
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_elastic_shrink_and_resume(tmp_path):
    code = f"""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import ckpt
    from repro.configs import ARCHS
    from repro.data.synthetic import TokenStream
    from repro.launch.mesh import make_custom_mesh
    from repro.models.registry import build_model
    from repro.runtime.health import (ElasticPlanner, FaultPolicy,
                                      HeartbeatTracker, StragglerDetector)
    from repro.sharding.specs import default_rules, set_constraint_mesh, tree_shardings
    from repro.train import optimizer as opt

    cfg = dataclasses.replace(ARCHS["stablelm-3b"].SMOKE, n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                              vocab=256)
    model = build_model(cfg)
    ts = TokenStream(vocab=256, seed=0)
    data = lambda step: {{k: jnp.asarray(v) for k, v in
                         ts.batch(step, 8, 32).items()}}
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

    def sharded_step(mesh):
        rules = default_rules()
        set_constraint_mesh(mesh, rules)
        st_ax = opt.state_logical_axes(model.logical_axes())
        def shard_state(state):
            sh = opt.TrainState(
                step=NamedSharding(mesh, P()),
                params=tree_shardings(mesh, st_ax.params, state.params, rules),
                mu=tree_shardings(mesh, st_ax.mu, state.mu, rules),
                nu=tree_shardings(mesh, st_ax.nu, state.nu, rules))
            return jax.tree.map(jax.device_put, state, sh), sh
        def step(st, b):
            (l, m), g = jax.value_and_grad(lambda p: model.loss(p, b),
                                           has_aux=True)(st.params)
            return opt.adamw_update(st, g, ocfg), l
        return shard_state, jax.jit(step)

    # phase 1: 2 hosts x 4 devices = (4, 2) mesh
    mesh_a = make_custom_mesh((4, 2), ("data", "model"))
    shard_a, step_a = sharded_step(mesh_a)
    state = opt.init_state(model.init_params(jax.random.PRNGKey(0)), ocfg)
    state, _ = shard_a(state)
    clock = [0.0]
    hb = HeartbeatTracker(["h0", "h1"], timeout=1.5, clock=lambda: clock[0])
    policy = FaultPolicy(hb, StragglerDetector(),
                         ElasticPlanner(model_parallel=2, pod_size=1024),
                         devices_per_host=4)
    losses = []
    with mesh_a:
        for s in range(4):
            state, loss = step_a(state, data(s))
            losses.append(float(loss))
            clock[0] += 1.0
            hb.beat("h0")
            hb.beat("h1" if s < 2 else "h0")  # h1 goes silent after step 2
            decision = policy.decide(s)
            if decision == "remesh":
                break
    assert decision == "remesh", decision
    ckpt.save(state, r"{tmp_path}", step=int(state.step))

    # phase 2: replan onto the surviving 4 devices, restore, continue
    plan = policy.replan()
    assert plan.devices_used == 4 and plan.shape[-1] == 2, plan
    mesh_b = make_custom_mesh(plan.shape, plan.axes)
    shard_b, step_b = sharded_step(mesh_b)
    template = opt.init_state(model.abstract_params(jnp.float32), ocfg)
    restored, at_step = ckpt.restore(template, r"{tmp_path}")
    restored, _ = shard_b(restored)
    assert int(restored.step) == int(state.step)
    # bitwise state equality across the mesh change
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with mesh_b:
        for s in range(int(at_step), int(at_step) + 3):
            restored, loss = step_b(restored, data(s))
            losses.append(float(loss))
    assert all(np.isfinite(losses)), losses
    print("ELASTIC_OK steps:", int(restored.step), "losses:",
          [round(l, 3) for l in losses])
    """
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": "/tmp"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "ELASTIC_OK" in out.stdout
