"""Plan derivation properties + shim/planned bit-exactness (ISSUE 3).

The plan/execute split moves all resource sizing into ``plan_network``;
these tests pin the sizing rules (capacities padded to 64-multiples but
capped at the fmap size, blocks snapped to divisors, autotuned event
blocks) and the contract that the legacy kwargs shims execute the exact
same computation as the planned path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSNNConfig, ConvSpec, FCSpec, calibrate_capacities,
                        encode_input, init_params, plan_network, snn_apply,
                        snn_apply_batched)
from repro.core.plan import effective_capacity, pad_capacity, plan_conv_layer
from repro.kernels.event_conv.ops import autotune_block_e, snap_divisor

jax.config.update("jax_platform_name", "cpu")

PAPER = CSNNConfig()  # 28x28-32C3-32C3-P3-10C3-F10, T=5
SMOKE = CSNNConfig(input_hw=(10, 10),
                   layers=(ConvSpec(4), ConvSpec(4, pool=3), FCSpec(3)),
                   t_steps=4)


# ------------------------------------------------------------- sizing rules
class TestPlanDerivation:
    def test_capacities_pad_to_64_multiples_capped_at_fmap(self):
        plan = plan_network(PAPER, capacity=256)
        for lp in plan.layers:
            h, w = lp.in_hw
            assert lp.capacity <= h * w
            assert lp.capacity % 64 == 0 or lp.capacity == h * w

    def test_small_requested_capacity_kept_verbatim(self):
        # depths <= 64 are never padded — identical truncation vs legacy
        plan = plan_network(PAPER, capacity=8)
        assert all(lp.capacity == 8 for lp in plan.layers)

    def test_blocks_divide_evenly(self):
        for cb in (1, 3, 8):
            plan = plan_network(PAPER, capacity=200, channel_block=cb)
            for lp in plan.layers:
                assert lp.c_out % lp.channel_block == 0
                assert lp.capacity % lp.block_e == 0
                assert lp.vm_tile == (lp.in_hw[0] + 2, lp.in_hw[1] + 2,
                                      lp.channel_block)

    def test_per_layer_capacities_reduce_total_padded_slots(self):
        """ISSUE 3 acceptance: per-layer plans strictly reduce total padded
        event slots vs the shared-capacity baseline on the paper network."""
        plan = plan_network(PAPER, capacity=256)
        shared = plan_network(PAPER, capacity=256, per_layer=False)
        assert plan.total_event_slots < shared.total_event_slots
        # the reduction comes from the post-pool layer (10x10 fmap < 256)
        assert plan.layers[2].capacity == 100

    def test_geometry_matches_config(self):
        plan = plan_network(PAPER)
        assert [lp.name for lp in plan.layers] == ["conv0", "conv1", "conv2"]
        assert plan.layers[0].in_hw == (28, 28) and plan.layers[0].c_in == 1
        assert plan.layers[1].out_hw == (10, 10)  # pool3 over 28x28
        assert plan.layers[2].in_hw == (10, 10) and plan.layers[2].c_in == 32
        assert plan.t_steps == PAPER.t_steps

    def test_calibrated_per_layer_capacities(self):
        params = init_params(jax.random.PRNGKey(0), SMOKE)
        sp = encode_input(jnp.asarray(
            np.random.default_rng(0).random((4, 10, 10, 1)), jnp.float32), SMOKE)
        _, stats = snn_apply_batched(params, sp, SMOKE, capacity=100)
        caps = calibrate_capacities(
            [np.asarray(st.in_spike_counts) for st in stats],
            percentile=100.0, margin=1.0)
        plan = plan_network(SMOKE, capacity=caps)
        for lp, cap in zip(plan.layers, caps):
            assert lp.capacity == effective_capacity(cap, lp.in_hw[0] * lp.in_hw[1])

    def test_validate_rejects_mismatched_plan(self):
        plan = plan_network(SMOKE)
        with pytest.raises(ValueError, match="conv layers"):
            plan.validate(PAPER)
        with pytest.raises(ValueError, match="does not match"):
            plan_network(CSNNConfig(input_hw=(12, 12), layers=SMOKE.layers,
                                    t_steps=4)).validate(SMOKE)

    def test_repr_records_block_e(self):
        plan = plan_network(PAPER, capacity=256, channel_block=8)
        for lp in plan.layers:
            assert f"block_e={lp.block_e}" in repr(lp)
        assert "total_event_slots" in repr(plan)

    def test_plan_arg_errors(self):
        with pytest.raises(ValueError, match="per conv layer"):
            plan_network(PAPER, capacity=[256, 256])
        with pytest.raises(ValueError, match="per conv layer"):
            plan_network(PAPER, stats=[[1, 2]])


# ------------------------------------------------------------- autotuning
class TestAutotuneBlockE:
    def test_divides_capacity(self):
        for cap in (8, 64, 100, 144, 256, 784, 1024):
            be = autotune_block_e(cap, (30, 30, 8))
            assert cap % be == 0 and 1 <= be <= cap

    def test_scales_with_capacity(self):
        small = autotune_block_e(256, (30, 30, 8))
        large = autotune_block_e(1024, (30, 30, 8))
        assert large > small  # keeps ~4 blocks per queue as depth grows

    def test_vmem_budget_caps_block(self):
        tile = (30, 30, 8)
        tight = autotune_block_e(256, tile,
                                 vmem_budget=2 * 4 * 30 * 30 * 8 + 300)
        assert tight < autotune_block_e(256, tile)
        assert 256 % tight == 0

    def test_snap_divisor(self):
        assert snap_divisor(100, 64) == 50
        assert snap_divisor(64, 64) == 64
        assert snap_divisor(7, 100) == 7
        assert snap_divisor(12, 0) == 1

    def test_pad_capacity_contract(self):
        assert pad_capacity(8) == 8 and pad_capacity(64) == 64
        assert pad_capacity(65) == 128 and pad_capacity(100) == 128
        assert effective_capacity(256, 100) == 100
        assert effective_capacity(100, 784) == 128

    def test_layer_plan_pins_explicit_block_e(self):
        lp = plan_conv_layer(0, "conv0", (10, 10), 1, 4, capacity=100,
                             block_e=32)
        assert lp.capacity % lp.block_e == 0 and lp.block_e <= 32


# ------------------------------------------------------- shim bit-exactness
class TestShimMatchesPlannedPath:
    def _case(self, seed=0, b=4):
        params = init_params(jax.random.PRNGKey(seed), SMOKE)
        imgs = jnp.asarray(np.random.default_rng(seed)
                           .random((b, 10, 10, 1)).astype(np.float32))
        return params, encode_input(imgs, SMOKE)

    @pytest.mark.parametrize("capacity", [8, 100])
    def test_batched_shim_bit_exact(self, capacity):
        params, sp = self._case()
        plan = plan_network(SMOKE, capacity=capacity, channel_block=2)
        got = snn_apply_batched(params, sp, SMOKE, plan, collect_stats=False)
        shim = snn_apply_batched(params, sp, SMOKE, capacity=capacity,
                                 channel_block=2, collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(shim))

    def test_single_sample_shim_bit_exact(self):
        params, sp = self._case(1)
        plan = plan_network(SMOKE, capacity=64)
        got = jax.vmap(lambda s: snn_apply(params, s, SMOKE, plan,
                                           collect_stats=False))(sp)
        shim = jax.vmap(lambda s: snn_apply(params, s, SMOKE, capacity=64,
                                            collect_stats=False))(sp)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(shim))

    def test_planned_sat_bits_bit_exact(self):
        params, sp = self._case(2)
        qparams = jax.tree.map(
            lambda x: jnp.clip(jnp.round(x * 16), -100, 100).astype(jnp.int8),
            params)
        plan = plan_network(SMOKE, capacity=100, sat_bits=8)
        got = snn_apply_batched(qparams, sp, SMOKE, plan, collect_stats=False)
        shim = snn_apply_batched(qparams, sp, SMOKE, capacity=100, sat_bits=8,
                                 collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(shim))

    def test_stats_record_event_block(self):
        params, sp = self._case(3)
        plan = plan_network(SMOKE, capacity=100)
        _, stats = snn_apply_batched(params, sp, SMOKE, plan)
        for lp, st in zip(plan.layers, stats):
            assert int(st.event_block) == lp.block_e

    def test_paper_network_planned_bit_exact(self):
        """Paper network: the per-layer plan (reduced slots) must not
        change a single bit vs the legacy shared-capacity shim."""
        params = init_params(jax.random.PRNGKey(7), PAPER)
        imgs = jnp.asarray(np.random.default_rng(7)
                           .random((4, 28, 28, 1)).astype(np.float32))
        sp = encode_input(imgs, PAPER)
        plan = plan_network(PAPER, capacity=256, channel_block=8)
        got = snn_apply_batched(params, sp, PAPER, plan, collect_stats=False)
        shim = snn_apply_batched(params, sp, PAPER, capacity=256,
                                 channel_block=8, collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(shim))


# ---------------------------------------------------- negative-path errors
class TestPlanValidationErrors:
    """Every ``raise ValueError`` branch in core/plan.py, asserted by
    message — the analyzer's contracts assume these guards stay live."""

    def test_snap_t_chunk_rejects_nonpositive(self):
        from repro.core.plan import snap_t_chunk
        with pytest.raises(ValueError, match="must be >= 1"):
            snap_t_chunk(0, 1)
        with pytest.raises(ValueError, match="must be >= 1"):
            snap_t_chunk(5, 0)

    def test_validate_rejects_conv_layer_count_mismatch(self):
        plan = plan_network(SMOKE, capacity=16)
        extra = CSNNConfig(input_hw=SMOKE.input_hw, t_steps=SMOKE.t_steps,
                           layers=(ConvSpec(4), ConvSpec(4, pool=3),
                                   ConvSpec(2), FCSpec(3)))
        with pytest.raises(ValueError, match="conv layers"):
            plan.validate(extra)

    def test_validate_rejects_t_steps_mismatch(self):
        plan = plan_network(SMOKE, capacity=16)
        other = CSNNConfig(input_hw=SMOKE.input_hw, layers=SMOKE.layers,
                           t_steps=SMOKE.t_steps + 1)
        with pytest.raises(ValueError, match="t_steps"):
            plan.validate(other)

    def test_validate_rejects_ragged_t_chunk(self):
        import dataclasses
        plan = plan_network(SMOKE, capacity=16)
        bad = dataclasses.replace(plan, t_chunk=SMOKE.t_steps + 1)
        with pytest.raises(ValueError, match="must divide"):
            bad.validate(SMOKE)

    def test_validate_rejects_layer_geometry_mismatch(self):
        plan = plan_network(SMOKE, capacity=16)
        other = CSNNConfig(input_hw=(12, 12), layers=SMOKE.layers,
                           t_steps=SMOKE.t_steps)
        with pytest.raises(ValueError, match="does not match cfg layer"):
            plan.validate(other)

    def test_validate_rejects_out_of_range_ingest_depth(self):
        import dataclasses
        plan = plan_network(SMOKE, capacity=16, ingest=True,
                            t_chunk=2)
        lp0 = dataclasses.replace(plan.layers[0],
                                  ingest_depth=SMOKE.t_steps + 1)
        bad = dataclasses.replace(plan, layers=(lp0,) + plan.layers[1:])
        with pytest.raises(ValueError, match="ingest_depth"):
            bad.validate(SMOKE)

    def test_plan_conv_layer_rejects_half_set_ingest(self):
        with pytest.raises(ValueError, match="set .*together"):
            plan_conv_layer(0, "conv0", (10, 10), 1, 4, capacity=16,
                            ingest_capacity=64)
        with pytest.raises(ValueError, match="set .*together"):
            plan_conv_layer(0, "conv0", (10, 10), 1, 4, capacity=16,
                            ingest_depth=2)

    def test_plan_conv_layer_rejects_nonpositive_ingest(self):
        with pytest.raises(ValueError, match="must be >= 1"):
            plan_conv_layer(0, "conv0", (10, 10), 1, 4, capacity=16,
                            ingest_capacity=0, ingest_depth=2)
        with pytest.raises(ValueError, match="must be >= 1"):
            plan_conv_layer(0, "conv0", (10, 10), 1, 4, capacity=16,
                            ingest_capacity=64, ingest_depth=0)

    def test_plan_network_rejects_wrong_per_layer_list_lengths(self):
        with pytest.raises(ValueError, match="one capacity/channel_block"):
            plan_network(SMOKE, capacity=[16])
        with pytest.raises(ValueError, match="one capacity/channel_block"):
            plan_network(SMOKE, capacity=16, channel_block=[1, 1, 1])
        with pytest.raises(ValueError, match="one capacity/channel_block"):
            plan_network(SMOKE, capacity=16, event_par=[1])

    def test_plan_network_rejects_wrong_stats_length(self):
        with pytest.raises(ValueError, match="one stats entry"):
            plan_network(SMOKE, capacity=16,
                         stats=[np.ones(4, np.int32)])
