"""Batched event pipeline: bit-exactness vs the single-sample path.

The batched subsystem (build_aeq_batched -> apply_events_batched /
event_conv_pallas_batched -> run_conv_layer_batched -> snn_apply_batched)
changes only the launch structure, never the per-sample schedule, so every
result must be *bit-identical* to ``jax.vmap`` over the single-sample
path — including the saturating integer datapaths and overfull queues.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CSNNConfig, ConvSpec, FCSpec, apply_events,
                        apply_events_batched, build_aeq_batched, encode_input,
                        init_params, pad_vm, run_conv_layer,
                        run_conv_layer_batched, run_fc_head,
                        run_fc_head_batched, snn_apply, snn_apply_batched,
                        snn_apply_dense)
from repro.kernels.event_conv.ops import event_conv_batched

jax.config.update("jax_platform_name", "cpu")


def _batch_spikes(rng, b, t, h, w, c, density=0.2):
    return jnp.asarray(rng.random((b, t, h, w, c)) < density)


# ------------------------------------------------------- event application
class TestApplyEventsBatched:
    @given(st.integers(1, 5), st.integers(4, 14), st.integers(4, 14),
           st.floats(0.0, 0.8), st.integers(0, 10_000))
    @pytest.mark.slow
    @settings(max_examples=15, deadline=None)
    def test_matches_vmapped_apply_events(self, b, h, w, density, seed):
        rng = np.random.default_rng(seed)
        fmaps = jnp.asarray(rng.random((b, h, w)) < density)
        q = build_aeq_batched(fmaps, capacity=h * w)
        kernel = jnp.asarray(rng.normal(size=(3, 3, 3)).astype(np.float32))
        vm0 = jax.vmap(pad_vm)(jnp.zeros((b, h, w, 3), jnp.float32))
        got = apply_events_batched(vm0, q.coords, q.valid, q.count, kernel,
                                   block=8)
        want = jax.vmap(lambda vm, i: apply_events(vm, q.queue_at((i,)), kernel),
                        in_axes=(0, 0))(vm0, jnp.arange(b))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_shared_early_exit_skips_nothing_valid(self):
        """One full queue forces the whole batch through every block; one
        empty queue must still come back untouched."""
        fmaps = jnp.stack([jnp.ones((6, 6), bool), jnp.zeros((6, 6), bool)])
        q = build_aeq_batched(fmaps, capacity=36)
        kernel = jnp.ones((3, 3), jnp.float32)
        vm0 = jax.vmap(pad_vm)(jnp.zeros((2, 6, 6), jnp.float32))
        out = apply_events_batched(vm0, q.coords, q.valid, q.count, kernel,
                                   block=8)
        assert float(np.abs(np.asarray(out[1])).max()) == 0.0
        assert float(np.asarray(out[0])[1:-1, 1:-1].min()) > 0.0

    def test_int8_saturation_matches_single(self):
        rng = np.random.default_rng(0)
        fmaps = jnp.asarray(rng.random((3, 8, 8)) < 0.7)
        q = build_aeq_batched(fmaps, capacity=64)
        kernel = jnp.asarray(rng.integers(-90, 90, size=(3, 3, 2)), jnp.int8)
        vm0 = jax.vmap(pad_vm)(jnp.zeros((3, 8, 8, 2), jnp.int8))
        got = apply_events_batched(vm0, q.coords, q.valid, q.count, kernel)
        want = jax.vmap(lambda vm, i: apply_events(vm, q.queue_at((i,)), kernel),
                        in_axes=(0, 0))(vm0, jnp.arange(3))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- pallas 2-D grid
class TestEventConvBatchedKernel:
    @pytest.mark.parametrize("dtype,seed", [(jnp.float32, 0), (jnp.int16, 1),
                                            (jnp.int8, 2)])
    def test_kernel_matches_oracle(self, dtype, seed):
        rng = np.random.default_rng(seed)
        Q, H, W, C = 4, 10, 12, 8
        fmaps = jnp.asarray(rng.random((Q, H, W)) < 0.3)
        queues = build_aeq_batched(fmaps, capacity=H * W)
        if dtype == jnp.float32:
            kernel = jnp.asarray(rng.normal(size=(3, 3, C)).astype(np.float32))
            vm = jnp.asarray(rng.normal(size=(Q, H, W, C)).astype(np.float32))
        else:
            kernel = jnp.asarray(rng.integers(-20, 20, size=(3, 3, C)), dtype)
            vm = jnp.asarray(rng.integers(-50, 50, size=(Q, H, W, C)), dtype)
        got = event_conv_batched(vm, queues, kernel, block_e=32, use_kernel=True)
        want = event_conv_batched(vm, queues, kernel, block_e=32, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_queue_count_mismatch_raises(self):
        queues = build_aeq_batched(jnp.zeros((2, 4, 4), bool), 16)
        with pytest.raises(ValueError, match="queue count mismatch"):
            from repro.kernels.event_conv.kernel import event_conv_pallas_batched
            event_conv_pallas_batched(jnp.zeros((3, 6, 6, 4), jnp.float32),
                                      queues.coords, queues.valid,
                                      jnp.zeros((3, 3, 4), jnp.float32),
                                      block_e=16)


# ------------------------------------------------------- layer + head
class TestRunConvLayerBatched:
    def _case(self, seed, b=3, t=3, h=8, w=8, cin=2, cout=4):
        rng = np.random.default_rng(seed)
        spikes = _batch_spikes(rng, b, t, h, w, cin)
        k = jnp.asarray(rng.normal(size=(3, 3, cin, cout)).astype(np.float32) * 0.5)
        bias = jnp.asarray(rng.normal(size=(cout,)).astype(np.float32) * 0.1)
        return spikes, k, bias

    @pytest.mark.parametrize("pool", [None, 3])
    @pytest.mark.parametrize("channel_block", [1, 2])
    def test_matches_vmapped_layer(self, pool, channel_block):
        spikes, k, bias = self._case(0)
        got, st_b = run_conv_layer_batched(spikes, k, bias, 1.0, capacity=64,
                                           pool=pool, channel_block=channel_block)
        want, st_v = jax.vmap(
            lambda s: run_conv_layer(s, k, bias, 1.0, capacity=64, pool=pool,
                                     channel_block=channel_block))(spikes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(st_b.in_spike_counts),
                                      np.asarray(st_v.in_spike_counts))
        np.testing.assert_array_equal(np.asarray(st_b.out_spike_counts),
                                      np.asarray(st_v.out_spike_counts))
        np.testing.assert_allclose(np.asarray(st_b.in_sparsity),
                                   np.asarray(st_v.in_sparsity), rtol=1e-6)

    def test_pallas_backend_matches_jax(self):
        spikes, k, bias = self._case(1)
        out_j, _ = run_conv_layer_batched(spikes, k, bias, 1.0, capacity=64,
                                          backend="jax")
        out_p, _ = run_conv_layer_batched(spikes, k, bias, 1.0, capacity=64,
                                          backend="pallas")
        np.testing.assert_array_equal(np.asarray(out_j), np.asarray(out_p))

    def test_fc_head_batched(self):
        rng = np.random.default_rng(2)
        spikes = jnp.asarray(rng.random((3, 4, 3, 3, 2)) < 0.5)
        w = jnp.asarray(rng.normal(size=(18, 5)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))
        got = run_fc_head_batched(spikes, w, b)
        want = jax.vmap(lambda s: run_fc_head(s, w, b))(spikes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- end to end
class TestSnnApplyBatched:
    def _smoke(self, seed=0, b=4):
        cfg = CSNNConfig(input_hw=(10, 10),
                         layers=(ConvSpec(4), ConvSpec(4, pool=3), FCSpec(3)),
                         t_steps=4)
        params = init_params(jax.random.PRNGKey(seed), cfg)
        imgs = jnp.asarray(np.random.default_rng(seed)
                           .random((b, 10, 10, 1)).astype(np.float32))
        return cfg, params, encode_input(imgs, cfg)

    def test_bit_exact_vs_vmap(self):
        cfg, params, sp = self._smoke()
        got = snn_apply_batched(params, sp, cfg, capacity=100, collect_stats=False)
        want = jax.vmap(lambda s: snn_apply(params, s, cfg, capacity=100,
                                            collect_stats=False))(sp)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_agrees_with_dense_oracle(self):
        cfg, params, sp = self._smoke(1)
        got = snn_apply_batched(params, sp, cfg, capacity=100, collect_stats=False)
        dense = jax.vmap(lambda s: snn_apply_dense(params, s, cfg))(sp)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("channel_block", [2, 4])
    def test_channel_block_variants(self, channel_block):
        cfg, params, sp = self._smoke(2)
        got = snn_apply_batched(params, sp, cfg, capacity=100,
                                channel_block=channel_block, collect_stats=False)
        want = jax.vmap(lambda s: snn_apply(
            params, s, cfg, capacity=100, channel_block=channel_block,
            collect_stats=False))(sp)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("sat_bits", [8, 16])
    def test_sat_bits_variants(self, sat_bits):
        cfg, params, sp = self._smoke(3)
        qparams = jax.tree.map(
            lambda x: jnp.clip(jnp.round(x * 16), -100, 100)
            .astype(jnp.int8 if sat_bits == 8 else jnp.int16), params)
        got = snn_apply_batched(qparams, sp, cfg, capacity=100,
                                sat_bits=sat_bits, collect_stats=False)
        want = jax.vmap(lambda s: snn_apply(qparams, s, cfg, capacity=100,
                                            sat_bits=sat_bits,
                                            collect_stats=False))(sp)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_capacity_overflow_drops_like_hardware(self):
        """An undersized queue drops the same tail events in both paths:
        results stay bit-identical (and differ from full capacity)."""
        cfg, params, sp = self._smoke(4)
        got = snn_apply_batched(params, sp, cfg, capacity=8, collect_stats=False)
        want = jax.vmap(lambda s: snn_apply(params, s, cfg, capacity=8,
                                            collect_stats=False))(sp)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        full = snn_apply_batched(params, sp, cfg, capacity=100,
                                 collect_stats=False)
        assert not np.array_equal(np.asarray(got), np.asarray(full))

    def test_paper_network_acceptance(self):
        """28x28-32C3-32C3-P3-10C3-F10, T=5, B=8: batched == vmap, bit-exact
        (the PR's acceptance criterion)."""
        cfg = CSNNConfig()  # paper defaults
        params = init_params(jax.random.PRNGKey(7), cfg)
        imgs = jnp.asarray(np.random.default_rng(7)
                           .random((8, 28, 28, 1)).astype(np.float32))
        sp = encode_input(imgs, cfg)
        got = snn_apply_batched(params, sp, cfg, capacity=256, channel_block=8,
                                collect_stats=False)
        want = jax.vmap(lambda s: snn_apply(params, s, cfg, capacity=256,
                                            channel_block=8,
                                            collect_stats=False))(sp)
        assert got.shape == (8, 10)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
