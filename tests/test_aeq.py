"""AEQ properties (promised by core/aeq.py): compaction round-trip, the
hazard-free interlaced read order, memory interlacing inverses, capacity
calibration, and the fused batched builder behind the batched pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aeq import (BatchedEventQueue, build_aeq, build_aeq_batched,
                            calibrate_capacity, column_index, deinterlace,
                            interlace, scatter_aeq)

jax.config.update("jax_platform_name", "cpu")


class TestRoundTrip:
    @pytest.mark.slow
    @given(st.integers(2, 28), st.integers(2, 28), st.floats(0.0, 1.0),
           st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_build_scatter_roundtrip(self, h, w, density, seed):
        """With enough capacity, scatter(build(fmap)) == fmap exactly."""
        rng = np.random.default_rng(seed)
        fmap = jnp.asarray(rng.random((h, w)) < density)
        q = build_aeq(fmap, capacity=h * w)
        assert int(q.count) == int(fmap.sum())
        np.testing.assert_array_equal(np.asarray(scatter_aeq(q, (h, w))),
                                      np.asarray(fmap))

    def test_overflow_drops_tail_events(self):
        """A full queue silently drops, exactly like the BRAM queue."""
        fmap = jnp.ones((8, 8), bool)
        q = build_aeq(fmap, capacity=20)
        assert int(q.valid.sum()) == 20
        assert int(q.count) == 64  # count reports demand, not occupancy
        back = scatter_aeq(q, (8, 8))
        assert int(back.sum()) == 20


class TestInterlacedOrder:
    @given(st.integers(3, 24), st.integers(3, 24), st.floats(0.05, 0.8),
           st.integers(0, 10_000))
    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    def test_emission_order_by_column(self, h, w, density, seed):
        """Events come out column 0..8 (the paper's hazard-free order)."""
        rng = np.random.default_rng(seed)
        fmap = jnp.asarray(rng.random((h, w)) < density)
        q = build_aeq(fmap, capacity=h * w)
        coords = np.asarray(q.coords)[np.asarray(q.valid)]
        cols = (coords[:, 0] % 3) * 3 + coords[:, 1] % 3
        assert (np.diff(cols) >= 0).all()

    @given(st.integers(3, 30), st.integers(3, 30), st.integers(0, 1000))
    @settings(max_examples=25, deadline=None)
    def test_any_3x3_window_hits_each_column_once(self, h, w, seed):
        """The 9-port invariant (paper Fig. 6) for a random window."""
        rng = np.random.default_rng(seed)
        i0 = int(rng.integers(0, h - 2))
        j0 = int(rng.integers(0, w - 2))
        ii, jj = np.meshgrid(np.arange(i0, i0 + 3), np.arange(j0, j0 + 3),
                             indexing="ij")
        cols = np.asarray(column_index(jnp.asarray(ii), jnp.asarray(jj)))
        assert sorted(cols.ravel().tolist()) == list(range(9))


class TestInterlacing:
    @given(st.integers(1, 30), st.integers(1, 30), st.integers(0, 1000))
    @settings(max_examples=25, deadline=None)
    def test_interlace_deinterlace_inverse(self, h, w, seed):
        rng = np.random.default_rng(seed)
        vm = jnp.asarray(rng.normal(size=(h, w)).astype(np.float32))
        cols = interlace(vm)
        assert cols.shape == (9, -(-h // 3), -(-w // 3))
        np.testing.assert_array_equal(np.asarray(deinterlace(cols, (h, w))),
                                      np.asarray(vm))


class TestCalibration:
    @given(st.integers(1, 200), st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_monotone_in_percentile_and_margin(self, n, seed):
        rng = np.random.default_rng(seed)
        counts = rng.integers(0, 500, size=n)
        caps_p = [calibrate_capacity(counts, percentile=p, margin=1.0, align=1)
                  for p in (50.0, 90.0, 99.0, 100.0)]
        assert caps_p == sorted(caps_p)
        caps_m = [calibrate_capacity(counts, percentile=99.0, margin=m, align=1)
                  for m in (1.0, 1.25, 2.0)]
        assert caps_m == sorted(caps_m)

    def test_alignment_and_floor(self):
        assert calibrate_capacity([], align=16) == 16
        cap = calibrate_capacity([5], percentile=100.0, margin=1.0, align=8)
        assert cap == 8 and cap % 8 == 0
        assert calibrate_capacity([0, 0], percentile=100.0, margin=1.0, align=4) == 4


class TestBatchedBuilder:
    @given(st.integers(2, 16), st.integers(2, 16), st.floats(0.0, 1.0),
           st.integers(1, 6), st.integers(0, 10_000))
    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    def test_batched_equals_vmapped_single(self, h, w, density, n, seed):
        """The fused one-sort builder is bit-exact vs per-fmap compaction."""
        rng = np.random.default_rng(seed)
        fmaps = jnp.asarray(rng.random((n, h, w)) < density)
        cap = max(1, (h * w) // 2)  # exercise the overflow path too
        bq = build_aeq_batched(fmaps, cap)
        vq = jax.vmap(lambda f: build_aeq(f, cap))(fmaps)
        np.testing.assert_array_equal(np.asarray(bq.coords), np.asarray(vq.coords))
        np.testing.assert_array_equal(np.asarray(bq.valid), np.asarray(vq.valid))
        np.testing.assert_array_equal(np.asarray(bq.count), np.asarray(vq.count))

    def test_multi_leading_dims_and_queue_at(self):
        rng = np.random.default_rng(3)
        fmaps = jnp.asarray(rng.random((2, 3, 4, 9, 7)) < 0.3)
        bq = build_aeq_batched(fmaps, capacity=32)
        assert isinstance(bq, BatchedEventQueue)
        assert bq.coords.shape == (2, 3, 4, 32, 2)
        assert bq.capacity == 32 and bq.num_queues == 24
        single = build_aeq(fmaps[1, 2, 0], 32)
        member = bq.queue_at((1, 2, 0))
        np.testing.assert_array_equal(np.asarray(member.coords),
                                      np.asarray(single.coords))
        np.testing.assert_array_equal(np.asarray(member.valid),
                                      np.asarray(single.valid))
        assert int(member.count) == int(single.count)

    def test_capacity_deeper_than_fmap_pads(self):
        fmaps = jnp.ones((3, 4, 4), bool)
        bq = build_aeq_batched(fmaps, capacity=40)
        assert bq.coords.shape == (3, 40, 2)
        assert int(bq.valid.sum()) == 3 * 16
        np.testing.assert_array_equal(np.asarray(bq.coords[:, 16:]),
                                      np.full((3, 24, 2), -1))

    def test_interlaced_flag_matches_single(self):
        rng = np.random.default_rng(9)
        fmaps = jnp.asarray(rng.random((4, 10, 10)) < 0.4)
        bq = build_aeq_batched(fmaps, 64, interlaced=False)
        vq = jax.vmap(lambda f: build_aeq(f, 64, interlaced=False))(fmaps)
        np.testing.assert_array_equal(np.asarray(bq.coords), np.asarray(vq.coords))
