"""Streaming DVS ingestion (ISSUE 6): differential streaming-equivalence
harness.

Contracts pinned here (core/aeq.py streaming API, data/dvs.py,
core/plan.py ingest fields, serve/csnn_engine.py stream mode):

* ``append_events`` is an idempotent, order/chunking-invariant merge:
  duplicates dedupe, out-of-window events (and ``num``-padding rows)
  drop, and any permutation/split of one event set yields the same
  :class:`StreamState` — single and batched.
* ``stream_queues`` reproduces ``build_aeq_batched`` over the binned
  frames of the same events BIT-EXACTLY — coords, valid, count, column
  segments — for interlaced and raster layouts, including capacity
  truncation, all-spike frames at exact capacity, and capacities smaller
  than one interlace column (property-tested).
* the streamed chunk step (``snn_step_chunk`` on a StreamState) matches
  the frame-binned step bit for bit: logits, full carry pytree and
  per-layer stats, across event_par variants, saturating datapaths and
  the pallas backend.
* a checked-in golden DVS trace (``golden_dvs.npz``) pins the whole
  path end to end: generator determinism, exact per-layer event counts
  and readout logits.
* ``plan_network(ingest=True)`` sizes the layer-0 ingestion buffers;
  the continuous engine's stream mode serves raw event traces with
  logits bit-exact vs the direct streamed pipeline.

Regenerate the golden fixture (only after an INTENDED semantic change)::

    PYTHONPATH=src python tests/test_streaming.py --regen
"""
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # direct `--regen` run, outside conftest
    import importlib.util as _ilu
    import sys as _sys
    _spec = _ilu.spec_from_file_location(
        "hypothesis", Path(__file__).with_name("_hypothesis_fallback.py"))
    _mod = _ilu.module_from_spec(_spec)
    _sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    from hypothesis import given, settings, strategies as st

from repro.core.aeq import (StreamState, append_events,
                            append_events_batched, build_aeq_batched,
                            init_stream_state, make_stream_chunk,
                            stream_frames, stream_queues)
from repro.core.csnn import (CSNNConfig, ConvSpec, FCSpec, init_params,
                             init_state, snn_readout, snn_step_chunk)
from repro.core.plan import plan_network
from repro.data.dvs import (dvs_moving_edges, events_to_banks,
                            events_to_frames, iter_stream_chunks)

jax.config.update("jax_platform_name", "cpu")

GOLDEN = Path(__file__).with_name("golden_dvs.npz")

# 2-polarity DVS smoke net: the golden fixture's and chunk-step tests' cfg
DVS_SMOKE = CSNNConfig(input_hw=(12, 12), input_channels=2,
                       layers=(ConvSpec(8), ConvSpec(8, pool=3), FCSpec(10)),
                       t_steps=4)


# ------------------------------------------------------------------ helpers
def _random_events(rng, t_bins, hw, channels, n, junk=False):
    """n random in-window (t, y, x, p) rows (duplicates allowed), plus a
    tail of out-of-window junk rows when ``junk`` — all of which
    ``append_events`` must drop."""
    h, w = hw
    ev = np.stack([rng.integers(0, t_bins, n), rng.integers(0, h, n),
                   rng.integers(0, w, n),
                   rng.integers(0, channels, n)], axis=-1).astype(np.int32)
    if junk:
        bad = np.stack([
            [-1, 0, 0, 0], [t_bins, 0, 0, 0], [0, -2, 0, 0], [0, h, 0, 0],
            [0, 0, -1, 0], [0, 0, w, 0], [0, 0, 0, -1], [0, 0, 0, channels],
        ]).astype(np.int32)
        ev = np.concatenate([ev, bad], axis=0)
        rng.shuffle(ev, axis=0)
    return ev


def _ingest(events, t_bins, hw, channels, rng=None, pieces=1):
    """Append ``events`` as ``pieces`` chunks (shuffled when rng given)."""
    ev = np.asarray(events, dtype=np.int32).reshape(-1, 4).copy()
    if rng is not None:
        rng.shuffle(ev, axis=0)
    state = init_stream_state(hw, t_bins, channels)
    cuts = (sorted(rng.integers(0, ev.shape[0] + 1, pieces - 1).tolist())
            if pieces > 1 else [])
    for part in np.split(ev, cuts):
        # +3 pad rows: num-masking must hide whatever sits in the padding
        chunk = make_stream_chunk(part, buffer=part.shape[0] + 3)
        state = append_events(state, chunk, hw)
    return state


def _binned_queues(events, t_bins, hw, channels, capacity, interlaced=True):
    frames = events_to_frames(events, t_bins, hw, channels)  # (T, H, W, C)
    fmaps = jnp.asarray(frames.transpose(0, 3, 1, 2))        # (T, C, H, W)
    return build_aeq_batched(fmaps, capacity, interlaced=interlaced)


def _assert_queues_equal(got, want):
    for name, a, b in zip(got._fields, got, want):
        assert (a is None) == (b is None), name
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"queue field {name}")


def _stream_vs_binned(events, t_bins, hw, channels, capacity, interlaced,
                      rng, pieces):
    state = _ingest(events, t_bins, hw, channels, rng=rng, pieces=pieces)
    got = stream_queues(state, capacity, hw, interlaced=interlaced)
    want = _binned_queues(events, t_bins, hw, channels, capacity,
                          interlaced=interlaced)
    _assert_queues_equal(got, want)


# ------------------------------------------------------------ append merge
class TestAppendEvents:
    HW, T, C = (7, 9), 3, 2

    def test_empty_chunk_is_identity(self):
        state = _ingest(_random_events(np.random.default_rng(0), self.T,
                                       self.HW, self.C, 20),
                        self.T, self.HW, self.C)
        after = append_events(state, make_stream_chunk(
            np.zeros((0, 4), np.int32), buffer=5), self.HW)
        np.testing.assert_array_equal(np.asarray(after.banks),
                                      np.asarray(state.banks))

    def test_junk_and_duplicates_drop(self):
        rng = np.random.default_rng(1)
        ev = _random_events(rng, self.T, self.HW, self.C, 30, junk=True)
        doubled = np.concatenate([ev, ev], axis=0)
        clean = _ingest(ev, self.T, self.HW, self.C)
        dirty = _ingest(doubled, self.T, self.HW, self.C,
                        rng=np.random.default_rng(2), pieces=4)
        np.testing.assert_array_equal(np.asarray(dirty.banks),
                                      np.asarray(clean.banks))
        # junk never lands anywhere: occupancy equals the binned reference
        np.testing.assert_array_equal(
            np.asarray(stream_frames(dirty, self.HW)).transpose(0, 2, 3, 1),
            events_to_frames(ev, self.T, self.HW, self.C))

    def test_order_and_chunking_invariance(self):
        ev = _random_events(np.random.default_rng(3), self.T, self.HW,
                            self.C, 40)
        a = _ingest(ev, self.T, self.HW, self.C,
                    rng=np.random.default_rng(4), pieces=1)
        b = _ingest(ev, self.T, self.HW, self.C,
                    rng=np.random.default_rng(5), pieces=7)
        np.testing.assert_array_equal(np.asarray(a.banks),
                                      np.asarray(b.banks))

    def test_batched_matches_per_row_loop(self):
        rng = np.random.default_rng(6)
        rows = [_random_events(rng, self.T, self.HW, self.C, 25, junk=True)
                for _ in range(3)]
        chunk = make_stream_chunk(rows[0], buffer=rows[0].shape[0])
        evs = jnp.stack([jnp.asarray(make_stream_chunk(
            r, buffer=rows[0].shape[0]).events) for r in rows])
        nums = jnp.asarray([r.shape[0] for r in rows], jnp.int32)
        batched = append_events_batched(
            init_stream_state(self.HW, self.T, self.C, lead=(3,)),
            type(chunk)(events=evs, num=nums), self.HW)
        for k, r in enumerate(rows):
            np.testing.assert_array_equal(
                np.asarray(batched.banks[k]),
                np.asarray(_ingest(r, self.T, self.HW, self.C).banks))

    def test_batched_lead_mismatch_raises(self):
        state = init_stream_state(self.HW, self.T, self.C, lead=(3,))
        chunk = make_stream_chunk(np.zeros((2, 4), np.int32))
        with pytest.raises(ValueError, match="leading dims"):
            append_events_batched(
                state, type(chunk)(events=jnp.asarray(chunk.events)[None],
                                   num=jnp.asarray(chunk.num)[None]),
                self.HW)

    def test_make_stream_chunk_overflow_raises(self):
        with pytest.raises(ValueError, match="exceed"):
            make_stream_chunk(np.zeros((4, 4), np.int32), buffer=3)


# ------------------------------------------- differential: queues vs binned
class TestStreamQueuesDifferential:
    @pytest.mark.parametrize("interlaced", [True, False])
    @pytest.mark.parametrize("hw,t,c,n,cap", [
        ((7, 9), 3, 2, 40, 64),     # plain, capacity ample
        ((7, 9), 3, 2, 120, 16),    # truncation: demand > capacity
        ((6, 6), 2, 1, 200, 36),    # heavy duplicates, cap == H*W
        ((5, 8), 1, 3, 10, 48),     # capacity > H*W (clamped take)
    ])
    def test_matches_binned(self, hw, t, c, n, cap, interlaced):
        rng = np.random.default_rng(n + cap)
        ev = _random_events(rng, t, hw, c, n, junk=True)
        _stream_vs_binned(ev, t, hw, c, cap, interlaced, rng, pieces=3)

    def test_all_spikes_at_exact_capacity(self):
        """Every pixel of every (bin, channel) fires and the capacity is
        exactly H*W: kept == count == capacity, no truncation, and the
        segment table covers the full frame."""
        hw, t, c = (6, 7), 2, 2
        yy, xx = np.mgrid[0:hw[0], 0:hw[1]]
        base = np.stack([yy.ravel(), xx.ravel()], axis=-1)
        ev = np.concatenate([
            np.concatenate([np.full((base.shape[0], 1), tb),
                            base, np.full((base.shape[0], 1), ch)], axis=-1)
            for tb in range(t) for ch in range(c)]).astype(np.int32)
        cap = hw[0] * hw[1]
        for interlaced in (True, False):
            _stream_vs_binned(ev, t, hw, c, cap, interlaced,
                              np.random.default_rng(0), pieces=2)
        q = stream_queues(_ingest(ev, t, hw, c), cap, hw)
        np.testing.assert_array_equal(np.asarray(q.count),
                                      np.full((t, c), cap))
        assert np.asarray(q.valid).all()

    def test_capacity_below_one_interlace_column(self):
        """capacity smaller than a single column's population still keeps
        the first `capacity` events in (s, i, j) order."""
        hw, t, c = (9, 9), 1, 1
        yy, xx = np.mgrid[0:9, 0:9]
        ev = np.stack([np.zeros(81, int), yy.ravel(), xx.ravel(),
                       np.zeros(81, int)], axis=-1).astype(np.int32)
        for cap in (2, 5):  # one 9x9 column holds 9 cells > cap
            _stream_vs_binned(ev, t, hw, c, cap, True,
                              np.random.default_rng(cap), pieces=2)
            q = stream_queues(_ingest(ev, t, hw, c), cap, hw)
            # demand is the whole frame; only cap slots kept, all from
            # column 0 (i%3 == j%3 == 0 sorts first)
            assert int(q.count[0, 0]) == 81
            coords = np.asarray(q.coords[0, 0])
            assert (coords % 3 == 0).all()
            np.testing.assert_array_equal(np.asarray(q.seg_counts[0, 0]),
                                          [cap] + [0] * 8)

    def test_empty_state(self):
        q = stream_queues(init_stream_state((7, 9), 2, 2), 16, (7, 9))
        assert not np.asarray(q.valid).any()
        np.testing.assert_array_equal(np.asarray(q.count), 0)
        np.testing.assert_array_equal(np.asarray(q.coords), -1)

    @pytest.mark.slow
    @given(st.integers(4, 13), st.integers(4, 13), st.integers(1, 3),
           st.integers(1, 2), st.floats(0.0, 2.0), st.floats(0.1, 1.5),
           st.booleans(), st.integers(0, 10_000))
    @settings(max_examples=40, deadline=None)
    def test_matches_binned_property(self, h, w, t, c, rate, cap_frac,
                                     interlaced, seed):
        rng = np.random.default_rng(seed)
        n = int(rate * h * w)
        cap = max(1, int(cap_frac * h * w))
        ev = _random_events(rng, t, (h, w), c, n, junk=True)
        _stream_vs_binned(ev, t, (h, w), c, cap, interlaced, rng,
                          pieces=int(rng.integers(1, 5)))


# ------------------------------------------------- windowed chunk iteration
class TestIterStreamChunks:
    def test_windows_rebase_and_roundtrip(self):
        hw, t_bins, window = (7, 9), 6, 2
        ev = _random_events(np.random.default_rng(8), t_bins, hw, 2, 60)
        full = events_to_frames(ev, t_bins, hw, 2)
        t0s = []
        for t0, padded, num in iter_stream_chunks(ev, t_bins, window, 80):
            t0s.append(t0)
            assert (padded[num:] == -1).all()
            state = init_stream_state(hw, window, 2)
            state = append_events(
                state, make_stream_chunk(padded, buffer=80), hw)
            np.testing.assert_array_equal(
                np.asarray(stream_frames(state, hw)).transpose(0, 2, 3, 1),
                full[t0:t0 + window])
        assert t0s == [0, 2, 4]

    def test_overflow_is_backpressure(self):
        ev = _random_events(np.random.default_rng(9), 2, (7, 9), 2, 50)
        with pytest.raises(ValueError, match="ingest buffer"):
            list(iter_stream_chunks(ev, 2, 2, buffer=4))


# ------------------------------------------------ plan: ingestion sizing
class TestPlanIngest:
    def test_ingest_fields_sized_and_validated(self):
        plan = plan_network(DVS_SMOKE, capacity=64, ingest=True)
        lp0, lp1 = plan.layers
        assert lp0.ingest_depth == DVS_SMOKE.t_steps
        assert lp0.ingest_capacity is not None and lp0.ingest_capacity > 0
        assert lp0.ingest_capacity % 64 == 0  # jit-stable padded depth
        assert lp1.ingest_capacity is None and lp1.ingest_depth is None
        assert "ingest=" in repr(lp0) and "ingest=" not in repr(lp1)
        plan.validate(DVS_SMOKE)

    def test_ingest_depth_follows_t_chunk(self):
        plan = plan_network(DVS_SMOKE, capacity=64, ingest=True, t_chunk=2)
        assert plan.layers[0].ingest_depth == 2

    def test_explicit_capacity_and_bad_pairs(self):
        plan = plan_network(DVS_SMOKE, capacity=64, ingest_capacity=512)
        assert plan.layers[0].ingest_capacity == 512
        from repro.core.plan import plan_conv_layer
        with pytest.raises(ValueError, match="ingest"):
            plan_conv_layer(0, "conv0", (12, 12), 2, 8, capacity=64,
                            ingest_capacity=128)  # depth missing


# --------------------------------------- end to end: streamed == binned
def _traces_and_plan(event_par=1, sat_bits=None, t_chunk=2, n=4, seed=13):
    cfg = DVS_SMOKE
    traces, labels = dvs_moving_edges(n, cfg.t_steps, cfg.input_hw,
                                      seed=seed)
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan = plan_network(cfg, capacity=64, channel_block=8, batch_tile=n,
                        event_par=event_par, t_chunk=t_chunk, ingest=True)
    banks = jnp.asarray(np.stack([
        events_to_banks(tr, cfg.t_steps, cfg.input_hw) for tr in traces]))
    frames = jnp.asarray(np.stack([
        events_to_frames(tr, cfg.t_steps, cfg.input_hw) for tr in traces]))
    return cfg, params, plan, traces, labels, banks, frames


def _run_chunked(params, cfg, plan, inputs, *, streamed, backend="jax"):
    """Chunked forward; ``inputs`` = banks (B,T,C,9,hb,wb) or frames
    (B,T,H,W,C).  Returns (logits, final state, stacked stats arrays)."""
    b = inputs.shape[0]
    tc = plan.t_chunk or cfg.t_steps
    state, all_stats = init_state(params, cfg, plan, b), []
    for t0 in range(0, cfg.t_steps, tc):
        sp = inputs[:, t0:t0 + tc]
        if streamed:
            sp = StreamState(banks=sp)
        state, stats = snn_step_chunk(params, state, sp, cfg, plan,
                                      backend=backend, collect_stats=True)
        all_stats.append(stats)
    logits = snn_readout(params, state, cfg)
    per_layer = [np.concatenate(  # (B, t, C_in) per chunk -> (B, T, C_in)
        [np.asarray(chunk[li].in_spike_counts) for chunk in all_stats],
        axis=1) for li in range(len(all_stats[0]))]
    return logits, state, per_layer, all_stats


class TestStreamedChunkStep:
    @pytest.mark.parametrize("event_par,sat_bits",
                             [(1, None), (None, 16)])
    def test_streamed_matches_binned(self, event_par, sat_bits):
        cfg, params, plan, _, _, banks, frames = _traces_and_plan(
            event_par=event_par, sat_bits=sat_bits)
        ls, ss, cs, sts = _run_chunked(params, cfg, plan, banks,
                                       streamed=True)
        lb, sb, cb, stb = _run_chunked(params, cfg, plan, frames,
                                       streamed=False)
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lb))
        for a, b in zip(jax.tree_util.tree_leaves(ss),
                        jax.tree_util.tree_leaves(sb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(sts),
                        jax.tree_util.tree_leaves(stb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.slow
    @pytest.mark.parametrize("event_par,sat_bits,backend", [
        (4, None, "jax"), (None, 8, "jax"), (1, None, "pallas"),
        (None, None, "pallas"),
    ])
    def test_streamed_matches_binned_slow(self, event_par, sat_bits,
                                          backend):
        cfg, params, plan, _, _, banks, frames = _traces_and_plan(
            event_par=event_par, sat_bits=sat_bits)
        ls, ss, _, _ = _run_chunked(params, cfg, plan, banks,
                                    streamed=True, backend=backend)
        lb, sb, _, _ = _run_chunked(params, cfg, plan, frames,
                                    streamed=False, backend=backend)
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lb))
        for a, b in zip(jax.tree_util.tree_leaves(ss),
                        jax.tree_util.tree_leaves(sb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- golden regression
def _golden_forward():
    """The fixture's frozen pipeline: 4 moving-edge traces through the
    2-polarity smoke net, streamed whole-T; returns everything the
    fixture pins."""
    cfg, params, plan, traces, labels, banks, _ = _traces_and_plan(
        t_chunk=None, seed=11)
    state = init_state(params, cfg, plan, len(traces))
    state, stats = snn_step_chunk(params, state, StreamState(banks=banks),
                                  cfg, plan, collect_stats=True)
    logits = snn_readout(params, state, cfg)
    return traces, labels, stats, logits


class TestGoldenTrace:
    def test_golden_dvs_trace(self):
        assert GOLDEN.exists(), \
            "golden_dvs.npz missing — regenerate per module docstring"
        ref = np.load(GOLDEN)
        traces, labels, stats, logits = _golden_forward()
        # generator regression: the same seed must reproduce the stored
        # raw traces row for row
        assert len(traces) == int(ref["n_traces"])
        for k, tr in enumerate(traces):
            np.testing.assert_array_equal(tr, ref[f"trace{k}"])
        np.testing.assert_array_equal(labels, ref["labels"])
        # exact per-layer event counts: ints, no tolerance
        for li, st_ in enumerate(stats):
            np.testing.assert_array_equal(
                np.asarray(st_.in_spike_counts, np.int64),
                ref[f"in_counts_l{li}"])
            np.testing.assert_array_equal(
                np.asarray(st_.out_spike_counts, np.int64),
                ref[f"out_counts_l{li}"])
        np.testing.assert_allclose(np.asarray(logits), ref["logits"],
                                   rtol=0, atol=1e-5)


# --------------------------------------------------- engine stream serving
class TestEngineStream:
    def test_stream_requires_continuous(self):
        from repro.serve.csnn_engine import CSNNEngine, CSNNServeConfig
        cfg, params, plan, *_ = _traces_and_plan()
        with pytest.raises(ValueError, match="continuous"):
            CSNNEngine(params, cfg, plan,
                       CSNNServeConfig(stream=True, continuous=False))

    def test_engine_stream_logits_bit_exact(self):
        from repro.serve.csnn_engine import CSNNEngine, CSNNServeConfig
        cfg, params, plan, traces, _, banks, _ = _traces_and_plan(
            n=5, t_chunk=2)
        engine = CSNNEngine(params, cfg, plan,
                            CSNNServeConfig(max_batch=4, continuous=True,
                                            stream=True, t_chunk=2))
        got = engine.run_requests(traces)
        want, _, _, _ = _run_chunked(params, cfg, plan, banks,
                                     streamed=True)
        np.testing.assert_array_equal(got, np.asarray(want))


if __name__ == "__main__":
    import sys
    if "--regen" not in sys.argv:
        sys.exit("usage: python tests/test_streaming.py --regen")
    traces, labels, stats, logits = _golden_forward()
    out = {"n_traces": np.int64(len(traces)), "labels": labels,
           "logits": np.asarray(logits)}
    for k, tr in enumerate(traces):
        out[f"trace{k}"] = tr
    for li, st_ in enumerate(stats):
        out[f"in_counts_l{li}"] = np.asarray(st_.in_spike_counts, np.int64)
        out[f"out_counts_l{li}"] = np.asarray(st_.out_spike_counts, np.int64)
    np.savez(GOLDEN, **out)
    print(f"wrote {GOLDEN}: logits {out['logits'].shape}, "
          f"{len(traces)} traces")
