"""Async CSNN serving engine: flush semantics + padding + launcher smoke.

The engine's contract (serve/csnn_engine.py): requests flush when either
``max_batch`` are pending (size flush) or the oldest has waited
``max_delay_ms`` (deadline flush); partial batches pad to the plan's
``batch_tile`` with zero images, and every request's logits are bit-exact
vs running the batched pipeline directly on the un-padded requests.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CSNNConfig, ConvSpec, FCSpec, encode_input,
                        init_params, plan_network, snn_apply_batched)
from repro.serve.csnn_engine import CSNNEngine, CSNNServeConfig

jax.config.update("jax_platform_name", "cpu")

CFG = CSNNConfig(input_hw=(8, 8),
                 layers=(ConvSpec(4), ConvSpec(4, pool=2), FCSpec(3)),
                 t_steps=3)


def _setup(seed=0, n=4, max_batch=4, delay_ms=50.0):
    params = init_params(jax.random.PRNGKey(seed), CFG)
    plan = plan_network(CFG, capacity=64, channel_block=2,
                        batch_tile=max_batch)
    engine = CSNNEngine(params, CFG, plan,
                        CSNNServeConfig(max_batch=max_batch,
                                        max_delay_ms=delay_ms))
    imgs = jnp.asarray(np.random.default_rng(seed)
                       .random((n, 8, 8, 1)).astype(np.float32))
    return params, plan, engine, imgs


class TestFlushSemantics:
    def test_size_flush_on_full_batch(self):
        """max_batch requests already queued flush immediately as one full
        batch — no deadline wait, no padding."""
        params, plan, engine, imgs = _setup(n=4, max_batch=4)
        logits = engine.run_requests(list(imgs))
        assert logits.shape == (4, 3)
        assert engine.stats["flushes_full"] == 1
        assert engine.stats["flushes_deadline"] == 0
        assert engine.stats["padded_slots"] == 0

    def test_deadline_flush_on_partial_batch(self):
        """A single request must come back after ~max_delay_ms even though
        the batch never fills."""
        params, plan, engine, imgs = _setup(n=1, max_batch=8, delay_ms=30.0)

        async def drive():
            async with engine:
                return await asyncio.wait_for(engine.submit(imgs[0]),
                                              timeout=30.0)

        logits = asyncio.run(drive())
        assert logits.shape == (3,)
        assert engine.stats["flushes_deadline"] == 1
        assert engine.stats["flushes_full"] == 0

    def test_partial_batch_pads_to_tile(self):
        """3 requests with tile 4 pad one zero slot; the padded slot never
        leaks into results."""
        params, plan, engine, imgs = _setup(n=3, max_batch=4, delay_ms=20.0)
        logits = engine.run_requests(list(imgs))
        assert logits.shape == (3, 3)
        assert engine.stats["padded_slots"] == 1
        assert engine.stats["batches"] == 1

    def test_logits_bit_exact_vs_direct_batched(self):
        """Engine results == running the planned batched pipeline directly
        on the un-padded requests (zero-pad samples are independent)."""
        params, plan, engine, imgs = _setup(n=3, max_batch=4, delay_ms=20.0)
        got = engine.run_requests(list(imgs))
        want = snn_apply_batched(params, encode_input(imgs, CFG), CFG, plan,
                                 collect_stats=False)
        np.testing.assert_array_equal(got, np.asarray(want))

    def test_multiple_waves_reuse_engine(self):
        params, plan, engine, imgs = _setup(n=4, max_batch=4)
        first = engine.run_requests(list(imgs))
        second = engine.run_requests(list(imgs))
        np.testing.assert_array_equal(first, second)
        assert engine.stats["batches"] == 2
        assert engine.stats["requests"] == 8

    def test_warmup_precompiles_tile_shapes(self):
        params, plan, engine, imgs = _setup(n=4, max_batch=4)
        compile_s = engine.warmup()
        assert compile_s > 0.0 and engine.stats["compile_s"] == compile_s

    def test_submit_outside_context_raises(self):
        params, plan, engine, imgs = _setup()
        try:
            engine.submit_nowait(imgs[0])
        except RuntimeError as e:
            assert "not running" in str(e)
        else:
            raise AssertionError("expected RuntimeError")

    def test_max_batch_must_align_to_tile(self):
        params = init_params(jax.random.PRNGKey(0), CFG)
        plan = plan_network(CFG, batch_tile=4)
        try:
            CSNNEngine(params, CFG, plan, CSNNServeConfig(max_batch=6))
        except ValueError as e:
            assert "batch_tile" in str(e)
        else:
            raise AssertionError("expected ValueError")


class TestServeLauncher:
    def test_csnn_engine_smoke(self, capsys):
        """launch/serve.py --arch csnn-paper --engine end-to-end: compile
        time reported separately, per-layer events with --verbose."""
        from repro.launch.serve import main
        rc = main(["--arch", "csnn-paper", "--smoke", "--requests", "3",
                   "--engine", "--batch-tile", "4", "--verbose",
                   "--capacity", "64", "--iters", "1"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "compile:" in out and "throughput:" in out
        assert "NetworkPlan" in out
        assert "layer conv0: events=" in out
        assert "padded_slots=1" in out

    def test_csnn_batched_smoke(self, capsys):
        from repro.launch.serve import main
        rc = main(["--arch", "csnn-paper", "--smoke", "--requests", "2",
                   "--capacity", "64", "--iters", "1"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "mode=batched" in out and "compile:" in out
