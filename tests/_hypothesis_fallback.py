"""Deterministic stand-in for `hypothesis` when the real package is absent.

The repo's property tests only use a narrow slice of the hypothesis API:
``given``, ``settings``, ``assume`` and the ``integers`` / ``floats`` /
``booleans`` / ``sampled_from`` strategies.  This module reimplements that
slice as a plain example enumerator: boundary values first, then samples
from a per-test seeded PRNG, so runs are reproducible and need no external
dependency.  ``tests/conftest.py`` installs it under the name
``hypothesis`` only when the real package cannot be imported — with
hypothesis installed (e.g. in CI) this file is inert.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

__version__ = "0.0.0+fallback"

_DEFAULT_MAX_EXAMPLES = 25


class _UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    """Abort the current example (not the test) when ``condition`` is falsy."""
    if not condition:
        raise _UnsatisfiedAssumption
    return True


class _Strategy:
    """An example source: a few deterministic corners, then PRNG samples."""

    def __init__(self, corners, sample):
        self._corners = list(corners)
        self._sample = sample

    def examples(self, rng: random.Random, n: int):
        out = self._corners[:n]
        while len(out) < n:
            out.append(self._sample(rng))
        return out


def integers(min_value: int, max_value: int) -> _Strategy:
    mid = (min_value + max_value) // 2
    return _Strategy(
        corners=[min_value, max_value, mid],
        sample=lambda rng: rng.randint(min_value, max_value),
    )


def floats(min_value: float, max_value: float, **_ignored) -> _Strategy:
    return _Strategy(
        corners=[min_value, max_value, 0.5 * (min_value + max_value)],
        sample=lambda rng: rng.uniform(min_value, max_value),
    )


def booleans() -> _Strategy:
    return _Strategy(corners=[False, True], sample=lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(corners=elements[:2], sample=lambda rng: rng.choice(elements))


def just(value) -> _Strategy:
    return _Strategy(corners=[value], sample=lambda _rng: value)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.booleans = booleans
strategies.sampled_from = sampled_from
strategies.just = just


def settings(**kwargs):
    """Attach example-count settings; works above or below ``@given``."""

    def decorate(fn):
        fn._fallback_settings = kwargs
        return fn

    return decorate


def given(*strats):
    """Run the wrapped test once per generated example tuple.

    Strategy values fill the test's trailing positional parameters
    (right-aligned, mirroring hypothesis), so ``self`` passes through.
    """

    def decorate(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        kept = params[: len(params) - len(strats)]
        # Strategy values bind to the TRAILING parameters (right-aligned,
        # as in hypothesis) — by keyword, so pytest-parametrized kwargs on
        # the earlier parameters cannot collide.
        drawn_names = [p.name for p in params[len(params) - len(strats):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", None) or getattr(
                fn, "_fallback_settings", {})
            n = int(cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES))
            n = max(1, min(n, _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            columns = [s.examples(rng, n) for s in strats]
            for values in zip(*columns):
                try:
                    fn(*args, **kwargs, **dict(zip(drawn_names, values)))
                except _UnsatisfiedAssumption:
                    continue

        # pytest resolves fixtures from the signature (following
        # __wrapped__); hide the strategy-supplied parameters so they are
        # not mistaken for fixtures.
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        return wrapper

    return decorate
