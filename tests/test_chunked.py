"""Step-resumable event pipeline: the chunked stepper must be bit-exact.

Contract (core/csnn.py): ``init_state`` + ``snn_step_chunk`` over any
divisor chunking of T + ``snn_readout`` reproduces ``snn_apply_batched``
exactly — per time step the computation is identical, only the scans are
cut at chunk boundaries.  This is what lets the serving engine admit
requests mid-flight (tests/test_continuous.py) without perturbing
in-flight ones.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSNNConfig, ConvSpec, FCSpec, encode_input,
                        init_params, init_state, plan_network, snap_t_chunk,
                        snn_apply, snn_apply_batched, snn_readout,
                        snn_step_chunk)

jax.config.update("jax_platform_name", "cpu")

CFG = CSNNConfig(input_hw=(8, 8),
                 layers=(ConvSpec(4), ConvSpec(4, pool=2), FCSpec(3)),
                 t_steps=4)


def _setup(seed=0, b=3, density=0.3):
    params = init_params(jax.random.PRNGKey(seed), CFG)
    plan = plan_network(CFG, capacity=64, channel_block=2, batch_tile=4)
    rng = np.random.default_rng(seed)
    spikes = jnp.asarray(rng.random((b, CFG.t_steps, 8, 8, 1)) < density)
    return params, plan, spikes


class TestChunkedStepper:
    @pytest.mark.parametrize("t_chunk", [1, 2, 4])
    def test_manual_chunking_bit_exact(self, t_chunk):
        """Chaining snn_step_chunk over t_chunk slices + readout ==
        monolithic snn_apply_batched, bit for bit."""
        params, plan, spikes = _setup()
        want = snn_apply_batched(params, spikes, CFG, plan,
                                 collect_stats=False)
        state = init_state(params, CFG, plan, spikes.shape[0])
        for k in range(0, CFG.t_steps, t_chunk):
            state = snn_step_chunk(params, state,
                                   spikes[:, k:k + t_chunk], CFG, plan)
        got = snn_readout(params, state, CFG)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("t_chunk", [1, 2, 4])
    def test_planned_t_chunk_wrapper_bit_exact(self, t_chunk):
        """snn_apply_batched with a t_chunk plan scans the chunks itself
        and stays bit-exact vs the single-chunk plan (and vs vmap)."""
        params, _, spikes = _setup()
        plan_c = plan_network(CFG, capacity=64, channel_block=2,
                              batch_tile=4, t_chunk=t_chunk)
        want = jax.vmap(lambda s: snn_apply(params, s, CFG,
                                            capacity=64, channel_block=2,
                                            collect_stats=False))(spikes)
        got = snn_apply_batched(params, spikes, CFG, plan_c,
                                collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_chunked_stats_concatenate_over_time(self):
        params, _, spikes = _setup()
        plan_c = plan_network(CFG, capacity=64, channel_block=2,
                              batch_tile=4, t_chunk=2)
        _, stats = snn_apply_batched(params, spikes, CFG, plan_c)
        plan_m = plan_network(CFG, capacity=64, channel_block=2, batch_tile=4)
        _, want = snn_apply_batched(params, spikes, CFG, plan_m)
        for st_c, st_m in zip(stats, want):
            np.testing.assert_array_equal(np.asarray(st_c.in_spike_counts),
                                          np.asarray(st_m.in_spike_counts))
            np.testing.assert_array_equal(np.asarray(st_c.out_spike_counts),
                                          np.asarray(st_m.out_spike_counts))

    def test_state_is_a_pytree(self):
        params, plan, spikes = _setup()
        state = init_state(params, CFG, plan, 3)
        leaves = jax.tree_util.tree_leaves(state)
        assert leaves and all(hasattr(l, "shape") for l in leaves)
        # jit over the state works (the serving engine relies on it)
        step = jax.jit(lambda st, sp: snn_step_chunk(params, st, sp, CFG,
                                                     plan))
        st2 = step(state, spikes[:, :CFG.t_steps])
        assert st2.fc_drive.shape == state.fc_drive.shape


class TestTChunkPlanning:
    def test_snap_t_chunk_divisors(self):
        assert snap_t_chunk(4, 2) == 2
        assert snap_t_chunk(4, 3) == 2
        assert snap_t_chunk(5, 2) == 1   # 5 is prime: falls to 1
        assert snap_t_chunk(6, 4) == 3
        assert snap_t_chunk(6, 99) == 6  # capped at T

    def test_plan_network_snaps_t_chunk(self):
        plan = plan_network(CFG, t_chunk=3)  # 3 does not divide T=4 -> 2
        assert plan.t_chunk == 2
        assert plan.chunk_steps == 2

    def test_default_plan_is_monolithic(self):
        plan = plan_network(CFG)
        assert plan.t_chunk is None
        assert plan.chunk_steps == CFG.t_steps

    def test_validate_rejects_non_divisor_t_chunk(self):
        import dataclasses
        plan = plan_network(CFG, t_chunk=2)
        bad = dataclasses.replace(plan, t_chunk=3)
        with pytest.raises(ValueError, match="t_chunk"):
            bad.validate(CFG)


class TestInputChannels:
    """plan_network/validate used to hardcode C_in=1; multi-channel input
    (e.g. a 2-polarity DVS encoding) must plan and run end to end."""

    CFG2 = CSNNConfig(input_hw=(8, 8), input_channels=2,
                      layers=(ConvSpec(4), FCSpec(3)), t_steps=3)

    def test_plan_threads_input_channels(self):
        plan = plan_network(self.CFG2, capacity=64)
        assert plan.layers[0].c_in == 2
        plan.validate(self.CFG2)  # geometry must round-trip

    def test_init_params_shapes(self):
        params = init_params(jax.random.PRNGKey(0), self.CFG2)
        assert params["conv0"]["w"].shape == (3, 3, 2, 4)

    def test_batched_bit_exact_vs_single(self):
        params = init_params(jax.random.PRNGKey(1), self.CFG2)
        plan = plan_network(self.CFG2, capacity=64, channel_block=2)
        rng = np.random.default_rng(2)
        spikes = jnp.asarray(rng.random((2, 3, 8, 8, 2)) < 0.3)
        want = jax.vmap(lambda s: snn_apply(params, s, self.CFG2, plan,
                                            collect_stats=False))(spikes)
        got = snn_apply_batched(params, spikes, self.CFG2, plan,
                                collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_encode_input_keeps_channels(self):
        imgs = jnp.zeros((2, 8, 8, 2))
        sp = encode_input(imgs, self.CFG2)
        assert sp.shape == (2, 3, 8, 8, 2)

    def test_single_channel_plans_unchanged(self):
        plan = plan_network(CFG, capacity=64)
        assert plan.layers[0].c_in == 1
