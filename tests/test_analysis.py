"""Static-analysis subsystem (ISSUE 7): clean tree, seeded violations.

Two-sided contract: ``python -m repro.analysis`` must (a) run clean on
the shipped tree — every invariant proven over the geometry sweep — and
(b) flag 100% of the seeded-violation fixtures, so the auditor itself
cannot rot silently.
"""
import json
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis import Report
from repro.analysis.contracts import audit_plan, run_contracts, sweep_cases
from repro.analysis.hazards import (CapturedCall, capture_pallas_calls,
                                    check_blockspec_bounds,
                                    check_column_disjointness,
                                    check_padded_queue, check_patch_bounds)
from repro.analysis.lint import lint_source
from repro.analysis.selftest import run_selftest


# ------------------------------------------------------------------ report
class TestReport:
    def test_roundtrip_and_exitworthiness(self, tmp_path):
        rep = Report()
        rep.proved("some-rule", 3)
        assert rep.ok
        rep.flag("lint", "lint-mutable-default", "a.py:1", "boom")
        assert not rep.ok
        path = rep.write_json(tmp_path / "r.json")
        data = json.loads(path.read_text())
        assert data["ok"] is False and data["n_findings"] == 1
        assert data["obligations"]["some-rule"] == 3
        assert data["findings"][0]["rule"] == "lint-mutable-default"


# --------------------------------------------------------------- contracts
class TestContracts:
    def test_sweep_is_clean_and_nontrivial(self):
        rep = run_contracts()
        assert rep.ok, rep.summary()
        # every registered rule discharged at least one obligation
        from repro.analysis.contracts import CONTRACTS
        for rule in CONTRACTS:
            assert rep.checked[rule] > 0, f"{rule} never ran"
        assert len(sweep_cases()) >= 8

    def test_corrupted_plan_is_flagged(self):
        import dataclasses

        from repro.core.csnn import CSNNConfig
        from repro.core.plan import plan_network
        plan = plan_network(CSNNConfig(), capacity=256)
        lp0 = dataclasses.replace(plan.layers[0],
                                  block_e=plan.layers[0].queue_depth - 1)
        bad = dataclasses.replace(plan, layers=(lp0,) + plan.layers[1:])
        rep = audit_plan(bad, None, case="corrupt")
        assert any(f.rule == "plan-block-e-divides-depth"
                   for f in rep.findings)


# ----------------------------------------------------------------- hazards
class TestHazards:
    def test_interlace_theorem_holds(self):
        rep = check_column_disjointness()
        assert rep.ok and rep.checked["hazard-column-disjoint"] > 0

    def test_colliding_column_scheme_is_flagged(self):
        rep = check_column_disjointness(
            column_of=lambda i, j: (i % 2) * 2 + (j % 2))
        assert any(f.rule == "hazard-column-disjoint" for f in rep.findings)

    def test_duplicate_event_in_group_is_flagged(self):
        coords = np.array([[2, 2], [2, 2]], np.int32)
        valid = np.ones(2, bool)
        rep = check_padded_queue(coords, valid, 2)
        assert any(f.rule == "hazard-segment-homogeneous"
                   for f in rep.findings)

    def test_capture_sees_every_kernel_entry_point(self):
        calls = capture_pallas_calls()
        names = {c.name for c in calls}
        assert {"event_conv_pallas", "event_conv_pallas_batched",
                "event_conv_pallas_interlaced",
                "event_conv_pallas_interlaced_batched",
                "threshold_pool_pallas"} <= names
        rep = check_blockspec_bounds(calls)
        assert rep.ok, rep.summary()

    def test_oversized_blockspec_is_flagged(self):
        call = CapturedCall(
            name="seeded", grid=(2,),
            in_specs=[SimpleNamespace(block_shape=(32, 2),
                                      index_map=lambda b: (b, 0))],
            out_specs=[None],
            arg_shapes=[(48, 2)], arg_dtypes=["int32"],
            out_shapes=[(48, 2)], out_dtypes=["int32"])
        rep = check_blockspec_bounds([call])
        assert any(f.rule == "oob-blockspec-bounds" for f in rep.findings)

    def test_oob_event_patch_is_flagged(self):
        assert check_patch_bounds(10, 10).ok
        rep = check_patch_bounds(10, 10, coord_hi=(10, 9))
        assert any(f.rule == "oob-event-patch" for f in rep.findings)


# ------------------------------------------------------------ kernel audit
class TestKernelAudit:
    def test_saturating_datapath_proven_and_wrap_flagged(self):
        from repro.analysis.kernel_audit import check_saturation
        assert check_saturation().ok

        def wrapping(vm_p, coords, valid, kernel):
            vm = np.asarray(vm_p).copy()
            k = np.asarray(kernel)
            for (i, j), v in zip(np.asarray(coords), np.asarray(valid)):
                if v:
                    with np.errstate(over="ignore"):
                        vm[i:i + 3, j:j + 3, :] += k
            return vm

        rep = check_saturation(wrapping)
        assert any(f.rule == "kernel-sat-overflow" for f in rep.findings)

    @pytest.mark.slow
    def test_full_kernel_audit_clean(self):
        from repro.analysis.kernel_audit import run_kernel_audit
        rep = run_kernel_audit()
        assert rep.ok, rep.summary()


# -------------------------------------------------------------------- lint
class TestLint:
    def test_mutable_default_dataclass_flagged(self):
        src = ("import dataclasses\n"
               "@dataclasses.dataclass\n"
               "class C:\n"
               "    xs: list = []\n")
        rep = lint_source(src, "core/c.py")
        assert any(f.rule == "lint-mutable-default" for f in rep.findings)

    def test_field_factory_is_allowed(self):
        src = ("import dataclasses\n"
               "@dataclasses.dataclass\n"
               "class C:\n"
               "    xs: list = dataclasses.field(default_factory=list)\n")
        assert lint_source(src, "core/c.py").ok

    def test_tracer_cast_and_host_call_flagged(self):
        src = ("import jax, numpy as np\n"
               "@jax.jit\n"
               "def f(x):\n"
               "    y = int(x)\n"
               "    return y + np.random.rand()\n")
        rules = {f.rule for f in lint_source(src, "core/f.py").findings}
        assert {"lint-tracer-cast", "lint-host-call-in-jit"} <= rules

    def test_module_level_jit_marks_function(self):
        src = ("import jax\n"
               "def f(x):\n"
               "    return int(x)\n"
               "f = jax.jit(f)\n")
        rep = lint_source(src, "core/f.py")
        assert any(f.rule == "lint-tracer-cast" for f in rep.findings)

    def test_pallas_call_location_rule(self):
        src = ("from jax.experimental import pallas as pl\n"
               "def f(x):\n"
               "    return pl.pallas_call(lambda r, o: None, out_shape=x)(x)\n")
        assert any(f.rule == "lint-pallas-call-outside-kernels"
                   for f in lint_source(src, "serve/f.py").findings)
        assert lint_source(src, "src/repro/kernels/ec/f.py").ok

    def test_ignore_comment_suppresses(self):
        src = ("class C:\n"
               "    pass\n"
               "# analysis: ignore[lint-mutable-default] — shared sentinel\n"
               "def f(c=C()):\n"
               "    return c\n")
        assert lint_source(src, "core/f.py").ok

    def test_shipped_tree_is_clean(self):
        from repro.analysis.lint import run_lint
        rep = run_lint()
        assert rep.ok, rep.summary()
        assert rep.checked["lint-missing-donate"] >= 2


# ---------------------------------------------------------------- selftest
class TestSelfTest:
    def test_every_seeded_violation_is_caught(self):
        rep = run_selftest()
        assert rep.ok, rep.summary()
        assert rep.checked["selftest-seeded"] >= 20


# --------------------------------------------------------------------- CLI
class TestCLI:
    def test_lint_pass_exit_zero_and_json(self, tmp_path, monkeypatch):
        from repro.analysis.__main__ import main
        out = tmp_path / "ANALYSIS_report.json"
        assert main(["--only", "lint", "--json", str(out)]) == 0
        data = json.loads(out.read_text())
        assert data["ok"] is True and data["findings"] == []

    def test_exit_nonzero_on_finding(self, tmp_path):
        from repro.analysis.__main__ import main
        bad = tmp_path / "bad.py"
        bad.write_text("def f(xs=[]):\n    return xs\n")
        from repro.analysis.lint import run_lint
        rep = run_lint([bad])
        assert not rep.ok
        # the CLI maps a non-ok report to a nonzero exit
        assert main(["--only", "contracts"]) == 0  # clean pass baseline
