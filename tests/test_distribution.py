"""Distribution tests: sharding rules, HLO cost model, collective-bytes
parsing, plus multi-device (forced host devices) subprocess tests for
mesh-agnostic checkpointing, overlap matmuls and compressed reductions.

Multi-device cases run in a subprocess because jax locks the device count
at first init (the same reason dryrun.py sets XLA_FLAGS first)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, _shape_bytes, _shape_dims
from repro.sharding.specs import default_rules, resolve

jax.config.update("jax_platform_name", "cpu")

REPO = Path(__file__).resolve().parent.parent


def run_subprocess(code: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu", "HOME": "/tmp"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


# ---------------------------------------------------------------- rules
class TestShardingRules:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_resolve_drops_nondivisible(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = default_rules()
        # 1-sized mesh axes divide everything -> axes assigned
        spec = resolve(("vocab", "embed"), (50304, 2560), rules, mesh)
        assert spec == jax.sharding.PartitionSpec("model", "data")

    def test_resolve_skips_missing_axes(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = default_rules()  # fsdp = ("pod", "data"); no pod on this mesh
        spec = resolve(("embed",), (128,), rules, mesh)
        assert spec == jax.sharding.PartitionSpec("data")

    def test_resolve_no_axis_reuse(self):
        try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
            mesh = jax.sharding.AbstractMesh((2, 2), ("data", "model"))
        except TypeError:  # jax 0.4.x: tuple of (name, size) pairs
            mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 2)))
        rules = {"a": ("model",), "b": ("model",)}
        spec = resolve(("a", "b"), (4, 4), rules, mesh)
        # second use of "model" must be dropped
        assert spec == jax.sharding.PartitionSpec("model", None)

    def test_long_context_rules(self):
        rules = default_rules(long_context=True)
        assert rules["batch"] == ()
        assert rules["cache_seq"] == ("data",)


# ---------------------------------------------------------------- hlo cost
class TestHloCost:
    def test_shape_parsing(self):
        assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
        assert _shape_bytes("(bf16[8,8], s32[4])") == 8 * 8 * 2 + 16
        assert _shape_dims("f32[3,5,7]") == [3, 5, 7]

    def test_trip_count_multiplier(self):
        hlo = textwrap.dedent("""\
        HloModule m

        %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
          %p = (s32[], f32[8,8]) parameter(0)
          %a = f32[8,8]{1,0} get-tuple-element(%p), index=1
          %d = f32[8,8]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          %c = s32[] constant(1)
          %i = s32[] get-tuple-element(%p), index=0
          ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
        }

        %cond (p2: (s32[], f32[8,8])) -> pred[] {
          %p2 = (s32[], f32[8,8]) parameter(0)
          %i2 = s32[] get-tuple-element(%p2), index=0
          %n = s32[] constant(5)
          ROOT %lt = pred[] compare(%i2, %n), direction=LT
        }

        ENTRY %main (x: f32[8,8]) -> f32[8,8] {
          %x = f32[8,8]{1,0} parameter(0)
          %z = s32[] constant(0)
          %tup = (s32[], f32[8,8]) tuple(%z, %x)
          %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
          ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
        }
        """)
        cost = HloCostModel(hlo, 1).entry_cost()
        assert cost.flops == 5 * 2 * 8 * 8 * 8  # dot x trip count

    def test_collective_wire_bytes(self):
        hlo = textwrap.dedent("""\
        HloModule m

        ENTRY %main (x: f32[16]) -> f32[16] {
          %x = f32[16]{0} parameter(0)
          ROOT %ar = f32[16]{0} all-reduce(%x), replica_groups=[1,4]<=[4], to_apply=%add
        }
        """)
        cost = HloCostModel(hlo, 4).entry_cost()
        assert cost.wire == pytest.approx(2 * 64 * 3 / 4)
        assert cost.coll_counts == {"all-reduce": 1}

    def test_real_compiled_module(self):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), ()
            out, _ = jax.lax.scan(body, x, None, length=3)
            return out.sum()
        x = jnp.ones((32, 32))
        c = jax.jit(f).lower(x, x).compile()
        cost = HloCostModel(c.as_text(), 1).entry_cost()
        assert cost.flops == pytest.approx(3 * 2 * 32**3)


# ---------------------------------------------------------------- multi-device
class TestMultiDevice:
    def test_checkpoint_across_meshes(self, tmp_path):
        """Save on a (4,2) mesh, restore onto (2,4) and (8,1) — the elastic
        restore path (mesh-agnostic checkpoints)."""
        out = run_subprocess(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import ckpt
        tree = {{"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.arange(8.0)}}
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = {{"w": NamedSharding(mesh_a, P("data", "model")),
                "b": NamedSharding(mesh_a, P("model"))}}
        sharded = jax.tree.map(jax.device_put, tree, sh_a)
        ckpt.save(sharded, r"{tmp_path}", step=1)
        for shape, axes in [((2, 4), ("data", "model")), ((8, 1), ("data", "model"))]:
            mesh_b = jax.make_mesh(shape, axes)
            sh_b = {{"w": NamedSharding(mesh_b, P("model", "data")),
                    "b": NamedSharding(mesh_b, P(None))}}
            restored, step = ckpt.restore(tree, r"{tmp_path}", shardings=sh_b)
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(tree["w"]))
            np.testing.assert_array_equal(np.asarray(restored["b"]),
                                          np.asarray(tree["b"]))
        print("CKPT_OK")
        """)
        assert "CKPT_OK" in out

    def test_overlap_matmuls_correct(self):
        out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.overlap import psum_matmul, ring_weight_gather_matmul
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 24)).astype(np.float32))
        want = np.asarray(x @ w)
        got1 = np.asarray(psum_matmul(x, w, mesh, "data"))
        np.testing.assert_allclose(got1, want, rtol=1e-4, atol=1e-4)
        got2 = np.asarray(ring_weight_gather_matmul(x, w, mesh, "data"))
        np.testing.assert_allclose(got2, want, rtol=1e-4, atol=1e-4)
        print("OVERLAP_OK")
        """)
        assert "OVERLAP_OK" in out

    def test_sparse_psum_matches_dense(self):
        out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.sharding.compression import compress_topk, decompress
        mesh = jax.make_mesh((8,), ("data",))

        def body():
            i = jax.lax.axis_index("data").astype(jnp.float32)
            g = jnp.zeros((32,)).at[(jax.lax.axis_index("data") * 3) % 32].set(1.0 + i)
            c = compress_topk(g, k=4)
            all_i = jax.lax.all_gather(c.indices, "data").reshape(-1)
            all_v = jax.lax.all_gather(c.values, "data").reshape(-1)
            dense = jnp.zeros((32,)).at[all_i].add(all_v) / 8
            ref = jax.lax.pmean(g, "data")
            return jnp.abs(dense - ref).max()

        diff = shard_map(body, mesh=mesh, in_specs=(), out_specs=P(),
                         check_vma=False)()
        assert float(diff.max()) < 1e-6, float(diff.max())
        print("SPARSE_OK")
        """)
        assert "SPARSE_OK" in out

    def test_mini_dryrun_16dev(self):
        """A reduced arch through the real dry-run path on a 4x4 mesh:
        lower + compile + roofline extraction all work end to end."""
        out = run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.configs.base import ShapeConfig
        from repro.launch import roofline as rl
        from repro.models.registry import build_model
        from repro.sharding.specs import default_rules, tree_shardings, set_constraint_mesh
        from repro.train import optimizer as opt

        mesh = jax.make_mesh((4, 4), ("data", "model"))
        model = build_model(ARCHS["stablelm-3b"].SMOKE)
        shape = ShapeConfig("mini", 256, 8, "train")
        rules = default_rules()
        set_constraint_mesh(mesh, rules)
        ocfg = opt.AdamWConfig()
        ap = model.abstract_params(jnp.float32)
        state = opt.abstract_state(ap, ocfg)
        st_ax = opt.state_logical_axes(model.logical_axes())
        st_sh = opt.TrainState(
            step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            params=tree_shardings(mesh, st_ax.params, state.params, rules),
            mu=tree_shardings(mesh, st_ax.mu, state.mu, rules),
            nu=tree_shardings(mesh, st_ax.nu, state.nu, rules))
        batch = model.input_specs(shape)
        b_sh = tree_shardings(mesh, model.input_axes(shape), batch, rules)

        def step(st, b):
            (l, m), g = jax.value_and_grad(lambda p: model.loss(p, b),
                                           has_aux=True)(st.params)
            return opt.adamw_update(st, g, ocfg), l

        fn = jax.jit(step, in_shardings=(st_sh, b_sh))
        with mesh:
            compiled = fn.lower(state, batch).compile()
            roof = rl.analyze(compiled, 16, model_flops=1e9)
        assert roof.flops_per_device > 0 and roof.bytes_per_device > 0
        assert roof.bottleneck in ("compute", "memory", "collective")
        print("DRYRUN_OK", roof.bottleneck)
        """, devices=16)
        assert "DRYRUN_OK" in out


class TestPipelineAndQuantizedCollectives:
    def test_pipeline_matches_sequential(self):
        out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.pipeline import pipeline_apply, bubble_fraction
        mesh = jax.make_mesh((4,), ("pp",))
        rng = np.random.default_rng(0)
        n_stages, d = 4, 16
        ws = jnp.asarray(rng.normal(size=(n_stages, d, d)).astype(np.float32) * 0.3)

        def stage(p, x):
            return jnp.tanh(x @ p["w"])

        x = jnp.asarray(rng.normal(size=(8, d)).astype(np.float32))
        want = x
        for s in range(n_stages):
            want = jnp.tanh(want @ ws[s])
        got = pipeline_apply(stage, {"w": ws}, x, mesh=mesh, axis="pp",
                             n_microbatches=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9
        print("PIPELINE_OK")
        """, devices=4)
        assert "PIPELINE_OK" in out

    def test_quantized_pmean_unbiased(self):
        out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.sharding.compression import quantized_pmean
        mesh = jax.make_mesh((8,), ("data",))

        def body(seed):
            g = jax.random.normal(jax.random.fold_in(
                jax.random.PRNGKey(0), jax.lax.axis_index("data")), (64,))
            ref = jax.lax.pmean(g, "data")
            got = quantized_pmean(g, jax.random.fold_in(seed, jax.lax.axis_index("data")), "data")
            return jnp.abs(got - ref).max() / jnp.abs(ref).max()

        errs = []
        for s in range(5):
            e = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          check_vma=False)(jax.random.PRNGKey(s))
            errs.append(float(e.max()))
        assert np.mean(errs) < 0.2, errs   # int8 noise, not bias
        print("QPMEAN_OK", [round(e, 3) for e in errs])
        """, devices=8)
        assert "QPMEAN_OK" in out

    def test_sharded_batcher(self):
        out = run_subprocess("""
        import jax, numpy as np
        from repro.data.synthetic import ShardedBatcher, TokenStream
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        b = ShardedBatcher(TokenStream(vocab=64, seed=0), 8, 16, mesh=mesh,
                           batch_axes=("data",))
        batch = b(step=3)
        tok = batch["tokens"]
        assert tok.shape == (8, 16)
        assert "data" in str(tok.sharding.spec)
        host = TokenStream(vocab=64, seed=0).batch(3, 8, 16)["tokens"]
        np.testing.assert_array_equal(np.asarray(tok), host)
        print("BATCHER_OK")
        """, devices=8)
        assert "BATCHER_OK" in out
