"""Device-sharded batched inference: bit-exactness over the batch mesh.

``snn_apply_sharded`` shard_maps the planned batched pipeline over the
batch axis — queues are per-sample-independent and the shared early exit
only ever skips invalid (zero-contribution) slots, so sharding must not
change a single bit.  The CI multi-device job runs this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the 8-way
acceptance check; on a single-device host the 1-way mesh still exercises
the full shard_map path and the 8-way cases skip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSNNConfig, ConvSpec, FCSpec, encode_input,
                        init_params, plan_network, snn_apply_batched,
                        snn_apply_sharded)
from repro.sharding.specs import batch_mesh

jax.config.update("jax_platform_name", "cpu")

# the 8-way forced-CPU-mesh tests are the heaviest in the suite; the CI
# multi-device job opts back in with `-m ""`
pytestmark = pytest.mark.slow

SMOKE = CSNNConfig(input_hw=(10, 10),
                   layers=(ConvSpec(4), ConvSpec(4, pool=3), FCSpec(3)),
                   t_steps=4)

N_DEV = len(jax.devices())
needs_8 = pytest.mark.skipif(
    N_DEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _case(cfg, seed=0, b=8):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    imgs = jnp.asarray(np.random.default_rng(seed)
                       .random((b,) + tuple(cfg.input_hw) + (1,))
                       .astype(np.float32))
    return params, encode_input(imgs, cfg)


class TestSnnApplySharded:
    def test_bit_exact_vs_batched_available_mesh(self):
        """Runs on any device count that divides B=8 (1-way locally)."""
        n = max(d for d in (1, 2, 4, 8) if d <= N_DEV and 8 % d == 0)
        params, sp = _case(SMOKE)
        plan = plan_network(SMOKE, capacity=100, channel_block=2)
        got = snn_apply_sharded(params, sp, SMOKE, plan,
                                mesh=batch_mesh(n, axis=plan.batch_axis))
        want = snn_apply_batched(params, sp, SMOKE, plan, collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @needs_8
    def test_bit_exact_vs_batched_8way(self):
        """ISSUE 3 acceptance: logits bit-exact vs ``snn_apply_batched`` on
        an 8-way host-device mesh."""
        params, sp = _case(SMOKE, seed=1, b=16)
        plan = plan_network(SMOKE, capacity=100, channel_block=2)
        got = snn_apply_sharded(params, sp, SMOKE, plan,
                                mesh=batch_mesh(8, axis=plan.batch_axis))
        want = snn_apply_batched(params, sp, SMOKE, plan, collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @needs_8
    def test_paper_network_8way(self):
        cfg = CSNNConfig()  # paper defaults, T=5
        params, sp = _case(cfg, seed=2, b=8)
        plan = plan_network(cfg, capacity=256, channel_block=8)
        got = snn_apply_sharded(params, sp, cfg, plan,
                                mesh=batch_mesh(8, axis=plan.batch_axis))
        want = snn_apply_batched(params, sp, cfg, plan, collect_stats=False)
        assert got.shape == (8, 10)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_stats_shard_over_batch(self):
        n = max(d for d in (1, 2, 4, 8) if d <= N_DEV and 8 % d == 0)
        params, sp = _case(SMOKE, seed=3)
        plan = plan_network(SMOKE, capacity=100)
        got_l, got_s = snn_apply_sharded(
            params, sp, SMOKE, plan, collect_stats=True,
            mesh=batch_mesh(n, axis=plan.batch_axis))
        want_l, want_s = snn_apply_batched(params, sp, SMOKE, plan)
        np.testing.assert_array_equal(np.asarray(got_l), np.asarray(want_l))
        for g, w in zip(got_s, want_s):
            np.testing.assert_array_equal(np.asarray(g.in_spike_counts),
                                          np.asarray(w.in_spike_counts))
            np.testing.assert_allclose(np.asarray(g.in_sparsity),
                                       np.asarray(w.in_sparsity), rtol=1e-6)

    def test_default_mesh_all_devices(self):
        params, sp = _case(SMOKE, seed=4, b=N_DEV * 2)
        got = snn_apply_sharded(params, sp, SMOKE, capacity=100)
        want = snn_apply_batched(params, sp, SMOKE, capacity=100,
                                 collect_stats=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_indivisible_batch_raises(self):
        params, sp = _case(SMOKE, seed=5, b=3)
        if N_DEV == 1:
            with pytest.raises(ValueError, match="lacks the plan's batch axis"):
                snn_apply_sharded(params, sp, SMOKE,
                                  mesh=batch_mesh(1, axis="wrong"))
        else:
            n = max(d for d in range(2, N_DEV + 1) if 3 % d)
            with pytest.raises(ValueError, match="does not divide"):
                snn_apply_sharded(params, sp, SMOKE,
                                  mesh=batch_mesh(n, axis="batch"))
