"""Substrate tests: optimizer, checkpoint, FT runtime, gradient
compression, data pipeline, train loop, serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.synthetic import TokenStream, synth_digits
from repro.models.registry import build_model
from repro.runtime.health import (ElasticPlanner, FaultPolicy, HeartbeatTracker,
                                  StragglerDetector)
from repro.sharding.compression import (EFState, compress_topk,
                                        compress_with_error_feedback, decompress)
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, make_train_step, run

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------------------ optimizer
class TestOptimizer:
    def _toy(self):
        params = {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.zeros((2, 2))}
        cfg = opt.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                              weight_decay=0.0, clip_norm=None)
        return params, cfg

    def test_adamw_descends_quadratic(self):
        params, cfg = self._toy()
        state = opt.init_state(params, cfg)
        loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)
        l0 = float(loss(params))
        for _ in range(50):
            grads = jax.grad(loss)(state.params)
            state = opt.adamw_update(state, grads, cfg)
        assert float(loss(state.params)) < 0.05 * l0

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = opt.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_lr_schedule(self):
        cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        assert float(opt.lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(opt.lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(opt.lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)

    def test_bf16_moments(self):
        params, _ = self._toy()
        cfg = opt.AdamWConfig(moment_dtype=jnp.bfloat16)
        state = opt.init_state(params, cfg)
        assert state.mu["w"].dtype == jnp.bfloat16
        grads = jax.tree.map(jnp.ones_like, params)
        state = opt.adamw_update(state, grads, cfg)
        assert state.mu["w"].dtype == jnp.bfloat16


# ------------------------------------------------------------------ checkpoint
class TestCheckpoint:
    def _tree(self):
        return {"layer": {"w": jnp.arange(12.0).reshape(3, 4),
                          "b": jnp.ones((5,), jnp.bfloat16)},
                "step_arr": jnp.asarray(7, jnp.int32)}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save(tree, tmp_path, step=3)
        restored, step = ckpt.restore(tree, tmp_path)
        assert step == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_atomic_and_gc(self, tmp_path):
        tree = self._tree()
        for s in [1, 2, 3, 4, 5]:
            ckpt.save(tree, tmp_path, step=s, keep=2)
        assert ckpt.latest_step(tmp_path) == 5
        steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
        assert steps == [4, 5]  # GC kept last 2

    def test_restore_into_different_dtype(self, tmp_path):
        tree = {"w": jnp.ones((4,), jnp.float32)}
        ckpt.save(tree, tmp_path, step=1)
        template = {"w": jnp.zeros((4,), jnp.bfloat16)}
        restored, _ = ckpt.restore(template, tmp_path)
        assert restored["w"].dtype == jnp.bfloat16

    def test_trainstate_roundtrip(self, tmp_path):
        params = {"w": jnp.arange(6.0).reshape(2, 3)}
        cfg = opt.AdamWConfig()
        state = opt.init_state(params, cfg)
        ckpt.save(state, tmp_path, step=11)
        restored, step = ckpt.restore(state, tmp_path)
        assert step == 11
        np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                      np.asarray(params["w"]))


# ------------------------------------------------------------------ runtime FT
class TestRuntime:
    def test_heartbeat_death(self):
        clock = [0.0]
        hb = HeartbeatTracker(["h0", "h1"], timeout=10.0, clock=lambda: clock[0])
        clock[0] = 5.0
        hb.beat("h0")
        clock[0] = 12.0
        assert hb.dead_hosts() == ["h1"]
        assert hb.alive_hosts() == ["h0"]

    def test_straggler_detection(self):
        det = StragglerDetector(factor=1.5, patience=2)
        for _step in range(4):
            for h in ["h0", "h1", "h2", "h3"]:
                det.record(h, 1.0 if h != "h3" else 3.0)
            slow = det.stragglers()
        assert slow == ["h3"]

    def test_elastic_planner_shrinks(self):
        pl = ElasticPlanner(model_parallel=16, pod_size=256)
        plan = pl.plan(512)
        assert plan.shape == (2, 16, 16) and plan.dropped == 0
        plan = pl.plan(500)  # lost 12 devices -> drop to largest multiple
        assert plan.devices_used == 496
        assert plan.shape[-1] == 16
        with pytest.raises(RuntimeError):
            pl.plan(8)

    def test_fault_policy_remesh_on_death(self):
        clock = [0.0]
        hb = HeartbeatTracker(["h0", "h1"], timeout=1.0, clock=lambda: clock[0])
        pol = FaultPolicy(hb, StragglerDetector(), ElasticPlanner(model_parallel=2),
                          devices_per_host=4)
        assert pol.decide(0) == "continue"
        clock[0] = 5.0
        hb.beat("h0")
        clock[0] = 5.5
        assert pol.decide(1) == "remesh"
        plan = pol.replan()
        assert plan.devices_used == 4  # one 4-device host left

    def test_preemption_checkpoints(self):
        hb = HeartbeatTracker(["h0"], timeout=1e9)
        pol = FaultPolicy(hb, StragglerDetector(), ElasticPlanner(model_parallel=1))
        assert pol.decide(3, preempted=True) == "checkpoint_now"


# ------------------------------------------------------------------ compression
class TestGradCompression:
    def test_topk_roundtrip(self):
        flat = jnp.asarray([0.0, 5.0, -3.0, 0.1, 0.0, -7.0])
        c = compress_topk(flat, k=2)
        dense = decompress(c)
        np.testing.assert_array_equal(np.asarray(dense),
                                      [0, 0, 0, 0, 0, -7.0] if False else np.asarray(dense))
        assert float(dense[5]) == -7.0 and float(dense[1]) == 5.0
        assert float(jnp.count_nonzero(dense)) == 2

    def test_error_feedback_conserves_mass(self):
        """transmitted + residual == grad + old residual (nothing lost)."""
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)).astype(np.float32))}
        ef = EFState.init(g)
        comp, ef2 = compress_with_error_feedback(g, ef, density=0.1)
        sent = decompress(comp["w"])
        np.testing.assert_allclose(np.asarray(sent + ef2.residual["w"]),
                                   np.asarray(g["w"]), rtol=1e-6)

    def test_error_feedback_converges(self):
        """top-1% compression with EF still minimizes a quadratic."""
        rng = np.random.default_rng(1)
        target = jnp.asarray(rng.normal(size=(200,)).astype(np.float32))
        x = jnp.zeros((200,))
        ef = EFState.init({"x": x})
        # stability needs lr * (1/density) < 1: compression delays updates by
        # ~1/density steps, and EF applies the accumulated residual at once
        lr = 0.05
        for _ in range(600):
            g = {"x": x - target}
            comp, ef = compress_with_error_feedback(g, ef, density=0.1)
            x = x - lr * decompress(comp["x"])
        assert float(jnp.linalg.norm(x - target)) < 0.1 * float(jnp.linalg.norm(target))

    @given(st.integers(1, 60), st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_topk_keeps_largest(self, k, seed):
        rng = np.random.default_rng(seed)
        flat = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        c = compress_topk(flat, k=k)
        dense = np.asarray(decompress(c))
        kept = np.nonzero(dense)[0]
        thresh = np.sort(np.abs(np.asarray(flat)))[-k]
        assert (np.abs(np.asarray(flat))[kept] >= thresh - 1e-6).all()


# ------------------------------------------------------------------ data
class TestData:
    def test_token_stream_deterministic(self):
        ts = TokenStream(vocab=100, seed=1)
        a = ts.batch(step=5, batch_size=2, seq_len=32)
        b = ts.batch(step=5, batch_size=2, seq_len=32)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ts.batch(step=6, batch_size=2, seq_len=32)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_token_stream_motifs(self):
        ts = TokenStream(vocab=1000, seed=0, motif_len=8, motif_every=32)
        b = ts.batch(0, 1, 128)["tokens"][0]
        np.testing.assert_array_equal(b[32:40], b[0:8])  # planted copy

    def test_synth_digits_stats(self):
        imgs, labels = synth_digits(64, seed=0)
        assert imgs.shape == (64, 28, 28, 1) and labels.shape == (64,)
        assert 0 <= imgs.min() and imgs.max() <= 1.0
        assert set(np.unique(labels)) <= set(range(10))
        active = (imgs > 0.5).mean()
        assert 0.03 < active < 0.4  # sparse strokes, like MNIST


# ------------------------------------------------------------------ loop + serve
class TestLoopAndServe:
    def _tiny_model(self):
        cfg = dataclasses.replace(ARCHS["stablelm-3b"].SMOKE, n_layers=1,
                                  d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                                  vocab=128)
        return build_model(cfg)

    def test_loss_decreases_and_resumes(self, tmp_path):
        model = self._tiny_model()
        ts = TokenStream(vocab=128, seed=0)
        data = lambda step: {k: jnp.asarray(v) for k, v in
                             ts.batch(step, 4, 32).items()}
        ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                               weight_decay=0.0)
        lcfg = LoopConfig(total_steps=30, ckpt_every=10, log_every=5,
                          ckpt_dir=str(tmp_path))
        state, hist = run(model, data, lcfg, ocfg, jax.random.PRNGKey(0))
        assert hist[-1]["loss"] < hist[0]["loss"]
        assert int(state.step) == 30
        # resume continues from the checkpoint, not from scratch
        lcfg2 = LoopConfig(total_steps=35, ckpt_every=10, log_every=5,
                           ckpt_dir=str(tmp_path))
        state2, _ = run(model, data, lcfg2, ocfg, jax.random.PRNGKey(0))
        assert int(state2.step) == 35

    def test_serve_engine_generates(self):
        from repro.serve.engine import Engine, ServeConfig
        model = self._tiny_model()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_seq=64, cfg=ServeConfig(max_new_tokens=8))
        prompts = jnp.asarray(np.random.default_rng(0).integers(0, 128, (3, 10)),
                              jnp.int32)
        out = eng.generate(prompts, jax.random.PRNGKey(1))
        assert out.shape == (3, 18)
        np.testing.assert_array_equal(np.asarray(out[:, :10]), np.asarray(prompts))

    def test_serve_greedy_matches_decode_consistency(self):
        """Greedy continuation of a prompt equals argmax of teacher-forced
        prefill logits for the first generated token."""
        from repro.serve.engine import Engine, ServeConfig
        model = self._tiny_model()
        params = model.init_params(jax.random.PRNGKey(0))
        prompts = jnp.asarray(np.random.default_rng(1).integers(0, 128, (2, 12)),
                              jnp.int32)
        logits, _ = model.prefill(params, {"tokens": prompts}, max_seq=32)
        want_first = np.asarray(jnp.argmax(logits, -1))
        eng = Engine(model, params, max_seq=32, cfg=ServeConfig(max_new_tokens=2))
        out = eng.generate(prompts, jax.random.PRNGKey(2))
        np.testing.assert_array_equal(np.asarray(out[:, 12]), want_first)
