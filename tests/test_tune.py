"""Measured plan autotuner + persistent plan cache (ISSUE 8).

The tuner is a pure scheduling choice — every candidate (block_e,
event_par, kernel variant, capacity sharing, t_chunk, stream finalize)
is bit-exact — so these tests pin the *machinery*: a measured run times
candidates and persists winners; a warm-cache ``tune="cached"`` load
rebuilds the identical plan with ZERO measurement runs (the
``measurement_runs()`` counter is the proof); geometry changes miss the
cache; corrupt files and stale/tampered entries are rejected and fall
back to measuring; and tuned plans stay bit-exact vs analytic plans
across dtypes, batching, and chunking.
"""
import json
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.csnn import (ConvSpec, CSNNConfig, FCSpec, init_params,
                             snn_apply_batched)
from repro.core.plan import plan_network
from repro.tune import (CACHE_VERSION, PlanCache, TuneConfig, cache_key,
                        default_cache_path, env_descriptor,
                        geometry_descriptor, measurement_runs)

jax.config.update("jax_platform_name", "cpu")

CFG = CSNNConfig(input_hw=(10, 10),
                 layers=(ConvSpec(4), ConvSpec(4, pool=3), FCSpec(3)),
                 t_steps=2)
KW = dict(capacity=32, channel_block=4, batch_tile=2)
# smallest honest tuning run: one timed invocation per candidate
TC = TuneConfig(batch=2, warmup=0, iters=1, max_block_candidates=2)


def _spikes(seed=3, batch=2, density=0.3):
    rng = np.random.default_rng(seed)
    h, w = CFG.input_hw
    return jnp.asarray(
        (rng.random((batch, CFG.t_steps, h, w, CFG.input_channels))
         < density).astype(np.float32))


@pytest.fixture(scope="module")
def warm(tmp_path_factory):
    """One measured tuning run, shared by every test that needs a warm
    cache (tuning compiles ~a dozen candidates; do it once)."""
    path = tmp_path_factory.mktemp("cache") / "plan_cache.json"
    n0 = measurement_runs()
    plan = plan_network(CFG, **KW, tune="measured", tune_config=TC,
                        cache_path=path)
    return SimpleNamespace(path=path, plan=plan,
                           measured=measurement_runs() - n0)


# ------------------------------------------------------ measured + cached
class TestMeasuredAndCached:
    def test_measured_run_times_candidates_and_persists(self, warm):
        assert warm.measured > 0
        data = json.loads(warm.path.read_text())
        assert data["version"] == CACHE_VERSION
        (entry,) = data["entries"].values()
        assert set(entry) >= {"geometry", "env", "winners",
                              "occupancy_capacities", "measured_us"}
        # per-layer winners recorded for every conv layer
        assert len(entry["winners"]["layers"]) == len(warm.plan.layers)

    def test_cache_hit_performs_zero_measurement_runs(self, warm):
        """ISSUE 8 acceptance: the second ``plan_network(tune="cached")``
        with a warm cache must never touch the timing path."""
        n0 = measurement_runs()
        plan2 = plan_network(CFG, **KW, tune="cached", tune_config=TC,
                             cache_path=warm.path)
        assert measurement_runs() == n0
        assert plan2 == warm.plan

    def test_geometry_change_invalidates_the_entry(self, warm):
        """Same cache file, different capacity request -> different key
        -> a miss that re-measures (never a silent wrong-plan hit)."""
        n0 = measurement_runs()
        plan = plan_network(CFG, capacity=64, channel_block=4, batch_tile=2,
                            tune="cached", tune_config=TC,
                            cache_path=warm.path)
        assert measurement_runs() > n0
        assert all(lp.capacity <= 64 for lp in plan.layers)
        assert len(json.loads(warm.path.read_text())["entries"]) == 2

    def test_tuned_plan_is_bit_exact_vs_analytic(self, warm):
        params = init_params(jax.random.PRNGKey(0), CFG)
        sp = _spikes()
        analytic = plan_network(CFG, **KW)
        out_a = snn_apply_batched(params, sp, CFG, analytic,
                                  collect_stats=False)
        out_t = snn_apply_batched(params, sp, CFG, warm.plan,
                                  collect_stats=False)
        assert np.array_equal(np.asarray(out_a), np.asarray(out_t))


# --------------------------------------------------- rejection + fallback
class TestRejection:
    def test_corrupt_cache_file_reads_as_empty(self, tmp_path):
        path = tmp_path / "cache.json"
        path.write_text("{ not json !!")
        assert PlanCache(path).get("anything") is None

    def test_wrong_version_reads_as_empty(self, tmp_path):
        path = tmp_path / "cache.json"
        path.write_text(json.dumps(
            {"version": CACHE_VERSION + 1, "entries": {"k": {}}}))
        assert PlanCache(path).get("k") is None

    def test_truncated_entry_is_a_miss_not_a_crash(self, tmp_path):
        path = tmp_path / "cache.json"
        path.write_text(json.dumps(
            {"version": CACHE_VERSION,
             "entries": {"k": {"geometry": {}}}}))  # no env/winners
        assert PlanCache(path).get("k") is None

    def test_tampered_resolved_values_reject_and_remeasure(self, warm,
                                                           tmp_path):
        """A stale entry (resolved values that no longer reproduce under
        the current snapping rules) must fail the fixed-point check and
        fall back to measuring — and the re-measure heals the entry."""
        path = tmp_path / "tampered.json"
        data = json.loads(warm.path.read_text())
        key = min(data["entries"])  # deterministic pick
        data["entries"] = {key: data["entries"][key]}
        data["entries"][key]["winners"]["resolved"][0]["queue_depth"] += 1
        path.write_text(json.dumps(data))
        n0 = measurement_runs()
        plan = plan_network(CFG, **KW, tune="cached", tune_config=TC,
                            cache_path=path)
        assert measurement_runs() > n0  # rejected -> re-measured
        # the healed entry now loads with zero measurement runs and
        # reproduces the re-measured plan exactly (winners may differ
        # from warm.plan — timings this small are noise — but the
        # cached rebuild must be a fixed point of whatever was written)
        n1 = measurement_runs()
        plan2 = plan_network(CFG, **KW, tune="cached", tune_config=TC,
                             cache_path=path)
        assert measurement_runs() == n1
        assert plan2 == plan


# --------------------------------------------------------- cache key + env
class TestCacheKey:
    BASE = dict(capacity=32, channel_block=4, batch_tile=2)

    def test_key_is_deterministic_and_geometry_sensitive(self):
        env = env_descriptor("jax", None)
        geom = geometry_descriptor(CFG, self.BASE)
        assert cache_key(geom, env) == cache_key(
            geometry_descriptor(CFG, dict(self.BASE)), env)
        other = geometry_descriptor(CFG, dict(self.BASE, capacity=64))
        assert cache_key(other, env) != cache_key(geom, env)

    def test_dtype_is_part_of_the_environment(self):
        assert (env_descriptor("jax", None)["dtype"]
                != env_descriptor("jax", 8)["dtype"])

    def test_unresolved_stats_refuse_to_fingerprint(self):
        with pytest.raises(ValueError, match="stats"):
            geometry_descriptor(CFG, dict(self.BASE,
                                          stats=[np.ones((2, 2))]))

    def test_env_var_overrides_default_path(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "pc.json"))
        assert default_cache_path() == tmp_path / "pc.json"


# ------------------------------------------- plan-level variant validation
class TestVariantValidation:
    def test_unknown_variant_rejected(self):
        with pytest.raises(ValueError, match="variant"):
            plan_network(CFG, **KW, variant="fused-marvel")

    def test_interlaced_requires_parallel_width(self):
        with pytest.raises(ValueError, match="event_par"):
            plan_network(CFG, **KW, variant="interlaced-pallas",
                         event_par=1)

    def test_unknown_stream_finalize_rejected(self):
        with pytest.raises(ValueError, match="stream_finalize"):
            plan_network(CFG, **KW, ingest=True, stream_finalize="bogus")

    def test_unknown_tune_mode_rejected(self):
        with pytest.raises(ValueError, match="psychic"):
            plan_network(CFG, **KW, tune="psychic")


# ----------------------- bit-exactness across the whole tunable plan space
class TestPlanSpaceBitExact:
    """Every knob the tuner can turn is a pure scheduling choice: the
    pinned-variant / chunked / dtype plans below span the search space
    and must all produce the analytic plan's exact outputs."""

    @pytest.mark.parametrize("sat_bits", [None, 8])
    def test_pinned_variants_chunked_and_dtypes(self, sat_bits):
        params = init_params(jax.random.PRNGKey(1), CFG)
        sp = _spikes(seed=5)
        ref = plan_network(CFG, **KW, sat_bits=sat_bits)
        out_ref = np.asarray(snn_apply_batched(
            params, sp, CFG, ref, collect_stats=False))
        tuned_like = [
            plan_network(CFG, **KW, sat_bits=sat_bits,
                         variant="banked-jax", event_par=4),
            plan_network(CFG, **KW, sat_bits=sat_bits, per_layer=False,
                         t_chunk=1),
            plan_network(CFG, **KW, sat_bits=sat_bits, block_e=8,
                         t_chunk=2),
        ]
        for plan in tuned_like:
            out = np.asarray(snn_apply_batched(
                params, sp, CFG, plan, collect_stats=False))
            assert np.array_equal(out, out_ref), plan


# --------------------------------------------------- streamed finalization
class TestIngestTuning:
    def test_ingest_tune_picks_a_stream_finalize(self, tmp_path):
        """Stage 3 ranks rank-compaction vs sort-rebuild head to head on
        ingest plans and pins the winner on layer 0 (satellite 2)."""
        path = tmp_path / "cache.json"
        plan = plan_network(CFG, **KW, ingest=True, tune="measured",
                            tune_config=TC, cache_path=path)
        assert plan.layers[0].stream_finalize in ("ranks", "sort")
        (entry,) = json.loads(path.read_text())["entries"].values()
        assert entry["winners"]["stream_finalize"] in ("ranks", "sort")
        assert any(k.startswith("stream_finalize/")
                   for k in entry["measured_us"])
